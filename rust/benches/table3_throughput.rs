//! Regenerates paper Table 3 (average throughput per family). Shares the
//! sweep with table2 (both tables come from the same grid).
use specdelay::benchkit::{experiments, Scale};
fn main() {
    experiments::tables_2_3(Scale::from_env()).expect("table 2/3");
}
