//! Regenerates paper Figure 1: depth-wise L1(p,q) divergence and OTLP
//! acceptance rates over offline trees along target trajectories.
use specdelay::benchkit::{experiments, Scale};
fn main() {
    experiments::figure_1(Scale::from_env(), "llama-sim").expect("fig1");
}
