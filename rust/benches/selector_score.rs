//! Shared-branching Eq. 3 scorer microbenchmark (pure rust, no PJRT).
//!
//! Per trace root, two full-action-space scorers are timed at equal output
//! (asserted before timing):
//!
//! * **legacy** — the frozen per-action scorer
//!   (`selector::score_superset_per_action`): every one of the 324 actions
//!   rebuilds its tree and recomputes every node's branching probabilities,
//!   the O(|A|·nodes·vocab) pre-shared-branching cost model.
//! * **shared** — `selector::score_superset_into` with a warm
//!   `ScoreScratch` arena: one merged structure per trunk depth, one
//!   branching computation per distinct (node, child-prefix), reach DP for
//!   all actions.
//!
//! A threads-vs-throughput curve then drives the parallel scoring path
//! (`par_map_init` with one arena per worker) that `collect_traces` uses.
//! Emits a table plus machine-readable `BENCH_selector_score.json` at the
//! repo root for the perf trajectory.
//!
//! Run: `cargo bench --bench selector_score`. Env overrides:
//! `SELECTOR_SCORE_ROOTS` (default 4 timed roots),
//! `SELECTOR_SCORE_VOCAB` (default 259, the byte-level testbed vocab).

use std::time::Instant;

use specdelay::selector::{
    score_superset_into, score_superset_per_action, ScoreScratch, Superset,
};
use specdelay::util::json::{arr, num, obj, s, Json};
use specdelay::util::threadpool::{default_workers, par_map_init};
use specdelay::util::Pcg64;
use specdelay::verify::OtlpSolver;

#[path = "../tests/common/mod.rs"]
mod common;

use common::superset::{make_superset, ot_solvers};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(default)
}

fn seeded_supersets(n: usize, vocab: usize) -> Vec<Superset> {
    let mut rng = Pcg64::seeded(0x5e1);
    (0..n).map(|_| make_superset(&mut rng, vocab)).collect()
}

/// (legacy µs/root, shared µs/root) for one solver roster over `supersets`.
fn time_pair(
    supersets: &[Superset],
    solvers: &[(&str, Box<dyn OtlpSolver>)],
    shared_reps: usize,
) -> (f64, f64) {
    let n = supersets.len();
    let t0 = Instant::now();
    for ss in supersets {
        let _ = score_superset_per_action(ss, solvers);
    }
    let legacy_us = t0.elapsed().as_secs_f64() / n as f64 * 1e6;

    let mut scratch = ScoreScratch::default();
    let mut table = Vec::new();
    for ss in supersets {
        score_superset_into(ss, solvers, &mut scratch, &mut table); // warm-up
    }
    let t0 = Instant::now();
    for _ in 0..shared_reps {
        for ss in supersets {
            score_superset_into(ss, solvers, &mut scratch, &mut table);
        }
    }
    let shared_us = t0.elapsed().as_secs_f64() / (n * shared_reps) as f64 * 1e6;
    (legacy_us, shared_us)
}

fn main() {
    let roots = env_usize("SELECTOR_SCORE_ROOTS", 4);
    let vocab = env_usize("SELECTOR_SCORE_VOCAB", 259);
    let shared_reps = 5usize;
    let solvers = ot_solvers();
    let supersets = seeded_supersets(roots, vocab);

    // Equal output first: the speedup below is only meaningful if the two
    // scorers agree on every (solver, action) entry.
    let mut max_diff = 0.0f64;
    {
        let mut scratch = ScoreScratch::default();
        let mut table = Vec::new();
        for ss in &supersets {
            let legacy = score_superset_per_action(ss, &solvers);
            score_superset_into(ss, &solvers, &mut scratch, &mut table);
            for (l_row, s_row) in legacy.iter().zip(&table) {
                for (&l, &sv) in l_row.iter().zip(s_row) {
                    max_diff = max_diff.max((l - sv).abs());
                }
            }
        }
    }
    assert!(max_diff < 1e-9, "scorers disagree: max |Δ| = {max_diff}");

    println!(
        "{:<12} {:>16} {:>16} {:>10}",
        "solver", "us/root legacy", "us/root shared", "speedup"
    );
    let mut per_solver: Vec<(&str, Json)> = Vec::new();
    for one in solvers.chunks(1) {
        let name = one[0].0;
        let (l_us, s_us) = time_pair(&supersets, one, shared_reps);
        println!("{name:<12} {l_us:>16.1} {s_us:>16.1} {:>9.2}x", l_us / s_us);
        per_solver.push((
            name,
            obj(vec![
                ("legacy_us_per_root", num(l_us)),
                ("shared_us_per_root", num(s_us)),
                ("speedup", num(l_us / s_us)),
            ]),
        ));
    }
    let (legacy_us, shared_us) = time_pair(&supersets, &solvers, shared_reps);
    let speedup = legacy_us / shared_us;
    println!(
        "{:<12} {legacy_us:>16.1} {shared_us:>16.1} {speedup:>9.2}x",
        "all-5"
    );

    // Threads-vs-throughput curve for the parallel scoring path. Each
    // worker owns one ScoreScratch arena; results are discarded (the
    // determinism tests assert they are bit-identical across counts).
    let par_roots = (roots * 8).max(16);
    let mut curve: Vec<Json> = Vec::new();
    let mut base_rps = 0.0f64;
    println!("\n{:<10} {:>14} {:>12}", "threads", "roots/sec", "scaling");
    for threads in [1usize, 2, 4, 8] {
        let batch = seeded_supersets(par_roots, vocab);
        let t0 = Instant::now();
        let done = par_map_init(batch, threads, ScoreScratch::default, |scratch, _i, ss| {
            let mut table = Vec::new();
            score_superset_into(&ss, &solvers, scratch, &mut table);
            table.len()
        });
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(done.len(), par_roots);
        let rps = par_roots as f64 / dt;
        if threads == 1 {
            base_rps = rps;
        }
        println!("{threads:<10} {rps:>14.1} {:>11.2}x", rps / base_rps);
        curve.push(obj(vec![
            ("threads", num(threads as f64)),
            ("roots_per_sec", num(rps)),
            ("scaling_vs_1", num(rps / base_rps)),
        ]));
    }

    let report = obj(vec![
        ("schema", s("selector_score/v1")),
        (
            "config",
            obj(vec![
                ("vocab", num(vocab as f64)),
                ("roots", num(roots as f64)),
                ("shared_reps", num(shared_reps as f64)),
                ("par_roots", num(par_roots as f64)),
                ("solvers", num(solvers.len() as f64)),
                ("machine_workers", num(default_workers() as f64)),
            ]),
        ),
        ("max_abs_diff_vs_legacy", num(max_diff)),
        ("legacy_us_per_root", num(legacy_us)),
        ("shared_us_per_root", num(shared_us)),
        ("speedup_vs_legacy", num(speedup)),
        ("per_solver", obj(per_solver)),
        ("threads_curve", arr(curve.into_iter())),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_selector_score.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("\nfull-action-space speedup vs frozen legacy: {speedup:.2}x");
    println!("wrote {path}");
}
