//! Regenerates paper Tables 4+5: NDE improvement ratios over static
//! baselines (trains selectors on demand).
use specdelay::benchkit::{experiments, Scale};
fn main() {
    experiments::tables_4_7(Scale::from_env()).expect("tables 4-7");
}
