//! Regenerates paper Tables 8+9: per-dataset breakdown incl. delayed
//! expansion variants and Traversal K=2..4.
use specdelay::benchkit::{experiments, Scale};
fn main() {
    experiments::tables_8_9(Scale::from_env()).expect("tables 8/9");
}
