//! SIMD backend + quantized-KV benchmark (pure rust, no artifacts).
//!
//! Three measurements, each preceded by an equal-output (tolerance)
//! assertion so the numbers always describe the configuration the tests
//! validate:
//!
//! 1. **Per-op µs** — prefill / decode / tree-verify on the small preset,
//!    `cpu-ref` (scalar reductions) vs `cpu-simd` (f32x8 lane chunks),
//!    with `speedup_vs_ref` per op and the geometric mean.
//! 2. **tokens/s per (backend × kv-dtype)** — real `SpecEngine::step`
//!    decode loops over paged pools of every element precision, both
//!    backends.
//! 3. **Effective capacity** — under one fixed f32-equivalent block
//!    budget, the rows a lane can commit before pool exhaustion: f16
//!    must fit exactly 2× and int8 exactly 4× the f32 rows (asserted).
//!
//! Emits `BENCH_backend_simd.json` at the repo root (uploaded as a CI
//! artifact). Env knobs: `BACKEND_SIMD_ITERS` (default 300, per-op
//! timing loops), `BACKEND_SIMD_MAX_NEW` (default 32, tokens per e2e
//! run).
//!
//! Run: `cargo bench --bench backend_simd`.

use std::time::Instant;

use specdelay::coordinator::{KvPools, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::kvcache::{BlockPool, KvCache, KvDtype};
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, CpuSimdBackend, Role};
use specdelay::util::json::{arr, num, obj, s, Json};
use specdelay::util::Pcg64;
use specdelay::verify;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn rel_err(got: &[f32], want: &[f32]) -> f32 {
    assert_eq!(got.len(), want.len());
    got.iter()
        .zip(want)
        .map(|(&g, &w)| (g - w).abs() / w.abs().max(1e-6))
        .fold(0.0f32, f32::max)
}

/// Part 1: per-op scalar vs f32x8 timing on the small preset. Returns the
/// JSON row set and the per-op speedups for the geomean.
fn per_op_micro(iters: usize) -> (Vec<Json>, Vec<f64>) {
    let cfg = CpuModelConfig::small();
    let rb = CpuRefBackend::new(&cfg, 11);
    let sb = CpuSimdBackend::new(&cfg, 11);
    let toks: Vec<i32> = (0..cfg.s_pre as i32).map(|i| (i * 31 + 5) % cfg.vocab as i32).collect();
    let n = toks.len();

    // warm caches: each backend reads its own committed rows
    let pr = rb.prefill(Role::Target, &toks, n).unwrap();
    let ps = sb.prefill(Role::Target, &toks, n).unwrap();
    assert!(rel_err(&ps.logits, &pr.logits) <= 1e-5, "prefill logits out of tolerance");
    let mut cr = KvCache::new(rb.dims(Role::Target));
    let mut cs = KvCache::new(sb.dims(Role::Target));
    cr.commit_prefill(&pr.k_rows, &pr.v_rows, cfg.s_pre, n);
    cs.commit_prefill(&ps.k_rows, &ps.v_rows, cfg.s_pre, n);
    let dr = rb.decode(Role::Target, cr.view(), 7, n).unwrap();
    let ds = sb.decode(Role::Target, cs.view(), 7, n).unwrap();
    assert!(rel_err(&ds.logits, &dr.logits) <= 1e-5, "decode logits out of tolerance");

    // a 16-node chain tree for the tree-verify op
    use specdelay::tree::{DraftTree, Provenance};
    let mut tree = DraftTree::new(7);
    let mut node = 0usize;
    for step in 1..8usize {
        node = tree.add_child(node, ((step * 13) % cfg.vocab) as u32, Provenance::Trunk { step });
    }
    let nb = 16usize;
    let (tt, tp) = tree.tokens_positions(nb, n - 1, 63);
    let bias = tree.attention_bias(nb);

    let time = |f: &mut dyn FnMut()| -> f64 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        t0.elapsed().as_secs_f64() * 1e6 / iters as f64
    };

    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    println!("{:>12} {:>12} {:>12} {:>10}", "op", "ref µs", "simd µs", "speedup");
    let ops: Vec<(&str, f64, f64)> = vec![
        (
            "prefill",
            time(&mut || {
                let _ = rb.prefill(Role::Target, &toks, n).unwrap();
            }),
            time(&mut || {
                let _ = sb.prefill(Role::Target, &toks, n).unwrap();
            }),
        ),
        (
            "decode",
            time(&mut || {
                let _ = rb.decode(Role::Target, cr.view(), 7, n).unwrap();
            }),
            time(&mut || {
                let _ = sb.decode(Role::Target, cs.view(), 7, n).unwrap();
            }),
        ),
        (
            "tree_verify",
            time(&mut || {
                let _ = rb.tree_verify(nb, cr.view(), &tt, &tp, &bias, n - 1).unwrap();
            }),
            time(&mut || {
                let _ = sb.tree_verify(nb, cs.view(), &tt, &tp, &bias, n - 1).unwrap();
            }),
        ),
    ];
    for (name, ref_us, simd_us) in ops {
        let speedup = ref_us / simd_us;
        println!("{name:>12} {ref_us:>12.2} {simd_us:>12.2} {speedup:>9.2}x");
        speedups.push(speedup);
        rows.push(obj(vec![
            ("op", s(name)),
            ("ref_us", num(ref_us)),
            ("simd_us", num(simd_us)),
            ("speedup_vs_ref", num(speedup)),
        ]));
    }
    (rows, speedups)
}

/// Part 2: end-to-end tokens/s of real `SpecEngine::step` loops per
/// (backend × kv-dtype) cell over paged pools.
fn e2e_matrix(max_new: usize) -> Vec<Json> {
    let cfg = CpuModelConfig::small();
    let backends: Vec<Box<dyn Backend>> =
        vec![Box::new(CpuRefBackend::new(&cfg, 11)), Box::new(CpuSimdBackend::new(&cfg, 11))];
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let action = Action::new(2, 2, 3);
    let prompts = ["12*3= ", "9-4= ", "(5+5)/2= ", "0.5*8= "];

    let mut rows = Vec::new();
    println!("\n{:>10} {:>6} {:>10} {:>10}", "backend", "kv", "tokens", "tok/s");
    for backend in &backends {
        let backend = backend.as_ref();
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            let pools = KvPools {
                target: BlockPool::with_dtype(
                    backend.dims(Role::Target),
                    specdelay::kvcache::default_block_tokens(),
                    None,
                    dtype,
                ),
                draft: BlockPool::with_dtype(
                    backend.dims(Role::Draft),
                    specdelay::kvcache::default_block_tokens(),
                    None,
                    dtype,
                ),
            };
            let spec = SpecEngine::new(backend, sampling).with_kv_pools(pools);
            let mut tokens = 0usize;
            let t0 = Instant::now();
            for (id, p) in prompts.iter().enumerate() {
                let mut seq = spec.start(p).unwrap();
                let mut rng = Pcg64::new(7, id as u64);
                while !seq.finished && seq.tokens.len() - seq.prompt_len < max_new {
                    spec.step(&mut seq, verifier.as_ref(), action, &mut rng).unwrap();
                }
                tokens += seq.tokens.len() - seq.prompt_len;
            }
            let wall = t0.elapsed().as_secs_f64();
            let tps = tokens as f64 / wall.max(1e-9);
            println!("{:>10} {:>6} {tokens:>10} {tps:>10.1}", backend.name(), dtype.name());
            rows.push(obj(vec![
                ("backend", s(backend.name())),
                ("kv_dtype", s(dtype.name())),
                ("tokens", num(tokens as f64)),
                ("wall_s", num(wall)),
                ("tokens_per_s", num(tps)),
            ]));
        }
    }
    rows
}

/// Part 3: under one fixed f32-equivalent block budget, commit rows into
/// a fresh lane until the pool's effective capacity is reached; f16/int8
/// must fit exactly 2×/4× the f32 rows (asserted — the ISSUE's capacity
/// criterion).
fn capacity_demo() -> Vec<Json> {
    let dims = specdelay::runtime::ModelDims {
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_head: 8,
        vocab: 64,
        max_seq: 64,
    };
    let (bt, budget) = (4usize, 4usize);
    let row: Vec<f32> = (0..dims.n_layers * dims.n_heads * dims.d_head)
        .map(|x| (x as f32 * 0.37).sin())
        .collect();
    let mut rows_fit = Vec::new();
    let mut out = Vec::new();
    println!("\n{:>6} {:>12} {:>12} {:>10}", "kv", "eff_blocks", "rows_fit", "vs f32");
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let pool = BlockPool::with_dtype(dims, bt, Some(budget), dtype);
        let eff = pool.effective_max_blocks().expect("capped pool");
        let capacity_rows = eff * bt;
        let mut lane = KvCache::paged(&pool);
        for pos in 0..capacity_rows {
            lane.commit_row(&row, &row, pos);
        }
        assert_eq!(
            pool.live_blocks(),
            eff,
            "{}: committed rows did not land on the effective block capacity",
            dtype.name()
        );
        rows_fit.push(capacity_rows);
        out.push(obj(vec![
            ("kv_dtype", s(dtype.name())),
            ("budget_f32_blocks", num(budget as f64)),
            ("effective_blocks", num(eff as f64)),
            ("rows_fit", num(capacity_rows as f64)),
        ]));
        println!(
            "{:>6} {eff:>12} {capacity_rows:>12} {:>9.1}x",
            dtype.name(),
            capacity_rows as f64 / rows_fit[0] as f64
        );
    }
    assert_eq!(rows_fit[1], 2 * rows_fit[0], "f16 must fit 2x the f32 rows");
    assert_eq!(rows_fit[2], 4 * rows_fit[0], "int8 must fit 4x the f32 rows");
    out
}

fn main() {
    let iters = env_usize("BACKEND_SIMD_ITERS", 300);
    let max_new = env_usize("BACKEND_SIMD_MAX_NEW", 32);

    let (ops, speedups) = per_op_micro(iters);
    let geomean =
        (speedups.iter().map(|x| x.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!("{:>12} {:>37.2}x", "geomean", geomean);
    let e2e = e2e_matrix(max_new);
    let capacity = capacity_demo();

    let report = obj(vec![
        ("schema", s("backend_simd/v1")),
        (
            "config",
            obj(vec![
                ("preset", s("small")),
                ("iters", num(iters as f64)),
                ("max_new", num(max_new as f64)),
            ]),
        ),
        ("equal_output_assertion", s("enabled")),
        ("per_op", arr(ops)),
        ("speedup_vs_ref_geomean", num(geomean)),
        ("e2e", arr(e2e)),
        ("capacity", arr(capacity)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_backend_simd.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("wrote {path}");
}
