//! Paged vs contiguous KV cache benchmark (pure rust, no artifacts).
//!
//! Three measurements, all preceded by equal-output assertions so the
//! numbers always describe the bit-identical configuration the tests
//! validate:
//!
//! 1. **µs/commit** — steady-state rollout-span + tree-row commits into a
//!    warm cache, contiguous vs paged (the per-block coalescing cost).
//! 2. **µs/handoff refresh** — `copy_prefix_from` of a committed prefix,
//!    contiguous (physical span copy) vs paged (copy-on-write refcount
//!    bumps): the trunk→branch handoff cost `draft::draft_delayed` pays
//!    every block.
//! 3. **Peak resident blocks** — a batched shared-trunk serving workload
//!    (`SpecEngine::step` lanes on one pool) per batch size: paged
//!    high-water blocks vs the contiguous equivalent (lanes × full-lane
//!    blocks for target + draft + handoff), plus the average prefix-share
//!    ratio (fraction of table-referenced blocks that are copy-on-write
//!    shared). The paged peak must be strictly below the contiguous
//!    equivalent — asserted, per the acceptance criterion.
//!
//! Emits `BENCH_kvcache_paged.json` at the repo root (uploaded as a CI
//! artifact). Env knobs: `KVCACHE_PAGED_ITERS` (default 2000),
//! `KVCACHE_PAGED_MAX_NEW` (default 24).
//!
//! Run: `cargo bench --bench kvcache_paged`.

use std::time::Instant;

use specdelay::coordinator::{Sequence, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::kvcache::{BlockPool, ContiguousKv, KvStorage, PagedKvCache};
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, Role};
use specdelay::util::json::{arr, num, obj, s, Json};
use specdelay::util::Pcg64;
use specdelay::verify;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Assert paged and contiguous caches hold bitwise-identical rows.
fn assert_rows_equal(paged: &PagedKvCache, cont: &ContiguousKv, ctx: &str) {
    let d = cont.dims;
    assert_eq!(paged.len(), cont.len, "{ctx}: len");
    for l in 0..d.n_layers {
        for hh in 0..d.n_heads {
            for pos in 0..d.max_seq {
                let (pk, pv) = paged.row(l, hh, pos);
                let (ck, cv) = cont.row(l, hh, pos);
                assert_eq!(pk, ck, "{ctx}: K l={l} h={hh} pos={pos}");
                assert_eq!(pv, cv, "{ctx}: V l={l} h={hh} pos={pos}");
            }
        }
    }
}

/// Part 1+2: steady-state commit and handoff-refresh microbenchmarks.
fn commit_micro(iters: usize) -> Json {
    let d = specdelay::runtime::ModelDims {
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        d_head: 16,
        vocab: 64,
        max_seq: 256,
    };
    let bt = specdelay::kvcache::default_block_tokens();
    let (kp, ls) = (2usize, 4usize);
    let n = d.n_layers * kp * ls * d.n_heads * d.d_head;
    let rows: Vec<f32> = (0..n).map(|x| (x as f32).sin()).collect();
    let nb = 8usize;
    let trow: Vec<f32> = (0..d.n_layers * nb * d.n_heads * d.d_head)
        .map(|x| (x as f32).cos())
        .collect();

    // equal-output assertion before timing
    let pool = BlockPool::new(d, bt, None);
    let mut pg = PagedKvCache::new(&pool);
    let mut ct = ContiguousKv::new(d);
    for base in [0usize, 5, 40, 200] {
        pg.commit_rollout_rows(&rows, &rows, kp, ls, 1, ls - 1, base);
        ct.commit_rollout_rows(&rows, &rows, kp, ls, 1, ls - 1, base);
        pg.commit_tree_row(&trow, &trow, nb, 3, base + ls);
        ct.commit_tree_row(&trow, &trow, nb, 3, base + ls);
    }
    assert_rows_equal(&pg, &ct, "commit equality");

    let spots: Vec<usize> = (0..64).map(|i| (i * 37) % (d.max_seq - ls)).collect();
    let t0 = Instant::now();
    for i in 0..iters {
        let base = spots[i % spots.len()];
        ct.commit_rollout_rows(&rows, &rows, kp, ls, 1, ls - 1, base);
        ct.commit_tree_row(&trow, &trow, nb, 3, base + ls);
    }
    let cont_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    let t1 = Instant::now();
    for i in 0..iters {
        let base = spots[i % spots.len()];
        pg.commit_rollout_rows(&rows, &rows, kp, ls, 1, ls - 1, base);
        pg.commit_tree_row(&trow, &trow, nb, 3, base + ls);
    }
    let paged_us = t1.elapsed().as_secs_f64() * 1e6 / iters as f64;

    // handoff refresh: committed 192-row prefix, refreshed into a warm
    // scratch cache every iteration (contiguous copies rows, paged bumps
    // block refcounts)
    let prefix = 192usize;
    let mut src_c = ContiguousKv::new(d);
    let mut src_p = PagedKvCache::new(&pool);
    let row1: Vec<f32> = (0..d.n_layers * d.n_heads * d.d_head).map(|x| x as f32 * 0.1).collect();
    for pos in 0..prefix {
        src_c.commit_row(&row1, &row1, pos);
        src_p.commit_row(&row1, &row1, pos);
    }
    let mut dst_c = ContiguousKv::new(d);
    let mut dst_p = PagedKvCache::new(&pool);
    let t2 = Instant::now();
    for _ in 0..iters {
        dst_c.copy_prefix_from(&src_c, prefix);
    }
    let cont_refresh_us = t2.elapsed().as_secs_f64() * 1e6 / iters as f64;
    let t3 = Instant::now();
    for _ in 0..iters {
        dst_p.copy_prefix_from(&src_p, prefix);
    }
    let paged_refresh_us = t3.elapsed().as_secs_f64() * 1e6 / iters as f64;
    assert_rows_equal(&dst_p, &dst_c, "refresh equality");

    println!(
        "commit      µs/op: contiguous {cont_us:>8.3}  paged {paged_us:>8.3}  ratio {:.2}",
        paged_us / cont_us
    );
    println!(
        "handoff     µs/op: contiguous {cont_refresh_us:>8.3}  paged {paged_refresh_us:>8.3}  speedup {:.1}x",
        cont_refresh_us / paged_refresh_us
    );
    obj(vec![
        ("iters", num(iters as f64)),
        ("block_tokens", num(bt as f64)),
        ("contiguous_us_per_commit", num(cont_us)),
        ("paged_us_per_commit", num(paged_us)),
        ("paged_over_contiguous_commit", num(paged_us / cont_us)),
        ("prefix_rows", num(prefix as f64)),
        ("contiguous_us_per_refresh", num(cont_refresh_us)),
        ("paged_us_per_refresh", num(paged_refresh_us)),
        ("refresh_speedup_vs_contiguous", num(cont_refresh_us / paged_refresh_us)),
    ])
}

/// One lane of the serve workload.
struct BenchLane {
    seq: Sequence,
    rng: Pcg64,
    emitted: usize,
}

/// Part 3: batched shared-trunk serving workload on one pool per batch
/// size, with a contiguous serial reference asserted stream-equal first.
fn serve_workload(max_new: usize) -> (Vec<Json>, usize) {
    let cfg = CpuModelConfig::tiny();
    let backend = CpuRefBackend::new(&cfg, 11);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let action = Action::new(2, 2, 3); // shared trunk of 2
    let prompts = ["12*3= ", "9-4= ", "1,2,3,", "(5+5)/2= ", "0.5*8= ", "77+1= ", "6/2= ", "8+8= "];
    let seed = 7u64;
    let mut equal_checks = 0usize;

    // contiguous serial reference streams
    let spec_c = SpecEngine::new(&backend, sampling).with_kv_storage(KvStorage::Contiguous);
    let mut ref_streams: Vec<Vec<u32>> = Vec::new();
    for (id, p) in prompts.iter().enumerate() {
        let mut seq = spec_c.start(p).unwrap();
        let mut rng = Pcg64::new(seed, id as u64);
        while !seq.finished && seq.tokens.len() - seq.prompt_len < max_new {
            spec_c.step(&mut seq, verifier.as_ref(), action, &mut rng).unwrap();
        }
        ref_streams.push(seq.tokens[seq.prompt_len..].to_vec());
    }

    let bt = specdelay::kvcache::default_block_tokens();
    let d_t = backend.dims(Role::Target);
    let d_d = backend.dims(Role::Draft);
    let full_lane_blocks = d_t.max_seq.div_ceil(bt) + 2 * d_d.max_seq.div_ceil(bt);

    let mut rows = Vec::new();
    println!(
        "\n{:>6} {:>12} {:>16} {:>12} {:>14}",
        "batch", "peak_blocks", "contig_equiv", "ratio", "prefix_share"
    );
    for batch in [1usize, 2, 4, 8] {
        let spec = SpecEngine::new(&backend, sampling).with_kv_storage(KvStorage::Paged);
        let pools = spec.kv_pools().expect("paged pools");
        let (pool_t, pool_d) = (pools.target.clone(), pools.draft.clone());
        let mut lanes: Vec<BenchLane> = (0..batch)
            .map(|id| BenchLane {
                seq: spec.start(prompts[id % prompts.len()]).unwrap(),
                rng: Pcg64::new(seed, id as u64),
                emitted: 0,
            })
            .collect();
        let mut share_sum = 0.0f64;
        let mut share_ticks = 0usize;
        loop {
            let mut any = false;
            for lane in lanes.iter_mut() {
                if lane.seq.finished || lane.emitted >= max_new {
                    continue;
                }
                any = true;
                spec.step(&mut lane.seq, verifier.as_ref(), action, &mut lane.rng).unwrap();
                lane.emitted = lane.seq.tokens.len() - lane.seq.prompt_len;
            }
            if !any {
                break;
            }
            // prefix-share ratio: fraction of table-referenced blocks that
            // are copy-on-write shared (handoff caches riding their lane's
            // committed trunk for free)
            let mut resident = 0usize;
            let mut shared = 0usize;
            for lane in &lanes {
                for cache in [Some(&lane.seq.target_kv), Some(&lane.seq.draft_kv), lane.seq.draft_scratch.branch_cache()]
                    .into_iter()
                    .flatten()
                {
                    let p = cache.as_paged().expect("paged lane");
                    resident += p.resident_blocks();
                    shared += p.cow_shared_blocks();
                }
            }
            if resident > 0 {
                share_sum += shared as f64 / resident as f64;
                share_ticks += 1;
            }
        }
        // streams must match the contiguous serial reference bitwise —
        // full equality, lengths included (identical seeds and stop
        // conditions guarantee equal lengths when the storages agree)
        for (id, lane) in lanes.iter().enumerate() {
            let got = &lane.seq.tokens[lane.seq.prompt_len..];
            let want = &ref_streams[id % prompts.len()];
            assert_eq!(
                got,
                want.as_slice(),
                "batch {batch} lane {id}: paged stream diverged from contiguous serial"
            );
            equal_checks += 1;
        }
        let peak = pool_t.peak_live_blocks() + pool_d.peak_live_blocks();
        let contig_equiv = batch * full_lane_blocks;
        let share = if share_ticks > 0 { share_sum / share_ticks as f64 } else { 0.0 };
        assert!(
            peak < contig_equiv,
            "batch {batch}: paged peak {peak} blocks not below contiguous equivalent {contig_equiv}"
        );
        println!(
            "{batch:>6} {peak:>12} {contig_equiv:>16} {:>12.3} {share:>14.3}",
            peak as f64 / contig_equiv as f64
        );
        rows.push(obj(vec![
            ("batch", num(batch as f64)),
            ("max_new", num(max_new as f64)),
            ("peak_resident_blocks", num(peak as f64)),
            ("contiguous_equiv_blocks", num(contig_equiv as f64)),
            ("peak_over_contiguous", num(peak as f64 / contig_equiv as f64)),
            ("prefix_share_ratio_avg", num(share)),
        ]));
        drop(lanes);
        pool_t.validate().unwrap();
        pool_d.validate().unwrap();
        assert_eq!(pool_t.live_blocks() + pool_d.live_blocks(), 0, "blocks leaked");
    }
    (rows, equal_checks)
}

fn main() {
    let iters = env_usize("KVCACHE_PAGED_ITERS", 2000);
    let max_new = env_usize("KVCACHE_PAGED_MAX_NEW", 24);

    let commit = commit_micro(iters);
    let (batches, equal_checks) = serve_workload(max_new);

    let report = obj(vec![
        ("schema", s("kvcache_paged/v1")),
        (
            "config",
            obj(vec![
                ("backend", s("cpu-ref")),
                ("block_tokens", num(specdelay::kvcache::default_block_tokens() as f64)),
                ("iters", num(iters as f64)),
                ("max_new", num(max_new as f64)),
            ]),
        ),
        ("equal_output_checks", num(equal_checks as f64)),
        ("equal_output_assertion", s("enabled")),
        ("commit", commit),
        ("serve", arr(batches)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kvcache_paged.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("wrote {path}");
}
