//! Cross-request radix prefix-cache benchmark: cold vs warm serving on
//! prefix-heavy workloads (pure rust CPU backend, no artifacts, no PJRT).
//!
//! Two workloads, each run through the same FIFO [`ServeLoop`] twice over
//! paged KV storage — once with the prefix cache disabled (cold) and once
//! enabled (warm):
//!
//! * **template** — every request shares a long instruction template and
//!   differs only in a short question suffix, the classic system-prompt
//!   shape. The first request inserts the template's block run; every
//!   later request adopts it at admission.
//! * **conversation** — multi-turn chats where turn `t+1`'s prompt is turn
//!   `t`'s prompt plus its generated reply plus a new user line, so each
//!   turn re-prefixes the whole conversation so far. Retirement inserts
//!   grow the cached run turn by turn.
//!
//! Before anything is reported, every arm's token streams are asserted
//! bit-identical to a serial contiguous `SpecEngine::generate` oracle on
//! the same per-request rng streams — the cache is allowed to change
//! *latency*, never content — and both pools must pass block-conservation
//! validation. Reported per arm: makespan, TTFT p50/p99, prefill rows
//! saved (Σ `cached_prefix_rows`), prefix-hit ratio and the full
//! [`PrefixCacheCounters`] set.
//!
//! Emits a human-readable table and `BENCH_prefix_cache.json` at the repo
//! root (uploaded as a CI artifact). Env knobs: `PREFIX_CACHE_REQUESTS`
//! (template requests, default 10), `PREFIX_CACHE_TEMPLATE_BLOCKS`
//! (template length in 16-token blocks, default 10), `PREFIX_CACHE_CONVS`
//! (conversations, default 2), `PREFIX_CACHE_TURNS` (turns each, default
//! 3), `PREFIX_CACHE_MAX_NEW` (default 12), `PREFIX_CACHE_SEED`
//! (default 11).
//!
//! Run: `cargo bench --bench prefix_cache`.

use std::time::Instant;

use specdelay::coordinator::{
    ActionPolicy, FixedPolicy, ServeLoop, ServeOutput, ServeRequest, SpecEngine,
};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::kvcache::KvStorage;
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend};
use specdelay::util::json::{num, obj, s, Json};
use specdelay::util::Pcg64;
use specdelay::verify;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// The template workload: one long shared instruction prefix, short unique
/// suffixes. The template is sized to `blocks` whole KV blocks including
/// the BOS token, so warm admissions can adopt it in full.
fn template_prompts(n: usize, blocks: usize, bt: usize) -> Vec<String> {
    let mut t = String::new();
    while t.len() + 1 < blocks * bt {
        t.push_str("system: you are a terse arithmetic assistant; reply with digits only. ");
    }
    t.truncate(blocks * bt - 1); // +BOS = exactly `blocks` whole blocks
    (0..n).map(|i| format!("{t} Q{i}: {}+{}= ", i, i + 1)).collect()
}

/// The conversation workload plus its oracle streams, built turn by turn:
/// each turn's prompt embeds every earlier prompt and reply of its
/// conversation. Prompts are indexed in submission order, so request `id`
/// replays with rng stream `Pcg64::new(seed, id)` — the same stream the
/// serve loop gives lane `id`.
#[allow(clippy::too_many_arguments)]
fn conversation_workload(
    spec: &SpecEngine<'_>,
    convs: usize,
    turns: usize,
    max_new: usize,
    verifier: &dyn specdelay::verify::Verifier,
    policy: &dyn ActionPolicy,
    seed: u64,
) -> (Vec<String>, Vec<String>) {
    let mut prompts = Vec::new();
    let mut want = Vec::new();
    for c in 0..convs {
        let mut ctx =
            format!("chat {c}\nuser: describe the golden harbor at dusk\nassistant: ");
        for t in 0..turns {
            let id = prompts.len() as u64;
            let mut rng = Pcg64::new(seed, id);
            let (reply, _stats) =
                spec.generate(&ctx, max_new, verifier, policy, &mut rng).expect("oracle");
            prompts.push(ctx.clone());
            want.push(reply.clone());
            ctx = format!("{ctx}{reply}\nuser: and then? ({t})\nassistant: ");
        }
    }
    (prompts, want)
}

struct ArmResult {
    makespan: f64,
    ttft_p50: f64,
    ttft_p99: f64,
    rows_saved: usize,
    hit_ratio: f64,
    json: Json,
}

/// One serving arm: FIFO loop, paged storage, batch of one (so retirement
/// order is submission order and every insert lands before the next
/// admission), prefix cache on or off. Streams are asserted against the
/// oracle and both pools validated before any number is reported.
#[allow(clippy::too_many_arguments)]
fn run_arm(
    backend: &dyn Backend,
    sampling: SamplingConfig,
    verifier: &dyn specdelay::verify::Verifier,
    policy: &dyn ActionPolicy,
    prompts: &[String],
    want: &[String],
    max_new: usize,
    seed: u64,
    warm: bool,
    equal_output_checks: &mut usize,
) -> ArmResult {
    let mut srv = ServeLoop::new(backend, sampling, verifier, policy, 1)
        .without_scheduler()
        .with_kv_storage(KvStorage::Paged)
        .with_prefix_cache(warm);
    for prompt in prompts {
        srv.submit(ServeRequest::new(prompt.clone(), max_new, seed));
    }
    let t0 = Instant::now();
    let outs = srv.run().expect("serve run");
    let makespan = t0.elapsed().as_secs_f64();
    assert_eq!(outs.len(), prompts.len());
    for (o, want_text) in outs.iter().zip(want) {
        assert!(o.error.is_none(), "lane {} failed: {:?}", o.id, o.error);
        assert_eq!(&o.text, want_text, "stream diverged (id {}, warm={warm})", o.id);
        *equal_output_checks += 1;
    }
    let pools = srv.spec().kv_pools().expect("paged pools");
    pools.target.validate().expect("target pool conserved");
    pools.draft.validate().expect("draft pool conserved");
    let rows_saved: usize = outs.iter().map(|o: &ServeOutput| o.cached_prefix_rows).sum();
    if !warm {
        assert_eq!(rows_saved, 0, "cold arm must not report cached rows");
    }
    let mut ttfts: Vec<f64> = outs.iter().filter_map(|o| o.ttft_secs).collect();
    ttfts.sort_by(f64::total_cmp);
    let (ttft_p50, ttft_p99) = (percentile(&ttfts, 0.5), percentile(&ttfts, 0.99));
    let c = srv.prefix_counters();
    let hit_ratio = if c.lookups > 0 { c.hits as f64 / c.lookups as f64 } else { 0.0 };
    let json = obj(vec![
        ("makespan_secs", num(makespan)),
        ("ttft_p50_secs", num(ttft_p50)),
        ("ttft_p99_secs", num(ttft_p99)),
        ("prefill_rows_saved", num(rows_saved as f64)),
        ("prefix_hit_ratio", num(hit_ratio)),
        ("lookups", num(c.lookups as f64)),
        ("hits", num(c.hits as f64)),
        ("matched_rows", num(c.matched_rows as f64)),
        ("inserted_runs", num(c.inserted_runs as f64)),
        ("evicted_blocks", num(c.evicted_blocks as f64)),
        ("reclaimed_under_pressure", num(c.reclaimed_under_pressure as f64)),
        ("skipped_contiguous", num(c.skipped_contiguous as f64)),
        ("completed", num(outs.len() as f64)),
    ]);
    ArmResult { makespan, ttft_p50, ttft_p99, rows_saved, hit_ratio, json }
}

fn main() {
    let requests = env_usize("PREFIX_CACHE_REQUESTS", 10);
    let template_blocks = env_usize("PREFIX_CACHE_TEMPLATE_BLOCKS", 10).max(2);
    let convs = env_usize("PREFIX_CACHE_CONVS", 2);
    let turns = env_usize("PREFIX_CACHE_TURNS", 3).max(2);
    let max_new = env_usize("PREFIX_CACHE_MAX_NEW", 12);
    let seed = env_usize("PREFIX_CACHE_SEED", 11) as u64;
    let bt = 16usize; // default_block_tokens() in the default configuration

    let cfg = CpuModelConfig::small();
    let backend = CpuRefBackend::new(&cfg, 0);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let action = Action::new(2, 2, 3);
    let policy = FixedPolicy(action);
    let verifier = verify::verifier("SpecInfer").unwrap();

    // serial contiguous oracle: every arm must reproduce these streams
    // bit-for-bit before its numbers are trusted
    let oracle = SpecEngine::new(&backend, sampling).with_kv_storage(KvStorage::Contiguous);
    let template = template_prompts(requests, template_blocks, bt);
    let mut template_want = Vec::with_capacity(requests);
    for (id, prompt) in template.iter().enumerate() {
        let mut rng = Pcg64::new(seed, id as u64);
        let (text, _stats) = oracle
            .generate(prompt, max_new, verifier.as_ref(), &policy, &mut rng)
            .expect("serial generate");
        template_want.push(text);
    }
    let (conversation, conversation_want) = conversation_workload(
        &oracle,
        convs,
        turns,
        max_new,
        verifier.as_ref(),
        &policy,
        seed,
    );
    let mut equal_output_checks = 0usize;

    println!(
        "{:<14} {:<6} {:>12} {:>12} {:>12} {:>10} {:>9}",
        "workload", "arm", "ttft_p50_ms", "ttft_p99_ms", "makespan_s", "rows_saved", "hit_ratio"
    );
    let mut workloads: Vec<(&str, Json)> = Vec::new();
    for (name, prompts, want) in [
        ("template", &template, &template_want),
        ("conversation", &conversation, &conversation_want),
    ] {
        let mut arms: Vec<(&str, Json)> = Vec::new();
        let mut warm_vs_cold = [0.0f64; 2];
        for (arm, enabled) in [("cold", false), ("warm", true)] {
            let r = run_arm(
                &backend,
                sampling,
                verifier.as_ref(),
                &policy,
                prompts,
                want,
                max_new,
                seed,
                enabled,
                &mut equal_output_checks,
            );
            if enabled {
                assert!(r.hit_ratio > 0.0, "{name} warm arm never hit the cache");
                assert!(r.rows_saved > 0, "{name} warm arm saved no prefill rows");
            }
            println!(
                "{:<14} {:<6} {:>12.3} {:>12.3} {:>12.3} {:>10} {:>9.3}",
                name,
                arm,
                r.ttft_p50 * 1e3,
                r.ttft_p99 * 1e3,
                r.makespan,
                r.rows_saved,
                r.hit_ratio,
            );
            warm_vs_cold[usize::from(enabled)] = r.ttft_p50;
            arms.push((arm, r.json));
        }
        println!(
            "{:<14} warm/cold ttft_p50 = {:.3}",
            name,
            warm_vs_cold[1] / warm_vs_cold[0].max(1e-12)
        );
        workloads.push((name, obj(arms)));
    }

    let report = obj(vec![
        ("schema", s("prefix_cache/v1")),
        (
            "config",
            obj(vec![
                ("backend", s("cpu-ref")),
                ("family", s(&backend.meta().family)),
                ("n_layers", num(cfg.n_layers as f64)),
                ("d_model", num(cfg.d_model as f64)),
                ("vocab", num(cfg.vocab as f64)),
                ("requests", num(requests as f64)),
                ("template_blocks", num(template_blocks as f64)),
                ("conversations", num(convs as f64)),
                ("turns", num(turns as f64)),
                ("max_new", num(max_new as f64)),
                ("max_batch", num(1.0)),
                ("block_tokens", num(bt as f64)),
                ("seed", num(seed as f64)),
                ("temperature", num(sampling.temperature as f64)),
                ("top_p", num(sampling.top_p as f64)),
                ("action", s(&format!("K={} L1={} L2={}", action.k, action.l1, action.l2))),
            ]),
        ),
        ("equal_output_checks", num(equal_output_checks as f64)),
        ("equal_output_assertion", s("enabled")),
        ("workloads", obj(workloads)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_prefix_cache.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("wrote {path}");
}
