//! Regenerates paper Tables 10-15: per-sampling-configuration breakdown
//! for each model family.
use specdelay::benchkit::{experiments, Scale};
fn main() {
    for f in specdelay::benchkit::FAMILIES {
        experiments::tables_10_15(Scale::from_env(), f).expect("tables 10-15");
    }
}
