//! Overload-robustness benchmark: FIFO vs the preemptive priority
//! scheduler on the same seeded open-loop arrival trace (pure rust CPU
//! backend, no artifacts, no PJRT).
//!
//! A Poisson-burst trace (exponential inter-arrivals with alternating
//! burst/lull rate modulation, seeded) is submitted twice via
//! [`ServeLoop::submit_after`] — once to a strict-FIFO loop, once to the
//! scheduler (chunked prefill, weighted per-class admission, preemption
//! under the shared block budget). Before anything is timed, both arms'
//! token streams are asserted bit-identical to a serial
//! `SpecEngine::generate` oracle on the same per-request rng streams — the
//! scheduler is allowed to change latency, never content. A third
//! scheduler-only *overload* arm caps the queue and records structured
//! shedding with a closed `submitted == completed + shed` accounting.
//!
//! Reported per arm: per-token latency p50/p99 (from each output's
//! per-tick emission trace), TTFT p50/p99 per priority class, queue wait,
//! makespan, preemption/resume/release/rebuild/shed counters, and peak
//! resident blocks in both pools.
//!
//! Emits a human-readable table and `BENCH_serve_sched.json` at the repo
//! root (uploaded as a CI artifact). Env knobs: `SERVE_SCHED_REQUESTS`
//! (default 24), `SERVE_SCHED_MAX_NEW` (default 24), `SERVE_SCHED_CHUNK`
//! (prefill chunk rows, default 8), `SERVE_SCHED_BUDGET` (blocks per pool,
//! default 24), `SERVE_SCHED_MEAN_MS` (mean inter-arrival, default 4),
//! `SERVE_SCHED_SEED` (default 7).
//!
//! Run: `cargo bench --bench serve_sched`.

use std::time::{Duration, Instant};

use specdelay::coordinator::{
    FixedPolicy, Priority, SchedConfig, ServeLoop, ServeOutput, ServeRequest, SpecEngine,
};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::kvcache::KvStorage;
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend};
use specdelay::util::json::{num, obj, s, Json};
use specdelay::util::Pcg64;
use specdelay::verify;

const PROMPTS: [&str; 4] = [
    "Q: compute 12 * 34 + 56 - 7 = ? A:",
    "story: the golden harbor at dusk, ",
    "fn partition(xs, pivot): # quicksort",
    "translate en->fr: the sea is calm => ",
];

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One request of the precomputed arrival trace.
struct TraceItem {
    prompt: &'static str,
    priority: Priority,
    arrival: Duration,
}

/// Seeded open-loop Poisson-burst trace: exponential inter-arrivals whose
/// rate alternates between a burst (4x) and a lull (1/4x) every few
/// requests, with a seeded 20/50/30 high/normal/low class mix.
fn build_trace(n: usize, mean_ms: f64, seed: u64) -> Vec<TraceItem> {
    let mut rng = Pcg64::new(seed, 0);
    let mut at = 0.0f64;
    let mut items = Vec::with_capacity(n);
    for i in 0..n {
        // burst of 6, lull of 2: sustained pressure then a breather
        let factor = if (i / 6) % 2 == 0 { 0.25 } else { 4.0 };
        let u = rng.next_f32().max(1e-6) as f64;
        at += -u.ln() * mean_ms * factor;
        let c = rng.next_f32();
        let priority = if c < 0.2 {
            Priority::High
        } else if c < 0.7 {
            Priority::Normal
        } else {
            Priority::Low
        };
        items.push(TraceItem {
            prompt: PROMPTS[i % PROMPTS.len()],
            priority,
            arrival: Duration::from_secs_f64(at / 1000.0),
        });
    }
    items
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Per-token inter-emission gaps of one output: each tick that emitted
/// `delta` tokens at `at` seconds contributes `delta` gaps of
/// `(at - prev) / delta`, with `prev` starting at arrival (0).
fn token_gaps(o: &ServeOutput) -> Vec<f64> {
    let mut gaps = Vec::new();
    let mut prev = 0.0f64;
    for &(at, delta) in &o.tick_emits {
        let per = (at - prev).max(0.0) / delta.max(1) as f64;
        for _ in 0..delta {
            gaps.push(per);
        }
        prev = at;
    }
    gaps
}

struct ArmStats {
    gap_p50: f64,
    gap_p99: f64,
    ttft: [(f64, f64); 3], // per class (p50, p99), NaN when the class is empty
    queue_mean: f64,
    makespan: f64,
}

fn arm_stats(outs: &[ServeOutput], makespan: f64) -> ArmStats {
    let mut gaps: Vec<f64> = outs.iter().flat_map(token_gaps).collect();
    gaps.sort_by(f64::total_cmp);
    let mut ttft = [(f64::NAN, f64::NAN); 3];
    for (c, slot) in ttft.iter_mut().enumerate() {
        let mut xs: Vec<f64> = outs
            .iter()
            .filter(|o| o.priority.index() == c)
            .filter_map(|o| o.ttft_secs)
            .collect();
        xs.sort_by(f64::total_cmp);
        *slot = (percentile(&xs, 0.5), percentile(&xs, 0.99));
    }
    let waits: Vec<f64> = outs.iter().map(|o| o.queue_secs).collect();
    let queue_mean = waits.iter().sum::<f64>() / waits.len().max(1) as f64;
    ArmStats {
        gap_p50: percentile(&gaps, 0.5),
        gap_p99: percentile(&gaps, 0.99),
        ttft,
        queue_mean,
        makespan,
    }
}

fn arm_json(stats: &ArmStats, srv: &ServeLoop<'_>, completed: usize, shed: usize) -> Json {
    let sc = srv.sched_counters();
    let (peak_t, peak_d) = srv
        .spec()
        .kv_pools()
        .map(|p| (p.target.peak_live_blocks(), p.draft.peak_live_blocks()))
        .unwrap_or((0, 0));
    let class_names = ["high", "normal", "low"];
    let ttft_rows: Vec<(&str, Json)> = class_names
        .iter()
        .zip(stats.ttft.iter())
        .map(|(name, &(p50, p99))| {
            (*name, obj(vec![("p50_secs", num(p50)), ("p99_secs", num(p99))]))
        })
        .collect();
    obj(vec![
        ("token_gap_p50_secs", num(stats.gap_p50)),
        ("token_gap_p99_secs", num(stats.gap_p99)),
        ("ttft_by_class", obj(ttft_rows)),
        ("queue_wait_mean_secs", num(stats.queue_mean)),
        ("makespan_secs", num(stats.makespan)),
        ("completed", num(completed as f64)),
        ("shed", num(shed as f64)),
        ("peak_active", num(sc.peak_active as f64)),
        ("preempted", num(sc.preempted as f64)),
        ("resumed", num(sc.resumed as f64)),
        ("released", num(sc.released as f64)),
        ("rebuilt", num(sc.rebuilt as f64)),
        ("prefill_chunks", num(sc.prefill_chunks as f64)),
        ("peak_blocks_target", num(peak_t as f64)),
        ("peak_blocks_draft", num(peak_d as f64)),
    ])
}

/// Feed the whole trace to a loop via open-loop delayed arrivals. In the
/// overload arm (`deadlines`), low-priority requests carry a deadline so
/// short it is effectively doomed — they are shed from the queue or
/// deadline-retired on their first tick.
fn submit_trace(
    srv: &mut ServeLoop<'_>,
    trace: &[TraceItem],
    max_new: usize,
    seed: u64,
    mean_ms: f64,
    deadlines: bool,
) {
    for item in trace {
        let mut req = ServeRequest::new(item.prompt.to_string(), max_new, seed)
            .with_priority(item.priority);
        if deadlines && item.priority == Priority::Low {
            req = req.with_deadline(Duration::from_secs_f64(mean_ms / 250.0));
        }
        srv.submit_after(req, item.arrival);
    }
}

fn main() {
    let requests = env_usize("SERVE_SCHED_REQUESTS", 24);
    let max_new = env_usize("SERVE_SCHED_MAX_NEW", 24);
    let chunk = env_usize("SERVE_SCHED_CHUNK", 8).max(1);
    let budget = env_usize("SERVE_SCHED_BUDGET", 24);
    let mean_ms = env_f64("SERVE_SCHED_MEAN_MS", 4.0);
    let seed = env_usize("SERVE_SCHED_SEED", 7) as u64;
    let max_batch = 3;

    let cfg = CpuModelConfig::small();
    let backend = CpuRefBackend::new(&cfg, 0);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let action = Action::new(2, 2, 3);
    let policy = FixedPolicy(action);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let trace = build_trace(requests, mean_ms, seed);

    // serial oracle streams (untimed): both arms must reproduce these
    // bit-for-bit — the bench aborts before reporting numbers otherwise
    let spec = SpecEngine::new(&backend, sampling).with_kv_storage(KvStorage::Contiguous);
    let mut want = Vec::with_capacity(requests);
    for (id, item) in trace.iter().enumerate() {
        let mut rng = Pcg64::new(seed, id as u64);
        let (text, _stats) = spec
            .generate(item.prompt, max_new, verifier.as_ref(), &policy, &mut rng)
            .expect("serial generate");
        want.push(text);
    }
    let mut equal_output_checks = 0usize;

    let mut report_arms: Vec<(&str, Json)> = Vec::new();
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>12} {:>10} {:>8} {:>6}",
        "arm", "gap_p50_ms", "gap_p99_ms", "ttft_hi_p99", "queue_mean", "makespan", "preempt", "shed"
    );

    // ---- arm 1: strict FIFO (tight worst-case reservations) ------------
    {
        let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, max_batch)
            .with_block_budget(budget)
            .without_scheduler();
        submit_trace(&mut srv, &trace, max_new, seed, mean_ms, false);
        let t0 = Instant::now();
        let outs = srv.run().expect("fifo run");
        let makespan = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), requests);
        for (o, want_text) in outs.iter().zip(&want) {
            assert!(o.error.is_none(), "fifo lane {} failed: {:?}", o.id, o.error);
            assert_eq!(&o.text, want_text, "fifo stream diverged (id {})", o.id);
            equal_output_checks += 1;
        }
        let stats = arm_stats(&outs, makespan);
        println!(
            "{:<10} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>10.3} {:>8} {:>6}",
            "fifo",
            stats.gap_p50 * 1e3,
            stats.gap_p99 * 1e3,
            stats.ttft[0].1 * 1e3,
            stats.queue_mean * 1e3,
            makespan,
            srv.sched_counters().preempted,
            srv.sched_counters().shed,
        );
        report_arms.push(("fifo", arm_json(&stats, &srv, outs.len(), 0)));
    }

    // ---- arm 2: the scheduler, same trace, same budget ------------------
    {
        let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, max_batch)
            .with_block_budget(budget)
            .with_scheduler(SchedConfig {
                prefill_chunk: chunk,
                max_queue: None,
                ..SchedConfig::default()
            });
        submit_trace(&mut srv, &trace, max_new, seed, mean_ms, false);
        let t0 = Instant::now();
        let outs = srv.run().expect("sched run");
        let makespan = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), requests);
        for (o, want_text) in outs.iter().zip(&want) {
            assert!(o.error.is_none(), "sched lane {} failed: {:?}", o.id, o.error);
            assert_eq!(&o.text, want_text, "sched stream diverged (id {})", o.id);
            equal_output_checks += 1;
        }
        let stats = arm_stats(&outs, makespan);
        println!(
            "{:<10} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>10.3} {:>8} {:>6}",
            "sched",
            stats.gap_p50 * 1e3,
            stats.gap_p99 * 1e3,
            stats.ttft[0].1 * 1e3,
            stats.queue_mean * 1e3,
            makespan,
            srv.sched_counters().preempted,
            srv.sched_counters().shed,
        );
        report_arms.push(("sched", arm_json(&stats, &srv, outs.len(), 0)));
    }

    // ---- arm 3: overload — capped queue + doomed low-priority deadlines -
    {
        let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, max_batch)
            .with_block_budget(budget)
            .with_scheduler(SchedConfig {
                prefill_chunk: chunk,
                max_queue: Some((requests / 4).max(2)),
                ..SchedConfig::default()
            });
        submit_trace(&mut srv, &trace, max_new, seed, mean_ms, true);
        let t0 = Instant::now();
        let outs = srv.run().expect("overload run");
        let makespan = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), requests);
        let mut completed = 0usize;
        let mut shed = 0usize;
        let mut deadline_retired = 0usize;
        for o in &outs {
            match o.error.as_ref().map(|e| e.kind()) {
                None => {
                    assert_eq!(
                        &o.text, &want[o.id as usize],
                        "overload survivor diverged (id {})",
                        o.id
                    );
                    equal_output_checks += 1;
                    completed += 1;
                }
                Some("shed") => {
                    assert!(o.tokens.is_empty(), "shed lane {} ran backend work", o.id);
                    shed += 1;
                }
                // a low-priority lane whose doomed deadline expired after
                // admission retires mid-flight instead of being shed
                Some("deadline") => deadline_retired += 1,
                Some(k) => panic!("unexpected overload error kind {k} (id {})", o.id),
            }
        }
        assert_eq!(
            completed + shed + deadline_retired,
            requests,
            "overload accounting must close"
        );
        assert_eq!(srv.sched_counters().shed, shed);
        let stats = arm_stats(&outs, makespan);
        println!(
            "{:<10} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>10.3} {:>8} {:>6}",
            "overload",
            stats.gap_p50 * 1e3,
            stats.gap_p99 * 1e3,
            stats.ttft[0].1 * 1e3,
            stats.queue_mean * 1e3,
            makespan,
            srv.sched_counters().preempted,
            shed,
        );
        let mut j = arm_json(&stats, &srv, completed, shed);
        if let Json::Obj(rows) = &mut j {
            rows.insert("deadline_retired".to_string(), num(deadline_retired as f64));
        }
        report_arms.push(("overload", j));
    }

    let report = obj(vec![
        ("schema", s("serve_sched/v1")),
        (
            "config",
            obj(vec![
                ("backend", s("cpu-ref")),
                ("family", s(&backend.meta().family)),
                ("n_layers", num(cfg.n_layers as f64)),
                ("d_model", num(cfg.d_model as f64)),
                ("vocab", num(cfg.vocab as f64)),
                ("requests", num(requests as f64)),
                ("max_new", num(max_new as f64)),
                ("max_batch", num(max_batch as f64)),
                ("prefill_chunk", num(chunk as f64)),
                ("block_budget", num(budget as f64)),
                ("mean_interarrival_ms", num(mean_ms)),
                ("seed", num(seed as f64)),
                ("temperature", num(sampling.temperature as f64)),
                ("top_p", num(sampling.top_p as f64)),
                ("action", s(&format!("K={} L1={} L2={}", action.k, action.l1, action.l2))),
                ("class_mix", s("20% high / 50% normal / 30% low (seeded)")),
            ]),
        ),
        ("equal_output_checks", num(equal_output_checks as f64)),
        ("equal_output_assertion", s("enabled")),
        ("arms", obj(report_arms)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_sched.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("wrote {path}");
}
