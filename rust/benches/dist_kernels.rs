//! Dense-vs-sparse distribution-kernel harness (pure rust, no PJRT).
//!
//! Measures the tentpole representation change across the grid the issue
//! names — vocab sizes {8k, 32k, 128k} × top-p {0.8, 0.95, 1.0} — at two
//! levels:
//!
//! * **per kernel**: µs/op for overlap, l1, kl, residual and sampling on
//!   nucleus-truncated distribution pairs, dense vs sparse, with an
//!   equal-output assertion (≤1e-6) before anything is timed;
//! * **per verifier**: steady-state µs/verify for all eight verifiers on
//!   dense trees vs their sparse twins, with seeded-rng verdict-equality
//!   asserted per configuration.
//!
//! Every entry carries `speedup_vs_dense`. Emits a human table plus
//! `BENCH_dist_kernels.json` at the repo root (CI smoke-runs it and uploads
//! the JSON next to the other bench artifacts).
//!
//! Run: `cargo bench --bench dist_kernels` (`DIST_KERNELS_ITERS` overrides
//! the kernel iteration base; verifier iterations scale down with vocab).

use std::time::Instant;

use specdelay::dist::{Dist, SparseDist};
use specdelay::tree::DraftTree;
use specdelay::util::json::{arr, num, obj, s, Json};
use specdelay::util::Pcg64;
use specdelay::verify::{self, Verdict, VerifyScratch};

#[path = "../tests/common/mod.rs"]
mod common;

use common::{make_topp_tree, random_topp_dist, sparsify_tree};

const VOCABS: [usize; 3] = [8_192, 32_768, 131_072];
const TOP_PS: [f32; 3] = [0.8, 0.95, 1.0];
const PAIRS: usize = 8;
const TREES: usize = 4;

fn time_us(iters: usize, mut f: impl FnMut(usize)) -> f64 {
    for i in 0..8.min(iters) {
        f(i); // warm-up: capacity, pages, branch predictors
    }
    let t0 = Instant::now();
    for i in 0..iters {
        f(i);
    }
    t0.elapsed().as_secs_f64() / iters as f64 * 1e6
}

struct KernelRow {
    vocab: usize,
    top_p: f32,
    kernel: &'static str,
    dense_us: f64,
    sparse_us: f64,
    support_mean: f64,
}

struct VerifierRow {
    vocab: usize,
    top_p: f32,
    verifier: &'static str,
    dense_us: f64,
    sparse_us: f64,
}

fn main() {
    let base_iters: usize = std::env::var("DIST_KERNELS_ITERS")
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(200);
    let mut rng = Pcg64::seeded(0xd1);
    let mut kernel_rows: Vec<KernelRow> = Vec::new();
    let mut verifier_rows: Vec<VerifierRow> = Vec::new();
    let names = ["NSS", "Naive", "NaiveTree", "SpecTr", "SpecInfer", "Khisti", "BV", "Traversal"];
    let mut equal_output_checks = 0usize;

    println!(
        "{:<8} {:>6} {:>12} {:>12} {:>12} {:>10} {:>12}",
        "vocab", "top_p", "kernel", "us/dense", "us/sparse", "speedup", "support"
    );

    for &vocab in &VOCABS {
        for &top_p in &TOP_PS {
            // ---- kernel pairs: dense + sparse twins, equality-checked ----
            let dense_pairs: Vec<(Dist, Dist)> = (0..PAIRS)
                .map(|_| {
                    (
                        random_topp_dist(vocab, &mut rng, top_p),
                        random_topp_dist(vocab, &mut rng, top_p),
                    )
                })
                .collect();
            let sparse_pairs: Vec<(SparseDist, SparseDist)> = dense_pairs
                .iter()
                .map(|(p, q)| (SparseDist::from_dense(p), SparseDist::from_dense(q)))
                .collect();
            let support_mean = sparse_pairs
                .iter()
                .map(|(p, q)| (p.support_len() + q.support_len()) as f64 / 2.0)
                .sum::<f64>()
                / PAIRS as f64;

            // equal-output assertion before timing anything
            let mut dense_buf = Dist::default();
            let mut sparse_buf = SparseDist::default();
            for ((pd, qd), (ps, qs)) in dense_pairs.iter().zip(&sparse_pairs) {
                assert!(
                    (Dist::overlap(pd, qd) - SparseDist::overlap(ps, qs)).abs() <= 1e-6,
                    "overlap mismatch at vocab {vocab} top_p {top_p}"
                );
                assert!(
                    (Dist::l1(pd, qd) - SparseDist::l1(ps, qs)).abs() <= 1e-6,
                    "l1 mismatch at vocab {vocab} top_p {top_p}"
                );
                assert!(
                    (pd.kl(qd) - ps.kl(qs)).abs() <= 1e-6,
                    "kl mismatch at vocab {vocab} top_p {top_p}"
                );
                let od = Dist::residual_into(pd, qd, &mut dense_buf);
                let os = SparseDist::residual_into(ps, qs, &mut sparse_buf);
                assert_eq!(od, os, "residual flag mismatch at vocab {vocab} top_p {top_p}");
                if od {
                    let sd = sparse_buf.to_dense();
                    for (t, (&a, &b)) in dense_buf.0.iter().zip(&sd.0).enumerate() {
                        assert!(
                            (a - b).abs() <= 1e-6,
                            "residual[{t}] mismatch at vocab {vocab} top_p {top_p}"
                        );
                    }
                }
                equal_output_checks += 1;
            }

            let kernels: Vec<(&'static str, f64, f64)> = {
                let it = base_iters;
                let overlap_d = time_us(it, |i| {
                    let (p, q) = &dense_pairs[i % PAIRS];
                    std::hint::black_box(Dist::overlap(p, q));
                });
                let overlap_s = time_us(it, |i| {
                    let (p, q) = &sparse_pairs[i % PAIRS];
                    std::hint::black_box(SparseDist::overlap(p, q));
                });
                let l1_d = time_us(it, |i| {
                    let (p, q) = &dense_pairs[i % PAIRS];
                    std::hint::black_box(Dist::l1(p, q));
                });
                let l1_s = time_us(it, |i| {
                    let (p, q) = &sparse_pairs[i % PAIRS];
                    std::hint::black_box(SparseDist::l1(p, q));
                });
                let kl_d = time_us(it, |i| {
                    let (p, q) = &dense_pairs[i % PAIRS];
                    std::hint::black_box(p.kl(q));
                });
                let kl_s = time_us(it, |i| {
                    let (p, q) = &sparse_pairs[i % PAIRS];
                    std::hint::black_box(p.kl(q));
                });
                let res_d = time_us(it, |i| {
                    let (p, q) = &dense_pairs[i % PAIRS];
                    std::hint::black_box(Dist::residual_into(p, q, &mut dense_buf));
                });
                let res_s = time_us(it, |i| {
                    let (p, q) = &sparse_pairs[i % PAIRS];
                    std::hint::black_box(SparseDist::residual_into(p, q, &mut sparse_buf));
                });
                let mut srng = Pcg64::seeded(7);
                let sample_d = time_us(it, |i| {
                    let (p, _) = &dense_pairs[i % PAIRS];
                    std::hint::black_box(p.sample(&mut srng));
                });
                let mut srng = Pcg64::seeded(7);
                let sample_s = time_us(it, |i| {
                    let (p, _) = &sparse_pairs[i % PAIRS];
                    std::hint::black_box(p.sample(&mut srng));
                });
                vec![
                    ("overlap", overlap_d, overlap_s),
                    ("l1", l1_d, l1_s),
                    ("kl", kl_d, kl_s),
                    ("residual_into", res_d, res_s),
                    ("sample", sample_d, sample_s),
                ]
            };
            for (kernel, dense_us, sparse_us) in kernels {
                println!(
                    "{vocab:<8} {top_p:>6.2} {kernel:>12} {dense_us:>12.3} {sparse_us:>12.3} {:>9.2}x {support_mean:>12.0}",
                    dense_us / sparse_us
                );
                kernel_rows.push(KernelRow { vocab, top_p, kernel, dense_us, sparse_us, support_mean });
            }
            drop(dense_pairs);
            drop(sparse_pairs);

            // ---- per-verifier µs/verify, dense vs sparse twins ----
            let dense_trees: Vec<DraftTree> =
                (0..TREES).map(|_| make_topp_tree(&mut rng, vocab, top_p)).collect();
            let sparse_trees: Vec<DraftTree> = dense_trees.iter().map(sparsify_tree).collect();
            let v_iters = (base_iters * VOCABS[0] / (8 * vocab)).max(2);
            for name in names {
                let ver = verify::verifier(name).unwrap();
                // verdict equality under seeded rng (the bench's equal-output
                // assertion for the walk itself)
                for seed in 0..3u64 {
                    let mut r1 = Pcg64::seeded(seed);
                    let mut r2 = Pcg64::seeded(seed);
                    let a = ver.verify(&dense_trees[0], &mut r1);
                    let b = ver.verify(&sparse_trees[0], &mut r2);
                    assert_eq!(a.accepted, b.accepted, "{name}: accepted diverged");
                    assert_eq!(a.correction, b.correction, "{name}: correction diverged");
                    equal_output_checks += 1;
                }
                let mut scratch = VerifyScratch::new();
                scratch.reserve(vocab, 16, 8);
                let mut verdict = Verdict::default();
                let mut drng = Pcg64::seeded(2);
                let dense_us = time_us(v_iters, |i| {
                    ver.verify_into(&dense_trees[i % TREES], &mut drng, &mut scratch, &mut verdict);
                });
                let mut srng = Pcg64::seeded(2);
                let sparse_us = time_us(v_iters, |i| {
                    ver.verify_into(&sparse_trees[i % TREES], &mut srng, &mut scratch, &mut verdict);
                });
                println!(
                    "{vocab:<8} {top_p:>6.2} {name:>12} {dense_us:>12.2} {sparse_us:>12.2} {:>9.2}x {:>12}",
                    dense_us / sparse_us, "-"
                );
                verifier_rows.push(VerifierRow { vocab, top_p, verifier: name, dense_us, sparse_us });
            }
        }
    }

    let kernel_json: Vec<Json> = kernel_rows
        .iter()
        .map(|r| {
            obj(vec![
                ("vocab", num(r.vocab as f64)),
                ("top_p", num(r.top_p as f64)),
                ("kernel", s(r.kernel)),
                ("dense_us", num(r.dense_us)),
                ("sparse_us", num(r.sparse_us)),
                ("speedup_vs_dense", num(r.dense_us / r.sparse_us)),
                ("support_mean", num(r.support_mean)),
            ])
        })
        .collect();
    let verifier_json: Vec<Json> = verifier_rows
        .iter()
        .map(|r| {
            obj(vec![
                ("vocab", num(r.vocab as f64)),
                ("top_p", num(r.top_p as f64)),
                ("verifier", s(r.verifier)),
                ("dense_us_per_verify", num(r.dense_us)),
                ("sparse_us_per_verify", num(r.sparse_us)),
                ("speedup_vs_dense", num(r.dense_us / r.sparse_us)),
            ])
        })
        .collect();

    let report = obj(vec![
        ("schema", s("dist_kernels/v1")),
        (
            "config",
            obj(vec![
                ("vocabs", arr(VOCABS.iter().map(|&v| num(v as f64)))),
                ("top_ps", arr(TOP_PS.iter().map(|&p| num(p as f64)))),
                ("pairs", num(PAIRS as f64)),
                ("trees", num(TREES as f64)),
                ("kernel_iters", num(base_iters as f64)),
                ("tree_shape", s("K=3 L1=2 L2=3 (12 nodes)")),
            ]),
        ),
        ("equal_output_checks", num(equal_output_checks as f64)),
        ("equal_output_assertion", s("enabled")),
        ("kernels", arr(kernel_json)),
        ("verifiers", arr(verifier_json)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_dist_kernels.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("\n{equal_output_checks} equal-output checks passed");
    println!("wrote {path}");
}
