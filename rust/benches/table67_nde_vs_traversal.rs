//! Regenerates paper Tables 6+7: Traversal vs NDE methods.
use specdelay::benchkit::{experiments, Scale};
fn main() {
    experiments::tables_4_7(Scale::from_env()).expect("tables 4-7");
}
