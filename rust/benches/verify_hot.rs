//! Hot-path microbenchmark: verification algorithms + branching calculators
//! on synthetic dists (pure L3, no PJRT). Used by the §Perf pass.
use std::time::Instant;

use specdelay::dist::Dist;
use specdelay::tree::{DraftTree, PathDraws, Provenance};
use specdelay::util::Pcg64;
use specdelay::verify;

fn random_dist(v: usize, rng: &mut Pcg64, sharp: f32) -> Dist {
    let mut d: Vec<f32> = (0..v).map(|_| rng.next_f32().powf(sharp) + 1e-4).collect();
    let s: f32 = d.iter().sum();
    for x in d.iter_mut() { *x /= s; }
    Dist(d)
}

fn make_tree(rng: &mut Pcg64, v: usize) -> DraftTree {
    // trunk 2 + 3 branches of 3
    let mut t = DraftTree::new(5);
    let mut node = 0;
    for s in 0..2 {
        let q = random_dist(v, rng, 1.0);
        let tok = q.sample(rng) as u32;
        t.set_q(node, q);
        t.set_p(node, random_dist(v, rng, 2.0));
        node = t.add_child(node, tok, Provenance::Trunk { step: s + 1 });
    }
    let bp = node;
    let mut paths = Vec::new();
    for b in 0..3 {
        let mut cur = bp;
        for s in 0..3 {
            if t.nodes[cur].q.is_none() {
                t.set_q(cur, random_dist(v, rng, 1.0));
            }
            if t.nodes[cur].p.is_none() {
                t.set_p(cur, random_dist(v, rng, 2.0));
            }
            let tok = t.nodes[cur].q.as_ref().unwrap().sample(rng) as u32;
            cur = t.add_child(cur, tok, Provenance::Branch { branch: b, step: s + 1 });
        }
        if t.nodes[cur].p.is_none() {
            t.set_p(cur, random_dist(v, rng, 2.0));
        }
        paths.push(t.path_nodes(cur));
    }
    t.path_draws = Some(PathDraws { paths, shared_edges: 2 });
    t
}

fn main() {
    let v = 259;
    let iters = 2000;
    let mut rng = Pcg64::seeded(1);
    let trees: Vec<DraftTree> = (0..64).map(|_| make_tree(&mut rng, v)).collect();
    println!("{:<12} {:>12} {:>14}", "verifier", "us/verify", "us/branching");
    for name in ["NSS", "Naive", "NaiveTree", "SpecTr", "SpecInfer", "Khisti", "BV", "Traversal"] {
        let ver = verify::verifier(name).unwrap();
        let t0 = Instant::now();
        for i in 0..iters {
            let _ = ver.verify(&trees[i % trees.len()], &mut rng);
        }
        let per_verify = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
        let per_branch = if let Some(solver) = verify::ot_solver(name) {
            let p = random_dist(v, &mut rng, 2.0);
            let q = random_dist(v, &mut rng, 1.0);
            let xs: Vec<u32> = (0..4).map(|_| q.sample(&mut rng) as u32).collect();
            let t1 = Instant::now();
            for _ in 0..iters {
                let _ = solver.branching(&p, &q, &xs);
            }
            t1.elapsed().as_secs_f64() / iters as f64 * 1e6
        } else {
            f64::NAN
        };
        println!("{name:<12} {per_verify:>12.1} {per_branch:>14.1}");
    }
}
