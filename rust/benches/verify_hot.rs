//! Hot-path microbenchmark harness: verification algorithms + branching
//! calculators on synthetic dists (pure L3, no PJRT).
//!
//! Emits both a human-readable table and a machine-readable
//! `BENCH_verify_hot.json` at the repo root so every PR's perf trajectory
//! can be tracked by CI. Three code paths are measured per verifier:
//!
//! * **legacy** — a frozen re-implementation of the pre-bootstrap walk for
//!   the OT verifiers (per-node `child_tokens` allocation, two-pass
//!   weighted sampling, allocating residuals, 60-iteration SpecTr
//!   bisection). This is the fixed baseline the ≥2× speedup target is
//!   measured against.
//! * **cold**  — `Verifier::verify` (a fresh scratch arena per call).
//! * **steady** — `Verifier::verify_into` with a warm arena and recycled
//!   verdict: the serving configuration. A counting global allocator
//!   reports allocations per verify on this path (0 for everything except
//!   the documented Khisti LP).
//!
//! Run: `cargo bench --bench verify_hot` (env `VERIFY_HOT_ITERS` overrides
//! the iteration count).

use std::time::Instant;

use specdelay::tree::DraftTree;
use specdelay::util::json::{num, obj, s, Json};
use specdelay::util::Pcg64;
use specdelay::verify::{self, Verdict, VerifyScratch};

// Allocator + workload shared with tests/alloc_free.rs so the zero-alloc
// test asserts exactly the configuration measured here.
#[path = "../tests/common/mod.rs"]
mod common;

use common::{allocs, make_tree, random_dist, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// ---------------------------------------------------------------------------
// Legacy baseline (frozen pre-bootstrap implementations, OT verifiers only)
// ---------------------------------------------------------------------------

mod legacy {
    use specdelay::dist::{Dist, NodeDist};
    use specdelay::tree::DraftTree;
    use specdelay::util::Pcg64;
    use specdelay::verify::{khisti, OtlpSolver};

    /// Pre-bootstrap sampling: two passes (total mass, then scan).
    fn sample(d: &Dist, rng: &mut Pcg64) -> usize {
        rng.sample_weighted(&d.0).unwrap_or(0)
    }

    /// Pre-bootstrap residual: fresh allocation per call.
    fn residual(p: &Dist, q: &Dist) -> Option<Dist> {
        let mut r: Vec<f32> = p
            .0
            .iter()
            .zip(&q.0)
            .map(|(&a, &b)| (a - b).max(0.0))
            .collect();
        let mass: f32 = r.iter().sum();
        if mass <= 0.0 {
            return None;
        }
        for v in r.iter_mut() {
            *v /= mass;
        }
        Some(Dist(r))
    }

    fn solve_nss(p: &Dist, rng: &mut Pcg64) -> u32 {
        sample(p, rng) as u32
    }

    fn solve_naive(p: &Dist, q: &Dist, xs: &[u32], rng: &mut Pcg64) -> u32 {
        let x1 = xs[0] as usize;
        let ratio = if q.p(x1) > 0.0 { p.p(x1) / q.p(x1) } else { 1.0 };
        if rng.next_f64() <= ratio as f64 {
            return x1 as u32;
        }
        match residual(p, q) {
            Some(res) => sample(&res, rng) as u32,
            None => x1 as u32,
        }
    }

    fn beta(p: &Dist, q: &Dist, rho: f64) -> f64 {
        p.0.iter()
            .zip(&q.0)
            .map(|(&a, &b)| (a as f64 / rho).min(b as f64))
            .sum()
    }

    fn p_acc(beta: f64, k: usize) -> f64 {
        1.0 - (1.0 - beta).powi(k as i32)
    }

    /// Pre-bootstrap ρ* search: 60 bisection iterations.
    fn solve_rho(p: &Dist, q: &Dist, k: usize) -> f64 {
        if k <= 1 {
            return 1.0;
        }
        let g = |rho: f64| {
            let b = beta(p, q, rho);
            p_acc(b, k) - rho * b
        };
        let (mut lo, mut hi) = (1.0f64, k as f64);
        if g(lo) <= 0.0 {
            return lo;
        }
        if g(hi) >= 0.0 {
            return hi;
        }
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if g(mid) > 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    fn spectr_residual(p: &Dist, q: &Dist, rho: f64, gamma: f64) -> Dist {
        let mut r: Vec<f32> = p
            .0
            .iter()
            .zip(&q.0)
            .map(|(&a, &b)| {
                let m = (a as f64 / rho).min(b as f64);
                (a as f64 - m * gamma).max(0.0) as f32
            })
            .collect();
        let mass: f32 = r.iter().sum();
        if mass > 0.0 {
            for v in r.iter_mut() {
                *v /= mass;
            }
        }
        Dist(r)
    }

    fn solve_spectr(p: &Dist, q: &Dist, xs: &[u32], rng: &mut Pcg64) -> u32 {
        let k = xs.len();
        let rho = solve_rho(p, q, k);
        let b = beta(p, q, rho);
        if b <= 0.0 {
            return sample(&spectr_residual(p, q, rho, 0.0), rng) as u32;
        }
        let gamma = p_acc(b, k) / b;
        for &x in xs {
            let xi = x as usize;
            let ratio = if q.p(xi) > 0.0 {
                p.p(xi) as f64 / q.p(xi) as f64
            } else {
                f64::INFINITY
            };
            if rho * rng.next_f64() <= ratio {
                return x;
            }
        }
        sample(&spectr_residual(p, q, rho, gamma), rng) as u32
    }

    fn solve_specinfer(p: &Dist, q: &Dist, xs: &[u32], rng: &mut Pcg64) -> u32 {
        let mut s: Vec<u32> = xs.to_vec();
        let mut p_cur = p.clone();
        while !s.is_empty() {
            let idx = rng.next_below(s.len());
            let x = s[idx] as usize;
            let ratio = if q.p(x) > 0.0 {
                p_cur.p(x) as f64 / q.p(x) as f64
            } else {
                f64::INFINITY
            };
            if rng.next_f64() <= ratio {
                return x as u32;
            }
            p_cur = residual(&p_cur, q).unwrap_or(p_cur);
            s.swap_remove(idx);
        }
        sample(&p_cur, rng) as u32
    }

    /// `p_nd`/`q_nd` are the tree's stored dists, handed through *borrowed*
    /// so the Khisti arm (whose baseline is the current allocating entry)
    /// adds no wrapping clones to the frozen measurement.
    fn solve(
        name: &str,
        p: &Dist,
        q: &Dist,
        p_nd: &NodeDist,
        q_nd: &NodeDist,
        xs: &[u32],
        rng: &mut Pcg64,
    ) -> u32 {
        match name {
            "NSS" => solve_nss(p, rng),
            "Naive" | "NaiveTree" => solve_naive(p, q, xs, rng),
            "SpecTr" => solve_spectr(p, q, xs, rng),
            "SpecInfer" => solve_specinfer(p, q, xs, rng),
            // Khisti's coupling construction is shared with the current
            // implementation; its baseline is the allocating entry point.
            "Khisti" => khisti::Khisti.solve(p_nd, q_nd, xs, rng),
            other => panic!("no legacy solver for {other}"),
        }
    }

    /// Pre-bootstrap OT walk: allocates child-token vectors per node and a
    /// fresh accepted vector per verify. Frozen baseline — dense trees only.
    pub fn verify_ot(name: &str, tree: &DraftTree, rng: &mut Pcg64) -> (Vec<usize>, u32) {
        let mut accepted = Vec::new();
        let mut node = 0usize;
        loop {
            let p_nd = tree.nodes[node].p.as_ref().expect("p dist set");
            let p = p_nd.as_dense().expect("legacy baseline walks dense trees");
            if tree.nodes[node].children.is_empty() {
                return (accepted, sample(p, rng) as u32);
            }
            let q_nd = tree.nodes[node].q.as_ref().expect("q dist set");
            let q = q_nd.as_dense().expect("legacy baseline walks dense trees");
            let xs = tree.child_tokens(node);
            let y = solve(name, p, q, p_nd, q_nd, &xs, rng);
            match tree.child_with_token(node, y) {
                Some(child) => {
                    accepted.push(child);
                    node = child;
                }
                None => return (accepted, y),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Measurement
// ---------------------------------------------------------------------------

struct PathStats {
    us_per_verify: f64,
    allocs_per_verify: f64,
}

fn bench_path(iters: usize, mut f: impl FnMut(usize)) -> PathStats {
    // warm-up pass (fills scratch capacity, faults pages, trains branches)
    for i in 0..64.min(iters) {
        f(i);
    }
    let a0 = allocs();
    let t0 = Instant::now();
    for i in 0..iters {
        f(i);
    }
    let dt = t0.elapsed().as_secs_f64();
    let da = allocs() - a0;
    PathStats {
        us_per_verify: dt / iters as f64 * 1e6,
        allocs_per_verify: da as f64 / iters as f64,
    }
}

fn main() {
    let v = 259;
    let iters: usize = std::env::var("VERIFY_HOT_ITERS")
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(2000);
    let mut rng = Pcg64::seeded(1);
    let trees: Vec<DraftTree> = (0..64).map(|_| make_tree(&mut rng, v)).collect();
    let names = ["NSS", "Naive", "NaiveTree", "SpecTr", "SpecInfer", "Khisti", "BV", "Traversal"];
    let ot_names = ["NSS", "Naive", "NaiveTree", "SpecTr", "SpecInfer", "Khisti"];

    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>14} {:>10} {:>14}",
        "verifier", "us/legacy", "us/cold", "us/steady", "allocs/steady", "speedup", "us/branching"
    );

    let mut rows: Vec<(&str, Json)> = Vec::new();
    let mut speedups: Vec<f64> = Vec::new();

    for name in names {
        let ver = verify::verifier(name).unwrap();
        let is_ot = ot_names.contains(&name);

        // legacy (frozen pre-bootstrap walk; OT verifiers only)
        let legacy = if is_ot {
            let mut lrng = Pcg64::seeded(2);
            Some(bench_path(iters, |i| {
                let _ = legacy::verify_ot(name, &trees[i % trees.len()], &mut lrng);
            }))
        } else {
            None
        };

        // cold: fresh arena per call (allocating convenience entry)
        let mut crng = Pcg64::seeded(2);
        let cold = bench_path(iters, |i| {
            let _ = ver.verify(&trees[i % trees.len()], &mut crng);
        });

        // steady: warm arena + recycled verdict (serving configuration)
        let mut srng = Pcg64::seeded(2);
        let mut scratch = VerifyScratch::new();
        scratch.reserve(v, 16, 8);
        let mut verdict = Verdict::default();
        verdict.accepted.reserve(64);
        let steady = bench_path(iters, |i| {
            ver.verify_into(&trees[i % trees.len()], &mut srng, &mut scratch, &mut verdict);
        });

        // branching calculator (OT only), reused out-buffer
        let branching_us = if let Some(solver) = verify::ot_solver(name) {
            let mut brng = Pcg64::seeded(3);
            let p = specdelay::dist::NodeDist::from(random_dist(v, &mut brng, 2.0));
            let q = specdelay::dist::NodeDist::from(random_dist(v, &mut brng, 1.0));
            let xs: Vec<u32> = (0..4).map(|_| q.sample(&mut brng) as u32).collect();
            let mut out: Vec<f64> = Vec::new();
            let st = bench_path(iters, |_| {
                solver.branching_into(&p, &q, &xs, &mut out);
            });
            st.us_per_verify
        } else {
            f64::NAN
        };

        let speedup = legacy.as_ref().map(|l| l.us_per_verify / steady.us_per_verify);
        // Khisti's "legacy" arm is the current implementation (its coupling
        // construction never changed), so its ~1x ratio would only dilute
        // the optimized-verifier geomean — report it per-verifier, but keep
        // it out of the aggregate.
        if let Some(x) = speedup {
            if name != "Khisti" {
                speedups.push(x);
            }
        }

        println!(
            "{name:<12} {:>12} {:>12.2} {:>12.2} {:>14.3} {:>10} {:>14.2}",
            legacy
                .as_ref()
                .map(|l| format!("{:.2}", l.us_per_verify))
                .unwrap_or_else(|| "-".to_string()),
            cold.us_per_verify,
            steady.us_per_verify,
            steady.allocs_per_verify,
            speedup.map(|x| format!("{x:.2}x")).unwrap_or_else(|| "-".to_string()),
            branching_us,
        );

        let mut fields = vec![
            ("us_per_verify_cold", num(cold.us_per_verify)),
            ("us_per_verify", num(steady.us_per_verify)),
            ("allocs_per_verify", num(steady.allocs_per_verify)),
            ("allocs_per_verify_cold", num(cold.allocs_per_verify)),
        ];
        if let Some(l) = &legacy {
            fields.push(("us_per_verify_legacy", num(l.us_per_verify)));
            fields.push(("allocs_per_verify_legacy", num(l.allocs_per_verify)));
        }
        if let Some(x) = speedup {
            fields.push(("speedup_vs_legacy", num(x)));
        }
        if branching_us.is_finite() {
            fields.push(("us_per_branching", num(branching_us)));
        }
        rows.push((name, obj(fields)));
    }

    let geomean = if speedups.is_empty() {
        f64::NAN
    } else {
        (speedups.iter().map(|x| x.ln()).sum::<f64>() / speedups.len() as f64).exp()
    };
    println!("\nOT geomean speedup vs legacy (excl. Khisti): {geomean:.2}x");

    let report = obj(vec![
        ("schema", s("verify_hot/v1")),
        (
            "config",
            obj(vec![
                ("vocab", num(v as f64)),
                ("trees", num(64.0)),
                ("iters", num(iters as f64)),
                ("tree_shape", s("K=3 L1=2 L2=3 (12 nodes)")),
            ]),
        ),
        ("ot_geomean_speedup_vs_legacy", num(geomean)),
        ("verifiers", obj(rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_verify_hot.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("wrote {path}");
}
