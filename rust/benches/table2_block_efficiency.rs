//! Regenerates paper Table 2 (average block efficiency per family).
//! SPECDELAY_BENCH_SCALE=quick|std|full controls cost.
use specdelay::benchkit::{experiments, Scale};
fn main() {
    experiments::tables_2_3(Scale::from_env()).expect("table 2/3");
}
