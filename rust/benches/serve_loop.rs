//! Batched serving-loop benchmark on the CPU reference backend (pure rust,
//! no artifacts, no PJRT): aggregate tokens/s and block efficiency vs batch
//! size, per verifier, through `coordinator::ServeLoop`.
//!
//! Before anything is timed, every batched run's per-request token stream
//! is asserted equal to a serial `SpecEngine::generate` reference on the
//! same per-request rng stream — the bench aborts on any divergence, so
//! the numbers always describe the deterministic configuration the tests
//! validate.
//!
//! A second section benches the online dynamic selector
//! ([`ServeLoop::with_selector`] over [`SelectorConfig::with_default_arms`])
//! against every static (verifier × drafter × action) arm served
//! standalone: the selector's streams are equality-asserted against a
//! serial selector replay before timing, and the report carries
//! `block_efficiency_selector` vs `block_efficiency_best_static` plus
//! per-arm and per-drafter block counts.
//!
//! Emits a human-readable table and `BENCH_serve_loop.json` at the repo
//! root (uploaded as a CI artifact). Env knobs: `SERVE_LOOP_REQUESTS`
//! (default 8), `SERVE_LOOP_MAX_NEW` (default 48), `SERVE_LOOP_VERIFIERS`
//! (comma list, default `SpecInfer,Traversal`).
//!
//! Run: `cargo bench --bench serve_loop`.

use std::time::Instant;

use specdelay::coordinator::{FixedPolicy, ServeLoop, ServeRequest, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::{Action, DrafterKind};
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend};
use specdelay::selector::{ArmStats, OnlineSelector, SelectorConfig};
use specdelay::tokenizer;
use specdelay::util::json::{arr, num, obj, s, Json};
use specdelay::util::threadpool::default_workers;
use specdelay::util::Pcg64;
use specdelay::verify;

const PROMPTS: [&str; 4] = [
    "Q: 6 * 7 = ? A:",
    "story: the golden ",
    "fn add(a, b):",
    "translate en->fr: the sea => ",
];

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Serial replay of one selector-driven request (the equality oracle for
/// the batched selector runs — mirrors `tests/selector_serve.rs`).
fn serial_selector(
    backend: &CpuRefBackend,
    sampling: SamplingConfig,
    config: &SelectorConfig,
    prompt: &str,
    max_new: usize,
    seed: u64,
    id: u64,
) -> (String, Vec<ArmStats>) {
    let sel = OnlineSelector::new(config.clone()).expect("selector config");
    let spec = SpecEngine::new(backend, sampling);
    let mut seq = spec.start(prompt).expect("prefill");
    let mut rng = Pcg64::new(seed, id);
    let mut sel_rng = Pcg64::new(config.seed, id);
    let mut tally = vec![ArmStats::default(); config.arms.len()];
    while !seq.finished && seq.tokens.len() - seq.prompt_len < max_new {
        let i = {
            let f = spec.root_features(&mut seq).expect("root features");
            let feats = f.as_features(&seq, sampling);
            sel.choose(&feats, &mut sel_rng).expect("active selector")
        };
        let arm = &sel.arms()[i];
        let b = spec
            .step_drafted(&mut seq, sel.verifier(i), arm.action, arm.drafter, &mut rng)
            .expect("selector step");
        tally[i].record(b.tree_nodes.saturating_sub(1), b.accepted, b.emitted);
    }
    (tokenizer::decode(&seq.tokens[seq.prompt_len..]), tally)
}

fn main() {
    let requests = env_usize("SERVE_LOOP_REQUESTS", 8);
    let max_new = env_usize("SERVE_LOOP_MAX_NEW", 48);
    let verifier_names: Vec<String> = std::env::var("SERVE_LOOP_VERIFIERS")
        .unwrap_or_else(|_| "SpecInfer,Traversal".to_string())
        .split(',')
        .map(|x| x.trim().to_string())
        .filter(|x| !x.is_empty())
        .collect();
    let batches = [1usize, 2, 4, 8];

    let cfg = CpuModelConfig::small();
    let backend = CpuRefBackend::new(&cfg, 0);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let action = Action::new(2, 2, 3);
    let policy = FixedPolicy(action);
    let seed = 42u64;

    println!(
        "{:<12} {:>6} {:>10} {:>12} {:>12} {:>14}",
        "verifier", "batch", "tokens", "wall_secs", "tokens/s", "block_eff"
    );

    let mut equal_output_checks = 0usize;
    let mut vrows: Vec<(&str, Json)> = Vec::new();
    for vname in &verifier_names {
        let verifier = verify::verifier(vname).expect("unknown verifier");

        // serial reference streams: the equality oracle (untimed)
        let spec = SpecEngine::new(&backend, sampling);
        let mut ref_texts = Vec::with_capacity(requests);
        for id in 0..requests {
            let mut rng = Pcg64::new(seed, id as u64);
            let (text, _stats) = spec
                .generate(PROMPTS[id % PROMPTS.len()], max_new, verifier.as_ref(), &policy, &mut rng)
                .expect("serial generate");
            ref_texts.push(text);
        }

        let mut brows: Vec<Json> = Vec::new();
        let mut tps_batch1 = f64::NAN;
        for &batch in &batches {
            let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, batch);
            for id in 0..requests {
                srv.submit(ServeRequest::new(PROMPTS[id % PROMPTS.len()].to_string(), max_new, seed));
            }
            let t0 = Instant::now();
            let outs = srv.run().expect("serve loop");
            let wall = t0.elapsed().as_secs_f64();
            // equal-output assertion before any number is recorded
            assert_eq!(outs.len(), ref_texts.len());
            for (o, want) in outs.iter().zip(&ref_texts) {
                assert!(o.error.is_none(), "lane {} failed: {:?}", o.id, o.error);
                assert_eq!(
                    &o.text, want,
                    "{vname} batch {batch} id {}: batched stream diverged from serial",
                    o.id
                );
                equal_output_checks += 1;
            }
            let tokens: usize = outs.iter().map(|o| o.stats.tokens).sum();
            let blocks: usize = outs.iter().map(|o| o.stats.blocks).sum();
            let block_eff = tokens as f64 / blocks.max(1) as f64;
            let tps = tokens as f64 / wall.max(1e-12);
            if batch == 1 {
                tps_batch1 = tps;
            }
            println!(
                "{vname:<12} {batch:>6} {tokens:>10} {wall:>12.3} {tps:>12.1} {block_eff:>14.2}"
            );
            brows.push(obj(vec![
                ("batch", num(batch as f64)),
                ("requests", num(requests as f64)),
                ("tokens", num(tokens as f64)),
                ("wall_secs", num(wall)),
                ("tokens_per_sec", num(tps)),
                ("block_efficiency", num(block_eff)),
                ("speedup_vs_batch1", num(tps / tps_batch1)),
            ]));
        }
        vrows.push((vname.as_str(), obj(vec![("batches", arr(brows))])));
    }

    // ---- dynamic selector vs the best static arm ----
    let sel_cfg = SelectorConfig::with_default_arms();
    // serial selector oracle: reference streams + expected priors (untimed)
    let mut sel_ref = Vec::with_capacity(requests);
    let mut want_priors = vec![ArmStats::default(); sel_cfg.arms.len()];
    for id in 0..requests {
        let (text, tally) = serial_selector(
            &backend,
            sampling,
            &sel_cfg,
            PROMPTS[id % PROMPTS.len()],
            max_new,
            seed,
            id as u64,
        );
        for (w, t) in want_priors.iter_mut().zip(&tally) {
            w.merge(t);
        }
        sel_ref.push(text);
    }

    // selector-driven batched runs, equality-asserted before timing
    let fb_verifier = verify::verifier("SpecInfer").expect("verifier");
    let mut sel_rows: Vec<Json> = Vec::new();
    let mut sel_tokens = 0usize;
    let mut sel_blocks = 0usize;
    for &batch in &batches {
        let mut srv = ServeLoop::new(&backend, sampling, fb_verifier.as_ref(), &policy, batch)
            .with_selector(sel_cfg.clone());
        for id in 0..requests {
            srv.submit(ServeRequest::new(PROMPTS[id % PROMPTS.len()].to_string(), max_new, seed));
        }
        let t0 = Instant::now();
        let outs = srv.run().expect("selector serve loop");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), sel_ref.len());
        for (o, want) in outs.iter().zip(&sel_ref) {
            assert!(o.error.is_none(), "selector lane {} failed: {:?}", o.id, o.error);
            assert_eq!(
                &o.text, want,
                "selector batch {batch} id {}: batched stream diverged from serial",
                o.id
            );
            equal_output_checks += 1;
        }
        assert_eq!(
            srv.selector_priors().arms,
            want_priors,
            "selector batch {batch}: calibrated priors diverged from the serial tallies"
        );
        let tokens: usize = outs.iter().map(|o| o.stats.tokens).sum();
        let blocks: usize = outs.iter().map(|o| o.stats.blocks).sum();
        sel_tokens = tokens;
        sel_blocks = blocks;
        let block_eff = tokens as f64 / blocks.max(1) as f64;
        let tps = tokens as f64 / wall.max(1e-12);
        println!(
            "{:<12} {batch:>6} {tokens:>10} {wall:>12.3} {tps:>12.1} {block_eff:>14.2}",
            "selector"
        );
        sel_rows.push(obj(vec![
            ("batch", num(batch as f64)),
            ("tokens", num(tokens as f64)),
            ("wall_secs", num(wall)),
            ("tokens_per_sec", num(tps)),
            ("block_efficiency", num(block_eff)),
        ]));
    }
    let block_eff_selector = sel_tokens as f64 / sel_blocks.max(1) as f64;

    // every selector arm served standalone as a static configuration
    let mut best_static = f64::MIN;
    let mut best_static_arm = String::new();
    let mut drafter_blocks = [0u64; 3];
    let mut arm_rows: Vec<Json> = Vec::new();
    for (arm, prior) in sel_cfg.arms.iter().zip(&want_priors) {
        let v = verify::verifier(&arm.verifier).expect("arm verifier");
        let sp = SpecEngine::new(&backend, sampling).with_drafter(arm.drafter);
        let pol = FixedPolicy(arm.action);
        let (mut tokens, mut blocks) = (0usize, 0usize);
        for id in 0..requests {
            let mut rng = Pcg64::new(seed, id as u64);
            let (_text, st) = sp
                .generate(PROMPTS[id % PROMPTS.len()], max_new, v.as_ref(), &pol, &mut rng)
                .expect("static arm generate");
            tokens += st.tokens;
            blocks += st.blocks;
        }
        let be = tokens as f64 / blocks.max(1) as f64;
        let label = format!(
            "{}/{} K={} L1={} L2={}",
            arm.verifier,
            arm.drafter.name(),
            arm.action.k,
            arm.action.l1,
            arm.action.l2
        );
        if be > best_static {
            best_static = be;
            best_static_arm = label.clone();
        }
        drafter_blocks[arm.drafter.index()] += prior.blocks;
        arm_rows.push(obj(vec![
            ("arm", s(&label)),
            ("static_block_efficiency", num(be)),
            ("selector_blocks", num(prior.blocks as f64)),
            ("selector_drafted", num(prior.drafted as f64)),
            ("selector_accepted", num(prior.accepted as f64)),
            ("selector_emitted", num(prior.emitted as f64)),
        ]));
    }
    println!(
        "-- selector block efficiency {block_eff_selector:.3} vs best static {best_static:.3} ({best_static_arm})"
    );

    let report = obj(vec![
        ("schema", s("serve_loop/v2")),
        (
            "config",
            obj(vec![
                ("backend", s("cpu-ref")),
                ("family", s(&backend.meta().family)),
                ("n_layers", num(cfg.n_layers as f64)),
                ("d_model", num(cfg.d_model as f64)),
                ("vocab", num(cfg.vocab as f64)),
                ("requests", num(requests as f64)),
                ("max_new", num(max_new as f64)),
                ("temperature", num(sampling.temperature as f64)),
                ("top_p", num(sampling.top_p as f64)),
                ("action", s(&format!("K={} L1={} L2={}", action.k, action.l1, action.l2))),
                ("machine_workers", num(default_workers() as f64)),
            ]),
        ),
        ("equal_output_checks", num(equal_output_checks as f64)),
        ("equal_output_assertion", s("enabled")),
        ("verifiers", obj(vrows)),
        (
            "selector",
            obj(vec![
                ("epsilon", num(sel_cfg.epsilon as f64)),
                ("seed", num(sel_cfg.seed as f64)),
                ("block_efficiency_selector", num(block_eff_selector)),
                ("block_efficiency_best_static", num(best_static)),
                ("best_static_arm", s(&best_static_arm)),
                ("arms", arr(arm_rows)),
                (
                    "drafter_blocks",
                    obj(DrafterKind::ALL
                        .into_iter()
                        .map(|k| (k.name(), num(drafter_blocks[k.index()] as f64)))
                        .collect()),
                ),
                ("batches", arr(sel_rows)),
            ]),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_loop.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("wrote {path}");
}
