//! Fault-injection recovery benchmark on the CPU reference backend: what
//! does resilience cost when nothing fails, and what does recovery cost
//! when things do?
//!
//! Four measurements through `coordinator::ServeLoop`:
//!
//! * **baseline** — resilience off, plain backend (the `serve_loop` bench's
//!   configuration);
//! * **checkpoint overhead** — resilience on, a quiet fault plan (rate 0):
//!   the pure cost of per-tick `(Sequence, rng)` checkpointing, reported as
//!   a ratio vs baseline;
//! * **fault sweep** — resilience on at fault rates {0, 1e-3, 1e-2}
//!   (transient at the rate, corruption at half of it): aggregate tokens/s
//!   plus a p99 per-token latency estimate. Before any number is recorded,
//!   every completed stream is asserted bit-identical to the fault-free
//!   serial oracle — the numbers always describe lossless recovery, never
//!   silently-divergent streams;
//! * **degraded mode** — the speculative path faulting at rate 1.0, so the
//!   circuit breaker pins lanes to autoregressive decode: the graceful-
//!   degradation throughput floor.
//!
//! The p99 per-token latency is estimated over the distribution of
//! per-request mean token latencies (request wall / tokens emitted) — with
//! per-block scheduling the loop does not observe individual token
//! timestamps, and the per-request mean is the serving-visible quantity.
//!
//! Emits a table and `BENCH_fault_recovery.json` at the repo root
//! (uploaded as a CI artifact). Env knobs: `FAULT_RECOVERY_REQUESTS`
//! (default 8), `FAULT_RECOVERY_MAX_NEW` (default 32).
//!
//! Run: `cargo bench --bench fault_recovery`.

use std::time::Instant;

use specdelay::coordinator::{
    FixedPolicy, ResilienceConfig, ServeLoop, ServeOutput, ServeRequest, SpecEngine,
};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::runtime::{
    Backend, CpuModelConfig, CpuRefBackend, FaultOp, FaultPlan, FaultyBackend,
};
use specdelay::util::json::{arr, num, obj, s, Json};
use specdelay::util::threadpool::default_workers;
use specdelay::util::Pcg64;
use specdelay::verify;

const PROMPTS: [&str; 4] = [
    "Q: 6 * 7 = ? A:",
    "story: the golden ",
    "fn add(a, b):",
    "translate en->fr: the sea => ",
];

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Resilience with the health machine effectively disabled: every
/// completed stream stays on the speculative (bit-identical) path.
fn retry_only() -> ResilienceConfig {
    ResilienceConfig {
        max_retries: 50,
        deadline: None,
        degrade_after: usize::MAX / 2,
        fail_after: usize::MAX / 2,
        probe_interval: 4,
    }
}

/// p99 of per-request mean token latency (seconds/token), estimated over
/// the request distribution (see the module docs).
fn p99_token_latency(outs: &[ServeOutput]) -> f64 {
    let mut per_req: Vec<f64> = outs
        .iter()
        .filter(|o| o.stats.tokens > 0)
        .map(|o| o.stats.wall_secs / o.stats.tokens as f64)
        .collect();
    if per_req.is_empty() {
        return f64::NAN;
    }
    per_req.sort_by(|a, b| a.total_cmp(b));
    let idx = ((per_req.len() as f64) * 0.99).ceil() as usize;
    per_req[idx.clamp(1, per_req.len()) - 1]
}

struct RunResult {
    tokens: usize,
    wall: f64,
    tps: f64,
    p99: f64,
    retries: usize,
    faults: usize,
    degraded_lanes: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_loop(
    backend: &dyn Backend,
    sampling: SamplingConfig,
    verifier: &dyn specdelay::verify::Verifier,
    policy: &FixedPolicy,
    batch: usize,
    requests: usize,
    max_new: usize,
    seed: u64,
    resilience: Option<ResilienceConfig>,
    oracle: Option<&[String]>,
) -> RunResult {
    let mut srv = ServeLoop::new(backend, sampling, verifier, policy, batch);
    if let Some(cfg) = resilience {
        srv = srv.with_resilience(cfg);
    }
    for id in 0..requests {
        srv.submit(ServeRequest::new(PROMPTS[id % PROMPTS.len()].to_string(), max_new, seed));
    }
    let t0 = Instant::now();
    let outs = srv.run().expect("serve loop");
    let wall = t0.elapsed().as_secs_f64();
    // equal-output assertion before any number is recorded
    for o in &outs {
        assert!(o.error.is_none(), "lane {} failed: {:?}", o.id, o.error);
    }
    if let Some(want) = oracle {
        for (o, w) in outs.iter().zip(want) {
            assert!(!o.degraded, "lane {} degraded in a lossless-path run", o.id);
            assert_eq!(
                &o.text, w,
                "lane {}: recovered stream diverged from the fault-free oracle",
                o.id
            );
        }
    }
    let tokens: usize = outs.iter().map(|o| o.stats.tokens).sum();
    let rc = srv.recovery();
    RunResult {
        tokens,
        wall,
        tps: tokens as f64 / wall.max(1e-12),
        p99: p99_token_latency(&outs),
        retries: rc.retries,
        faults: rc.transient_seen + rc.corrupt_seen + rc.panics,
        degraded_lanes: outs.iter().filter(|o| o.degraded).count(),
    }
}

fn main() {
    let requests = env_usize("FAULT_RECOVERY_REQUESTS", 8);
    let max_new = env_usize("FAULT_RECOVERY_MAX_NEW", 32);
    let batch = 4usize;
    let seed = 42u64;

    let cfg = CpuModelConfig::small();
    let backend = CpuRefBackend::new(&cfg, 0);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let action = Action::new(2, 2, 3);
    let policy = FixedPolicy(action);
    let verifier = verify::verifier("SpecInfer").expect("verifier");

    // fault-free serial oracle streams (untimed)
    let spec = SpecEngine::new(&backend, sampling);
    let mut oracle = Vec::with_capacity(requests);
    for id in 0..requests {
        let mut rng = Pcg64::new(seed, id as u64);
        let (text, _stats) = spec
            .generate(PROMPTS[id % PROMPTS.len()], max_new, verifier.as_ref(), &policy, &mut rng)
            .expect("serial generate");
        oracle.push(text);
    }

    println!(
        "{:<24} {:>10} {:>12} {:>12} {:>14} {:>9} {:>9}",
        "mode", "tokens", "wall_secs", "tokens/s", "p99_tok_ms", "faults", "retries"
    );
    let print_row = |label: &str, r: &RunResult| {
        println!(
            "{label:<24} {:>10} {:>12.3} {:>12.1} {:>14.3} {:>9} {:>9}",
            r.tokens,
            r.wall,
            r.tps,
            r.p99 * 1e3,
            r.faults,
            r.retries
        );
    };

    // baseline: resilience off, plain backend
    let base = run_loop(
        &backend, sampling, verifier.as_ref(), &policy, batch, requests, max_new, seed, None,
        Some(&oracle),
    );
    print_row("baseline", &base);

    // checkpoint overhead: resilience on, quiet plan (rate 0)
    let quiet = FaultyBackend::new(&backend, FaultPlan::quiet(7));
    let ckpt = run_loop(
        &quiet, sampling, verifier.as_ref(), &policy, batch, requests, max_new, seed,
        Some(retry_only()), Some(&oracle),
    );
    print_row("resilient rate=0", &ckpt);
    let overhead = base.tps / ckpt.tps.max(1e-12);

    // fault sweep
    let rates = [0.0f64, 1e-3, 1e-2];
    let mut rate_rows: Vec<Json> = Vec::new();
    for &rate in &rates {
        let plan = FaultPlan::quiet(0xFA17).with_transient(rate).with_corrupt(rate / 2.0);
        let fb = FaultyBackend::new(&backend, plan);
        let r = run_loop(
            &fb, sampling, verifier.as_ref(), &policy, batch, requests, max_new, seed,
            Some(retry_only()), Some(&oracle),
        );
        print_row(&format!("resilient rate={rate}"), &r);
        rate_rows.push(obj(vec![
            ("fault_rate", num(rate)),
            ("tokens", num(r.tokens as f64)),
            ("wall_secs", num(r.wall)),
            ("tokens_per_sec", num(r.tps)),
            ("p99_token_latency_secs", num(r.p99)),
            ("faults", num(r.faults as f64)),
            ("retries", num(r.retries as f64)),
            ("recovery_overhead_vs_baseline", num(base.tps / r.tps.max(1e-12))),
        ]));
    }

    // degraded mode: speculative path permanently down, AR fallback serves
    let plan = FaultPlan::quiet(5)
        .with_transient(1.0)
        .with_ops(vec![FaultOp::Rollout, FaultOp::TreeVerify]);
    let fb = FaultyBackend::new(&backend, plan);
    let degraded_cfg = ResilienceConfig {
        max_retries: 4,
        deadline: None,
        degrade_after: 2,
        fail_after: usize::MAX / 2,
        probe_interval: 0,
    };
    let deg = run_loop(
        &fb, sampling, verifier.as_ref(), &policy, batch, requests, max_new, seed,
        Some(degraded_cfg), None,
    );
    assert!(
        deg.degraded_lanes == requests,
        "every lane should degrade at rate 1.0 ({} of {requests} did)",
        deg.degraded_lanes
    );
    print_row("degraded (AR fallback)", &deg);

    println!("checkpoint overhead ratio (baseline tps / resilient rate=0 tps): {overhead:.3}");
    println!(
        "degraded-mode throughput: {:.1} tok/s ({:.2}x baseline)",
        deg.tps,
        deg.tps / base.tps.max(1e-12)
    );

    let row = |r: &RunResult| {
        obj(vec![
            ("tokens", num(r.tokens as f64)),
            ("wall_secs", num(r.wall)),
            ("tokens_per_sec", num(r.tps)),
            ("p99_token_latency_secs", num(r.p99)),
            ("faults", num(r.faults as f64)),
            ("retries", num(r.retries as f64)),
        ])
    };
    let report = obj(vec![
        ("schema", s("fault_recovery/v1")),
        (
            "config",
            obj(vec![
                ("backend", s("cpu-ref")),
                ("family", s(&backend.meta().family)),
                ("n_layers", num(cfg.n_layers as f64)),
                ("d_model", num(cfg.d_model as f64)),
                ("vocab", num(cfg.vocab as f64)),
                ("requests", num(requests as f64)),
                ("max_new", num(max_new as f64)),
                ("batch", num(batch as f64)),
                ("temperature", num(sampling.temperature as f64)),
                ("top_p", num(sampling.top_p as f64)),
                ("action", s(&format!("K={} L1={} L2={}", action.k, action.l1, action.l2))),
                ("machine_workers", num(default_workers() as f64)),
            ]),
        ),
        ("equal_output_assertion", s("enabled")),
        ("baseline", row(&base)),
        ("resilient_quiet", row(&ckpt)),
        ("checkpoint_overhead_ratio", num(overhead)),
        ("fault_rates", arr(rate_rows)),
        (
            "degraded",
            obj(vec![
                ("tokens", num(deg.tokens as f64)),
                ("wall_secs", num(deg.wall)),
                ("tokens_per_sec", num(deg.tps)),
                ("p99_token_latency_secs", num(deg.p99)),
                ("throughput_vs_baseline", num(deg.tps / base.tps.max(1e-12))),
                ("degraded_lanes", num(deg.degraded_lanes as f64)),
            ]),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fault_recovery.json");
    std::fs::write(path, format!("{}\n", report.to_string_pretty())).expect("write bench json");
    println!("wrote {path}");
}
