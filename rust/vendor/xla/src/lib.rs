//! Stub of the PJRT/XLA client API consumed by `specdelay::runtime`.
//!
//! The offline build environment cannot link a real PJRT plugin, so this
//! crate provides the exact type/method surface the runtime layer compiles
//! against. Every constructor returns an error ("no PJRT backend linked"),
//! and all post-construction types are uninhabited, so the stub can never
//! silently produce wrong results: code paths beyond client creation are
//! statically unreachable. Swapping this path dependency for a real `xla`
//! crate (with identical method names) enables actual model execution.

/// Uninhabited marker: values of stub device types cannot exist.
#[derive(Clone, Copy, Debug)]
enum Never {}

/// Error type mirroring the real crate's debug-printable error.
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: no PJRT backend linked (specdelay built against the offline xla stub; \
         see rust/README.md for enabling a real backend)"
    ))
}

/// PJRT client handle (stub: creation always fails).
pub struct PjRtClient {
    _p: Never,
}

impl PjRtClient {
    /// CPU client constructor — always errors in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        match self._p {}
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match self._p {}
    }
}

/// Device-resident buffer (stub: uninhabited).
pub struct PjRtBuffer {
    _p: Never,
}

impl PjRtBuffer {
    /// Fetch the buffer contents back to the host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match self._p {}
    }
}

/// Compiled executable (stub: uninhabited).
pub struct PjRtLoadedExecutable {
    _p: Never,
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers; returns per-device outputs.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self._p {}
    }
}

/// Parsed HLO module proto (stub: parsing always fails).
pub struct HloModuleProto {
    _p: Never,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a module proto.
pub struct XlaComputation {
    _p: Never,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto._p {}
    }
}

/// Host-side literal value (stub: uninhabited).
pub struct Literal {
    _p: Never,
}

impl Literal {
    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        match self._p {}
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        match self._p {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_missing_backend() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.0.contains("no PJRT backend"));
        let e = HloModuleProto::from_text_file("x.hlo.txt").err().expect("stub must fail");
        assert!(e.0.contains("no PJRT backend"));
    }
}
