//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so this path crate provides
//! the (small) subset of the real `anyhow` API the workspace uses: the
//! [`Error`] type, the [`Result`] alias, the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!`/`bail!` macros. Error chains are
//! flattened into a single message at attachment time, so `{e}` and `{e:#}`
//! both print `context: cause` the way downstream code expects.
//!
//! Like the real crate, [`Error::new`] additionally retains the source
//! error value so callers can recover it with [`Error::downcast_ref`]
//! (the serving loop classifies backend dispatch faults this way). The
//! blanket `?` conversion and the [`Context`] trait still flatten to a
//! message — only errors raised explicitly through `Error::new` carry a
//! typed payload, and [`Error::context`] preserves it.

use std::any::Any;
use std::fmt;

/// A flattened, message-carrying error value.
pub struct Error {
    msg: String,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), payload: None }
    }

    /// Build an error from a concrete error value, retaining it for
    /// [`Error::downcast_ref`] (the real anyhow's `Error::new`).
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: e.to_string(), payload: Some(Box::new(e)) }
    }

    /// Prepend a context layer, `context: cause` style. The typed payload
    /// (when present) survives context attachment.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), payload: self.payload }
    }

    /// The retained source error, if this error was built with
    /// [`Error::new`] from a value of type `T`.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref().and_then(|p| p.downcast_ref::<T>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversion from any std error. `Error` itself deliberately does not
// implement `std::error::Error`, exactly like the real anyhow, so this
// blanket impl cannot collide with the reflexive `From<Error> for Error`.
// Flattens to a message: use `Error::new` when the value must survive for
// downcasting.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `std::result::Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}"), payload: None })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()), payload: None })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn macro_forms() {
        let name = "x";
        let a = anyhow!("plain");
        let b = anyhow!("with {name} capture");
        let c = anyhow!("positional {}", 3);
        let d = anyhow!(String::from("owned"));
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "with x capture");
        assert_eq!(c.to_string(), "positional 3");
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");

        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(5u32).context("never").unwrap(), 5);
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn new_retains_payload_for_downcast() {
        let e = Error::new(io_err());
        assert_eq!(e.to_string(), "boom");
        let io = e.downcast_ref::<std::io::Error>().expect("payload retained");
        assert_eq!(io.kind(), std::io::ErrorKind::Other);
        assert!(e.downcast_ref::<fmt::Error>().is_none());
    }

    #[test]
    fn context_preserves_payload() {
        let e = Error::new(io_err()).context("during dispatch");
        assert_eq!(e.to_string(), "during dispatch: boom");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn msg_and_blanket_conversion_have_no_payload() {
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
        let via_from: Error = io_err().into();
        assert!(via_from.downcast_ref::<std::io::Error>().is_none());
    }
}
