//! Drafting policies: (K, L1, L2) delayed-tree construction (paper
//! Definition 5.2) over the fused [`Backend::rollout`] entry points.
//!
//! A delayed tree needs at most two backend dispatches: one trunk rollout
//! (single path, exact compiled length) and one branch rollout (K paths,
//! bucketed length, truncated to L2). Root-node i.i.d. multipath (paper
//! §3.2) is the L1 = 0 special case; single-path drafting is K ≤ 1 or
//! L2 = 0.
//!
//! The [`Drafter`] trait is the seam the serving loop dispatches through:
//! every implementation shares the same rollout dispatches, the same
//! [`DraftScratch`] handoff contract, and — critically — the same
//! losslessness construction (tokens sampled through [`Backend::rollout`]
//! from rng-consumed uniforms, with the proposal recorded per node via
//! [`NodeDist::from_probs`]), so only the tree *shape* differs between
//! drafters and every verifier stays exact over all of them.

use anyhow::Result;

use crate::dist::{DistStorage, NodeDist, SamplingConfig};
use crate::kvcache::KvCache;
use crate::runtime::{guard_finite, Backend, FamilyMeta, FaultOp, RolloutOut};
use crate::tree::{DraftTree, PathDraws, Provenance};
use crate::util::Pcg64;

/// A delayed-expansion action a = (K, L1, L2) from the paper's action space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Action {
    /// Branch count K (K ≤ 1 means single path).
    pub k: usize,
    /// Trunk (delay) length L1.
    pub l1: usize,
    /// Branch length L2.
    pub l2: usize,
}

impl Action {
    /// Build an action from its (K, L1, L2) components.
    pub fn new(k: usize, l1: usize, l2: usize) -> Action {
        Action { k, l1, l2 }
    }

    /// Canonicalize: K=1 trees are single paths (trunk only, capped at the
    /// longest compiled trunk); L2 = 0 likewise. Branching actions cap L1
    /// at the longest compiled trunk too — the trunk rollout has no longer
    /// entry point, and the serving loop's worst-case block reservation
    /// (`ServeLoop::with_block_budget`) relies on `l1 ≤ max_trunk` holding
    /// for every normalized action.
    pub fn normalized(self, max_trunk: usize) -> Action {
        if self.k <= 1 || self.l2 == 0 {
            Action { k: 1, l1: (self.l1 + self.l2).min(max_trunk), l2: 0 }
        } else {
            Action { k: self.k.min(4), l1: self.l1.min(max_trunk), l2: self.l2 }
        }
    }

    /// Number of tree nodes including the root.
    pub fn nodes(&self) -> usize {
        1 + self.l1 + if self.k > 1 { self.k * self.l2 } else { 0 }
    }
}

/// Reusable drafting scratch (the `VerifyScratch` convention): the
/// branch-rollout handoff cache trunk rows are committed into. Create one
/// per sequence and reuse it across blocks — after the first trunk+branch
/// block the cache is warm and steady-state drafting performs no
/// cache-sized allocations. The handoff cache inherits the sequence
/// cache's storage ([`KvCache::new_like`]): with paged storage the prefix
/// refresh is a copy-on-write fork (refcount bumps) instead of a physical
/// prefix copy, and only the trunk's own blocks ever diverge.
#[derive(Clone, Default)]
pub struct DraftScratch {
    branch_kv: Option<KvCache>,
}

impl DraftScratch {
    /// The handoff cache, once a trunk+branch block has warmed it (bench /
    /// test introspection hook for prefix-sharing measurements).
    pub fn branch_cache(&self) -> Option<&KvCache> {
        self.branch_kv.as_ref()
    }
}

/// Drafting output: the merged tree plus raw rollout tensors for KV commits.
pub struct Drafted {
    /// The merged delayed tree (node 0 = root).
    pub tree: DraftTree,
    /// Raw trunk rollout output (None when L1 = 0).
    pub trunk: Option<RolloutOut>,
    /// Raw branch rollout output (None for single-path actions).
    pub branch: Option<RolloutOut>,
    /// Node index the branches attach to: the trunk end for delayed trees,
    /// the root for root-branching and greedy trees.
    pub branch_point: usize,
    /// Offset of the branch rollout's start position past `root_pos`: L1
    /// for delayed trees, 0 when the branches start at the root. KV
    /// commits of branch rows are based at `root_pos + branch_start`.
    pub branch_start: usize,
}

/// Which drafting policy shapes the tree (CLI `--drafter`, server wire
/// field `"drafter"`). All kinds are lossless: they share the rollout +
/// proposal-recording construction and differ only in tree shape.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DrafterKind {
    /// Delayed tree expansion (paper Definition 5.2): an L1 trunk, then K
    /// branches of L2 attached at the trunk end.
    #[default]
    Delayed,
    /// Classic i.i.d. root branching (paper §3.2): K independent paths
    /// drawn from the root; the requested L1 budget folds into the path
    /// length.
    Root,
    /// Greedy multi-path: one trunk of L1 *and* K branches of L2, all
    /// starting at the root — the undelayed counterpart of `Delayed` with
    /// the same node budget.
    Greedy,
}

impl DrafterKind {
    /// Every drafter kind, in CLI order.
    pub const ALL: [DrafterKind; 3] = [DrafterKind::Delayed, DrafterKind::Root, DrafterKind::Greedy];

    /// Wire/CLI name (`"delayed"` / `"root"` / `"greedy"`).
    pub fn name(self) -> &'static str {
        match self {
            DrafterKind::Delayed => "delayed",
            DrafterKind::Root => "root",
            DrafterKind::Greedy => "greedy",
        }
    }

    /// Parse a wire/CLI name; `None` for unknown names.
    pub fn parse(s: &str) -> Option<DrafterKind> {
        DrafterKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// The (stateless) drafter implementing this kind.
    pub fn drafter(self) -> &'static dyn Drafter {
        match self {
            DrafterKind::Delayed => &DelayedDrafter,
            DrafterKind::Root => &RootDrafter,
            DrafterKind::Greedy => &GreedyDrafter,
        }
    }

    /// Stable index into per-drafter counter arrays (= position in
    /// [`DrafterKind::ALL`]).
    pub fn index(self) -> usize {
        match self {
            DrafterKind::Delayed => 0,
            DrafterKind::Root => 1,
            DrafterKind::Greedy => 2,
        }
    }
}

/// A drafting policy: shapes a requested action onto its own tree geometry
/// and drafts the tree over the shared [`DraftScratch`]/`KvRef` handoff
/// contract. Implementations are stateless unit structs dispatched through
/// [`DrafterKind::drafter`].
pub trait Drafter: Send + Sync {
    /// Wire/CLI name of this drafter.
    fn name(&self) -> &'static str;

    /// Whether branches attach at the root (independent of the trunk)
    /// rather than at the trunk end.
    fn branches_at_root(&self) -> bool;

    /// Map a requested (K, L1, L2) action onto this drafter's geometry.
    /// The result is a fixed point of itself, never drafts deeper than the
    /// normalized input's `l1 + l2` (the serving loop's context-window
    /// reservation bound), and always fits the compiled rollout and tree
    /// buckets.
    fn shape(&self, action: Action, meta: &FamilyMeta) -> Action;

    /// Draft a tree for an already-[`Drafter::shape`]d action. The default
    /// body is the shared generalized construction; `shaped` must come
    /// from this drafter's `shape`.
    #[allow(clippy::too_many_arguments)]
    fn draft(
        &self,
        engine: &dyn Backend,
        draft_kv: &KvCache,
        root_token: u32,
        root_pos: usize,
        shaped: Action,
        sampling: SamplingConfig,
        scratch: &mut DraftScratch,
        rng: &mut Pcg64,
    ) -> Result<Drafted> {
        draft_tree(
            engine,
            draft_kv,
            root_token,
            root_pos,
            shaped,
            sampling,
            scratch,
            rng,
            self.branches_at_root(),
        )
    }
}

fn max_trunk(meta: &FamilyMeta) -> usize {
    meta.trunk_lens.iter().copied().max().unwrap_or(8)
}

/// Delayed tree expansion (the repo's original drafter): trunk from the
/// root, branches attached at the trunk end, branch rollout run off the
/// reusable handoff cache.
pub struct DelayedDrafter;

impl Drafter for DelayedDrafter {
    fn name(&self) -> &'static str {
        "delayed"
    }
    fn branches_at_root(&self) -> bool {
        false
    }
    fn shape(&self, action: Action, meta: &FamilyMeta) -> Action {
        action.normalized(max_trunk(meta))
    }
}

/// Classic i.i.d. root branching: K independent paths from the root, no
/// trunk. The requested L1 budget folds into the branch length (clamped to
/// the longest compiled branch bucket), so a root-shaped action never
/// exceeds the requested depth or node budget.
pub struct RootDrafter;

impl Drafter for RootDrafter {
    fn name(&self) -> &'static str {
        "root"
    }
    fn branches_at_root(&self) -> bool {
        true
    }
    fn shape(&self, action: Action, meta: &FamilyMeta) -> Action {
        let n = action.normalized(max_trunk(meta));
        if n.k <= 1 {
            return n;
        }
        let max_branch = meta.branch_lens.iter().copied().max().unwrap_or(8);
        Action { k: n.k, l1: 0, l2: (n.l1 + n.l2).min(max_branch) }
    }
}

/// Greedy multi-path: the normalized delayed action's trunk *and* branches,
/// but with the branches starting at the root (no delay), so the trunk and
/// each branch are K+1 independent path draws over the same node budget.
pub struct GreedyDrafter;

impl Drafter for GreedyDrafter {
    fn name(&self) -> &'static str {
        "greedy"
    }
    fn branches_at_root(&self) -> bool {
        true
    }
    fn shape(&self, action: Action, meta: &FamilyMeta) -> Action {
        action.normalized(max_trunk(meta))
    }
}

/// Draft a delayed tree from the current draft KV cache by issuing the
/// fused rollout dispatches on any [`Backend`].
///
/// Back-compat wrapper over [`DelayedDrafter`]: normalizes the action and
/// runs the shared construction with delayed geometry. `root_token` is the
/// last committed token at position `root_pos`; the draft cache must hold
/// valid rows for positions < root_pos.
#[allow(clippy::too_many_arguments)]
pub fn draft_delayed(
    engine: &dyn Backend,
    draft_kv: &KvCache,
    root_token: u32,
    root_pos: usize,
    action: Action,
    sampling: SamplingConfig,
    scratch: &mut DraftScratch,
    rng: &mut Pcg64,
) -> Result<Drafted> {
    let a = DelayedDrafter.shape(action, &engine.meta());
    DelayedDrafter.draft(engine, draft_kv, root_token, root_pos, a, sampling, scratch, rng)
}

/// The shared drafting construction behind every [`Drafter`]: at most one
/// trunk rollout (single path, exact length) plus one branch rollout (K
/// paths, bucketed length, truncated to L2). With `branch_at_root` false
/// the branches attach at the trunk end and the trunk's freshly drafted KV
/// rows are committed into `scratch`'s reusable handoff cache before the
/// branch rollout (the fused rollout only carries its *own* path's rows,
/// and the branch paths start l1 positions past the committed prefix) —
/// with a warm scratch the handoff allocates nothing. With `branch_at_root`
/// true the branches run off `draft_kv` directly (their prefix is the
/// committed context, no trunk rows needed) and every path is an
/// independent draw (`shared_edges` = 0).
#[allow(clippy::too_many_arguments)]
fn draft_tree(
    engine: &dyn Backend,
    draft_kv: &KvCache,
    root_token: u32,
    root_pos: usize,
    a: Action,
    sampling: SamplingConfig,
    scratch: &mut DraftScratch,
    rng: &mut Pcg64,
    branch_at_root: bool,
) -> Result<Drafted> {
    let meta = engine.meta();
    let v = meta.draft.vocab;

    let mut tree = DraftTree::new(root_token);
    let mut trunk_out = None;
    let mut branch_out = None;
    let mut node = 0usize; // walk pointer (trunk end)

    // --- trunk rollout (single path, exact length) ---
    if a.l1 > 0 {
        let uniforms: Vec<f32> = (0..a.l1).map(|_| rng.next_f32()).collect();
        let out = engine.rollout(
            1,
            a.l1,
            draft_kv.view(),
            root_token,
            root_pos,
            &uniforms,
            sampling.temperature,
            sampling.top_p,
        )?;
        guard_finite(FaultOp::Rollout, "trunk rollout dists", &out.dists)?;
        let storage = DistStorage::global();
        for step in 0..a.l1 {
            let q = NodeDist::from_probs(&out.dists[step * v..(step + 1) * v], storage);
            tree.set_q(node, q);
            let tok = out.tokens[step] as u32;
            node = tree.add_child(node, tok, Provenance::Trunk { step: step + 1 });
        }
        trunk_out = Some(out);
    }
    let trunk_end = node;
    let (branch_point, branch_start) = if branch_at_root { (0, 0) } else { (trunk_end, a.l1) };

    let mut paths: Vec<Vec<usize>> = Vec::new();
    if branch_at_root && a.l1 > 0 {
        // the root-started trunk is its own independent path draw, recorded
        // ahead of the branch draws (draft order)
        paths.push(tree.path_nodes(trunk_end));
    }

    // --- branch rollout (K paths, bucketed length) ---
    if a.k > 1 && a.l2 > 0 {
        let lb = meta.branch_bucket(a.l2)?;
        let start_token = tree.nodes[branch_point].token;
        let start_pos = root_pos + branch_start;
        let uniforms: Vec<f32> = (0..a.k * lb).map(|_| rng.next_f32()).collect();
        // Delayed geometry: branch paths start l1 positions past the
        // committed prefix, so the trunk's rows must be visible to them —
        // refresh the reusable handoff cache with the committed prefix (for
        // contiguous lanes a span copy tracking the context length; for
        // paged lanes a copy-on-write fork — O(blocks) refcount bumps;
        // stale rows past start_pos are never read) and commit the trunk
        // rollout's rows on top — the same handoff
        // selector::draft_superset performs for superset sampling.
        // Root-started branches need no trunk rows: they read only the
        // committed prefix, straight off `draft_kv`.
        let branch_kv: &KvCache = match &trunk_out {
            Some(tr) if !branch_at_root && a.l1 > 0 => {
                let kv = scratch
                    .branch_kv
                    .get_or_insert_with(|| draft_kv.new_like());
                kv.copy_prefix_from(draft_kv, root_pos);
                kv.commit_rollout_rows(&tr.k_rows, &tr.v_rows, 1, a.l1, 0, a.l1 - 1, root_pos);
                kv
            }
            _ => draft_kv,
        };
        let out = engine.rollout(
            a.k,
            lb,
            branch_kv.view(),
            start_token,
            start_pos,
            &uniforms,
            sampling.temperature,
            sampling.top_p,
        )?;
        guard_finite(FaultOp::Rollout, "branch rollout dists", &out.dists)?;
        let storage = DistStorage::global();
        for b in 0..a.k {
            let mut cur = branch_point;
            for step in 0..a.l2 {
                if tree.nodes[cur].q.is_none() {
                    let q = NodeDist::from_probs(
                        &out.dists[(b * lb + step) * v..(b * lb + step + 1) * v],
                        storage,
                    );
                    tree.set_q(cur, q);
                }
                let tok = out.tokens[b * lb + step] as u32;
                cur = tree.add_child(cur, tok, Provenance::Branch { branch: b, step: step + 1 });
            }
            paths.push(tree.path_nodes(cur));
        }
        branch_out = Some(out);
    } else if !branch_at_root && a.l1 > 0 {
        paths.push(tree.path_nodes(trunk_end));
    }

    let shared_edges = if branch_at_root { 0 } else { a.l1 };
    tree.path_draws = Some(PathDraws { paths, shared_edges });
    Ok(Drafted { tree, trunk: trunk_out, branch: branch_out, branch_point, branch_start })
}

/// KV rows that must be written into the draft cache when the chain of
/// accepted nodes is committed. Returns (max trunk step, Option<(branch id,
/// max branch step)>) over the accepted chain (+ the always-present rows).
pub fn accepted_row_extent(
    tree: &DraftTree,
    accepted: &[usize],
) -> (Option<usize>, Option<(usize, usize)>) {
    let mut trunk_max: Option<usize> = None;
    let mut branch_max: Option<(usize, usize)> = None;
    for &n in accepted {
        match tree.nodes[n].provenance {
            Provenance::Trunk { step } => {
                // node's own row is at rollout step `step` only while it was
                // *visited*; the deepest trunk token's row comes from the
                // branch rollout (step 0), which commit_branch covers.
                trunk_max = Some(trunk_max.map_or(step, |m: usize| m.max(step)));
            }
            Provenance::Branch { branch, step } => {
                let cur = branch_max.map_or(step, |(_, m)| m.max(step));
                branch_max = Some((branch, cur));
            }
            Provenance::Root => {}
        }
    }
    (trunk_max, branch_max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization() {
        assert_eq!(Action::new(1, 3, 5).normalized(8), Action::new(1, 8, 0));
        assert_eq!(Action::new(3, 0, 4).normalized(8), Action::new(3, 0, 4));
        assert_eq!(Action::new(2, 2, 0).normalized(8), Action::new(1, 2, 0));
        assert_eq!(Action::new(4, 8, 8).normalized(8).nodes(), 1 + 8 + 32);
        // branching actions clamp the trunk to the longest compiled length
        // (the block-budget reservation relies on this bound)
        assert_eq!(Action::new(2, 40, 1).normalized(8), Action::new(2, 8, 1));
    }

    fn meta() -> FamilyMeta {
        use crate::runtime::{CpuModelConfig, CpuRefBackend};
        CpuRefBackend::new(&CpuModelConfig::tiny(), 1).meta().clone()
    }

    #[test]
    fn drafter_kind_roundtrip() {
        for k in DrafterKind::ALL {
            assert_eq!(DrafterKind::parse(k.name()), Some(k));
            assert_eq!(k.drafter().name(), k.name());
            assert_eq!(DrafterKind::ALL[k.index()], k);
        }
        assert_eq!(DrafterKind::parse("bogus"), None);
        assert_eq!(DrafterKind::default(), DrafterKind::Delayed);
    }

    #[test]
    fn drafter_shapes() {
        let m = meta();
        let mt = m.trunk_lens.iter().copied().max().unwrap();
        let mb = m.branch_lens.iter().copied().max().unwrap();
        let a = Action::new(3, 2, 2);
        // delayed: plain normalization
        assert_eq!(DelayedDrafter.shape(a, &m), a.normalized(mt));
        // root: trunk budget folds into the branch length, capped by the
        // longest compiled branch bucket
        assert_eq!(RootDrafter.shape(a, &m), Action::new(3, 0, 4));
        assert_eq!(RootDrafter.shape(Action::new(2, 8, 8), &m), Action::new(2, 0, mb.min(16)));
        // single-path requests collapse identically for every drafter
        let sp = Action::new(1, 3, 2);
        for k in DrafterKind::ALL {
            assert_eq!(k.drafter().shape(sp, &m), sp.normalized(mt));
        }
        // greedy keeps the delayed node budget, only the geometry differs
        assert_eq!(GreedyDrafter.shape(a, &m), a.normalized(mt));
        // every shape is a fixed point of itself (the serving loop shapes
        // exactly once per block) and respects the depth reservation
        for k in DrafterKind::ALL {
            let s = k.drafter().shape(a, &m);
            assert_eq!(k.drafter().shape(s, &m), s);
            let n = a.normalized(mt);
            assert!(s.l1 + s.l2 <= n.l1 + n.l2);
        }
    }

    #[test]
    fn drafted_geometry_per_kind() {
        use crate::runtime::{CpuModelConfig, CpuRefBackend, Role};
        let be = CpuRefBackend::new(&CpuModelConfig::tiny(), 3);
        let m = be.meta();
        let toks: Vec<i32> = vec![1, 5, 9];
        let pre = be.prefill(Role::Draft, &toks, toks.len()).unwrap();
        let mut kv = KvCache::new(be.dims(Role::Draft));
        kv.commit_prefill(&pre.k_rows, &pre.v_rows, m.s_pre, toks.len());
        let (root_token, root_pos) = (9u32, 2usize);
        let req = Action::new(3, 2, 2);

        for kind in DrafterKind::ALL {
            let d = kind.drafter();
            let shaped = d.shape(req, &m);
            let mut scratch = DraftScratch::default();
            let mut rng = Pcg64::seeded(7);
            let out = d
                .draft(
                    &be,
                    &kv,
                    root_token,
                    root_pos,
                    shaped,
                    SamplingConfig::default(),
                    &mut scratch,
                    &mut rng,
                )
                .unwrap();
            let draws = out.tree.path_draws.as_ref().unwrap();
            match kind {
                DrafterKind::Delayed => {
                    assert_eq!(out.branch_start, 2);
                    assert_eq!(out.tree.nodes[out.branch_point].depth, 2);
                    assert_eq!(draws.shared_edges, 2);
                    assert_eq!(draws.paths.len(), 3);
                    assert_eq!(out.tree.max_depth(), 4);
                }
                DrafterKind::Root => {
                    assert!(out.trunk.is_none());
                    assert_eq!((out.branch_point, out.branch_start), (0, 0));
                    assert_eq!(draws.shared_edges, 0);
                    assert_eq!(draws.paths.len(), 3);
                    assert_eq!(out.tree.max_depth(), 4);
                }
                DrafterKind::Greedy => {
                    assert!(out.trunk.is_some() && out.branch.is_some());
                    assert_eq!((out.branch_point, out.branch_start), (0, 0));
                    assert_eq!(draws.shared_edges, 0);
                    // one trunk draw + K branch draws, trunk recorded first
                    assert_eq!(draws.paths.len(), 4);
                    assert_eq!(draws.paths[0].len(), 2);
                    assert_eq!(out.tree.max_depth(), 2);
                }
            }
            // the losslessness prerequisite: every expanded node carries
            // the proposal it sampled its children from
            for n in &out.tree.nodes {
                if !n.children.is_empty() {
                    assert!(n.q.is_some(), "{}: expanded node without q", kind.name());
                }
            }
        }
    }

    #[test]
    fn extent_tracks_deepest() {
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 1, Provenance::Trunk { step: 1 });
        let b = t.add_child(a, 2, Provenance::Trunk { step: 2 });
        let c = t.add_child(b, 3, Provenance::Branch { branch: 2, step: 1 });
        let (tm, bm) = accepted_row_extent(&t, &[a, b, c]);
        assert_eq!(tm, Some(2));
        assert_eq!(bm, Some((2, 1)));
        let (tm, bm) = accepted_row_extent(&t, &[a]);
        assert_eq!(tm, Some(1));
        assert_eq!(bm, None);
    }
}
