//! Drafting policies: (K, L1, L2) delayed-tree construction (paper
//! Definition 5.2) over the fused [`Backend::rollout`] entry points.
//!
//! A delayed tree needs at most two backend dispatches: one trunk rollout
//! (single path, exact compiled length) and one branch rollout (K paths,
//! bucketed length, truncated to L2). Root-node i.i.d. multipath (paper
//! §3.2) is the L1 = 0 special case; single-path drafting is K ≤ 1 or
//! L2 = 0.

use anyhow::Result;

use crate::dist::{DistStorage, NodeDist, SamplingConfig};
use crate::kvcache::KvCache;
use crate::runtime::{guard_finite, Backend, FaultOp, RolloutOut};
use crate::tree::{DraftTree, PathDraws, Provenance};
use crate::util::Pcg64;

/// A delayed-expansion action a = (K, L1, L2) from the paper's action space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Action {
    /// Branch count K (K ≤ 1 means single path).
    pub k: usize,
    /// Trunk (delay) length L1.
    pub l1: usize,
    /// Branch length L2.
    pub l2: usize,
}

impl Action {
    /// Build an action from its (K, L1, L2) components.
    pub fn new(k: usize, l1: usize, l2: usize) -> Action {
        Action { k, l1, l2 }
    }

    /// Canonicalize: K=1 trees are single paths (trunk only, capped at the
    /// longest compiled trunk); L2 = 0 likewise. Branching actions cap L1
    /// at the longest compiled trunk too — the trunk rollout has no longer
    /// entry point, and the serving loop's worst-case block reservation
    /// (`ServeLoop::with_block_budget`) relies on `l1 ≤ max_trunk` holding
    /// for every normalized action.
    pub fn normalized(self, max_trunk: usize) -> Action {
        if self.k <= 1 || self.l2 == 0 {
            Action { k: 1, l1: (self.l1 + self.l2).min(max_trunk), l2: 0 }
        } else {
            Action { k: self.k.min(4), l1: self.l1.min(max_trunk), l2: self.l2 }
        }
    }

    /// Number of tree nodes including the root.
    pub fn nodes(&self) -> usize {
        1 + self.l1 + if self.k > 1 { self.k * self.l2 } else { 0 }
    }
}

/// Reusable drafting scratch (the `VerifyScratch` convention): the
/// branch-rollout handoff cache trunk rows are committed into. Create one
/// per sequence and reuse it across blocks — after the first trunk+branch
/// block the cache is warm and steady-state drafting performs no
/// cache-sized allocations. The handoff cache inherits the sequence
/// cache's storage ([`KvCache::new_like`]): with paged storage the prefix
/// refresh is a copy-on-write fork (refcount bumps) instead of a physical
/// prefix copy, and only the trunk's own blocks ever diverge.
#[derive(Clone, Default)]
pub struct DraftScratch {
    branch_kv: Option<KvCache>,
}

impl DraftScratch {
    /// The handoff cache, once a trunk+branch block has warmed it (bench /
    /// test introspection hook for prefix-sharing measurements).
    pub fn branch_cache(&self) -> Option<&KvCache> {
        self.branch_kv.as_ref()
    }
}

/// Drafting output: the merged tree plus raw rollout tensors for KV commits.
pub struct Drafted {
    /// The merged delayed tree (node 0 = root).
    pub tree: DraftTree,
    /// Raw trunk rollout output (None when L1 = 0).
    pub trunk: Option<RolloutOut>,
    /// Raw branch rollout output (None for single-path actions).
    pub branch: Option<RolloutOut>,
    /// node index of the trunk end (branch point); root if L1 = 0
    pub branch_point: usize,
}

/// Draft a delayed tree from the current draft KV cache by issuing the
/// fused rollout dispatches on any [`Backend`].
///
/// `root_token` is the last committed token at position `root_pos`; the
/// draft cache must hold valid rows for positions < root_pos. When the
/// action has both a trunk and branches, the trunk's freshly drafted KV
/// rows are committed into `scratch`'s reusable handoff cache before the
/// branch rollout (the fused rollout only carries its *own* path's rows,
/// and the branch paths start l1 positions past the committed prefix);
/// with a warm scratch the handoff allocates nothing.
#[allow(clippy::too_many_arguments)]
pub fn draft_delayed(
    engine: &dyn Backend,
    draft_kv: &KvCache,
    root_token: u32,
    root_pos: usize,
    action: Action,
    sampling: SamplingConfig,
    scratch: &mut DraftScratch,
    rng: &mut Pcg64,
) -> Result<Drafted> {
    let meta = engine.meta();
    let max_trunk = meta.trunk_lens.iter().copied().max().unwrap_or(8);
    let a = action.normalized(max_trunk);
    let v = meta.draft.vocab;

    let mut tree = DraftTree::new(root_token);
    let mut trunk_out = None;
    let mut branch_out = None;
    let mut node = 0usize; // walk pointer (trunk end)

    // --- trunk rollout (single path, exact length) ---
    if a.l1 > 0 {
        let uniforms: Vec<f32> = (0..a.l1).map(|_| rng.next_f32()).collect();
        let out = engine.rollout(
            1,
            a.l1,
            draft_kv.view(),
            root_token,
            root_pos,
            &uniforms,
            sampling.temperature,
            sampling.top_p,
        )?;
        guard_finite(FaultOp::Rollout, "trunk rollout dists", &out.dists)?;
        let storage = DistStorage::global();
        for step in 0..a.l1 {
            let q = NodeDist::from_probs(&out.dists[step * v..(step + 1) * v], storage);
            tree.set_q(node, q);
            let tok = out.tokens[step] as u32;
            node = tree.add_child(node, tok, Provenance::Trunk { step: step + 1 });
        }
        trunk_out = Some(out);
    }
    let branch_point = node;

    // --- branch rollout (K paths, bucketed length) ---
    let mut paths: Vec<Vec<usize>> = Vec::new();
    if a.k > 1 && a.l2 > 0 {
        let lb = meta.branch_bucket(a.l2)?;
        let start_token = tree.nodes[branch_point].token;
        let start_pos = root_pos + a.l1;
        let uniforms: Vec<f32> = (0..a.k * lb).map(|_| rng.next_f32()).collect();
        // Branch paths start l1 positions past the committed prefix, so the
        // trunk's rows must be visible to them: refresh the reusable
        // handoff cache with the committed prefix (for contiguous lanes a
        // span copy tracking the context length; for paged lanes a
        // copy-on-write fork — O(blocks) refcount bumps; stale rows past
        // start_pos are never read) and commit the trunk rollout's rows on
        // top — the same handoff selector::draft_superset performs for
        // superset sampling.
        let branch_kv: &KvCache = match &trunk_out {
            Some(tr) if a.l1 > 0 => {
                let kv = scratch
                    .branch_kv
                    .get_or_insert_with(|| draft_kv.new_like());
                kv.copy_prefix_from(draft_kv, root_pos);
                kv.commit_rollout_rows(&tr.k_rows, &tr.v_rows, 1, a.l1, 0, a.l1 - 1, root_pos);
                kv
            }
            _ => draft_kv,
        };
        let out = engine.rollout(
            a.k,
            lb,
            branch_kv.view(),
            start_token,
            start_pos,
            &uniforms,
            sampling.temperature,
            sampling.top_p,
        )?;
        guard_finite(FaultOp::Rollout, "branch rollout dists", &out.dists)?;
        let storage = DistStorage::global();
        for b in 0..a.k {
            let mut cur = branch_point;
            for step in 0..a.l2 {
                if tree.nodes[cur].q.is_none() {
                    let q = NodeDist::from_probs(
                        &out.dists[(b * lb + step) * v..(b * lb + step + 1) * v],
                        storage,
                    );
                    tree.set_q(cur, q);
                }
                let tok = out.tokens[b * lb + step] as u32;
                cur = tree.add_child(cur, tok, Provenance::Branch { branch: b, step: step + 1 });
            }
            paths.push(tree.path_nodes(cur));
        }
        branch_out = Some(out);
    } else if a.l1 > 0 {
        paths.push(tree.path_nodes(node));
    }

    tree.path_draws = Some(PathDraws { paths, shared_edges: a.l1 });
    Ok(Drafted { tree, trunk: trunk_out, branch: branch_out, branch_point })
}

/// KV rows that must be written into the draft cache when the chain of
/// accepted nodes is committed. Returns (max trunk step, Option<(branch id,
/// max branch step)>) over the accepted chain (+ the always-present rows).
pub fn accepted_row_extent(
    tree: &DraftTree,
    accepted: &[usize],
) -> (Option<usize>, Option<(usize, usize)>) {
    let mut trunk_max: Option<usize> = None;
    let mut branch_max: Option<(usize, usize)> = None;
    for &n in accepted {
        match tree.nodes[n].provenance {
            Provenance::Trunk { step } => {
                // node's own row is at rollout step `step` only while it was
                // *visited*; the deepest trunk token's row comes from the
                // branch rollout (step 0), which commit_branch covers.
                trunk_max = Some(trunk_max.map_or(step, |m: usize| m.max(step)));
            }
            Provenance::Branch { branch, step } => {
                let cur = branch_max.map_or(step, |(_, m)| m.max(step));
                branch_max = Some((branch, cur));
            }
            Provenance::Root => {}
        }
    }
    (trunk_max, branch_max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization() {
        assert_eq!(Action::new(1, 3, 5).normalized(8), Action::new(1, 8, 0));
        assert_eq!(Action::new(3, 0, 4).normalized(8), Action::new(3, 0, 4));
        assert_eq!(Action::new(2, 2, 0).normalized(8), Action::new(1, 2, 0));
        assert_eq!(Action::new(4, 8, 8).normalized(8).nodes(), 1 + 8 + 32);
        // branching actions clamp the trunk to the longest compiled length
        // (the block-budget reservation relies on this bound)
        assert_eq!(Action::new(2, 40, 1).normalized(8), Action::new(2, 8, 1));
    }

    #[test]
    fn extent_tracks_deepest() {
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 1, Provenance::Trunk { step: 1 });
        let b = t.add_child(a, 2, Provenance::Trunk { step: 2 });
        let c = t.add_child(b, 3, Provenance::Branch { branch: 2, step: 1 });
        let (tm, bm) = accepted_row_extent(&t, &[a, b, c]);
        assert_eq!(tm, Some(2));
        assert_eq!(bm, Some((2, 1)));
        let (tm, bm) = accepted_row_extent(&t, &[a]);
        assert_eq!(tm, Some(1));
        assert_eq!(bm, None);
    }
}
