//! specdelay CLI — the layer-3 leader entrypoint.
//!
//! Subcommands:
//!   generate        one-off generation with any verifier/action
//!   serve           TCP line-protocol server (see coordinator::server)
//!   microbench      per-entry latency model (Eq. 11 inputs)
//!   collect-traces  offline NDE trace collection
//!   train-selector  fit the neural delay-and-branch predictor
//!   bench <id>      regenerate a paper table/figure (table2, table3, fig1,
//!                   table45, table67, table89, table1015)

use anyhow::{anyhow, Result};

use specdelay::benchkit::{self, experiments, Scale};
use specdelay::coordinator::{server, FixedPolicy, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::selector::{self, LatencyModel};
use specdelay::util::cli::Args;
use specdelay::util::Pcg64;
use specdelay::verify;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let res = match cmd.as_str() {
        "generate" => cmd_generate(argv),
        "serve" => cmd_serve(argv),
        "microbench" => cmd_microbench(argv),
        "collect-traces" | "train-selector" => cmd_selector(argv),
        "bench" => cmd_bench(argv),
        "version" => {
            println!("specdelay {}", specdelay::version());
            Ok(())
        }
        _ => {
            print_usage();
            Err(anyhow!("unknown command {cmd}"))
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "usage: specdelay <generate|serve|microbench|collect-traces|train-selector|bench|version> [--opts]"
    );
}

fn cmd_generate(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["ar"]).map_err(|e| anyhow!(e))?;
    let family = a.get_or("family", "qwen-sim").to_string();
    let engine = benchkit::load_engine(&family)?;
    let sampling = SamplingConfig::new(
        a.get_f64("temperature", 0.8).map_err(|e| anyhow!(e))? as f32,
        a.get_f64("top-p", 1.0).map_err(|e| anyhow!(e))? as f32,
    );
    let prompt = a.get_or("prompt", "Q: 6 * 7 = ? A:").to_string();
    let max_new = a.get_usize("max-new", 64).map_err(|e| anyhow!(e))?;
    let mut rng = Pcg64::seeded(a.get_usize("seed", 0).map_err(|e| anyhow!(e))? as u64);

    if a.flag("ar") {
        let (text, stats) = specdelay::coordinator::generate_autoregressive(
            &engine, sampling, &prompt, max_new, &mut rng,
        )?;
        println!("{text}");
        println!("-- AR: {} tokens, {:.2} tok/s", stats.tokens, stats.tps());
        return Ok(());
    }

    let vname = a.get_or("verifier", "SpecInfer");
    let verifier = verify::verifier(vname).ok_or_else(|| anyhow!("unknown verifier {vname}"))?;
    let action = Action::new(
        a.get_usize("k", 2).map_err(|e| anyhow!(e))?,
        a.get_usize("l1", 2).map_err(|e| anyhow!(e))?,
        a.get_usize("l2", 4).map_err(|e| anyhow!(e))?,
    );
    let spec = SpecEngine::new(&engine, sampling);
    let (text, stats) = spec.generate(&prompt, max_new, verifier.as_ref(), &FixedPolicy(action), &mut rng)?;
    println!("{text}");
    println!(
        "-- {vname} (K={},L1={},L2={}): {} tokens, block efficiency {:.2}, {:.2} tok/s",
        action.k,
        action.l1,
        action.l2,
        stats.tokens,
        stats.block_efficiency(),
        stats.tps()
    );
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let family = a.get_or("family", "qwen-sim").to_string();
    let engine = benchkit::load_engine(&family)?;
    let cfg = server::ServerConfig {
        addr: a.get_or("addr", "127.0.0.1:7333").to_string(),
        seed: a.get_usize("seed", 0).map_err(|e| anyhow!(e))? as u64,
    };
    server::serve(&engine, &cfg, None)
}

fn cmd_microbench(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let family = a.get_or("family", "qwen-sim").to_string();
    let engine = benchkit::load_engine(&family)?;
    let lat = LatencyModel::measure(&engine)?;
    println!("{}", lat.to_json().to_string_pretty());
    Ok(())
}

fn cmd_selector(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let scale = Scale::from_env();
    let families: Vec<String> = a
        .get_or("family", "qwen-sim,gemma-sim,llama-sim")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let solvers: Vec<String> = a
        .get_or("solver", &experiments::OT_ALGOS.join(","))
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    for family in &families {
        let engine = benchkit::load_engine(family)?;
        for solver in &solvers {
            let _ = experiments::ensure_selector(&engine, family, solver, scale)?;
            println!("selector ready: {family}/{solver}");
        }
    }
    Ok(())
}

fn cmd_bench(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let which = a.positional.first().map(|s| s.as_str()).unwrap_or("table2");
    let scale = Scale::from_env();
    match which {
        "table2" | "table3" | "table23" => {
            experiments::tables_2_3(scale)?;
        }
        "fig1" => {
            experiments::figure_1(scale, a.get_or("family", "llama-sim"))?;
        }
        "table45" | "table67" | "nde" => {
            experiments::tables_4_7(scale)?;
        }
        "table89" => {
            experiments::tables_8_9(scale)?;
        }
        "table1015" => {
            for f in benchkit::FAMILIES {
                experiments::tables_10_15(scale, f)?;
            }
        }
        other => return Err(anyhow!("unknown bench id {other}")),
    }
    Ok(())
}
