//! specdelay CLI — the layer-3 leader entrypoint.
//!
//! Subcommands (default build, CPU reference backend):
//!   generate        one-off generation with any verifier/action
//!   serve           TCP line-protocol server (see coordinator::server)
//!   serve-loop      multi-request batched serving demo (coordinator::ServeLoop)
//!   version
//!
//! Backend selection: `--backend cpu` (default; `--preset tiny|small`,
//! `--model-seed N` size and seed the reference model), `--backend
//! cpu-simd` (same model, f32x8 lane-chunk kernels) or `--backend pjrt`
//! (`--family <name>`, needs a `--features pjrt` build plus compiled
//! artifacts). With no `--backend` flag the `SPECDELAY_BACKEND`
//! environment variable picks the default. `--kv-dtype f32|f16|int8`
//! mirrors `SPECDELAY_KV_DTYPE` and selects the paged-KV element
//! precision for the whole process.
//!
//! Drafting policy: `--drafter delayed|root|greedy` (generate and
//! serve-loop) picks the tree shape; `--selector` (serve-loop) replaces
//! the static verifier/action flags with the online dynamic selector over
//! [`SelectorConfig::with_default_arms`].
//!
//! pjrt-only subcommands (need artifacts):
//!   microbench      per-entry latency model (Eq. 11 inputs)
//!   collect-traces  offline NDE trace collection
//!   train-selector  fit the neural delay-and-branch predictor
//!   bench <id>      regenerate a paper table/figure (table2, table3, fig1,
//!                   table45, table67, table89, table1015)

use std::time::Instant;

use anyhow::{anyhow, Result};

#[cfg(feature = "pjrt")]
use specdelay::benchkit::{self, experiments, Scale};
use specdelay::coordinator::{server, FixedPolicy, ServeLoop, ServeRequest, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::{Action, DrafterKind};
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, CpuSimdBackend};
#[cfg(feature = "pjrt")]
use specdelay::selector::LatencyModel;
use specdelay::selector::SelectorConfig;
use specdelay::util::cli::Args;
use specdelay::util::Pcg64;
use specdelay::verify;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    // `--kv-dtype` mirrors SPECDELAY_KV_DTYPE; it must be exported before
    // the first KV pool latches the process-wide dtype
    // (`kvcache::KvDtype::global`), so handle it ahead of dispatch. The
    // option stays in argv — subcommand parsers simply ignore it.
    for (i, s) in argv.iter().enumerate() {
        if let Some(v) = s.strip_prefix("--kv-dtype=") {
            std::env::set_var("SPECDELAY_KV_DTYPE", v);
        } else if s == "--kv-dtype" {
            if let Some(v) = argv.get(i + 1) {
                std::env::set_var("SPECDELAY_KV_DTYPE", v);
            }
        }
    }
    let res = match cmd.as_str() {
        "generate" => cmd_generate(argv),
        "serve" => cmd_serve(argv),
        "serve-loop" => cmd_serve_loop(argv),
        "microbench" => cmd_microbench(argv),
        "collect-traces" | "train-selector" => cmd_selector(argv),
        "bench" => cmd_bench(argv),
        "version" => {
            println!("specdelay {}", specdelay::version());
            Ok(())
        }
        _ => {
            print_usage();
            Err(anyhow!("unknown command {cmd}"))
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "usage: specdelay <generate|serve|serve-loop|microbench|collect-traces|train-selector|bench|version> [--opts]\n\
         backend: --backend cpu|cpu-simd (--preset tiny|small) | --backend pjrt (--family <name>)\n\
         kv: --kv-dtype f32|f16|int8 (paged pools; mirrors SPECDELAY_KV_DTYPE)"
    );
}

/// Resolve `--drafter delayed|root|greedy` (default `delayed`).
fn parse_drafter(a: &Args) -> Result<DrafterKind> {
    let name = a.get_or("drafter", "delayed");
    DrafterKind::parse(name).ok_or_else(|| anyhow!("unknown drafter {name} (delayed|root|greedy)"))
}

fn cpu_config(a: &Args) -> Result<CpuModelConfig> {
    match a.get_or("preset", "small") {
        "tiny" => Ok(CpuModelConfig::tiny()),
        "small" => Ok(CpuModelConfig::small()),
        other => Err(anyhow!("unknown CPU preset {other} (tiny|small)")),
    }
}

/// Resolve `--backend cpu|cpu-simd|pjrt` into a boxed backend. When the
/// flag is absent, `SPECDELAY_BACKEND` supplies the default ("cpu" if
/// that is unset too).
fn load_backend(a: &Args) -> Result<Box<dyn Backend>> {
    let env = std::env::var("SPECDELAY_BACKEND").ok();
    let choice = a.get("backend").unwrap_or_else(|| env.as_deref().unwrap_or("cpu"));
    match choice {
        "cpu" | "cpu-ref" => {
            let seed = a.get_usize("model-seed", 0).map_err(|e| anyhow!(e))? as u64;
            Ok(Box::new(CpuRefBackend::new(&cpu_config(a)?, seed)))
        }
        "cpu-simd" => {
            let seed = a.get_usize("model-seed", 0).map_err(|e| anyhow!(e))? as u64;
            Ok(Box::new(CpuSimdBackend::new(&cpu_config(a)?, seed)))
        }
        "pjrt" => pjrt_backend(a),
        other => Err(anyhow!("unknown backend {other} (cpu|cpu-simd|pjrt)")),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(a: &Args) -> Result<Box<dyn Backend>> {
    Ok(Box::new(benchkit::load_engine(a.get_or("family", "qwen-sim"))?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_a: &Args) -> Result<Box<dyn Backend>> {
    Err(anyhow!("--backend pjrt requires a build with --features pjrt"))
}

fn cmd_generate(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["ar"]).map_err(|e| anyhow!(e))?;
    let backend = load_backend(&a)?;
    let sampling = SamplingConfig::new(
        a.get_f64("temperature", 0.8).map_err(|e| anyhow!(e))? as f32,
        a.get_f64("top-p", 1.0).map_err(|e| anyhow!(e))? as f32,
    );
    let prompt = a.get_or("prompt", "Q: 6 * 7 = ? A:").to_string();
    let max_new = a.get_usize("max-new", 64).map_err(|e| anyhow!(e))?;
    let mut rng = Pcg64::seeded(a.get_usize("seed", 0).map_err(|e| anyhow!(e))? as u64);

    if a.flag("ar") {
        let (text, stats) = specdelay::coordinator::generate_autoregressive(
            backend.as_ref(),
            sampling,
            &prompt,
            max_new,
            &mut rng,
        )?;
        println!("{text}");
        println!("-- AR: {} tokens, {:.2} tok/s", stats.tokens, stats.tps());
        return Ok(());
    }

    let vname = a.get_or("verifier", "SpecInfer");
    let verifier = verify::verifier(vname).ok_or_else(|| anyhow!("unknown verifier {vname}"))?;
    let action = Action::new(
        a.get_usize("k", 2).map_err(|e| anyhow!(e))?,
        a.get_usize("l1", 2).map_err(|e| anyhow!(e))?,
        a.get_usize("l2", 4).map_err(|e| anyhow!(e))?,
    );
    let drafter = parse_drafter(&a)?;
    let spec = SpecEngine::new(backend.as_ref(), sampling).with_drafter(drafter);
    let (text, stats) =
        spec.generate(&prompt, max_new, verifier.as_ref(), &FixedPolicy(action), &mut rng)?;
    println!("{text}");
    println!(
        "-- {vname} ({} drafter) on {} (K={},L1={},L2={}): {} tokens, block efficiency {:.2}, {:.2} tok/s",
        drafter.name(),
        backend.name(),
        action.k,
        action.l1,
        action.l2,
        stats.tokens,
        stats.block_efficiency(),
        stats.tps()
    );
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let backend = load_backend(&a)?;
    let cfg = server::ServerConfig::new(
        a.get_or("addr", "127.0.0.1:7333").to_string(),
        a.get_usize("seed", 0).map_err(|e| anyhow!(e))? as u64,
    );
    server::serve(backend.as_ref(), &cfg, None)
}

fn cmd_serve_loop(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["selector"]).map_err(|e| anyhow!(e))?;
    let backend = load_backend(&a)?;
    let sampling = SamplingConfig::new(
        a.get_f64("temperature", 0.8).map_err(|e| anyhow!(e))? as f32,
        a.get_f64("top-p", 1.0).map_err(|e| anyhow!(e))? as f32,
    );
    let vname = a.get_or("verifier", "SpecInfer");
    let verifier = verify::verifier(vname).ok_or_else(|| anyhow!("unknown verifier {vname}"))?;
    let action = Action::new(
        a.get_usize("k", 2).map_err(|e| anyhow!(e))?,
        a.get_usize("l1", 2).map_err(|e| anyhow!(e))?,
        a.get_usize("l2", 4).map_err(|e| anyhow!(e))?,
    );
    let policy = FixedPolicy(action);
    let batch = a.get_usize("batch", 4).map_err(|e| anyhow!(e))?;
    let requests = a.get_usize("requests", 8).map_err(|e| anyhow!(e))?;
    let max_new = a.get_usize("max-new", 48).map_err(|e| anyhow!(e))?;
    let seed = a.get_usize("seed", 0).map_err(|e| anyhow!(e))? as u64;

    const PROMPTS: [&str; 4] = [
        "Q: 6 * 7 = ? A:",
        "story: the golden ",
        "fn add(a, b):",
        "translate en->fr: the sea => ",
    ];
    let drafter = parse_drafter(&a)?;
    let mut srv = ServeLoop::new(backend.as_ref(), sampling, verifier.as_ref(), &policy, batch)
        .with_drafter(drafter);
    if a.flag("selector") {
        // dynamic per-block (verifier × drafter × action) selection with
        // online-calibrated acceptance priors; the static flags above stay
        // the fallback for degraded/AR ticks
        srv = srv.with_selector(SelectorConfig::with_default_arms());
    }
    for i in 0..requests {
        srv.submit(ServeRequest::new(PROMPTS[i % PROMPTS.len()].to_string(), max_new, seed));
    }
    let t0 = Instant::now();
    let outs = srv.run()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut total = 0usize;
    for o in &outs {
        if let Some(e) = &o.error {
            println!("[{:>3}] error: {e}", o.id);
            continue;
        }
        total += o.stats.tokens;
        println!(
            "[{:>3}] {} tokens | block efficiency {:.2} | {:?}",
            o.id,
            o.stats.tokens,
            o.stats.block_efficiency(),
            o.text
        );
    }
    println!(
        "-- {vname} on {}, batch {batch}: {requests} requests, {total} tokens in {wall:.2}s = {:.1} tok/s aggregate",
        backend.name(),
        total as f64 / wall.max(1e-9)
    );
    if srv.selector_active() {
        let sel = srv.selector().expect("active selector");
        for (arm, stats) in sel.arms().iter().zip(&srv.selector_priors().arms) {
            println!(
                "-- arm {}/{} (K={},L1={},L2={}): {} blocks, {} drafted, {} accepted",
                arm.verifier,
                arm.drafter.name(),
                arm.action.k,
                arm.action.l1,
                arm.action.l2,
                stats.blocks,
                stats.drafted,
                stats.accepted
            );
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_microbench(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let family = a.get_or("family", "qwen-sim").to_string();
    let engine = benchkit::load_engine(&family)?;
    let lat = LatencyModel::measure(&engine)?;
    println!("{}", lat.to_json().to_string_pretty());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_selector(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let scale = Scale::from_env();
    let families: Vec<String> = a
        .get_or("family", "qwen-sim,gemma-sim,llama-sim")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let solvers: Vec<String> = a
        .get_or("solver", &experiments::OT_ALGOS.join(","))
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    for family in &families {
        let engine = benchkit::load_engine(family)?;
        for solver in &solvers {
            let _ = experiments::ensure_selector(&engine, family, solver, scale)?;
            println!("selector ready: {family}/{solver}");
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_bench(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &[]).map_err(|e| anyhow!(e))?;
    let which = a.positional.first().map(|s| s.as_str()).unwrap_or("table2");
    let scale = Scale::from_env();
    match which {
        "table2" | "table3" | "table23" => {
            experiments::tables_2_3(scale)?;
        }
        "fig1" => {
            experiments::figure_1(scale, a.get_or("family", "llama-sim"))?;
        }
        "table45" | "table67" | "nde" => {
            experiments::tables_4_7(scale)?;
        }
        "table89" => {
            experiments::tables_8_9(scale)?;
        }
        "table1015" => {
            for f in benchkit::FAMILIES {
                experiments::tables_10_15(scale, f)?;
            }
        }
        other => return Err(anyhow!("unknown bench id {other}")),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_microbench(_argv: Vec<String>) -> Result<()> {
    Err(anyhow!("microbench requires a build with --features pjrt"))
}

#[cfg(not(feature = "pjrt"))]
fn cmd_selector(_argv: Vec<String>) -> Result<()> {
    Err(anyhow!("selector commands require a build with --features pjrt"))
}

#[cfg(not(feature = "pjrt"))]
fn cmd_bench(_argv: Vec<String>) -> Result<()> {
    Err(anyhow!("paper-table benches require a build with --features pjrt"))
}
