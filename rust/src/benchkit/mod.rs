//! Shared benchmark harness: workload loading, configuration sweeps, table
//! formatting. Every paper table/figure bench (rust/benches/*) and the CLI
//! route through these functions.
//!
//! Scale: `SPECDELAY_BENCH_SCALE=quick|std|full` controls prompt counts,
//! generation lengths and grid sizes (quick is the default — the testbed is
//! a single CPU core).

#[cfg(feature = "pjrt")]
pub mod experiments;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

#[cfg(feature = "pjrt")]
use crate::coordinator::{ActionPolicy, FixedPolicy, SpecEngine};
use crate::dist::SamplingConfig;
#[cfg(feature = "pjrt")]
use crate::draft::Action;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::util::stats::Running;
use crate::util::Json;
#[cfg(feature = "pjrt")]
use crate::util::Pcg64;
#[cfg(feature = "pjrt")]
use crate::verify;

pub const FAMILIES: [&str; 3] = ["qwen-sim", "gemma-sim", "llama-sim"];
pub const DOMAINS: [&str; 5] = ["writing", "coding", "translation", "math_easy", "math_hard"];

/// Paper display names per domain (Table 8/9 column headers).
pub fn domain_label(d: &str) -> &'static str {
    match d {
        "writing" => "Writing",
        "coding" => "Coding",
        "translation" => "Translation",
        "math_easy" => "Math (E)",
        "math_hard" => "Math (H)",
        _ => "?",
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scale {
    Quick,
    Std,
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("SPECDELAY_BENCH_SCALE").as_deref() {
            Ok("full") => Scale::Full,
            Ok("std") => Scale::Std,
            _ => Scale::Quick,
        }
    }
    pub fn prompts_per_domain(self) -> usize {
        match self {
            Scale::Quick => 1,
            Scale::Std => 3,
            Scale::Full => 8,
        }
    }
    pub fn max_new(self) -> usize {
        match self {
            Scale::Quick => 24,
            Scale::Std => 48,
            Scale::Full => 96,
        }
    }
    /// Sampling configurations (paper §4.1: 6 temperatures + 2 nucleus).
    pub fn sampling_grid(self) -> Vec<SamplingConfig> {
        match self {
            Scale::Quick => vec![SamplingConfig::new(0.8, 1.0)],
            Scale::Std => vec![
                SamplingConfig::new(0.4, 1.0),
                SamplingConfig::new(0.8, 1.0),
                SamplingConfig::new(1.0, 0.9),
            ],
            Scale::Full => {
                let mut v: Vec<SamplingConfig> = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2]
                    .iter()
                    .map(|&t| SamplingConfig::new(t, 1.0))
                    .collect();
                v.push(SamplingConfig::new(1.0, 0.9));
                v.push(SamplingConfig::new(1.0, 0.99));
                v
            }
        }
    }
    /// Static (K, L) grid for the §4 comparison (best-of selection).
    pub fn kl_grid(self) -> Vec<(usize, usize)> {
        match self {
            Scale::Quick => vec![(1, 4), (2, 4), (4, 4)],
            Scale::Std => vec![(1, 4), (1, 6), (2, 4), (3, 4), (4, 4), (4, 6)],
            Scale::Full => {
                let mut v = Vec::new();
                for k in 1..=4 {
                    for l in [2, 4, 6, 8] {
                        v.push((k, l));
                    }
                }
                v
            }
        }
    }
}

pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(
        std::env::var("SPECDELAY_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    )
}

/// Load held-out prompts for one domain.
pub fn load_prompts(domain: &str, count: usize) -> Result<Vec<String>> {
    let path = artifacts_dir().join("prompts").join(format!("{domain}.json"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
    Ok(j.as_arr()
        .ok_or_else(|| anyhow!("prompts not an array"))?
        .iter()
        .filter_map(|v| v.as_str().map(|s| s.to_string()))
        .take(count)
        .collect())
}

#[cfg(feature = "pjrt")]
pub fn load_engine(family: &str) -> Result<Engine> {
    Engine::load(&artifacts_dir().join(family))
}

/// Measured outcome of one (engine, verifier, policy, sampling) config.
#[derive(Clone, Debug, Default)]
pub struct ConfigResult {
    pub block_eff: Running,
    pub tps: Running,
}

/// Run one configuration over a prompt set.
#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
pub fn run_config(
    engine: &Engine,
    verifier_name: &str,
    policy: &dyn ActionPolicy,
    sampling: SamplingConfig,
    prompts: &[String],
    max_new: usize,
    seed: u64,
) -> Result<ConfigResult> {
    let verifier = verify::verifier(verifier_name)
        .ok_or_else(|| anyhow!("unknown verifier {verifier_name}"))?;
    let spec = SpecEngine::new(engine, sampling);
    let mut out = ConfigResult::default();
    for (i, p) in prompts.iter().enumerate() {
        let mut rng = Pcg64::new(seed, i as u64);
        let (_text, stats) = spec.generate(p, max_new, verifier.as_ref(), policy, &mut rng)?;
        if stats.blocks > 0 {
            out.block_eff.push(stats.block_efficiency());
            out.tps.push(stats.tps());
        }
    }
    Ok(out)
}

/// Best static i.i.d. configuration for a verifier (paper §4.2: select the
/// (K, L) maximizing the metric). Returns (block_eff at best-be config,
/// tps at best-tps config).
#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
pub fn best_static(
    engine: &Engine,
    verifier_name: &str,
    sampling: SamplingConfig,
    prompts: &[String],
    max_new: usize,
    grid: &[(usize, usize)],
    seed: u64,
    single_path_only: bool,
) -> Result<(f64, f64, Action, Action)> {
    let mut best_be = (f64::MIN, Action::new(1, 4, 0));
    let mut best_tps = (f64::MIN, Action::new(1, 4, 0));
    for &(k, l) in grid {
        if single_path_only && k != 1 {
            continue;
        }
        // i.i.d. multipath = delayed tree with L1 = 0
        let action = if k == 1 { Action::new(1, l, 0) } else { Action::new(k, 0, l) };
        let r = run_config(engine, verifier_name, &FixedPolicy(action), sampling, prompts, max_new, seed)?;
        if r.block_eff.mean() > best_be.0 {
            best_be = (r.block_eff.mean(), action);
        }
        if r.tps.mean() > best_tps.0 {
            best_tps = (r.tps.mean(), action);
        }
    }
    Ok((best_be.0, best_tps.0, best_be.1, best_tps.1))
}

/// Simple fixed-width table printer.
pub fn print_table(title: &str, headers: &[&str], rows: &[(String, Vec<f64>)]) {
    println!("\n=== {title} ===");
    let w0 = rows
        .iter()
        .map(|(n, _)| n.len())
        .chain([10])
        .max()
        .unwrap_or(10)
        + 2;
    print!("{:w0$}", "Method", w0 = w0);
    for h in headers {
        print!("{h:>12}");
    }
    println!();
    for (name, vals) in rows {
        print!("{name:w0$}", w0 = w0);
        for v in vals {
            if v.is_nan() {
                print!("{:>12}", "-");
            } else {
                print!("{v:>12.2}");
            }
        }
        println!();
    }
}

/// ASCII line plot for Figure 1 style series.
pub fn ascii_plot(title: &str, xlabel: &str, series: &[(String, Vec<f64>)]) {
    println!("\n--- {title} ---");
    let max = series
        .iter()
        .flat_map(|(_, v)| v.iter())
        .cloned()
        .fold(f64::MIN, f64::max);
    let min = series
        .iter()
        .flat_map(|(_, v)| v.iter())
        .cloned()
        .fold(f64::MAX, f64::min);
    let span = (max - min).max(1e-9);
    for (name, vals) in series {
        let bars: String = vals
            .iter()
            .map(|&v| {
                let t = ((v - min) / span * 7.0).round() as usize;
                ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'][t.min(7)]
            })
            .collect();
        let nums: Vec<String> = vals.iter().map(|v| format!("{v:.3}")).collect();
        println!("{name:>14} {bars}  [{}]", nums.join(", "));
    }
    println!("{:>14} ({xlabel} →)", "");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_grids_nonempty() {
        for s in [Scale::Quick, Scale::Std, Scale::Full] {
            assert!(!s.sampling_grid().is_empty());
            assert!(!s.kl_grid().is_empty());
            assert!(s.prompts_per_domain() >= 1);
        }
    }

    #[test]
    fn full_grid_matches_paper() {
        assert_eq!(Scale::Full.sampling_grid().len(), 8);
        assert_eq!(Scale::Full.kl_grid().len(), 16);
    }
}
