//! Shared benchmark harness: workload loading, configuration sweeps, table
//! formatting. Every paper table/figure bench (rust/benches/*) and the CLI
//! route through these functions.
//!
//! Scale: `SPECDELAY_BENCH_SCALE=quick|std|full` controls prompt counts,
//! generation lengths and grid sizes (quick is the default).
//!
//! Sweeps are data-parallel: [`run_config`] fans prompts out across
//! workers and [`best_static`] fans out grid points, both through
//! `util::threadpool::par_map_init`, whose contract (per-item seeded rng
//! streams, order-preserving folds) makes every speculation outcome —
//! tokens, blocks, block efficiency — **bit-identical** between serial and
//! parallel runs. Wall-clock-derived tps is a measurement, not an outcome:
//! under a parallel sweep it includes contention, so pin
//! `SPECDELAY_THREADS=1` when per-prompt latency fidelity matters (that
//! also forces the fully serial path).

#[cfg(feature = "pjrt")]
pub mod experiments;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{ActionPolicy, FixedPolicy, SpecEngine};
use crate::dist::SamplingConfig;
use crate::draft::Action;
use crate::runtime::Backend;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::util::stats::Running;
use crate::util::Json;
use crate::util::Pcg64;
use crate::verify;

/// The three simulated model families of the paper's evaluation.
pub const FAMILIES: [&str; 3] = ["qwen-sim", "gemma-sim", "llama-sim"];
/// The five workload domains (Table 8/9).
pub const DOMAINS: [&str; 5] = ["writing", "coding", "translation", "math_easy", "math_hard"];

/// Paper display names per domain (Table 8/9 column headers).
pub fn domain_label(d: &str) -> &'static str {
    match d {
        "writing" => "Writing",
        "coding" => "Coding",
        "translation" => "Translation",
        "math_easy" => "Math (E)",
        "math_hard" => "Math (H)",
        _ => "?",
    }
}

/// Experiment scale knob (`SPECDELAY_BENCH_SCALE=quick|std|full`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scale {
    /// Smoke-test scale (the default).
    Quick,
    /// Medium scale for local iteration.
    Std,
    /// Full paper-replication scale.
    Full,
}

impl Scale {
    /// Read the scale from `SPECDELAY_BENCH_SCALE` (default: quick).
    pub fn from_env() -> Scale {
        match std::env::var("SPECDELAY_BENCH_SCALE").as_deref() {
            Ok("full") => Scale::Full,
            Ok("std") => Scale::Std,
            _ => Scale::Quick,
        }
    }
    /// Held-out prompts evaluated per domain.
    pub fn prompts_per_domain(self) -> usize {
        match self {
            Scale::Quick => 1,
            Scale::Std => 3,
            Scale::Full => 8,
        }
    }
    /// Generation budget per prompt.
    pub fn max_new(self) -> usize {
        match self {
            Scale::Quick => 24,
            Scale::Std => 48,
            Scale::Full => 96,
        }
    }
    /// Sampling configurations (paper §4.1: 6 temperatures + 2 nucleus).
    pub fn sampling_grid(self) -> Vec<SamplingConfig> {
        match self {
            Scale::Quick => vec![SamplingConfig::new(0.8, 1.0)],
            Scale::Std => vec![
                SamplingConfig::new(0.4, 1.0),
                SamplingConfig::new(0.8, 1.0),
                SamplingConfig::new(1.0, 0.9),
            ],
            Scale::Full => {
                let mut v: Vec<SamplingConfig> = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2]
                    .iter()
                    .map(|&t| SamplingConfig::new(t, 1.0))
                    .collect();
                v.push(SamplingConfig::new(1.0, 0.9));
                v.push(SamplingConfig::new(1.0, 0.99));
                v
            }
        }
    }
    /// Static (K, L) grid for the §4 comparison (best-of selection).
    pub fn kl_grid(self) -> Vec<(usize, usize)> {
        match self {
            Scale::Quick => vec![(1, 4), (2, 4), (4, 4)],
            Scale::Std => vec![(1, 4), (1, 6), (2, 4), (3, 4), (4, 4), (4, 6)],
            Scale::Full => {
                let mut v = Vec::new();
                for k in 1..=4 {
                    for l in [2, 4, 6, 8] {
                        v.push((k, l));
                    }
                }
                v
            }
        }
    }
}

/// Root of the compiled model artifacts (`SPECDELAY_ARTIFACTS` override).
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(
        std::env::var("SPECDELAY_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    )
}

/// Load held-out prompts for one domain.
pub fn load_prompts(domain: &str, count: usize) -> Result<Vec<String>> {
    let path = artifacts_dir().join("prompts").join(format!("{domain}.json"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
    Ok(j.as_arr()
        .ok_or_else(|| anyhow!("prompts not an array"))?
        .iter()
        .filter_map(|v| v.as_str().map(|s| s.to_string()))
        .take(count)
        .collect())
}

/// Load a family's PJRT engine from the artifacts directory.
#[cfg(feature = "pjrt")]
pub fn load_engine(family: &str) -> Result<Engine> {
    Engine::load(&artifacts_dir().join(family))
}

/// Measured outcome of one (backend, verifier, policy, sampling) config.
#[derive(Clone, Debug, Default)]
pub struct ConfigResult {
    /// Per-prompt block efficiency E[τ + 1].
    pub block_eff: Running,
    /// Per-prompt decode throughput (tokens/s).
    pub tps: Running,
}

/// Run one configuration over a prompt set with the default worker count
/// ([`crate::util::threadpool::default_workers`], `SPECDELAY_THREADS`
/// override). Results are bit-identical to a serial run.
#[allow(clippy::too_many_arguments)]
pub fn run_config(
    engine: &dyn Backend,
    verifier_name: &str,
    policy: &dyn ActionPolicy,
    sampling: SamplingConfig,
    prompts: &[String],
    max_new: usize,
    seed: u64,
) -> Result<ConfigResult> {
    let workers = crate::util::threadpool::default_workers();
    run_config_threads(engine, verifier_name, policy, sampling, prompts, max_new, seed, workers)
}

/// Run one configuration over a prompt set on up to `workers` threads.
///
/// Each prompt already draws from its own seeded rng stream
/// (`Pcg64::new(seed, prompt_index)`), so every *speculation outcome* —
/// tokens, blocks, acceptances, and the block-efficiency metric — is
/// independent of scheduling, and the fold below walks prompts in input
/// order: those results are **bit-identical** between serial and parallel
/// runs. The tps metric is a wall-clock *measurement* (it differs between
/// any two runs, serial ones included); under a parallel sweep each
/// prompt's wall time additionally includes contention with its
/// neighbours, so for latency-faithful per-prompt tps numbers pin
/// `SPECDELAY_THREADS=1`.
///
/// On a prompt failure the remaining workers stop picking up new prompts
/// (already-running generations finish) and the failure is propagated.
#[allow(clippy::too_many_arguments)]
pub fn run_config_threads(
    engine: &dyn Backend,
    verifier_name: &str,
    policy: &dyn ActionPolicy,
    sampling: SamplingConfig,
    prompts: &[String],
    max_new: usize,
    seed: u64,
    workers: usize,
) -> Result<ConfigResult> {
    use std::sync::atomic::{AtomicBool, Ordering};
    let verifier = verify::verifier(verifier_name)
        .ok_or_else(|| anyhow!("unknown verifier {verifier_name}"))?;
    let verifier = verifier.as_ref();
    let failed = AtomicBool::new(false);
    let per_prompt = crate::util::threadpool::par_map_init(
        prompts.iter().collect::<Vec<&String>>(),
        workers,
        || SpecEngine::new(engine, sampling),
        |spec, i, p| -> Result<Option<(f64, f64)>> {
            if failed.load(Ordering::Relaxed) {
                return Ok(None); // abandoned after an earlier failure
            }
            let mut rng = Pcg64::new(seed, i as u64);
            match spec.generate(p, max_new, verifier, policy, &mut rng) {
                Ok((_text, stats)) => {
                    Ok((stats.blocks > 0).then(|| (stats.block_efficiency(), stats.tps())))
                }
                Err(e) => {
                    failed.store(true, Ordering::Relaxed);
                    Err(e)
                }
            }
        },
    );
    let mut out = ConfigResult::default();
    for r in per_prompt {
        if let Some((be, tps)) = r? {
            out.block_eff.push(be);
            out.tps.push(tps);
        }
    }
    Ok(out)
}

/// Best static i.i.d. configuration for a verifier (paper §4.2: select the
/// (K, L) maximizing the metric). Returns (block_eff at best-be config,
/// tps at best-tps config).
///
/// Grid points run in parallel (each point's prompt sweep stays serial to
/// avoid nested fan-out); the best-of fold walks the grid in input order
/// with the same `>` comparisons as the old serial loop, so winners and
/// tie-breaks match a serial sweep wherever the compared metric is a
/// deterministic speculation outcome (see [`run_config_threads`] for the
/// tps caveat). A failing grid point stops the remaining queue and is
/// propagated.
#[allow(clippy::too_many_arguments)]
pub fn best_static(
    engine: &dyn Backend,
    verifier_name: &str,
    sampling: SamplingConfig,
    prompts: &[String],
    max_new: usize,
    grid: &[(usize, usize)],
    seed: u64,
    single_path_only: bool,
) -> Result<(f64, f64, Action, Action)> {
    use std::sync::atomic::{AtomicBool, Ordering};
    // i.i.d. multipath = delayed tree with L1 = 0
    let actions: Vec<Action> = grid
        .iter()
        .filter(|&&(k, _)| !(single_path_only && k != 1))
        .map(|&(k, l)| if k == 1 { Action::new(1, l, 0) } else { Action::new(k, 0, l) })
        .collect();
    let failed = AtomicBool::new(false);
    let results = crate::util::threadpool::par_map_init(
        actions.clone(),
        crate::util::threadpool::default_workers(),
        || (),
        |_state, _i, action| -> Result<Option<ConfigResult>> {
            if failed.load(Ordering::Relaxed) {
                return Ok(None); // abandoned after an earlier failure
            }
            let r = run_config_threads(
                engine,
                verifier_name,
                &FixedPolicy(action),
                sampling,
                prompts,
                max_new,
                seed,
                1,
            );
            match r {
                Ok(v) => Ok(Some(v)),
                Err(e) => {
                    failed.store(true, Ordering::Relaxed);
                    Err(e)
                }
            }
        },
    );
    let mut best_be = (f64::MIN, Action::new(1, 4, 0));
    let mut best_tps = (f64::MIN, Action::new(1, 4, 0));
    for (action, r) in actions.into_iter().zip(results) {
        let Some(r) = r? else {
            continue; // abandoned point; the failing point's Err surfaces via `?`
        };
        if r.block_eff.mean() > best_be.0 {
            best_be = (r.block_eff.mean(), action);
        }
        if r.tps.mean() > best_tps.0 {
            best_tps = (r.tps.mean(), action);
        }
    }
    Ok((best_be.0, best_tps.0, best_be.1, best_tps.1))
}

/// Simple fixed-width table printer.
pub fn print_table(title: &str, headers: &[&str], rows: &[(String, Vec<f64>)]) {
    println!("\n=== {title} ===");
    let w0 = rows
        .iter()
        .map(|(n, _)| n.len())
        .chain([10])
        .max()
        .unwrap_or(10)
        + 2;
    print!("{:w0$}", "Method", w0 = w0);
    for h in headers {
        print!("{h:>12}");
    }
    println!();
    for (name, vals) in rows {
        print!("{name:w0$}", w0 = w0);
        for v in vals {
            if v.is_nan() {
                print!("{:>12}", "-");
            } else {
                print!("{v:>12.2}");
            }
        }
        println!();
    }
}

/// ASCII line plot for Figure 1 style series.
pub fn ascii_plot(title: &str, xlabel: &str, series: &[(String, Vec<f64>)]) {
    println!("\n--- {title} ---");
    let max = series
        .iter()
        .flat_map(|(_, v)| v.iter())
        .cloned()
        .fold(f64::MIN, f64::max);
    let min = series
        .iter()
        .flat_map(|(_, v)| v.iter())
        .cloned()
        .fold(f64::MAX, f64::min);
    let span = (max - min).max(1e-9);
    for (name, vals) in series {
        let bars: String = vals
            .iter()
            .map(|&v| {
                let t = ((v - min) / span * 7.0).round() as usize;
                ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'][t.min(7)]
            })
            .collect();
        let nums: Vec<String> = vals.iter().map(|v| format!("{v:.3}")).collect();
        println!("{name:>14} {bars}  [{}]", nums.join(", "));
    }
    println!("{:>14} ({xlabel} →)", "");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_grids_nonempty() {
        for s in [Scale::Quick, Scale::Std, Scale::Full] {
            assert!(!s.sampling_grid().is_empty());
            assert!(!s.kl_grid().is_empty());
            assert!(s.prompts_per_domain() >= 1);
        }
    }

    #[test]
    fn full_grid_matches_paper() {
        assert_eq!(Scale::Full.sampling_grid().len(), 8);
        assert_eq!(Scale::Full.kl_grid().len(), 16);
    }
}
