//! Generators for every table and figure in the paper's evaluation
//! (experiment index in DESIGN.md §7). Each prints the same rows the paper
//! reports and returns the numbers for EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use super::{
    artifacts_dir, ascii_plot, best_static, domain_label, load_engine, load_prompts, print_table,
    run_config, ConfigResult, Scale, DOMAINS, FAMILIES,
};
use crate::coordinator::{FixedPolicy, SpecEngine};
use crate::dist::{DistStorage, NodeDist, SamplingConfig};
use crate::draft::Action;
use crate::runtime::Engine;
use crate::selector::{
    self, action_space, collect_traces, load_checkpoint, save_checkpoint, train, LatencyModel,
    NeuralPolicy, TrainConfig,
};
use crate::util::stats::Running;
use crate::util::Pcg64;
use crate::verify;

/// All eight verification algorithms, in the paper's table order.
pub const ALGOS: [&str; 8] =
    ["NSS", "BV", "Khisti", "NaiveTree", "Naive", "SpecInfer", "SpecTr", "Traversal"];
/// The OT-based subset (NDE applies to these only).
pub const OT_ALGOS: [&str; 5] = ["Khisti", "NaiveTree", "NSS", "SpecInfer", "SpecTr"];

fn is_single_path(name: &str) -> bool {
    matches!(name, "Naive" | "BV")
}

/// Tables 2 + 3: average block efficiency and throughput per family for all
/// eight verification algorithms, best static (K, L) per configuration.
pub fn tables_2_3(scale: Scale) -> Result<(Vec<(String, Vec<f64>)>, Vec<(String, Vec<f64>)>)> {
    let max_new = scale.max_new();
    let grid = scale.kl_grid();
    let mut be_rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut tps_rows: Vec<(String, Vec<f64>)> = Vec::new();
    for algo in ALGOS {
        be_rows.push((algo.to_string(), Vec::new()));
        tps_rows.push((algo.to_string(), Vec::new()));
    }

    for family in FAMILIES {
        let engine = load_engine(family)?;
        let mut be_acc = vec![Running::new(); ALGOS.len()];
        let mut tps_acc = vec![Running::new(); ALGOS.len()];
        for sampling in scale.sampling_grid() {
            for domain in DOMAINS {
                let prompts = load_prompts(domain, scale.prompts_per_domain())?;
                for (ai, algo) in ALGOS.iter().enumerate() {
                    let (be, tps, _, _) = best_static(
                        &engine,
                        algo,
                        sampling,
                        &prompts,
                        max_new,
                        &grid,
                        0xbe5c + ai as u64,
                        is_single_path(algo),
                    )?;
                    be_acc[ai].push(be);
                    tps_acc[ai].push(tps);
                }
            }
        }
        for ai in 0..ALGOS.len() {
            be_rows[ai].1.push(be_acc[ai].mean());
            tps_rows[ai].1.push(tps_acc[ai].mean());
        }
    }
    // append row average
    for rows in [&mut be_rows, &mut tps_rows] {
        for (_n, v) in rows.iter_mut() {
            let avg = v.iter().sum::<f64>() / v.len() as f64;
            v.push(avg);
        }
        // NaN-safe: a config whose sweep produced no finished blocks yields
        // NaN means; total_cmp sorts those deterministically (NaN last)
        // instead of panicking in partial_cmp.
        rows.sort_by(|a, b| {
            let (av, bv) = (a.1.last().copied(), b.1.last().copied());
            av.unwrap_or(f64::NAN).total_cmp(&bv.unwrap_or(f64::NAN))
        });
    }
    print_table("Table 2: average block efficiency", &["Qwen", "Gemma", "Llama", "Average"], &be_rows);
    print_table("Table 3: average throughput (tok/s)", &["Qwen", "Gemma", "Llama", "Average"], &tps_rows);
    Ok((be_rows, tps_rows))
}

/// Figure 1: depth-wise L1(p, q) divergence and OTLP acceptance rates over
/// offline draft trees rooted along target trajectories.
pub fn figure_1(scale: Scale, family: &str) -> Result<Vec<(String, Vec<f64>)>> {
    let engine = load_engine(family)?;
    let sampling = SamplingConfig::new(0.8, 1.0);
    let spec = SpecEngine::new(&engine, sampling);
    let depth_max = 6usize;
    let k = 4usize;
    let n_roots = match scale {
        Scale::Quick => 12,
        Scale::Std => 40,
        Scale::Full => 200,
    };

    let solvers: Vec<&str> = OT_ALGOS.to_vec();
    let mut l1_by_depth = vec![Running::new(); depth_max];
    let mut acc_by_depth: BTreeMap<&str, Vec<Running>> = solvers
        .iter()
        .map(|&s| (s, vec![Running::new(); depth_max]))
        .collect();

    let mut rng = Pcg64::seeded(0xf16);
    let mut collected = 0usize;
    'outer: for domain in DOMAINS {
        for prompt in load_prompts(domain, 3)? {
            let mut seq = spec.start(&prompt)?;
            // walk the target trajectory, dropping offline trees along it
            for _ in 0..4 {
                if seq.finished {
                    break;
                }
                // offline tree: K i.i.d. paths of depth_max from the root
                // (l1 = 0, so the sequence's handoff scratch stays idle)
                let drafted = crate::draft::draft_delayed(
                    &engine,
                    &seq.draft_kv,
                    *seq.tokens.last().unwrap(),
                    seq.root_pos,
                    Action::new(k, 0, depth_max),
                    sampling,
                    &mut seq.draft_scratch,
                    &mut rng,
                )?;
                let mut tree = drafted.tree;
                let n_bucket = engine.meta.tree_bucket(tree.len())?;
                let (toks, pos) = tree.tokens_positions(n_bucket, seq.root_pos, crate::tokenizer::PAD);
                let bias = tree.attention_bias(n_bucket);
                let out = crate::runtime::Backend::tree_verify(
                    &engine,
                    n_bucket,
                    seq.target_kv.view(),
                    &toks,
                    &pos,
                    &bias,
                    seq.root_pos,
                )?;
                let v = engine.meta.target.vocab;
                let storage = DistStorage::global();
                for i in 0..tree.len() {
                    tree.set_p(
                        i,
                        NodeDist::from_logits(&out.logits[i * v..(i + 1) * v], sampling, storage),
                    );
                }
                for i in 0..tree.len() {
                    let d = tree.nodes[i].depth;
                    if d >= depth_max || tree.nodes[i].q.is_none() {
                        continue;
                    }
                    let p = tree.nodes[i].p.as_ref().unwrap();
                    let q = tree.nodes[i].q.as_ref().unwrap();
                    l1_by_depth[d].push(NodeDist::l1(p, q) as f64);
                    // the acceptance calculators are dense-only (cold path)
                    let (pd, qd) = (p.to_dense(), q.to_dense());
                    for &s in &solvers {
                        let solver = verify::ot_solver(s).unwrap();
                        acc_by_depth.get_mut(s).unwrap()[d]
                            .push(solver.acceptance_rate(&pd, &qd, k));
                    }
                }
                collected += 1;
                if collected >= n_roots {
                    break 'outer;
                }
                // advance along the trajectory
                let verifier = verify::verifier("SpecInfer").unwrap();
                spec.step(&mut seq, verifier.as_ref(), Action::new(2, 2, 4), &mut rng)?;
            }
        }
    }

    let mut series: Vec<(String, Vec<f64>)> = vec![(
        "L1(p,q)".to_string(),
        l1_by_depth.iter().map(|r| r.mean()).collect(),
    )];
    for &s in &solvers {
        series.push((
            s.to_string(),
            acc_by_depth[s].iter().map(|r| r.mean()).collect(),
        ));
    }
    ascii_plot(
        &format!("Figure 1 ({family}): L1 divergence & OTLP acceptance by tree depth (k={k})"),
        "depth",
        &series,
    );
    Ok(series)
}

// ---------------------------------------------------------------------------
// NDE pipeline
// ---------------------------------------------------------------------------

fn selector_path(family: &str, solver: &str) -> PathBuf {
    artifacts_dir().join("selector").join(format!("{family}_{solver}.json"))
}

/// Train (or load) the neural selector for one (family, solver). Trace
/// collection is shared: the first missing solver triggers one collection
/// pass that scores ALL OT solvers, then each selector trains from it.
pub fn ensure_selector(
    engine: &Engine,
    family: &str,
    solver: &str,
    scale: Scale,
) -> Result<selector::Checkpoint> {
    let path = selector_path(family, solver);
    if path.exists() {
        return load_checkpoint(&path);
    }
    eprintln!("[nde] collecting traces for {family} (first use) ...");
    let lat = LatencyModel::measure(engine)?;
    let n_roots = match scale {
        Scale::Quick => 10,
        Scale::Std => 24,
        Scale::Full => 80,
    };
    let mut prompts = Vec::new();
    let grid = scale.sampling_grid();
    for (i, domain) in DOMAINS.iter().enumerate() {
        for p in load_prompts(domain, 2)? {
            prompts.push((p, grid[i % grid.len()]));
        }
    }
    let solvers: Vec<(&str, Box<dyn verify::OtlpSolver>)> = OT_ALGOS
        .iter()
        .map(|&n| (n, verify::ot_solver(n).unwrap()))
        .collect();
    let mut rng = Pcg64::seeded(0x7ace);
    let roots = collect_traces(engine, &prompts, &lat, 96, &mut rng, &solvers, n_roots)?;
    if roots.is_empty() {
        return Err(anyhow!("no trace roots collected"));
    }
    // train every solver's selector from the shared traces
    let cfg = TrainConfig::default();
    let mut requested = None;
    for s in OT_ALGOS {
        let sp = selector_path(family, s);
        if sp.exists() && s != solver {
            continue;
        }
        let (ckpt, ratio) = train(
            &roots,
            s,
            engine.meta.target.d_model,
            engine.meta.draft.d_model,
            &lat,
            &cfg,
        )?;
        eprintln!(
            "[nde] {family}/{s}: train TPS ratio {ratio:.3} over {} roots",
            roots.len()
        );
        save_checkpoint(&sp, &ckpt, engine.meta.target.d_model, engine.meta.draft.d_model)?;
        if s == solver {
            requested = Some(ckpt);
        }
    }
    requested.ok_or_else(|| anyhow!("solver {solver} not in OT set")).or_else(|_| load_checkpoint(&path))
}

/// Run one NDE configuration (trained selector policy).
pub fn run_nde(
    engine: &Engine,
    solver: &str,
    ckpt: selector::Checkpoint,
    sampling: SamplingConfig,
    prompts: &[String],
    max_new: usize,
    seed: u64,
) -> Result<ConfigResult> {
    let policy = NeuralPolicy::new(ckpt, engine.meta.target.max_seq);
    run_config(engine, solver, &policy, sampling, prompts, max_new, seed)
}

/// Tables 4–7: NDE vs static baselines and vs Traversal.
/// Returns (table4 rows, table5 rows, table6 rows, table7 rows).
#[allow(clippy::type_complexity)]
pub fn tables_4_7(
    scale: Scale,
) -> Result<(
    Vec<(String, Vec<f64>)>,
    Vec<(String, Vec<f64>)>,
    Vec<(String, Vec<f64>)>,
    Vec<(String, Vec<f64>)>,
)> {
    let max_new = scale.max_new();
    let grid = scale.kl_grid();
    let mut t4: Vec<(String, Vec<f64>)> = Vec::new();
    let mut t5: Vec<(String, Vec<f64>)> = Vec::new();
    let mut t6: Vec<(String, Vec<f64>)> = vec![("Traversal".into(), Vec::new())];
    let mut t7: Vec<(String, Vec<f64>)> = vec![("Traversal".into(), Vec::new())];
    for algo in OT_ALGOS {
        t4.push((format!("{algo} NDE"), Vec::new()));
        t5.push((format!("{algo} NDE"), Vec::new()));
        t6.push((format!("{algo} NDE"), Vec::new()));
        t7.push((format!("{algo} NDE"), Vec::new()));
    }

    for family in FAMILIES {
        let engine = load_engine(family)?;
        // Traversal reference
        let mut trav_be = Running::new();
        let mut trav_tps = Running::new();
        // per OT algo accumulators: (nde_be, nde_tps, base_be, base_tps)
        let mut acc = vec![(Running::new(), Running::new(), Running::new(), Running::new()); OT_ALGOS.len()];

        for sampling in scale.sampling_grid() {
            for domain in DOMAINS {
                let prompts = load_prompts(domain, scale.prompts_per_domain())?;
                let (be, tps, _, _) = best_static(
                    &engine, "Traversal", sampling, &prompts, max_new, &grid, 0x7a41, false,
                )?;
                trav_be.push(be);
                trav_tps.push(tps);
                for (ai, algo) in OT_ALGOS.iter().enumerate() {
                    let ckpt = ensure_selector(&engine, family, algo, scale)?;
                    let r = run_nde(&engine, algo, ckpt, sampling, &prompts, max_new, 0x4de + ai as u64)?;
                    let (sbe, stps, _, _) = best_static(
                        &engine, algo, sampling, &prompts, max_new, &grid, 0xba5e + ai as u64, false,
                    )?;
                    acc[ai].0.push(r.block_eff.mean());
                    acc[ai].1.push(r.tps.mean());
                    acc[ai].2.push(sbe);
                    acc[ai].3.push(stps);
                }
            }
        }
        t6[0].1.push(trav_be.mean());
        t7[0].1.push(trav_tps.mean());
        for (ai, _) in OT_ALGOS.iter().enumerate() {
            t4[ai].1.push(acc[ai].0.mean() / acc[ai].2.mean().max(1e-9));
            t5[ai].1.push(acc[ai].1.mean() / acc[ai].3.mean().max(1e-9));
            t6[ai + 1].1.push(acc[ai].0.mean());
            t7[ai + 1].1.push(acc[ai].1.mean());
        }
    }
    for rows in [&mut t4, &mut t5, &mut t6, &mut t7] {
        for (_n, v) in rows.iter_mut() {
            let avg = v.iter().sum::<f64>() / v.len() as f64;
            v.push(avg);
        }
    }
    let hdr = &["Qwen", "Gemma", "Llama", "Average"];
    print_table("Table 4: NDE block-efficiency ratio vs baseline", hdr, &t4);
    print_table("Table 5: NDE throughput ratio vs baseline", hdr, &t5);
    print_table("Table 6: block efficiency — Traversal vs NDE", hdr, &t6);
    print_table("Table 7: throughput (tok/s) — Traversal vs NDE", hdr, &t7);
    Ok((t4, t5, t6, t7))
}

/// Tables 8 + 9: per-dataset breakdown including delayed-expansion static
/// variants and Traversal K ∈ {2,3,4} (averaged over families).
pub fn tables_8_9(scale: Scale) -> Result<(Vec<(String, Vec<f64>)>, Vec<(String, Vec<f64>)>)> {
    let max_new = scale.max_new();
    let sampling = SamplingConfig::new(0.8, 1.0);
    let mut rows_tps: Vec<(String, Vec<f64>)> = Vec::new();
    let mut rows_be: Vec<(String, Vec<f64>)> = Vec::new();

    // method list mirrors the paper's Table 8 rows
    let mut methods: Vec<(String, String, Action)> = Vec::new();
    for algo in OT_ALGOS {
        methods.push((format!("{algo}, delayed"), algo.to_string(), Action::new(3, 2, 3)));
        methods.push((algo.to_string(), algo.to_string(), Action::new(3, 0, 4)));
    }
    methods.push(("Naive".into(), "Naive".into(), Action::new(1, 5, 0)));
    methods.push(("BV".into(), "BV".into(), Action::new(1, 5, 0)));
    for k in [2, 3, 4] {
        methods.push((format!("Traversal, K={k}"), "Traversal".into(), Action::new(k, 0, 4)));
    }

    let engines: Vec<Engine> = FAMILIES.iter().map(|f| load_engine(f)).collect::<Result<_>>()?;
    for (name, verifier, action) in &methods {
        let mut tps_cols = Vec::new();
        let mut be_cols = Vec::new();
        for domain in DOMAINS {
            let prompts = load_prompts(domain, scale.prompts_per_domain())?;
            let mut tps = Running::new();
            let mut be = Running::new();
            for engine in &engines {
                let r = run_config(
                    engine,
                    verifier,
                    &FixedPolicy(*action),
                    sampling,
                    &prompts,
                    max_new,
                    0x89,
                )?;
                tps.push(r.tps.mean());
                be.push(r.block_eff.mean());
            }
            tps_cols.push(tps.mean());
            be_cols.push(be.mean());
        }
        rows_tps.push((name.clone(), tps_cols));
        rows_be.push((name.clone(), be_cols));
    }
    let hdr: Vec<&str> = DOMAINS.iter().map(|d| domain_label(d)).collect();
    print_table("Table 8: tokens/s by dataset (family-avg)", &hdr, &rows_tps);
    print_table("Table 9: block efficiency by dataset (family-avg)", &hdr, &rows_be);
    Ok((rows_tps, rows_be))
}

/// Tables 10–15: per-sampling-configuration breakdown per family.
pub fn tables_10_15(scale: Scale, family: &str) -> Result<(Vec<(String, Vec<f64>)>, Vec<(String, Vec<f64>)>)> {
    let max_new = scale.max_new();
    let engine = load_engine(family)?;
    let configs: Vec<SamplingConfig> = match scale {
        Scale::Quick => vec![
            SamplingConfig::new(0.4, 1.0),
            SamplingConfig::new(1.0, 1.0),
            SamplingConfig::new(1.0, 0.9),
        ],
        _ => Scale::Full.sampling_grid(),
    };
    let methods: Vec<(String, String, Action)> = {
        let mut m = Vec::new();
        for algo in OT_ALGOS {
            m.push((format!("{algo}, delayed"), algo.to_string(), Action::new(3, 2, 3)));
            m.push((algo.to_string(), algo.to_string(), Action::new(3, 0, 4)));
        }
        m.push(("Naive".into(), "Naive".into(), Action::new(1, 5, 0)));
        m.push(("BV".into(), "BV".into(), Action::new(1, 5, 0)));
        for k in [2, 3, 4] {
            m.push((format!("Traversal, K={k}"), "Traversal".into(), Action::new(k, 0, 4)));
        }
        m
    };
    let prompts = load_prompts("coding", scale.prompts_per_domain())?;
    let mut rows_tps = Vec::new();
    let mut rows_be = Vec::new();
    for (name, verifier, action) in &methods {
        let mut tps_cols = Vec::new();
        let mut be_cols = Vec::new();
        for &cfg in &configs {
            let r = run_config(&engine, verifier, &FixedPolicy(*action), cfg, &prompts, max_new, 0x1015)?;
            tps_cols.push(r.tps.mean());
            be_cols.push(r.block_eff.mean());
        }
        rows_tps.push((name.clone(), tps_cols));
        rows_be.push((name.clone(), be_cols));
    }
    let hdr: Vec<String> = configs
        .iter()
        .map(|c| {
            if c.top_p < 1.0 {
                format!("top-p={}", c.top_p)
            } else {
                format!("T={}", c.temperature)
            }
        })
        .collect();
    let hdr_refs: Vec<&str> = hdr.iter().map(|s| s.as_str()).collect();
    print_table(&format!("Table 10-15 ({family}): throughput by sampling config"), &hdr_refs, &rows_tps);
    print_table(&format!("Table 10-15 ({family}): block efficiency by sampling config"), &hdr_refs, &rows_be);
    Ok((rows_tps, rows_be))
}
