//! Streaming statistics and lightweight histograms for metrics and benches.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// Empty accumulator.
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    /// Unbiased sample variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator in (parallel-reduction merge).
    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket latency histogram with percentile queries.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// log-spaced bucket upper bounds (seconds, or any unit)
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Log-spaced histogram covering [lo, hi] with `n` buckets.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
        let bounds = (0..n).map(|i| lo * ratio.powi(i as i32)).collect();
        Histogram { bounds, counts: vec![0; n + 1], total: 0 }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b < x);
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap()
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

/// Format a mean ± sem pair compactly for tables.
pub fn fmt_mean_sem(r: &Running) -> String {
    format!("{:.2}±{:.2}", r.mean(), r.sem())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_basic() {
        let mut r = Running::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 4);
        assert!((r.mean() - 2.5).abs() < 1e-12);
        assert!((r.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 4.0);
    }

    #[test]
    fn running_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Running::new();
        let mut a = Running::new();
        let mut b = Running::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::log_spaced(1e-6, 10.0, 64);
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!((p50 - 0.5).abs() / 0.5 < 0.3, "p50 {p50}");
    }

    #[test]
    fn histogram_empty_nan() {
        let h = Histogram::log_spaced(1e-6, 1.0, 8);
        assert!(h.quantile(0.5).is_nan());
    }
}
