//! Streaming statistics, lightweight histograms, and the chi-square
//! goodness-of-fit machinery the statistical losslessness suites use.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// Empty accumulator.
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    /// Unbiased sample variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator in (parallel-reduction merge).
    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket latency histogram with percentile queries.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// log-spaced bucket upper bounds (seconds, or any unit)
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Log-spaced histogram covering [lo, hi] with `n` buckets.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
        let bounds = (0..n).map(|i| lo * ratio.powi(i as i32)).collect();
        Histogram { bounds, counts: vec![0; n + 1], total: 0 }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b < x);
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap()
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

/// Format a mean ± sem pair compactly for tables.
pub fn fmt_mean_sem(r: &Running) -> String {
    format!("{:.2}±{:.2}", r.mean(), r.sem())
}

// ---------------------------------------------------------------------------
// Chi-square goodness of fit
// ---------------------------------------------------------------------------

/// ln Γ(x) via the Lanczos approximation (g = 7, n = 9), accurate to
/// ~1e-13 over the range the chi-square machinery needs.
fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_59,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let t = x + 7.5;
        let mut a = COEF[0];
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Iteration budget for the incomplete-gamma expansions. Both the series
/// and the continued fraction slow down near the switchover x ≈ a, where
/// the number of terms needed grows like O(√a); a fixed cap silently
/// truncates at large dof and returns a partial sum that *looks* like a
/// healthy p-value. Scale the budget with the arguments so convergence is
/// reached (and detected) across the dof range the quantized-KV
/// chi-square matrix produces.
fn gamma_iters(a: f64, x: f64) -> usize {
    600 + (10.0 * a.max(x).max(1.0).sqrt()) as usize
}

/// Regularized lower incomplete gamma P(a, x) by series expansion
/// (converges fast for x < a + 1). Returns `(value, converged)` so the
/// caller can detect a truncated sum instead of trusting it.
fn gamma_p_series(a: f64, x: f64) -> (f64, bool) {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    let mut converged = false;
    for _ in 0..gamma_iters(a, x) {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-14 {
            converged = true;
            break;
        }
    }
    (sum * (-x + a * x.ln() - ln_gamma(a)).exp(), converged)
}

/// Regularized *upper* incomplete gamma Q(a, x) by Lentz's continued
/// fraction (converges fast for x ≥ a + 1). Returns `(value, converged)`.
fn gamma_q_contfrac(a: f64, x: f64) -> (f64, bool) {
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b.max(tiny);
    let mut h = d;
    let mut converged = false;
    for i in 1..gamma_iters(a, x) {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = d * c;
        h *= delta;
        if (delta - 1.0).abs() < 1e-14 {
            converged = true;
            break;
        }
    }
    ((-x + a * x.ln() - ln_gamma(a)).exp() * h, converged)
}

/// Standard normal survival function Φ̄(z) = erfc(z/√2)/2 via the
/// Abramowitz–Stegun 7.1.26 rational approximation (abs error < 1.5e-7)
/// — only used as the Wilson–Hilferty fallback when the incomplete-gamma
/// expansions fail to converge, never on the primary path.
fn normal_sf(z: f64) -> f64 {
    let x = z.abs() / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    let erfc = poly * (-x * x).exp();
    let tail = 0.5 * erfc;
    if z >= 0.0 {
        tail
    } else {
        1.0 - tail
    }
}

/// Wilson–Hilferty cube-root normal approximation of the chi-square
/// survival function — the last-resort fallback when both incomplete-gamma
/// expansions report non-convergence (accurate to a few 1e-3 at moderate
/// dof and improving with dof, which is exactly the regime where the
/// expansions are slowest).
fn chi_square_sf_wilson_hilferty(stat: f64, dof: f64) -> f64 {
    let v = stat / dof;
    let s = 2.0 / (9.0 * dof);
    normal_sf((v.cbrt() - (1.0 - s)) / s.sqrt())
}

/// Survival function of the chi-square distribution: `P(X² ≥ stat)` with
/// `dof` degrees of freedom — the p-value of a goodness-of-fit statistic.
///
/// Hardened for the extremes the backend × kv-dtype losslessness matrix
/// can reach (large dof, tiny tail mass): the incomplete-gamma iteration
/// budget scales with dof, a truncated expansion falls back to the
/// Wilson–Hilferty approximation instead of returning a partial sum, and
/// the result is never NaN — a non-finite intermediate degrades to 0.0
/// (a conservative *fail* for callers asserting `p > floor`, never a
/// false pass).
pub fn chi_square_sf(stat: f64, dof: usize) -> f64 {
    if stat.is_nan() {
        return 0.0;
    }
    if stat <= 0.0 || dof == 0 {
        return 1.0;
    }
    if stat.is_infinite() {
        return 0.0;
    }
    let a = dof as f64 / 2.0;
    let x = stat / 2.0;
    let (q, converged) = if x < a + 1.0 {
        let (p, c) = gamma_p_series(a, x);
        (1.0 - p, c)
    } else {
        gamma_q_contfrac(a, x)
    };
    let q = if !converged || q.is_nan() { chi_square_sf_wilson_hilferty(stat, dof as f64) } else { q };
    if q.is_nan() {
        return 0.0;
    }
    q.clamp(0.0, 1.0)
}

/// Pearson goodness-of-fit statistic Σ (O−E)²/E over the given bins, with
/// every bin whose expectation falls below `min_expected` pooled into one
/// joint bin (the standard validity fix for sparse tails). If even the
/// pooled remainder stays below `min_expected` it is folded into the
/// smallest regular bin instead — the statistic never contains a term
/// whose expectation violates the chi-square approximation. `expected` is
/// taken as counts (probabilities already scaled by the sample size).
/// Returns `(statistic, dof)` with `dof = effective_bins - 1`, or `None`
/// when fewer than two effective bins remain.
pub fn chi_square_stat(
    observed: &[usize],
    expected: &[f64],
    min_expected: f64,
) -> Option<(f64, usize)> {
    assert_eq!(observed.len(), expected.len(), "bin count mismatch");
    let mut bins: Vec<(f64, f64)> = Vec::new(); // (observed, expected)
    let (mut pooled_obs, mut pooled_exp) = (0.0f64, 0.0f64);
    for (&o, &e) in observed.iter().zip(expected) {
        if e >= min_expected {
            bins.push((o as f64, e));
        } else {
            pooled_obs += o as f64;
            pooled_exp += e;
        }
    }
    if pooled_exp >= min_expected {
        bins.push((pooled_obs, pooled_exp));
    } else if pooled_exp > 0.0 {
        // undersized remainder: fold into the smallest regular bin
        if let Some(min_bin) = bins.iter_mut().min_by(|a, b| a.1.total_cmp(&b.1)) {
            min_bin.0 += pooled_obs;
            min_bin.1 += pooled_exp;
        }
    }
    if bins.len() < 2 {
        return None;
    }
    let stat = bins
        .iter()
        .map(|&(o, e)| {
            let d = o - e;
            d * d / e
        })
        .sum();
    Some((stat, bins.len() - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_basic() {
        let mut r = Running::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 4);
        assert!((r.mean() - 2.5).abs() < 1e-12);
        assert!((r.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 4.0);
    }

    #[test]
    fn running_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Running::new();
        let mut a = Running::new();
        let mut b = Running::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::log_spaced(1e-6, 10.0, 64);
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!((p50 - 0.5).abs() / 0.5 < 0.3, "p50 {p50}");
    }

    #[test]
    fn histogram_empty_nan() {
        let h = Histogram::log_spaced(1e-6, 1.0, 8);
        assert!(h.quantile(0.5).is_nan());
    }

    /// Pin the chi-square survival function against standard critical
    /// values (95th/99th percentiles from any chi-square table).
    #[test]
    fn chi_square_sf_known_quantiles() {
        for (stat, dof, want) in [
            (3.841f64, 1usize, 0.05f64),
            (6.635, 1, 0.01),
            (9.488, 4, 0.05),
            (18.307, 10, 0.05),
            (124.342, 100, 0.05),
        ] {
            let got = chi_square_sf(stat, dof);
            assert!(
                (got - want).abs() < 2e-4,
                "sf({stat}, {dof}) = {got}, want ≈ {want}"
            );
        }
        assert_eq!(chi_square_sf(0.0, 5), 1.0);
        assert_eq!(chi_square_sf(-1.0, 5), 1.0);
        // monotone decreasing in the statistic
        let mut prev = 1.0;
        for i in 1..40 {
            let p = chi_square_sf(i as f64, 6);
            assert!(p <= prev + 1e-15, "sf must be non-increasing");
            prev = p;
        }
    }

    /// The extremes the quantized-KV losslessness matrix can reach: large
    /// dof (many effective bins) and tiny tail mass. Pin against closed
    /// forms (dof 1: `erfc(√(stat/2))`; dof 2: `exp(−stat/2)`) and
    /// published table quantiles at dof 200/1000 — the fixed-iteration
    /// expansions used to truncate silently here and report a partial sum.
    #[test]
    fn chi_square_sf_extreme_pins() {
        // dof 1 deep tail: sf(100, 1) = erfc(√50) ≈ 1.524e-23
        let got = chi_square_sf(100.0, 1);
        let want = 1.523_970_604_832_1e-23;
        assert!(
            ((got - want) / want).abs() < 1e-9,
            "sf(100, 1) = {got:e}, want {want:e}"
        );
        // dof 2 closed form: sf(stat, 2) = exp(−stat/2), down to ~1e-218
        for stat in [10.0f64, 100.0, 500.0, 1000.0] {
            let got = chi_square_sf(stat, 2);
            let want = (-stat / 2.0).exp();
            assert!(
                ((got - want) / want).abs() < 1e-9,
                "sf({stat}, 2) = {got:e}, want {want:e}"
            );
        }
        // published table quantiles at large dof (series/contfrac both sit
        // near the slow x ≈ a switchover here)
        for (stat, dof, want) in [
            (233.994f64, 200usize, 0.05f64),
            (1074.679, 1000, 0.05),
            (1106.969, 1000, 0.01),
        ] {
            let got = chi_square_sf(stat, dof);
            assert!(
                (got - want).abs() < 2e-4,
                "sf({stat}, {dof}) = {got}, want ≈ {want}"
            );
        }
    }

    /// Hardening contract: the sf never returns NaN and stays monotone in
    /// the statistic even at dof and statistic magnitudes far beyond what
    /// the suites produce.
    #[test]
    fn chi_square_sf_never_nan_and_monotone_at_scale() {
        for &dof in &[1usize, 2, 10, 100, 1000, 10_000, 100_000] {
            let mut prev = 1.0f64;
            for i in 0..60 {
                let stat = dof as f64 * (0.05 * i as f64);
                let p = chi_square_sf(stat, dof);
                assert!(!p.is_nan(), "sf({stat}, {dof}) is NaN");
                assert!((0.0..=1.0).contains(&p), "sf({stat}, {dof}) = {p} out of range");
                assert!(p <= prev + 1e-12, "sf not monotone at ({stat}, {dof})");
                prev = p;
            }
        }
        assert_eq!(chi_square_sf(f64::NAN, 5), 0.0);
        assert_eq!(chi_square_sf(f64::INFINITY, 5), 0.0);
        assert_eq!(chi_square_sf(f64::NEG_INFINITY, 5), 1.0);
        // a huge statistic at dof 1 underflows cleanly to 0, not NaN
        assert_eq!(chi_square_sf(1e9, 1), 0.0);
    }

    /// The Wilson–Hilferty fallback (used only on expansion
    /// non-convergence) must itself be a sane approximation.
    #[test]
    fn wilson_hilferty_fallback_close_to_exact() {
        for (stat, dof, want) in
            [(124.342f64, 100usize, 0.05f64), (1074.679, 1000, 0.05), (18.307, 10, 0.05)]
        {
            let got = chi_square_sf_wilson_hilferty(stat, dof as f64);
            assert!(
                (got - want).abs() < 5e-3,
                "WH sf({stat}, {dof}) = {got}, want ≈ {want}"
            );
        }
    }

    #[test]
    fn chi_square_stat_pools_sparse_bins() {
        // uniform expectation, perfect observation: stat 0, dof n-1
        let (s, dof) = chi_square_stat(&[10, 10, 10, 10], &[10.0; 4], 5.0).unwrap();
        assert_eq!(s, 0.0);
        assert_eq!(dof, 3);
        // two tiny-expectation bins pool; the undersized remainder folds
        // into a regular bin instead of standing alone with E < 5
        let (s, dof) =
            chi_square_stat(&[10, 10, 1, 1], &[10.0, 10.0, 1.0, 1.0], 5.0).unwrap();
        assert_eq!(s, 0.0);
        assert_eq!(dof, 1);
        // a pooled remainder meeting the threshold stays its own bin
        let (s, dof) =
            chi_square_stat(&[10, 10, 3, 3], &[10.0, 10.0, 3.0, 3.0], 5.0).unwrap();
        assert_eq!(s, 0.0);
        assert_eq!(dof, 2);
        // a single effective bin is untestable
        assert!(chi_square_stat(&[10, 1], &[10.0, 0.1], 5.0).is_none());
        // a real deviation registers
        let (s, _) = chi_square_stat(&[30, 10], &[20.0, 20.0], 5.0).unwrap();
        assert!((s - 10.0).abs() < 1e-12); // 100/20 + 100/20
    }
}
