//! Tiny CLI argument parser (offline environment has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches that were present.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments (already stripped of argv[0] / subcommand).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("option --{rest} expects a value"))?;
                    out.options.insert(rest.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Whether the bare flag `--name` was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of option `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Integer option with a default; errors on an unparsable value.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    /// Float option with a default; errors on an unparsable value.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(sv(&["pos1", "--k", "v", "--x=3", "--quick", "pos2"]), &["quick"])
            .unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_usize("x", 0).unwrap(), 3);
        assert!(a.flag("quick"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(sv(&["--k"]), &[]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(sv(&["--n", "abc"]), &[]).unwrap();
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(sv(&[]), &[]).unwrap();
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("t", 1.5).unwrap(), 1.5);
    }
}
