//! Shared substrates: deterministic RNG, JSON, CLI parsing, thread pool,
//! statistics helpers. Everything here is dependency-free (std only) because
//! the build environment is offline.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use json::Json;
pub use rng::Pcg64;
