//! Minimal JSON parser/writer (the offline build has no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; used for
//! artifact metadata, prompt sets, selector checkpoints and bench reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// String slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Key→value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; errors carry the key name.
    pub fn get(&self, key: &str) -> Result<&Json, String> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| format!("missing key '{key}'"))
    }

    /// Render with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Object builder: emit JSON without going through a map literal dance.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
/// Array builder from any `Json` iterator.
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}
/// Number builder.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
/// String builder.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("short \\u escape")?;
                            self.i += 4;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            // Surrogate pairs.
                            let cp = if (0xd800..0xdc00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or("short low surrogate")?;
                                    self.i += 6;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2).map_err(|e| e.to_string())?,
                                        16,
                                    )
                                    .map_err(|e| e.to_string())?;
                                    0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                                } else {
                                    return Err("lone high surrogate".into());
                                }
                            } else {
                                cp
                            };
                            out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Re-assemble multi-byte UTF-8.
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let bytes = self
                        .b
                        .get(self.i - 1..self.i - 1 + len)
                        .ok_or("truncated utf-8")?;
                    out.push_str(std::str::from_utf8(bytes).map_err(|e| e.to_string())?);
                    self.i += len - 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo😀\"").unwrap(), Json::Str("héllo😀".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"n": [1.5, -2, 1e3], "s": "x\"y\\z\n", "b": [true, false, null]}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", num(1.0)), ("y", arr([s("a"), s("b")]))]);
        assert_eq!(v.get("y").unwrap().as_arr().unwrap().len(), 2);
    }
}
