//! PCG-XSL-RR 128/64 pseudorandom generator.
//!
//! Speculative-decoding verification is a randomized algorithm; every
//! stochastic choice in the coordinator flows through this generator so runs
//! are exactly reproducible given a seed.

/// Permuted congruential generator (PCG-XSL-RR 128/64).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Derive an independent generator for a sub-task (e.g. one request).
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    /// Sample an index from an unnormalized non-negative weight vector.
    /// Returns `None` when the total mass is zero / non-finite.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> Option<usize> {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut u = self.next_f64() * total;
        let mut last = None;
        for (i, &w) in weights.iter().enumerate() {
            let w = w.max(0.0) as f64;
            if w > 0.0 {
                last = Some(i);
                if u < w {
                    return Some(i);
                }
                u -= w;
            }
        }
        last // numerical slack: fall back to the last positive-mass index
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Pcg64::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Pcg64::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = rng.next_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_weighted_respects_mass() {
        let mut rng = Pcg64::seeded(11);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.sample_weighted(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn sample_weighted_zero_mass_is_none() {
        let mut rng = Pcg64::seeded(13);
        assert!(rng.sample_weighted(&[0.0, 0.0]).is_none());
        assert!(rng.sample_weighted(&[]).is_none());
    }
}
