//! Fixed-size worker pool over std threads (no tokio in this environment).
//!
//! The serving coordinator uses it for request handling; benches use
//! `scope_map` for simple data-parallel sweeps.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A basic thread pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("specdelay-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), handles }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("workers alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Map `f` over `items` with up to `workers` scoped threads, preserving order.
pub fn scope_map<T: Send, R: Send, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let slots = Mutex::new(&mut results);

    thread::scope(|scope| {
        for _ in 0..workers.max(1).min(n.max(1)) {
            scope.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, t)) => {
                        let r = f(t);
                        slots.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("all items done")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop waits for completion
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let out = scope_map((0..50).collect(), 8, |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty() {
        let out: Vec<i32> = scope_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
