//! Fixed-size worker pool plus the deterministic data-parallel layer
//! (no tokio in this environment).
//!
//! The serving coordinator uses [`ThreadPool`] for request handling. Every
//! data-parallel sweep in the crate (benchkit's `run_config` over prompts,
//! `best_static` over grid points, superset scoring over Eq. 3 samples, the
//! bench harnesses) routes through [`par_map_init`], whose contract makes
//! serial and parallel runs produce **bit-identical** results.
//!
//! ## Determinism contract
//!
//! `par_map_init(items, workers, init, f)` maps `f` over `items` with up to
//! `workers` threads. Each worker owns one *contiguous chunk* of the input,
//! builds its private state once via `init` (scratch arenas, buffers), and
//! writes results into a disjoint slice of the output — order-preserving
//! with no per-slot lock and no work-stealing races. `f` receives the
//! item's **global index**, so randomized work derives its stream from the
//! index (`Pcg64::new(seed, index)`), never from the worker or from
//! iteration order. Under that contract the result vector is identical for
//! every worker count, including 1 (the serial path is the same code).
//! State handed out by `init` must act as scratch only: results must not
//! depend on which items previously used the state.
//!
//! Static chunking trades load balancing for simplicity and cache-local
//! writes: a heavily skewed workload degenerates toward the slowest
//! chunk's serial time. If that ever dominates a sweep, a work-queue
//! variant with the same index-seeded contract (output slot = item index)
//! would stay bit-identical — determinism does not depend on the
//! schedule, only on the contract above.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Worker count for data-parallel sweeps: `SPECDELAY_THREADS=n` with
/// n ≥ 1 pins the count (1 forces the serial path); `0`, unset, or an
/// unparsable value mean "auto" — the machine's available parallelism.
pub fn default_workers() -> usize {
    match std::env::var("SPECDELAY_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n,
        _ => thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Deterministic data-parallel map with per-worker init state.
///
/// See the module docs for the determinism contract. `init` runs once per
/// worker (on that worker's thread); `f(state, index, item)` runs for every
/// item with its global index. Results come back in input order.
pub fn par_map_init<T, R, S, I, F>(items: Vec<T>, workers: usize, init: I, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        let mut state = init();
        return items
            .into_iter()
            .enumerate()
            .map(|(i, t)| f(&mut state, i, t))
            .collect();
    }

    // Contiguous chunk per worker (first `n % workers` chunks get one
    // extra item), so the output can be pre-split into disjoint slices.
    let base = n / workers;
    let extra = n % workers;
    let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
    let mut starts: Vec<usize> = Vec::with_capacity(workers);
    {
        let mut it = items.into_iter();
        let mut start = 0usize;
        for w in 0..workers {
            let len = base + usize::from(w < extra);
            starts.push(start);
            chunks.push(it.by_ref().take(len).collect());
            start += len;
        }
    }

    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    {
        let mut slices: Vec<&mut [Option<R>]> = Vec::with_capacity(workers);
        let mut rest = out.as_mut_slice();
        for chunk in &chunks {
            let (head, tail) = rest.split_at_mut(chunk.len());
            slices.push(head);
            rest = tail;
        }
        let init = &init;
        let f = &f;
        thread::scope(|scope| {
            for ((chunk, slice), start) in chunks.into_iter().zip(slices).zip(starts) {
                scope.spawn(move || {
                    let mut state = init();
                    for (off, t) in chunk.into_iter().enumerate() {
                        slice[off] = Some(f(&mut state, start + off, t));
                    }
                });
            }
        });
    }
    out.into_iter()
        .map(|r| r.expect("worker filled every slot"))
        .collect()
}

/// Map `f` over `items` with up to `workers` threads, preserving order.
/// Stateless convenience wrapper over [`par_map_init`].
pub fn scope_map<T: Send, R: Send, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    F: Fn(T) -> R + Sync,
{
    par_map_init(items, workers, || (), |_state, _i, t| f(t))
}

/// A basic thread pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `workers` (≥ 1) named worker threads.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("specdelay-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), handles }
    }

    /// Enqueue a job on the pool.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("workers alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop waits for completion
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let out = scope_map((0..50).collect(), 8, |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty() {
        let out: Vec<i32> = scope_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    /// Index-seeded randomized work must come out bit-identical for every
    /// worker count — the determinism contract the bench harness relies on.
    #[test]
    fn par_map_init_bit_identical_across_worker_counts() {
        let work = |state: &mut Vec<f64>, i: usize, x: u64| -> f64 {
            // scratch state is reused across items but must not leak
            state.clear();
            let mut rng = Pcg64::new(0xD0, i as u64);
            for _ in 0..64 {
                state.push(rng.next_f64() * x as f64);
            }
            state.iter().sum()
        };
        let items: Vec<u64> = (1..=97).collect();
        let serial = par_map_init(items.clone(), 1, Vec::new, work);
        for workers in [2, 3, 5, 8, 200] {
            let par = par_map_init(items.clone(), workers, Vec::new, work);
            assert_eq!(serial, par, "workers = {workers}");
        }
    }

    #[test]
    fn par_map_init_runs_init_once_per_worker() {
        let inits = AtomicUsize::new(0);
        let out = par_map_init(
            (0..40).collect::<Vec<usize>>(),
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
            },
            |_state, i, x| {
                assert_eq!(i, x);
                x * 2
            },
        );
        assert_eq!(out, (0..40).map(|x| x * 2).collect::<Vec<_>>());
        assert_eq!(inits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn par_map_init_more_workers_than_items() {
        let out = par_map_init((0..3).collect::<Vec<i32>>(), 16, || (), |_state, _i, x| x + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }
}
