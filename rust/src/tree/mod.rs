//! Draft tree structure (paper Definition 3.1 / 5.2).
//!
//! Nodes are distinct contexts; child lists carry *multiplicity* (two i.i.d.
//! paths sampling the same token at the same node contribute the same child
//! node twice). Node 0 is always the root: the last committed token, whose
//! KV row is recomputed by the target tree pass.
//!
//! ## Index invariant (load-bearing for the hot path)
//!
//! [`DraftTree::add_child`] assigns every *new* node the index
//! `nodes.len()` at creation time, so within any node's child list the
//! **first occurrence of each distinct child has a strictly larger index
//! than every previously-seen distinct child**. Duplicate occurrences repeat
//! an earlier (smaller-or-equal) index. Consumers exploit this to
//! deduplicate children with a running maximum in O(k) and zero
//! allocations instead of an O(k²) `seen.contains` scan.
//!
//! ## Hot accessors
//!
//! Every accessor the per-block verification walk touches has an `_into`
//! variant writing into caller-provided scratch (see
//! `verify::VerifyScratch`), plus [`CsrChildren`], a reusable CSR snapshot
//! of the child lists for pointer-chase-free walks. The allocating wrappers
//! remain for construction-time and test use.

use crate::dist::NodeDist;

/// Where a node's draft-model KV row came from (for cache commits).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Provenance {
    /// The committed root token: no draft rows to commit.
    Root,
    /// Trunk rollout step `step` (single path, K = 1).
    Trunk { step: usize },
    /// Branch rollout: path `branch`, step `step`.
    Branch { branch: usize, step: usize },
}

/// One draft-tree node: a distinct context extending its parent by `token`.
#[derive(Clone, Debug)]
pub struct Node {
    /// Token extending the parent context (root: the committed root token).
    pub token: u32,
    /// Parent node index (`None` for the root).
    pub parent: Option<usize>,
    /// Edge count from the root.
    pub depth: usize,
    /// Children **with multiplicity**, in draft order.
    pub children: Vec<usize>,
    /// Draft distribution q(.|context of this node) — the transformed
    /// distribution the rollout actually sampled children from. Dense or
    /// sparse per the construction-time [`crate::dist::DistStorage`]; one
    /// tree always uses one representation.
    pub q: Option<NodeDist>,
    /// Target distribution p(.|context of this node); filled after the tree
    /// pass.
    pub p: Option<NodeDist>,
    /// Which rollout produced this node's draft KV row.
    pub provenance: Provenance,
}

/// The i.i.d. path draws that produced the tree. Distinct paths are
/// independent draws even where their tokens coincide; the first
/// `shared_edges` edges (the delayed-expansion trunk) are a *single* draw
/// shared by every path. Bottom-up verification (Traversal) needs this to
/// know how many independent trials each edge supports.
#[derive(Clone, Debug, Default)]
pub struct PathDraws {
    /// Root→leaf node-index sequences (root excluded), in draft order.
    pub paths: Vec<Vec<usize>>,
    /// Number of leading edges shared as one draw across all paths.
    pub shared_edges: usize,
}

/// Reusable CSR (compressed sparse row) snapshot of a tree's child lists.
///
/// One `build` per verification walk turns the per-node `Vec<usize>` child
/// lists into three flat arrays, so the walk reads contiguous child/token
/// slices with no per-node allocation. All buffers retain capacity across
/// rebuilds; steady-state rebuilds are allocation-free.
#[derive(Clone, Debug, Default)]
pub struct CsrChildren {
    /// `offsets[i]..offsets[i+1]` bounds node i's slice in `children`/`tokens`.
    offsets: Vec<u32>,
    /// Child node indices with multiplicity, in draft order.
    children: Vec<u32>,
    /// `tokens[j]` = token of `children[j]` (gathered once at build).
    tokens: Vec<u32>,
}

impl CsrChildren {
    /// Rebuild the snapshot for `tree`, reusing all capacity.
    pub fn build(&mut self, tree: &DraftTree) {
        self.offsets.clear();
        self.children.clear();
        self.tokens.clear();
        self.offsets.reserve(tree.len() + 1);
        self.offsets.push(0);
        for node in &tree.nodes {
            for &c in &node.children {
                self.children.push(c as u32);
                self.tokens.push(tree.nodes[c].token);
            }
            self.offsets.push(self.children.len() as u32);
        }
    }

    /// Child node indices of `node`, with multiplicity.
    #[inline]
    pub fn child_nodes(&self, node: usize) -> &[u32] {
        let (a, b) = (self.offsets[node] as usize, self.offsets[node + 1] as usize);
        &self.children[a..b]
    }

    /// Child tokens of `node`, with multiplicity (aligned with
    /// [`CsrChildren::child_nodes`]).
    #[inline]
    pub fn child_tokens(&self, node: usize) -> &[u32] {
        let (a, b) = (self.offsets[node] as usize, self.offsets[node + 1] as usize);
        &self.tokens[a..b]
    }
}

/// A draft tree plus construction helpers.
///
/// ```
/// use specdelay::tree::{DraftTree, Provenance};
///
/// let mut t = DraftTree::new(7);
/// let a = t.add_child(0, 1, Provenance::Trunk { step: 1 });
/// let b = t.add_child(0, 1, Provenance::Branch { branch: 1, step: 1 });
/// assert_eq!(a, b, "identical contexts merge; multiplicity grows");
/// assert_eq!(t.child_tokens(0), vec![1, 1]);
/// assert_eq!(t.distinct_children(0), vec![a]);
/// assert_eq!(t.max_depth(), 1);
/// ```
#[derive(Clone, Debug)]
pub struct DraftTree {
    /// Nodes in creation order; node 0 is always the root.
    pub nodes: Vec<Node>,
    /// Draw provenance; `None` means "each leaf path is an independent
    /// draw" (plain i.i.d. multipath).
    pub path_draws: Option<PathDraws>,
}

impl DraftTree {
    /// New tree containing only the root token.
    pub fn new(root_token: u32) -> DraftTree {
        DraftTree {
            nodes: vec![Node {
                token: root_token,
                parent: None,
                depth: 0,
                children: Vec::new(),
                q: None,
                p: None,
                provenance: Provenance::Root,
            }],
            path_draws: None,
        }
    }

    /// Path draws: recorded ones, or one independent draw per leaf.
    pub fn draws(&self) -> PathDraws {
        match &self.path_draws {
            Some(d) => d.clone(),
            None => PathDraws {
                paths: self.leaves().iter().map(|&l| self.path_nodes(l)).collect(),
                shared_edges: 0,
            },
        }
    }

    /// Node count (root included).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    /// Whether the tree holds no nodes (only via `DraftTree::new(0)` swaps).
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Deepest node's edge count from the root.
    pub fn max_depth(&self) -> usize {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// Append a child of `parent` with the given token; if an identical
    /// child context already exists it is reused and only the multiplicity
    /// grows. Returns the child node index.
    ///
    /// New nodes always receive index `nodes.len()`, which upholds the
    /// first-occurrence-increasing invariant documented on the module.
    pub fn add_child(&mut self, parent: usize, token: u32, provenance: Provenance) -> usize {
        if let Some(&existing) = self.nodes[parent]
            .children
            .iter()
            .find(|&&c| self.nodes[c].token == token)
        {
            self.nodes[parent].children.push(existing);
            return existing;
        }
        let idx = self.nodes.len();
        let depth = self.nodes[parent].depth + 1;
        self.nodes.push(Node {
            token,
            parent: Some(parent),
            depth,
            children: Vec::new(),
            q: None,
            p: None,
            provenance,
        });
        self.nodes[parent].children.push(idx);
        idx
    }

    /// Set the draft distribution at a node (idempotent: identical contexts
    /// across branches produce identical dists). Accepts `Dist`,
    /// `SparseDist` or `NodeDist`.
    pub fn set_q(&mut self, node: usize, q: impl Into<NodeDist>) {
        self.nodes[node].q = Some(q.into());
    }

    /// Set the target distribution at a node (after the tree pass).
    pub fn set_p(&mut self, node: usize, p: impl Into<NodeDist>) {
        self.nodes[node].p = Some(p.into());
    }

    /// Child tokens of `node` with multiplicity, written into `out`.
    pub fn child_tokens_into(&self, node: usize, out: &mut Vec<u32>) {
        out.clear();
        for &c in &self.nodes[node].children {
            out.push(self.nodes[c].token);
        }
    }

    /// Child tokens of `node` with multiplicity, in draft order.
    pub fn child_tokens(&self, node: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.nodes[node].children.len());
        self.child_tokens_into(node, &mut out);
        out
    }

    /// Visit the first occurrence of each distinct child of `node` as
    /// `(position_in_child_list, child_index)`, in first-appearance order.
    ///
    /// O(k) and allocation-free. This is the home of the
    /// first-occurrence-increasing index invariant (module docs): an
    /// occurrence is a duplicate exactly when it does not exceed the
    /// running maximum of children seen so far. Every consumer that needs
    /// per-distinct-child iteration over a `DraftTree` (Eq. 3 estimators,
    /// accessors) routes through here; the one external replica is the
    /// reach DP in `selector::score`, whose `MergedBranches` upholds the
    /// same invariant (a child's first edge is its creation) and documents
    /// the dependency at the dedup site.
    pub fn for_each_distinct_child<F: FnMut(usize, usize)>(&self, node: usize, mut f: F) {
        let mut max_seen: Option<usize> = None;
        for (i, &c) in self.nodes[node].children.iter().enumerate() {
            let first = match max_seen {
                Some(m) => c > m,
                None => true,
            };
            if first {
                max_seen = Some(c);
                f(i, c);
            }
        }
    }

    /// Distinct child node indices in first-appearance order, written into
    /// `out`.
    pub fn distinct_children_into(&self, node: usize, out: &mut Vec<usize>) {
        out.clear();
        self.for_each_distinct_child(node, |_, c| out.push(c));
    }

    /// Distinct child node indices in first-appearance order.
    pub fn distinct_children(&self, node: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.nodes[node].children.len());
        self.distinct_children_into(node, &mut out);
        out
    }

    /// Find the child node of `node` carrying `token`.
    pub fn child_with_token(&self, node: usize, token: u32) -> Option<usize> {
        self.nodes[node]
            .children
            .iter()
            .copied()
            .find(|&c| self.nodes[c].token == token)
    }

    /// Root-to-node token path (excluding the root token itself).
    pub fn path_tokens(&self, node: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.path_tokens_into(node, &mut out);
        out
    }

    /// Root-to-node token path (root excluded), written into `out`.
    pub fn path_tokens_into(&self, mut node: usize, out: &mut Vec<u32>) {
        out.clear();
        while let Some(p) = self.nodes[node].parent {
            out.push(self.nodes[node].token);
            node = p;
        }
        out.reverse();
    }

    /// Node indices from root (exclusive) down to `node` (inclusive).
    pub fn path_nodes(&self, node: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.path_nodes_into(node, &mut out);
        out
    }

    /// Node indices from root (exclusive) down to `node` (inclusive),
    /// written into `out`.
    pub fn path_nodes_into(&self, mut node: usize, out: &mut Vec<usize>) {
        out.clear();
        while let Some(p) = self.nodes[node].parent {
            out.push(node);
            node = p;
        }
        out.reverse();
    }

    /// Is `anc` an ancestor of `node` (or equal)?
    pub fn is_ancestor_or_self(&self, anc: usize, node: usize) -> bool {
        let mut cur = node;
        loop {
            if cur == anc {
                return true;
            }
            match self.nodes[cur].parent {
                Some(p) => cur = p,
                None => return false,
            }
        }
    }

    /// Additive attention bias for the target tree pass, padded to
    /// `n_bucket` nodes: bias[i][j] = 0 when j is ancestor-or-self of i,
    /// else -1e30. Padding rows see only themselves.
    ///
    /// Written into `out` (capacity reused). Because parents always precede
    /// children in index order, each row is the parent's finished row copied
    /// wholesale (one memcpy of the bucket) plus the node's own diagonal —
    /// O(N·bucket) instead of re-walking the ancestor chain per node.
    pub fn attention_bias_into(&self, n_bucket: usize, out: &mut Vec<f32>) {
        assert!(self.len() <= n_bucket, "tree {} > bucket {n_bucket}", self.len());
        out.clear();
        out.resize(n_bucket * n_bucket, -1e30f32);
        for i in 0..self.len() {
            if let Some(p) = self.nodes[i].parent {
                out.copy_within(p * n_bucket..(p + 1) * n_bucket, i * n_bucket);
            }
            out[i * n_bucket + i] = 0.0;
        }
        for i in self.len()..n_bucket {
            out[i * n_bucket + i] = 0.0;
        }
    }

    /// Allocating wrapper over [`DraftTree::attention_bias_into`].
    pub fn attention_bias(&self, n_bucket: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n_bucket * n_bucket);
        self.attention_bias_into(n_bucket, &mut out);
        out
    }

    /// Tokens and positions padded to the bucket, for the tree pass.
    /// `root_pos` is the cache position of the root token; node at depth d
    /// sits at `root_pos + d`. Padding uses `pad_token` at `root_pos`.
    pub fn tokens_positions(
        &self,
        n_bucket: usize,
        root_pos: usize,
        pad_token: u32,
    ) -> (Vec<i32>, Vec<i32>) {
        let mut toks = vec![pad_token as i32; n_bucket];
        let mut pos = vec![root_pos as i32; n_bucket];
        for (i, n) in self.nodes.iter().enumerate() {
            toks[i] = n.token as i32;
            pos[i] = (root_pos + n.depth) as i32;
        }
        (toks, pos)
    }

    /// All leaves (no children), written into `out` in node-index order.
    pub fn leaves_into(&self, out: &mut Vec<usize>) {
        out.clear();
        for (i, n) in self.nodes.iter().enumerate() {
            if n.children.is_empty() {
                out.push(i);
            }
        }
    }

    /// All leaves (no children), in node-index order (= draft order).
    pub fn leaves(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.leaves_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(tokens: &[u32]) -> DraftTree {
        let mut t = DraftTree::new(7);
        let mut cur = 0;
        for (i, &tok) in tokens.iter().enumerate() {
            cur = t.add_child(cur, tok, Provenance::Trunk { step: i });
        }
        t
    }

    #[test]
    fn chain_structure() {
        let t = chain(&[1, 2, 3]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.max_depth(), 3);
        assert_eq!(t.path_tokens(3), vec![1, 2, 3]);
        assert_eq!(t.path_nodes(3), vec![1, 2, 3]);
        assert_eq!(t.leaves(), vec![3]);
    }

    #[test]
    fn multiplicity_merging() {
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 5, Provenance::Branch { branch: 0, step: 0 });
        let b = t.add_child(0, 5, Provenance::Branch { branch: 1, step: 0 });
        let c = t.add_child(0, 9, Provenance::Branch { branch: 2, step: 0 });
        assert_eq!(a, b, "same context merges");
        assert_ne!(a, c);
        assert_eq!(t.child_tokens(0), vec![5, 5, 9]);
        assert_eq!(t.distinct_children(0), vec![a, c]);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn distinct_children_running_max_dedup() {
        // interleave duplicates: children [a, c, a, c, d] with first
        // occurrences in increasing index order (the structural invariant)
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 5, Provenance::Branch { branch: 0, step: 0 });
        let c = t.add_child(0, 9, Provenance::Branch { branch: 1, step: 0 });
        let a2 = t.add_child(0, 5, Provenance::Branch { branch: 2, step: 0 });
        let c2 = t.add_child(0, 9, Provenance::Branch { branch: 3, step: 0 });
        let d = t.add_child(0, 2, Provenance::Branch { branch: 4, step: 0 });
        assert_eq!((a, c), (a2, c2));
        assert_eq!(t.nodes[0].children, vec![a, c, a, c, d]);
        assert_eq!(t.distinct_children(0), vec![a, c, d]);
        let mut scratch = Vec::new();
        t.distinct_children_into(0, &mut scratch);
        assert_eq!(scratch, vec![a, c, d]);
    }

    #[test]
    fn csr_matches_child_lists() {
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 1, Provenance::Trunk { step: 0 });
        let b = t.add_child(a, 2, Provenance::Trunk { step: 1 });
        let _b2 = t.add_child(a, 2, Provenance::Branch { branch: 1, step: 0 });
        let c = t.add_child(a, 3, Provenance::Branch { branch: 2, step: 0 });
        let mut csr = CsrChildren::default();
        csr.build(&t);
        assert_eq!(csr.child_nodes(0), &[a as u32]);
        assert_eq!(csr.child_tokens(0), &[1]);
        assert_eq!(csr.child_nodes(a), &[b as u32, b as u32, c as u32]);
        assert_eq!(csr.child_tokens(a), &[2, 2, 3]);
        assert!(csr.child_nodes(b).is_empty());
        // rebuild on a different tree reuses buffers and stays consistent
        let t2 = chain(&[4, 6]);
        csr.build(&t2);
        assert_eq!(csr.child_tokens(0), &[4]);
        assert_eq!(csr.child_tokens(1), &[6]);
        assert!(csr.child_tokens(2).is_empty());
    }

    #[test]
    fn ancestor_queries() {
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 1, Provenance::Trunk { step: 0 });
        let b = t.add_child(a, 2, Provenance::Trunk { step: 1 });
        let c = t.add_child(0, 3, Provenance::Branch { branch: 1, step: 0 });
        assert!(t.is_ancestor_or_self(0, b));
        assert!(t.is_ancestor_or_self(a, b));
        assert!(t.is_ancestor_or_self(b, b));
        assert!(!t.is_ancestor_or_self(c, b));
        assert!(!t.is_ancestor_or_self(b, a));
    }

    #[test]
    fn bias_matrix() {
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 1, Provenance::Trunk { step: 0 });
        let b = t.add_child(a, 2, Provenance::Trunk { step: 1 });
        let c = t.add_child(0, 3, Provenance::Branch { branch: 1, step: 0 });
        let n = 6;
        let bias = t.attention_bias(n);
        let at = |i: usize, j: usize| bias[i * n + j];
        // b sees root, a, b; not c
        assert_eq!(at(b, 0), 0.0);
        assert_eq!(at(b, a), 0.0);
        assert_eq!(at(b, b), 0.0);
        assert!(at(b, c) < -1e29);
        // a does not see its descendant b
        assert!(at(a, b) < -1e29);
        // c sees root and itself only
        assert_eq!(at(c, 0), 0.0);
        assert_eq!(at(c, c), 0.0);
        assert!(at(c, a) < -1e29);
        // padding rows self-only
        assert_eq!(at(5, 5), 0.0);
        assert!(at(5, 0) < -1e29);
    }

    #[test]
    fn bias_into_reuses_buffer() {
        let t = chain(&[1, 2]);
        let mut buf = Vec::new();
        t.attention_bias_into(5, &mut buf);
        let first = buf.clone();
        // second fill must produce identical contents in the same buffer
        t.attention_bias_into(5, &mut buf);
        assert_eq!(buf, first);
        assert_eq!(buf.len(), 25);
    }

    #[test]
    fn tokens_positions_padding() {
        let t = chain(&[1, 2]);
        let (toks, pos) = t.tokens_positions(5, 10, 258);
        assert_eq!(toks, vec![7, 1, 2, 258, 258]);
        assert_eq!(pos, vec![10, 11, 12, 10, 10]);
    }
}
