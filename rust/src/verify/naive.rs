//! Naive speculative sampling as an OTLP solver — paper Algorithm 2 / 7 / 12.
//!
//! Accept the *first* draft token X_1 with probability min(1, p(X_1)/q(X_1));
//! otherwise sample from the residual ∝ (p − q)_+. Used for both the
//! single-path "Naive" baseline and the multi-path "NaiveTree" (the residual
//! draw may land on X_2..X_k, letting the walk branch).
//!
//! Sparse inputs run the O(|support|) residual merge; dense inputs the
//! vocab-length reference. Both draw identical rng streams.

use super::{OtlpSolver, SolverScratch};
use crate::dist::{Dist, NodeDist};
use crate::util::Pcg64;

/// The naive speculative-sampling OTLP solver (paper Algorithm 2).
pub struct Naive;

impl OtlpSolver for Naive {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn solve_scratch(
        &self,
        p: &NodeDist,
        q: &NodeDist,
        xs: &[u32],
        rng: &mut Pcg64,
        scratch: &mut SolverScratch,
    ) -> u32 {
        let x1 = xs[0] as usize;
        let ratio = if q.p(x1) > 0.0 { p.p(x1) / q.p(x1) } else { 1.0 };
        if rng.next_f64() <= ratio as f64 {
            return x1 as u32;
        }
        if NodeDist::residual_into(p, q, &mut scratch.dist_a) {
            scratch.dist_a.sample(rng) as u32
        } else {
            // p == q: rejection has probability zero; numerical fallback.
            x1 as u32
        }
    }

    /// Algorithm 7: Σ min(p, q) + Σ (p − q)_+ (1 − (1 − q)^{k−1}).
    fn acceptance_rate(&self, p: &Dist, q: &Dist, k: usize) -> f64 {
        let overlap: f64 = p
            .0
            .iter()
            .zip(&q.0)
            .map(|(&a, &b)| a.min(b) as f64)
            .sum();
        let residual_hit: f64 = p
            .0
            .iter()
            .zip(&q.0)
            .map(|(&a, &b)| {
                ((a - b).max(0.0) as f64)
                    * (1.0 - (1.0 - b as f64).powi(k as i32 - 1))
            })
            .sum();
        overlap + residual_hit
    }

    /// Algorithm 12: B(X_i) = (1 − a) p_res(X_i) + a·1{X_i = X_1},
    /// a = min(1, p(X_1)/q(X_1)).
    fn branching_into(&self, p: &NodeDist, q: &NodeDist, xs: &[u32], out: &mut Vec<f64>) {
        let x1 = xs[0] as usize;
        let a = if q.p(x1) > 0.0 {
            (p.p(x1) / q.p(x1)).min(1.0) as f64
        } else {
            1.0
        };
        let res = NodeDist::residual(p, q);
        out.clear();
        out.extend(xs.iter().map(|&x| {
            let r = res.as_ref().map_or(0.0, |d| d.p(x as usize) as f64);
            (1.0 - a) * r + if x as usize == x1 { a } else { 0.0 }
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nd(v: Vec<f32>) -> NodeDist {
        NodeDist::from(Dist(v))
    }

    fn pq() -> (NodeDist, NodeDist) {
        (nd(vec![0.5, 0.3, 0.2]), nd(vec![0.2, 0.2, 0.6]))
    }

    /// The solver output must follow p for any q (OTLP property).
    #[test]
    fn output_follows_p() {
        let (p, q) = pq();
        let mut rng = Pcg64::seeded(3);
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            let xs: Vec<u32> = (0..2).map(|_| q.sample(&mut rng) as u32).collect();
            counts[Naive.solve(&p, &q, &xs, &mut rng) as usize] += 1;
        }
        for t in 0..3 {
            let f = counts[t] as f64 / n as f64;
            assert!((f - p.p(t) as f64).abs() < 0.01, "token {t}: {f}");
        }
    }

    /// Scratch-based and allocating entry points draw identical streams —
    /// in both representations.
    #[test]
    fn solve_scratch_matches_solve() {
        let (p, q) = pq();
        let (ps, qs) = (p.sparsify(), q.sparsify());
        let mut scratch = SolverScratch::default();
        for seed in 0..100 {
            let mut r1 = Pcg64::seeded(seed);
            let mut r2 = Pcg64::seeded(seed);
            let mut r3 = Pcg64::seeded(seed);
            let xs = [2u32, 0];
            let a = Naive.solve(&p, &q, &xs, &mut r1);
            let b = Naive.solve_scratch(&p, &q, &xs, &mut r2, &mut scratch);
            let c = Naive.solve_scratch(&ps, &qs, &xs, &mut r3, &mut scratch);
            assert_eq!(a, b, "seed {seed}");
            assert_eq!(a, c, "seed {seed} (sparse)");
        }
    }

    #[test]
    fn acceptance_rate_matches_mc() {
        let p = Dist(vec![0.5, 0.3, 0.2]);
        let q = Dist(vec![0.2, 0.2, 0.6]);
        let (pn, qn) = (nd(p.0.clone()), nd(q.0.clone()));
        for k in 1..=4 {
            let exact = Naive.acceptance_rate(&p, &q, k);
            let mut rng = Pcg64::seeded(10 + k as u64);
            let n = 80_000;
            let mut hits = 0usize;
            for _ in 0..n {
                let xs: Vec<u32> = (0..k).map(|_| q.sample(&mut rng) as u32).collect();
                let y = Naive.solve(&pn, &qn, &xs, &mut rng);
                if xs.contains(&y) {
                    hits += 1;
                }
            }
            let mc = hits as f64 / n as f64;
            assert!((mc - exact).abs() < 0.01, "k={k}: mc {mc} vs exact {exact}");
        }
    }

    #[test]
    fn branching_matches_mc() {
        let (p, q) = pq();
        let xs = vec![2u32, 0, 1];
        let b = Naive.branching(&p, &q, &xs);
        assert_eq!(b, Naive.branching(&p.sparsify(), &q.sparsify(), &xs));
        let mut rng = Pcg64::seeded(20);
        let n = 120_000usize;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            let y = Naive.solve(&p, &q, &xs, &mut rng) as usize;
            counts[y] += 1;
        }
        for (i, &x) in xs.iter().enumerate() {
            let mc = counts[x as usize] as f64 / n as f64;
            assert!((mc - b[i]).abs() < 0.01, "pos {i}: mc {mc} vs {b:?}");
        }
    }
}
