//! SpecInfer (Miao et al. 2024) — paper Algorithm 4 / 9 / 14.
//!
//! Multi-round naive with uniform child selection and per-round residual
//! update of p. The branching calculator is the exact multiset recursion of
//! Algorithm 14 (k ≤ 4 keeps it tiny).
//!
//! The per-round residual chain only ever *shrinks* the support, so the
//! sparse path keeps every round O(|support|) via the sparse residual merge.

use std::collections::HashMap;

use super::{OtlpSolver, SolverScratch};
use crate::dist::{Dist, NodeDist};
use crate::util::Pcg64;

/// The SpecInfer multi-round OTLP solver (paper Algorithm 4).
pub struct SpecInfer;

/// p ← normalize((p − q)_+); falls back to p unchanged on zero mass.
fn residualize(p: &NodeDist, q: &NodeDist) -> NodeDist {
    NodeDist::residual(p, q).unwrap_or_else(|| p.clone())
}

impl OtlpSolver for SpecInfer {
    fn name(&self) -> &'static str {
        "SpecInfer"
    }

    fn solve_scratch(
        &self,
        p: &NodeDist,
        q: &NodeDist,
        xs: &[u32],
        rng: &mut Pcg64,
        scratch: &mut SolverScratch,
    ) -> u32 {
        // multiset of remaining draws in reusable scratch; the round target
        // stays a borrow of `p` until the first rejection forces a residual
        // (common case: round 1 accepts and no support-length copy happens),
        // then ping-pongs between dist_a and dist_b
        scratch.tokens.clear();
        scratch.tokens.extend_from_slice(xs);
        let mut on_p = true;
        while !scratch.tokens.is_empty() {
            let idx = rng.next_below(scratch.tokens.len());
            let x = scratch.tokens[idx] as usize;
            let cur = if on_p { p } else { &scratch.dist_a };
            let ratio = if q.p(x) > 0.0 {
                cur.p(x) as f64 / q.p(x) as f64
            } else {
                f64::INFINITY
            };
            if rng.next_f64() <= ratio {
                return x as u32;
            }
            // p ← normalize((p − q)_+); zero residual mass keeps the current
            // target (residualize fallback), matching the allocating path
            if on_p {
                if NodeDist::residual_into(p, q, &mut scratch.dist_a) {
                    on_p = false;
                }
            } else if NodeDist::residual_into(&scratch.dist_a, q, &mut scratch.dist_b) {
                std::mem::swap(&mut scratch.dist_a, &mut scratch.dist_b);
            }
            scratch.tokens.swap_remove(idx);
        }
        if on_p {
            p.sample(rng) as u32
        } else {
            scratch.dist_a.sample(rng) as u32
        }
    }

    /// Algorithm 9.
    fn acceptance_rate(&self, p: &Dist, q: &Dist, k: usize) -> f64 {
        let n = p.len();
        let mut p_cur: Vec<f64> = p.0.iter().map(|&v| v as f64).collect();
        let mut p_rej = 1.0f64;
        let mut m = vec![1.0f64; n];
        for _ in 0..k {
            let r: f64 = p_cur
                .iter()
                .zip(&q.0)
                .map(|(&a, &b)| a.min(b as f64))
                .sum();
            if r >= 1.0 - 1e-12 {
                // every round accepts: rejection path has zero mass
                p_rej = 0.0;
                break;
            }
            p_rej *= 1.0 - r;
            for t in 0..n {
                let miss = (q.0[t] as f64 - p_cur[t]).max(0.0) / (1.0 - r);
                m[t] *= (1.0 - miss).max(0.0);
            }
            // p ∝ (p − q)_+
            let mut mass = 0.0;
            for t in 0..n {
                p_cur[t] = (p_cur[t] - q.0[t] as f64).max(0.0);
                mass += p_cur[t];
            }
            if mass <= 0.0 {
                break;
            }
            for v in p_cur.iter_mut() {
                *v /= mass;
            }
        }
        let tail: f64 = p_cur
            .iter()
            .zip(&m)
            .map(|(&pt, &mt)| pt * (1.0 - mt))
            .sum();
        (1.0 - p_rej) + p_rej * tail
    }

    /// Algorithm 14 — exact recursion over sub-multisets.
    fn branching_into(&self, p: &NodeDist, q: &NodeDist, xs: &[u32], out: &mut Vec<f64>) {
        let k = xs.len();
        // Pre-compute round distributions p_0..p_k and acceptance vectors
        // a_i(t) = min(1, p_{i-1}(t)/q(t)) for rounds i = 1..k.
        let mut p_rounds: Vec<NodeDist> = vec![p.clone()];
        for _ in 0..k {
            let last = p_rounds.last().unwrap();
            p_rounds.push(residualize(last, q));
        }
        let accept = |round: usize, t: usize| -> f64 {
            // round is 1-based: uses p_{round-1}
            if q.p(t) > 0.0 {
                (p_rounds[round - 1].p(t) as f64 / q.p(t) as f64).min(1.0)
            } else {
                1.0
            }
        };

        // B_i(S; x): prob of eventually outputting x given the remaining
        // multiset S at the start of round i+1 (|S| = k − i).
        // Memoized over (i, sorted multiset, x).
        #[allow(clippy::too_many_arguments)]
        fn rec(
            i: usize,
            s: &mut Vec<u32>,
            x: u32,
            k: usize,
            p_rounds: &[NodeDist],
            q: &NodeDist,
            accept: &dyn Fn(usize, usize) -> f64,
            memo: &mut HashMap<(usize, Vec<u32>, u32), f64>,
        ) -> f64 {
            if i == k {
                return p_rounds[k].p(x as usize) as f64;
            }
            let mut key_s = s.clone();
            key_s.sort_unstable();
            if let Some(&v) = memo.get(&(i, key_s.clone(), x)) {
                return v;
            }
            let len = s.len() as f64;
            let mut total = 0.0;
            for j in 0..s.len() {
                let t = s[j];
                let a = accept(i + 1, t as usize);
                let hit = if t == x { a } else { 0.0 };
                let removed = s.swap_remove(j);
                let deeper = rec(i + 1, s, x, k, p_rounds, q, accept, memo);
                s.push(removed);
                let last = s.len() - 1;
                s.swap(j, last);
                total += (hit + (1.0 - a) * deeper) / len;
            }
            memo.insert((i, key_s, x), total);
            total
        }

        let mut memo = HashMap::new();
        out.clear();
        out.extend(xs.iter().map(|&x| {
            let mut s = xs.to_vec();
            rec(0, &mut s, x, k, &p_rounds, q, &accept, &mut memo)
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pq() -> (NodeDist, NodeDist) {
        (
            NodeDist::from(Dist(vec![0.45, 0.25, 0.2, 0.1])),
            NodeDist::from(Dist(vec![0.1, 0.3, 0.25, 0.35])),
        )
    }

    #[test]
    fn output_follows_p() {
        let (p, q) = pq();
        let mut rng = Pcg64::seeded(6);
        let n = 80_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let xs: Vec<u32> = (0..3).map(|_| q.sample(&mut rng) as u32).collect();
            counts[SpecInfer.solve(&p, &q, &xs, &mut rng) as usize] += 1;
        }
        for t in 0..4 {
            let f = counts[t] as f64 / n as f64;
            assert!((f - p.p(t) as f64).abs() < 0.012, "token {t}: {f}");
        }
    }

    /// The scratch path must replay the identical randomized algorithm —
    /// and the sparse representation the identical stream again.
    #[test]
    fn solve_scratch_matches_solve() {
        let (p, q) = pq();
        let (ps, qs) = (p.sparsify(), q.sparsify());
        let mut scratch = SolverScratch::default();
        for seed in 0..200 {
            let mut r1 = Pcg64::seeded(seed);
            let mut r2 = Pcg64::seeded(seed);
            let mut r3 = Pcg64::seeded(seed);
            let xs = [1u32, 3, 1, 0];
            let a = SpecInfer.solve(&p, &q, &xs, &mut r1);
            let b = SpecInfer.solve_scratch(&p, &q, &xs, &mut r2, &mut scratch);
            let c = SpecInfer.solve_scratch(&ps, &qs, &xs, &mut r3, &mut scratch);
            assert_eq!(a, b, "seed {seed}");
            assert_eq!(a, c, "seed {seed} (sparse)");
        }
    }

    #[test]
    fn acceptance_rate_matches_mc() {
        let (p, q) = pq();
        let (pd, qd) = (p.to_dense(), q.to_dense());
        for k in 1..=4 {
            let exact = SpecInfer.acceptance_rate(&pd, &qd, k);
            let mut rng = Pcg64::seeded(60 + k as u64);
            let n = 80_000;
            let mut hits = 0usize;
            for _ in 0..n {
                let xs: Vec<u32> = (0..k).map(|_| q.sample(&mut rng) as u32).collect();
                if xs.contains(&SpecInfer.solve(&p, &q, &xs, &mut rng)) {
                    hits += 1;
                }
            }
            let mc = hits as f64 / n as f64;
            assert!((mc - exact).abs() < 0.012, "k={k}: mc {mc} vs exact {exact}");
        }
    }

    #[test]
    fn branching_matches_mc() {
        let (p, q) = pq();
        let xs = vec![1u32, 3, 1, 0];
        let b = SpecInfer.branching(&p, &q, &xs);
        assert_eq!(b, SpecInfer.branching(&p.sparsify(), &q.sparsify(), &xs));
        let mut rng = Pcg64::seeded(70);
        let n = 150_000usize;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[SpecInfer.solve(&p, &q, &xs, &mut rng) as usize] += 1;
        }
        for (i, &x) in xs.iter().enumerate() {
            let mc = counts[x as usize] as f64 / n as f64;
            assert!((mc - b[i]).abs() < 0.012, "pos {i} tok {x}: mc {mc} vs {}", b[i]);
        }
    }

    #[test]
    fn reduces_to_naive_at_k1() {
        let (p, q) = pq();
        let (pd, qd) = (p.to_dense(), q.to_dense());
        let a = SpecInfer.acceptance_rate(&pd, &qd, 1);
        let n = super::super::naive::Naive.acceptance_rate(&pd, &qd, 1);
        assert!((a - n).abs() < 1e-9, "{a} vs {n}");
    }
}
