//! Traversal Verification (Weng et al. 2025) — bottom-up, non-OT.
//!
//! Reconstructed to the paper's specification: bottom-up block acceptance
//! that "starts at leaf nodes and has a higher chance of accepting longer
//! sequences", reducing exactly to Block Verification at K = 1 (§3.2). The
//! construction runs block (BV) trials over the i.i.d. path draws in draft
//! order with residual handoff:
//!
//! 1. Run the BV coupling (verify::bv) on the first root→leaf path draw.
//! 2. If it stops at node a with weight w, the conditional target at a given
//!    the stop is the w-weighted residual r ∝ (p_a − q_a/w)_+. Any remaining
//!    *independent* path draw passing through a is then tried from a against
//!    r (its edges below a are fresh i.i.d. draws); each trial updates the
//!    residual on failure, exactly like sequential multi-draft residual
//!    composition.
//! 3. Draw accounting matters: the delayed-expansion trunk is one shared
//!    draw, so a rejection inside the trunk ends verification (no fresh
//!    draws exist), while the K branches are independent draws and each
//!    supports one trial. `DraftTree::path_draws` carries this structure.
//! 4. When no draws remain, the correction token is sampled from the
//!    current residual target at a.
//!
//! Losslessness follows by composing the per-trial BV guarantee with the
//! residual chain rule, and is validated in tests/losslessness.rs.
//!
//! The walk is allocation-free in steady state: path draws are borrowed
//! from the tree (or rebuilt into scratch for plain multipath trees), the
//! BV buffers live in [`VerifyScratch`], and the evolving residual target
//! ping-pongs between the two scratch distributions.

use super::bv::{bv_path, weighted_residual_into};
use super::{Verdict, Verifier, VerifyScratch};
use crate::tree::DraftTree;
use crate::util::Pcg64;

/// Traversal Verification (Weng et al. 2025): bottom-up, non-OT.
pub struct Traversal;

impl Verifier for Traversal {
    fn name(&self) -> &'static str {
        "Traversal"
    }

    fn verify_into(
        &self,
        tree: &DraftTree,
        rng: &mut Pcg64,
        sc: &mut VerifyScratch,
        out: &mut Verdict,
    ) {
        out.accepted.clear();
        // Path draws: borrow recorded ones, or rebuild one independent draw
        // per leaf into scratch (inner path buffers are recycled by index so
        // steady-state rebuilds allocate nothing).
        let (paths, shared_edges): (&[Vec<usize>], usize) = match &tree.path_draws {
            Some(d) => (d.paths.as_slice(), d.shared_edges),
            None => {
                let mut count = 0usize;
                for leaf in 0..tree.len() {
                    if !tree.nodes[leaf].children.is_empty() {
                        continue;
                    }
                    if count == sc.fallback_paths.len() {
                        sc.fallback_paths.push(Vec::new());
                    }
                    tree.path_nodes_into(leaf, &mut sc.fallback_paths[count]);
                    count += 1;
                }
                (&sc.fallback_paths[..count], 0)
            }
        };

        sc.used.clear();
        sc.used.resize(paths.len(), false);
        let mut a = 0usize; // current accepted node
        // current residual target p̃, kept in dist_a
        sc.dist_a.copy_from(tree.nodes[0].p.as_ref().expect("p dist"));
        // depth (edge count from root) of the current node
        let mut depth = 0usize;
        // whether a rejection has already consumed the shared trunk draw
        let mut trunk_dead = false;

        loop {
            // next untried path draw passing through the current node
            let mut candidate = None;
            for (i, path) in paths.iter().enumerate() {
                if sc.used[i] || path.len() <= depth {
                    continue;
                }
                // passes through a: its node at depth-1 .. matches
                let through = if depth == 0 { true } else { path[depth - 1] == a };
                if !through {
                    continue;
                }
                // if the trunk draw is dead, paths whose next edge is still
                // inside the shared trunk cannot retry it
                if trunk_dead && depth < shared_edges {
                    continue;
                }
                candidate = Some(i);
                break;
            }

            let Some(pi) = candidate else {
                out.correction = sc.dist_a.sample(rng) as u32;
                return;
            };
            sc.used[pi] = true;
            let subpath = &paths[pi][depth..];
            let (tau, w_tau) =
                bv_path(tree, a, &sc.dist_a, subpath, rng, &mut sc.w, &mut sc.e, &mut sc.thr);

            if tau == subpath.len() {
                // accepted to the leaf: bonus token from the leaf target
                out.accepted.extend_from_slice(subpath);
                let leaf = *subpath.last().unwrap();
                out.correction = tree.nodes[leaf].p.as_ref().unwrap().sample(rng) as u32;
                return;
            }

            // advance to the stop node, update the residual target there
            out.accepted.extend_from_slice(&subpath[..tau]);
            if tau > 0 {
                a = subpath[tau - 1];
            }
            depth += tau;
            let q_stop = tree.nodes[a].q.as_ref().expect("q dist");
            if tau == 0 {
                // stop at the current node: residual of the current target
                weighted_residual_into(&sc.dist_a, q_stop, w_tau, &mut sc.dist_b);
            } else {
                let p_stop = tree.nodes[a].p.as_ref().unwrap();
                weighted_residual_into(p_stop, q_stop, w_tau, &mut sc.dist_b);
            }
            std::mem::swap(&mut sc.dist_a, &mut sc.dist_b);
            if depth < shared_edges {
                // the rejected edge was part of the shared trunk draw
                trunk_dead = true;
            }
            // mark sibling paths that shared the just-rejected *node* draw:
            // none — distinct paths are independent draws below the trunk, and
            // trunk rejections are handled by trunk_dead.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::tree::{PathDraws, Provenance};

    /// K=1 must reduce to BV exactly (same RNG stream → same verdicts).
    #[test]
    fn k1_reduces_to_bv() {
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 1, Provenance::Trunk { step: 0 });
        let b = t.add_child(a, 0, Provenance::Trunk { step: 1 });
        let p = Dist(vec![0.6, 0.4]);
        let q = Dist(vec![0.3, 0.7]);
        for n in [0, a, b] {
            t.set_p(n, p.clone());
            t.set_q(n, q.clone());
        }
        t.path_draws = Some(PathDraws { paths: vec![vec![a, b]], shared_edges: 0 });
        for seed in 0..200 {
            let mut r1 = Pcg64::seeded(seed);
            let mut r2 = Pcg64::seeded(seed);
            let v1 = Traversal.verify(&t, &mut r1);
            let v2 = super::super::bv::BlockVerify.verify(&t, &mut r2);
            assert_eq!(v1.accepted, v2.accepted, "seed {seed}");
            assert_eq!(v1.correction, v2.correction, "seed {seed}");
        }
    }

    /// Trunk rejection must not retry trunk edges (shared draw).
    #[test]
    fn trunk_rejection_terminates() {
        // trunk edge with p(token)=0 → always rejected at depth 0
        let mut t = DraftTree::new(0);
        let a = t.add_child(0, 1, Provenance::Trunk { step: 0 });
        let b1 = t.add_child(a, 0, Provenance::Branch { branch: 0, step: 0 });
        let b2 = t.add_child(a, 1, Provenance::Branch { branch: 1, step: 0 });
        let p_root = Dist(vec![1.0, 0.0]); // token 1 (the trunk edge) impossible
        let q_root = Dist(vec![0.0, 1.0]);
        t.set_p(0, p_root);
        t.set_q(0, q_root);
        let flat = Dist(vec![0.5, 0.5]);
        for n in [a, b1, b2] {
            t.set_p(n, flat.clone());
            t.set_q(n, flat.clone());
        }
        t.path_draws = Some(PathDraws {
            paths: vec![vec![a, b1], vec![a, b2]],
            shared_edges: 1,
        });
        let mut rng = Pcg64::seeded(3);
        for _ in 0..200 {
            let v = Traversal.verify(&t, &mut rng);
            assert_eq!(v.tau(), 0, "trunk edge must always be rejected");
            assert_eq!(v.correction, 0, "correction must follow the residual");
        }
    }

    /// Multipath: a second branch can rescue after the first is rejected.
    #[test]
    fn second_branch_can_accept() {
        let mut t = DraftTree::new(0);
        let c1 = t.add_child(0, 1, Provenance::Branch { branch: 0, step: 0 });
        let c2 = t.add_child(0, 0, Provenance::Branch { branch: 1, step: 0 });
        // p prefers token 0 strongly; branch 1 drafted token 1 (likely
        // rejected), branch 2 drafted token 0 (likely accepted on retry).
        t.set_p(0, Dist(vec![0.9, 0.1]));
        t.set_q(0, Dist(vec![0.5, 0.5]));
        let flat = Dist(vec![0.5, 0.5]);
        for n in [c1, c2] {
            t.set_p(n, flat.clone());
            t.set_q(n, flat.clone());
        }
        t.path_draws = Some(PathDraws { paths: vec![vec![c1], vec![c2]], shared_edges: 0 });
        let mut rng = Pcg64::seeded(9);
        let n = 30_000;
        let mut tau1 = 0usize;
        for _ in 0..n {
            if Traversal.verify(&t, &mut rng).tau() >= 1 {
                tau1 += 1;
            }
        }
        // single-draw naive acceptance would be Σ min(p,q) = 0.6;
        // two draws must beat it
        let frac = tau1 as f64 / n as f64;
        assert!(frac > 0.62, "two-branch acceptance {frac} should beat 0.6");
    }

    /// Recorded-draws and fallback (path_draws = None) walks agree for
    /// i.i.d. multipath trees, including with a reused scratch.
    #[test]
    fn fallback_paths_match_recorded() {
        let mut t = DraftTree::new(0);
        let c1 = t.add_child(0, 1, Provenance::Branch { branch: 0, step: 0 });
        let c2 = t.add_child(0, 0, Provenance::Branch { branch: 1, step: 0 });
        t.set_p(0, Dist(vec![0.7, 0.3]));
        t.set_q(0, Dist(vec![0.4, 0.6]));
        let flat = Dist(vec![0.5, 0.5]);
        for n in [c1, c2] {
            t.set_p(n, flat.clone());
            t.set_q(n, flat.clone());
        }
        let mut recorded = t.clone();
        recorded.path_draws =
            Some(PathDraws { paths: vec![vec![c1], vec![c2]], shared_edges: 0 });
        let mut sc = VerifyScratch::default();
        let mut out = Verdict::default();
        for seed in 0..200 {
            let mut r1 = Pcg64::seeded(seed);
            let mut r2 = Pcg64::seeded(seed);
            let v1 = Traversal.verify(&recorded, &mut r1);
            Traversal.verify_into(&t, &mut r2, &mut sc, &mut out);
            assert_eq!(v1.accepted, out.accepted, "seed {seed}");
            assert_eq!(v1.correction, out.correction, "seed {seed}");
        }
    }
}
