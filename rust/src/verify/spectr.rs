//! SpecTr K-SEQ (Sun et al. 2023) — paper Algorithm 3 / 8 / 13.
//!
//! Computes the division factor ρ* ∈ [1, k] by binary search on
//! ρ ↦ p_acc(ρ) − ρ·β(ρ), then runs k ρ*-damped naive rounds followed by a
//! γ-corrected residual. Reduces to Naive at k = 1.
//!
//! β(ρ) = Σ_t min(p(t)/ρ, q(t)) is supported only on the intersection of
//! the two supports, and the γ-corrected residual only on p's support — so
//! the sparse path runs every bisection step and the residual build in
//! O(|support|), identical in value to the dense reference (zero terms are
//! exact zeros).

use super::{OtlpSolver, SolverScratch};
use crate::dist::{mixed_repr, Dist, NodeDist, SparseDist};
use crate::util::Pcg64;

/// The SpecTr K-SEQ OTLP solver (paper Algorithm 3).
pub struct SpecTr;

/// β(ρ) = Σ_t min(p(t)/ρ, q(t)) — dense reference.
fn beta(p: &Dist, q: &Dist, rho: f64) -> f64 {
    p.0.iter()
        .zip(&q.0)
        .map(|(&a, &b)| (a as f64 / rho).min(b as f64))
        .sum()
}

/// β(ρ) over the support intersection (terms with p = 0 or q = 0 vanish).
fn beta_sparse(p: &SparseDist, q: &SparseDist, rho: f64) -> f64 {
    let mut s = 0.0f64;
    p.zip_support(q, |_, a, b| {
        s += (a as f64 / rho).min(b as f64);
    });
    s
}

fn beta_nd(p: &NodeDist, q: &NodeDist, rho: f64) -> f64 {
    match (p, q) {
        (NodeDist::Dense(a), NodeDist::Dense(b)) => beta(a, b, rho),
        (NodeDist::Sparse(a), NodeDist::Sparse(b)) => beta_sparse(a, b, rho),
        _ => mixed_repr(),
    }
}

fn p_acc(beta: f64, k: usize) -> f64 {
    1.0 - (1.0 - beta).powi(k as i32)
}

/// Bisection core for p_acc(ρ) = ρ β(ρ) on [1, k] (g is monotone
/// decreasing there, per Sun et al.).
fn solve_rho_with(beta_of: impl Fn(f64) -> f64, k: usize) -> f64 {
    if k <= 1 {
        return 1.0;
    }
    let g = |rho: f64| {
        let b = beta_of(rho);
        p_acc(b, k) - rho * b
    };
    let (mut lo, mut hi) = (1.0f64, k as f64);
    if g(lo) <= 0.0 {
        return lo;
    }
    if g(hi) >= 0.0 {
        return hi;
    }
    // 30 halvings of an interval of width ≤ 3 pin ρ* to ~3e-9 — far below
    // the f32 resolution of the dists — at half the per-node cost of the
    // old 60-iteration loop (each g() is an O(support) scan on the verify
    // path).
    for _ in 0..30 {
        let mid = 0.5 * (lo + hi);
        if g(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Solve p_acc(ρ) = ρ β(ρ) on [1, k] for either representation.
pub fn solve_rho(p: &NodeDist, q: &NodeDist, k: usize) -> f64 {
    solve_rho_with(|rho| beta_nd(p, q, rho), k)
}

fn solve_rho_dense(p: &Dist, q: &Dist, k: usize) -> f64 {
    solve_rho_with(|rho| beta(p, q, rho), k)
}

/// Residual ∝ (p − min(p/ρ*, q)·γ)_+ with γ = p_acc/β, written into `out`
/// (no allocation once `out` has capacity) — dense reference.
fn residual_into(p: &Dist, q: &Dist, rho: f64, gamma: f64, out: &mut Dist) {
    let o = &mut out.0;
    o.clear();
    o.reserve(p.0.len());
    let mut mass = 0.0f64;
    for (&a, &b) in p.0.iter().zip(&q.0) {
        let m = (a as f64 / rho).min(b as f64);
        let v = (a as f64 - m * gamma).max(0.0) as f32;
        o.push(v);
        mass += v as f64;
    }
    if mass > 0.0 {
        let inv = (1.0 / mass) as f32;
        for v in o.iter_mut() {
            *v *= inv;
        }
    }
}

/// Sparse residual: support ⊆ support(p), O(|support_p| + |support_q|).
fn residual_sparse_into(p: &SparseDist, q: &SparseDist, rho: f64, gamma: f64, out: &mut SparseDist) {
    out.clear_for(p.vocab);
    let mut mass = 0.0f64;
    p.zip_support(q, |id, a, b| {
        let m = (a as f64 / rho).min(b as f64);
        let v = (a as f64 - m * gamma).max(0.0) as f32;
        if v > 0.0 {
            out.ids.push(id);
            out.ps.push(v);
        }
        mass += v as f64;
    });
    if mass > 0.0 {
        let inv = (1.0 / mass) as f32;
        for v in out.ps.iter_mut() {
            *v *= inv;
        }
        out.mass = 1.0;
    }
}

fn residual_nd_into(p: &NodeDist, q: &NodeDist, rho: f64, gamma: f64, out: &mut NodeDist) {
    match (p, q) {
        (NodeDist::Dense(a), NodeDist::Dense(b)) => {
            residual_into(a, b, rho, gamma, out.make_dense_mut())
        }
        (NodeDist::Sparse(a), NodeDist::Sparse(b)) => {
            residual_sparse_into(a, b, rho, gamma, out.make_sparse_mut())
        }
        _ => mixed_repr(),
    }
}

/// Allocating wrapper over [`residual_into`] for the dense calculators.
fn residual(p: &Dist, q: &Dist, rho: f64, gamma: f64) -> Dist {
    let mut out = Dist(Vec::with_capacity(p.len()));
    residual_into(p, q, rho, gamma, &mut out);
    out
}

/// Allocating residual in the inputs' representation (branching path).
fn residual_nd(p: &NodeDist, q: &NodeDist, rho: f64, gamma: f64) -> NodeDist {
    let mut out = match p {
        NodeDist::Dense(_) => NodeDist::Dense(Dist::default()),
        NodeDist::Sparse(_) => NodeDist::Sparse(SparseDist::default()),
    };
    residual_nd_into(p, q, rho, gamma, &mut out);
    out
}

impl OtlpSolver for SpecTr {
    fn name(&self) -> &'static str {
        "SpecTr"
    }

    fn solve_scratch(
        &self,
        p: &NodeDist,
        q: &NodeDist,
        xs: &[u32],
        rng: &mut Pcg64,
        scratch: &mut SolverScratch,
    ) -> u32 {
        let k = xs.len();
        let rho = solve_rho(p, q, k);
        let b = beta_nd(p, q, rho);
        if b <= 0.0 {
            // p and q disjoint: no round can accept.
            residual_nd_into(p, q, rho, 0.0, &mut scratch.dist_a);
            return scratch.dist_a.sample(rng) as u32;
        }
        let gamma = p_acc(b, k) / b;
        for &x in xs {
            let xi = x as usize;
            let ratio = if q.p(xi) > 0.0 {
                p.p(xi) as f64 / q.p(xi) as f64
            } else {
                f64::INFINITY
            };
            if rho * rng.next_f64() <= ratio {
                return x;
            }
        }
        residual_nd_into(p, q, rho, gamma, &mut scratch.dist_a);
        scratch.dist_a.sample(rng) as u32
    }

    /// Algorithm 8.
    fn acceptance_rate(&self, p: &Dist, q: &Dist, k: usize) -> f64 {
        let rho = solve_rho_dense(p, q, k);
        let b = beta(p, q, rho);
        if b <= 0.0 {
            return 0.0;
        }
        let pa = p_acc(b, k);
        let gamma = pa / b;
        let res = residual(p, q, rho, gamma);
        // r(t) = (q − p/ρ*)_+ / (1 − β)
        let hit: f64 = res
            .0
            .iter()
            .enumerate()
            .map(|(t, &rt)| {
                let r = ((q.p(t) as f64 - p.p(t) as f64 / rho).max(0.0)) / (1.0 - b).max(1e-12);
                rt as f64 * (1.0 - (1.0 - r).powi(k as i32))
            })
            .sum();
        pa + (1.0 - pa) * hit
    }

    /// Algorithm 13.
    fn branching_into(&self, p: &NodeDist, q: &NodeDist, xs: &[u32], out: &mut Vec<f64>) {
        let k = xs.len();
        let rho = solve_rho(p, q, k);
        let b = beta_nd(p, q, rho);
        let gamma = if b > 0.0 { p_acc(b, k) / b } else { 0.0 };
        let res = residual_nd(p, q, rho, gamma);
        let a: Vec<f64> = xs
            .iter()
            .map(|&x| {
                let xi = x as usize;
                if q.p(xi) > 0.0 {
                    (p.p(xi) as f64 / (rho * q.p(xi) as f64)).min(1.0)
                } else {
                    1.0
                }
            })
            .collect();
        let mut no_accept_all = 1.0;
        for &ai in &a {
            no_accept_all *= 1.0 - ai;
        }
        out.clear();
        out.extend(xs.iter().map(|&xi_tok| {
            let mut total = 0.0;
            let mut pre = 1.0;
            for (j, &aj) in a.iter().enumerate() {
                if xs[j] == xi_tok {
                    total += aj * pre;
                }
                pre *= 1.0 - aj;
            }
            total + res.p(xi_tok as usize) as f64 * no_accept_all
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pq() -> (NodeDist, NodeDist) {
        (
            NodeDist::from(Dist(vec![0.45, 0.25, 0.2, 0.1])),
            NodeDist::from(Dist(vec![0.1, 0.3, 0.25, 0.35])),
        )
    }

    #[test]
    fn rho_in_range_and_root() {
        let (p, q) = pq();
        for k in 2..=4 {
            let rho = solve_rho(&p, &q, k);
            assert!((1.0..=k as f64).contains(&rho), "rho {rho}");
            let b = beta_nd(&p, &q, rho);
            let g = p_acc(b, k) - rho * b;
            assert!(g.abs() < 1e-6, "g {g}");
            // the sparse bisection walks the identical interval sequence
            assert_eq!(rho, solve_rho(&p.sparsify(), &q.sparsify(), k));
        }
    }

    #[test]
    fn output_follows_p() {
        let (p, q) = pq();
        let mut rng = Pcg64::seeded(4);
        let n = 80_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let xs: Vec<u32> = (0..3).map(|_| q.sample(&mut rng) as u32).collect();
            counts[SpecTr.solve(&p, &q, &xs, &mut rng) as usize] += 1;
        }
        for t in 0..4 {
            let f = counts[t] as f64 / n as f64;
            assert!((f - p.p(t) as f64).abs() < 0.012, "token {t}: {f} vs {}", p.p(t));
        }
    }

    #[test]
    fn acceptance_rate_matches_mc() {
        let (p, q) = pq();
        let (pd, qd) = (p.to_dense(), q.to_dense());
        for k in 1..=4 {
            let exact = SpecTr.acceptance_rate(&pd, &qd, k);
            let mut rng = Pcg64::seeded(40 + k as u64);
            let n = 80_000;
            let mut hits = 0usize;
            for _ in 0..n {
                let xs: Vec<u32> = (0..k).map(|_| q.sample(&mut rng) as u32).collect();
                if xs.contains(&SpecTr.solve(&p, &q, &xs, &mut rng)) {
                    hits += 1;
                }
            }
            let mc = hits as f64 / n as f64;
            assert!((mc - exact).abs() < 0.012, "k={k}: mc {mc} vs exact {exact}");
        }
    }

    #[test]
    fn branching_matches_mc() {
        let (p, q) = pq();
        let xs = vec![3u32, 0, 3];
        let b = SpecTr.branching(&p, &q, &xs);
        assert_eq!(b, SpecTr.branching(&p.sparsify(), &q.sparsify(), &xs));
        let mut rng = Pcg64::seeded(50);
        let n = 120_000usize;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[SpecTr.solve(&p, &q, &xs, &mut rng) as usize] += 1;
        }
        for (i, &x) in xs.iter().enumerate() {
            let mc = counts[x as usize] as f64 / n as f64;
            assert!((mc - b[i]).abs() < 0.012, "pos {i}: mc {mc} vs {}", b[i]);
        }
    }

    #[test]
    fn reduces_to_naive_at_k1() {
        let (p, q) = pq();
        let (pd, qd) = (p.to_dense(), q.to_dense());
        let a_spectr = SpecTr.acceptance_rate(&pd, &qd, 1);
        let a_naive = super::super::naive::Naive.acceptance_rate(&pd, &qd, 1);
        assert!((a_spectr - a_naive).abs() < 1e-9);
    }
}
