//! Block Verification (Sun et al. 2024c) — single-path, non-OT.
//!
//! Reconstructed from the paper's description (§3.1): recursive weights
//! w_i = min(1, w_{i−1}·p_i/q_i), single-uniform acceptance of the deepest
//! weight-covered node, and the *w-weighted naive residual*
//! ∝ (p − q/w_τ)_+ as the correction.
//!
//! Derivation (validated by the Monte-Carlo losslessness suite): the weights
//! w_i are the tightest reach probabilities satisfying the conditional
//! losslessness constraint w_{i+1} ≤ w_i·p_{i+1}/q_{i+1} (accepted-child mass
//! at a node must not exceed the target mass); deep steps with p/q > 1 repay
//! earlier deficits, which is exactly how BV beats per-token naive
//! acceptance. Because w is not monotone, a single uniform cannot use w
//! directly as thresholds; the backward pass below rebuilds monotone
//! thresholds W_i with E[W_i | x_{1:i}] = w_i by distributing the slack
//! s_i = w_i − E[w_{i+1}|x_{1:i}] over the headroom (1 − W_{i+1}):
//!
//! ```text
//!     e_i = Σ_t min(q_{i+1}(t), w_i·p_{i+1}(t))      (= E[w_{i+1}|x_{1:i}])
//!     W_L = w_L,   W_i = W_{i+1} + (w_i − e_i)·(1 − W_{i+1})/(1 − e_i)
//! ```
//!
//! Stop depth τ = max{i : u ≤ W_i}; the accepted-child conditional mass is
//! then min(q, w_τ·p)/w_τ ≤ p pointwise and the residual (p − q/w_τ)_+
//! restores the target exactly.
//!
//! The forward/backward buffers (w, e, thr) and the residual target are all
//! caller-provided scratch, so the per-block pass allocates nothing.

use super::{Verdict, Verifier, VerifyScratch};
use crate::dist::{mixed_repr, Dist, NodeDist, SparseDist};
use crate::tree::DraftTree;
use crate::util::Pcg64;

/// Block Verification (Sun et al. 2024c): single-path, non-OT.
pub struct BlockVerify;

/// e = Σ_t min(q(t), w·p(t)) — the expected next-step weight. Terms vanish
/// where either side is zero, so the sparse arm merges p's support against
/// q in O(|support|), exactly equal to the dense zip.
fn e_weight(p: &NodeDist, q: &NodeDist, w: f64) -> f64 {
    match (p, q) {
        (NodeDist::Dense(p), NodeDist::Dense(q)) => p
            .0
            .iter()
            .zip(&q.0)
            .map(|(&pt, &qt)| (qt as f64).min(w * pt as f64))
            .sum(),
        (NodeDist::Sparse(p), NodeDist::Sparse(q)) => {
            let mut s = 0.0f64;
            p.zip_support(q, |_, pt, qt| {
                s += (qt as f64).min(w * pt as f64);
            });
            s
        }
        _ => mixed_repr(),
    }
}

/// Forward/backward pass over one path. `p_first` overrides the target
/// distribution at the first node (used by Traversal's residual handoff).
///
/// `path` lists node indices below the start node; `w`/`e`/`thr` are
/// reusable buffers for the forward weights, expected next-step weights and
/// backward monotone thresholds. Returns (stop depth τ ∈ 0..=L, weight w_τ
/// at the stop node).
#[allow(clippy::too_many_arguments)]
pub(crate) fn bv_path(
    tree: &DraftTree,
    start: usize,
    p_first: &NodeDist,
    path: &[usize],
    rng: &mut Pcg64,
    w: &mut Vec<f64>,
    e: &mut Vec<f64>,
    thr: &mut Vec<f64>,
) -> (usize, f64) {
    let l = path.len();
    debug_assert!(l > 0);

    // dists along the path: entry i gives (p, q) at the node *above* edge i.
    let node_p = |i: usize| -> &NodeDist {
        if i == 0 {
            p_first
        } else {
            tree.nodes[path[i - 1]].p.as_ref().expect("p dist")
        }
    };
    let node_q = |i: usize| -> &NodeDist {
        let n = if i == 0 { start } else { path[i - 1] };
        tree.nodes[n].q.as_ref().expect("q dist")
    };

    // forward weights
    w.clear();
    w.resize(l + 1, 1.0);
    for i in 1..=l {
        let tok = tree.nodes[path[i - 1]].token as usize;
        let (p, q) = (node_p(i - 1), node_q(i - 1));
        let r = if q.p(tok) > 0.0 {
            p.p(tok) as f64 / q.p(tok) as f64
        } else {
            1.0
        };
        w[i] = (w[i - 1] * r).min(1.0);
    }

    // e_i = Σ_t min(q_{i+1}(t), w_i p_{i+1}(t)) for i < L
    e.clear();
    e.resize(l, 0.0);
    for i in 0..l {
        e[i] = e_weight(node_p(i), node_q(i), w[i]);
    }

    // backward monotone thresholds
    thr.clear();
    thr.resize(l + 1, 0.0);
    thr[l] = w[l];
    for i in (0..l).rev() {
        let s = (w[i] - e[i]).max(0.0);
        thr[i] = if e[i] >= 1.0 - 1e-12 {
            thr[i + 1]
        } else {
            thr[i + 1] + s * (1.0 - thr[i + 1]) / (1.0 - e[i])
        };
    }

    let u = rng.next_f64();
    let mut tau = 0usize;
    for i in (0..=l).rev() {
        if u <= thr[i] {
            tau = i;
            break;
        }
    }
    (tau, w[tau])
}

/// Dense w-weighted naive residual ∝ (p − q/w)_+ written into `out`.
/// Zero-probability stops (numerical) fall back to the target p.
fn weighted_residual_dense_into(p: &Dist, q: &Dist, w: f64, out: &mut Dist) {
    let o = &mut out.0;
    o.clear();
    o.reserve(p.0.len());
    let mut mass = 0.0f64;
    for (&pt, &qt) in p.0.iter().zip(&q.0) {
        let v = (pt as f64 - qt as f64 / w.max(1e-12)).max(0.0);
        o.push(v as f32);
        mass += v;
    }
    if mass > 0.0 {
        let inv = (1.0 / mass) as f32;
        for v in o.iter_mut() {
            *v *= inv;
        }
    } else {
        out.copy_from(p);
    }
}

/// Sparse w-weighted residual: support ⊆ support(p), O(|support|) merge.
fn weighted_residual_sparse_into(p: &SparseDist, q: &SparseDist, w: f64, out: &mut SparseDist) {
    out.clear_for(p.vocab);
    let mut mass = 0.0f64;
    p.zip_support(q, |id, pt, qt| {
        let v = (pt as f64 - qt as f64 / w.max(1e-12)).max(0.0);
        if v > 0.0 {
            out.ids.push(id);
            out.ps.push(v as f32);
        }
        mass += v;
    });
    if mass > 0.0 {
        let inv = (1.0 / mass) as f32;
        for v in out.ps.iter_mut() {
            *v *= inv;
        }
        out.mass = 1.0;
    } else {
        out.copy_from(p);
    }
}

/// w-weighted naive residual at the stop node, ∝ (p − q/w)_+, written into
/// `out` in the inputs' representation.
pub(crate) fn weighted_residual_into(p: &NodeDist, q: &NodeDist, w: f64, out: &mut NodeDist) {
    match (p, q) {
        (NodeDist::Dense(p), NodeDist::Dense(q)) => {
            weighted_residual_dense_into(p, q, w, out.make_dense_mut())
        }
        (NodeDist::Sparse(p), NodeDist::Sparse(q)) => {
            weighted_residual_sparse_into(p, q, w, out.make_sparse_mut())
        }
        _ => mixed_repr(),
    }
}

impl Verifier for BlockVerify {
    fn name(&self) -> &'static str {
        "BV"
    }

    fn verify_into(
        &self,
        tree: &DraftTree,
        rng: &mut Pcg64,
        sc: &mut VerifyScratch,
        out: &mut Verdict,
    ) {
        out.accepted.clear();
        // single-path: follow the first-child chain
        sc.path.clear();
        let mut cur = 0usize;
        while let Some(&c) = tree.nodes[cur].children.first() {
            sc.path.push(c);
            cur = c;
        }
        let p_root = tree.nodes[0].p.as_ref().expect("p dist");
        if sc.path.is_empty() {
            out.correction = p_root.sample(rng) as u32;
            return;
        }
        let (tau, w_tau) =
            bv_path(tree, 0, p_root, &sc.path, rng, &mut sc.w, &mut sc.e, &mut sc.thr);
        out.accepted.extend_from_slice(&sc.path[..tau]);
        if tau == sc.path.len() {
            // whole block accepted: bonus token from the leaf target dist
            let leaf = *sc.path.last().unwrap();
            out.correction = tree.nodes[leaf].p.as_ref().unwrap().sample(rng) as u32;
        } else {
            let stop = if tau == 0 { 0 } else { sc.path[tau - 1] };
            let p = if tau == 0 { p_root } else { tree.nodes[stop].p.as_ref().unwrap() };
            let q = tree.nodes[stop].q.as_ref().expect("q dist");
            weighted_residual_into(p, q, w_tau, &mut sc.dist_a);
            out.correction = sc.dist_a.sample(rng) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::Provenance;

    /// Build a path tree with prescribed p/q at each node.
    fn path_tree(tokens: &[u32], dists: Vec<(Dist, Dist)>) -> DraftTree {
        let mut t = DraftTree::new(0);
        let mut cur = 0;
        for (i, &tok) in tokens.iter().enumerate() {
            cur = t.add_child(cur, tok, Provenance::Trunk { step: i });
        }
        let mut node = 0;
        for (i, (p, q)) in dists.into_iter().enumerate() {
            t.set_p(node, p);
            t.set_q(node, q);
            if i < tokens.len() {
                node = t.nodes[node].children[0];
            }
        }
        t
    }

    #[test]
    fn repayment_beats_naive() {
        // r1 = 2 (surplus), r2 = 0.6: naive accepts depth-2 w.p. 0.6;
        // BV weights: w1 = 1, w2 = 0.6 — but the coupled thresholds let the
        // early surplus repay, so P(τ ≥ 2) = 0.6 = naive here; the gain shows
        // when the deficit comes first: r1 = 0.6, r2 = 2 → naive 0.6·1 = 0.6
        // at depth 2... with w: w1 = 0.6, w2 = min(1, 1.2) = 1?? no:
        // w2 = min(1, 0.6·2) = 1 ≥ naive's 0.6 — deep repayment.
        let p0 = Dist(vec![0.6, 0.4]);
        let q0 = Dist(vec![1.0, 0.0]);
        // at node (tok 0): p gives token 0 prob 0.8, q gives 0.4 → r = 2
        let p1 = Dist(vec![0.8, 0.2]);
        let q1 = Dist(vec![0.4, 0.6]);
        let p2 = Dist(vec![0.5, 0.5]);
        let q2 = Dist(vec![0.5, 0.5]);
        let tree = path_tree(&[0, 0], vec![(p0, q0), (p1, q1), (p2, q2)]);
        let mut rng = Pcg64::seeded(11);
        let n = 60_000;
        let mut depth2 = 0usize;
        for _ in 0..n {
            if BlockVerify.verify(&tree, &mut rng).tau() >= 2 {
                depth2 += 1;
            }
        }
        let frac = depth2 as f64 / n as f64;
        // naive would give min(1,0.6)·min(1,2) = 0.6; BV's w2 = min(1,1.2) = 1
        // capped by the thresholds' budget E[W_2] = w_2-budget... empirically
        // BV must be >= naive's 0.6.
        assert!(frac >= 0.6 - 0.01, "depth-2 acceptance {frac} < naive 0.6");
    }

    #[test]
    fn weights_monotone_thresholds() {
        let p = Dist(vec![0.5, 0.5]);
        let q = Dist(vec![0.9, 0.1]);
        let tree = path_tree(
            &[0, 1],
            vec![(p.clone(), q.clone()), (p.clone(), q.clone()), (p, q)],
        );
        let mut rng = Pcg64::seeded(12);
        // just exercising: no panics, tau in range
        for _ in 0..1000 {
            let v = BlockVerify.verify(&tree, &mut rng);
            assert!(v.tau() <= 2);
        }
    }

    /// Reusing one scratch across many verifies must not change verdicts
    /// relative to fresh-scratch calls (warm buffers are state-free).
    #[test]
    fn scratch_reuse_is_stateless() {
        let p = Dist(vec![0.55, 0.45]);
        let q = Dist(vec![0.3, 0.7]);
        let tree = path_tree(
            &[1, 0, 1],
            vec![(p.clone(), q.clone()), (p.clone(), q.clone()), (p.clone(), q.clone()), (p, q)],
        );
        let mut sc = VerifyScratch::default();
        let mut warm = Verdict::default();
        for seed in 0..300 {
            let mut r1 = Pcg64::seeded(seed);
            let mut r2 = Pcg64::seeded(seed);
            let cold = BlockVerify.verify(&tree, &mut r1);
            BlockVerify.verify_into(&tree, &mut r2, &mut sc, &mut warm);
            assert_eq!(cold.accepted, warm.accepted, "seed {seed}");
            assert_eq!(cold.correction, warm.correction, "seed {seed}");
        }
    }
}
