//! Verification algorithms (paper §3, Appendix B).
//!
//! Two kinds:
//! * **OT-based** algorithms are built from an [`OtlpSolver`] (paper
//!   Definition 3.2) and share the generic top-down walk in [`OtVerifier`]:
//!   at each node the solver emits a token distributed as p; if it matches a
//!   drafted child we descend, otherwise it terminates the block as the
//!   correction token. Each solver also provides its acceptance-rate
//!   calculator (Algorithms 6–10) and branching-probability calculator
//!   (Algorithms 11–15) used by Figure 1 and the Eq. 3 block-efficiency
//!   estimator.
//! * **Non-OT** algorithms (Block Verification, Traversal) implement
//!   [`Verifier`] directly.
//!
//! Losslessness of every implementation is validated by the Monte-Carlo
//! harness in `rust/tests/losslessness.rs` (the same validation the paper
//! reports for its calculators).

pub mod bv;
pub mod khisti;
pub mod naive;
pub mod nss;
pub mod specinfer;
pub mod spectr;
pub mod traversal;

use crate::dist::Dist;
use crate::tree::DraftTree;
use crate::util::Pcg64;

/// Outcome of verifying one draft tree.
#[derive(Clone, Debug)]
pub struct Verdict {
    /// Accepted node indices, root-exclusive, in root→leaf order.
    pub accepted: Vec<usize>,
    /// The correction/bonus token appended after the accepted prefix.
    pub correction: u32,
}

impl Verdict {
    /// τ — the depth of the accepted node.
    pub fn tau(&self) -> usize {
        self.accepted.len()
    }
    /// Decoded tokens this block = τ + 1.
    pub fn block_tokens(&self) -> usize {
        self.accepted.len() + 1
    }
}

/// A verification algorithm over a draft tree whose nodes carry p and q.
pub trait Verifier: Send + Sync {
    fn name(&self) -> &'static str;
    fn verify(&self, tree: &DraftTree, rng: &mut Pcg64) -> Verdict;
}

/// An OTLP solver f_{p,q,k} (paper Definition 3.2): maps i.i.d. draft tokens
/// X_1..X_k ~ q to an output token distributed exactly as p.
pub trait OtlpSolver: Send + Sync {
    fn name(&self) -> &'static str;

    /// Draw the output token given the realized draft tokens.
    fn solve(&self, p: &Dist, q: &Dist, xs: &[u32], rng: &mut Pcg64) -> u32;

    /// Acceptance rate α(f_{p,q,k}) = P(f(X_1..X_k) ∈ {X_1..X_k}) over
    /// X_i ~ q i.i.d. (Algorithms 6–10; Khisti's is a bound, see khisti.rs).
    fn acceptance_rate(&self, p: &Dist, q: &Dist, k: usize) -> f64;

    /// Branching probabilities B(f, xs, t) for each *position* i (aligned
    /// with xs; duplicate tokens receive the same total value at each
    /// occurrence — callers sum per distinct token before use).
    /// Returned value at position i is P(f outputs token xs[i]).
    fn branching(&self, p: &Dist, q: &Dist, xs: &[u32]) -> Vec<f64>;
}

/// Generic top-down OT walk (paper §3.2).
pub struct OtVerifier<S: OtlpSolver> {
    pub solver: S,
    name: &'static str,
}

impl<S: OtlpSolver> OtVerifier<S> {
    pub fn new(solver: S, name: &'static str) -> Self {
        OtVerifier { solver, name }
    }
}

impl<S: OtlpSolver> Verifier for OtVerifier<S> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn verify(&self, tree: &DraftTree, rng: &mut Pcg64) -> Verdict {
        let mut accepted = Vec::new();
        let mut node = 0usize;
        loop {
            let p = tree.nodes[node].p.as_ref().expect("p dist set");
            if tree.nodes[node].children.is_empty() {
                // Leaf: sample the bonus token directly from p.
                return Verdict { accepted, correction: p.sample(rng) as u32 };
            }
            let q = tree.nodes[node].q.as_ref().expect("q dist set");
            let xs = tree.child_tokens(node);
            let y = self.solver.solve(p, q, &xs, rng);
            match tree.child_with_token(node, y) {
                Some(child) => {
                    accepted.push(child);
                    node = child;
                }
                None => return Verdict { accepted, correction: y },
            }
        }
    }
}

/// Expected number of accepted tokens from walking the tree with a solver's
/// branching probabilities (the inner sum of paper Eq. 3): Σ over non-root
/// nodes of ∏ branching probabilities along the path.
pub fn expected_accepted(tree: &DraftTree, solver: &dyn OtlpSolver) -> f64 {
    let mut reach = vec![0.0f64; tree.len()];
    reach[0] = 1.0;
    let mut total = 0.0f64;
    for node in 0..tree.len() {
        if reach[node] <= 0.0 || tree.nodes[node].children.is_empty() {
            continue;
        }
        let p = tree.nodes[node].p.as_ref().expect("p dist set");
        let q = tree.nodes[node].q.as_ref().expect("q dist set");
        let xs = tree.child_tokens(node);
        let probs = solver.branching(p, q, &xs);
        // Sum duplicate positions per distinct child once: positions carrying
        // the same token all hold the same total probability of the solver
        // outputting that token, so take the value at the first occurrence.
        let mut seen: Vec<usize> = Vec::new();
        for (i, &child) in tree.nodes[node].children.iter().enumerate() {
            if seen.contains(&child) {
                continue;
            }
            seen.push(child);
            let pr = reach[node] * probs[i];
            reach[child] += pr;
            total += pr;
        }
    }
    total
}

/// All eight verifiers by paper name.
pub fn all_verifiers() -> Vec<Box<dyn Verifier>> {
    vec![
        Box::new(OtVerifier::new(nss::Nss, "NSS")),
        Box::new(OtVerifier::new(naive::Naive, "Naive")),
        Box::new(OtVerifier::new(naive::Naive, "NaiveTree")),
        Box::new(OtVerifier::new(spectr::SpecTr, "SpecTr")),
        Box::new(OtVerifier::new(specinfer::SpecInfer, "SpecInfer")),
        Box::new(OtVerifier::new(khisti::Khisti, "Khisti")),
        Box::new(bv::BlockVerify),
        Box::new(traversal::Traversal),
    ]
}

/// OT solvers by name (for NDE, which applies to OT-based methods only).
pub fn ot_solver(name: &str) -> Option<Box<dyn OtlpSolver>> {
    match name {
        "NSS" => Some(Box::new(nss::Nss)),
        "Naive" | "NaiveTree" => Some(Box::new(naive::Naive)),
        "SpecTr" => Some(Box::new(spectr::SpecTr)),
        "SpecInfer" => Some(Box::new(specinfer::SpecInfer)),
        "Khisti" => Some(Box::new(khisti::Khisti)),
        _ => None,
    }
}

/// Verifier lookup by paper name.
pub fn verifier(name: &str) -> Option<Box<dyn Verifier>> {
    match name {
        "NSS" => Some(Box::new(OtVerifier::new(nss::Nss, "NSS"))),
        "Naive" => Some(Box::new(OtVerifier::new(naive::Naive, "Naive"))),
        "NaiveTree" => Some(Box::new(OtVerifier::new(naive::Naive, "NaiveTree"))),
        "SpecTr" => Some(Box::new(OtVerifier::new(spectr::SpecTr, "SpecTr"))),
        "SpecInfer" => Some(Box::new(OtVerifier::new(specinfer::SpecInfer, "SpecInfer"))),
        "Khisti" => Some(Box::new(OtVerifier::new(khisti::Khisti, "Khisti"))),
        "BV" => Some(Box::new(bv::BlockVerify)),
        "Traversal" => Some(Box::new(traversal::Traversal)),
        _ => None,
    }
}
