//! Verification algorithms (paper §3, Appendix B).
//!
//! Two kinds:
//! * **OT-based** algorithms are built from an [`OtlpSolver`] (paper
//!   Definition 3.2) and share the generic top-down walk in [`OtVerifier`]:
//!   at each node the solver emits a token distributed as p; if it matches a
//!   drafted child we descend, otherwise it terminates the block as the
//!   correction token. Each solver also provides its acceptance-rate
//!   calculator (Algorithms 6–10) and branching-probability calculator
//!   (Algorithms 11–15) used by Figure 1 and the Eq. 3 block-efficiency
//!   estimator.
//! * **Non-OT** algorithms (Block Verification, Traversal) implement
//!   [`Verifier`] directly.
//!
//! ## The allocation-free hot path
//!
//! Verification runs once per decoded block, so its per-node heap traffic
//! is pure overhead on the throughput-critical path. The steady-state entry
//! point is [`Verifier::verify_into`]: all working memory lives in a
//! caller-owned [`VerifyScratch`] arena and the verdict is written into a
//! reusable [`Verdict`], so a warm call performs **zero heap allocations**
//! (asserted by `tests/alloc_free.rs`; the one exception is the Khisti
//! solver, whose per-node transportation LP is documented as allocating).
//! [`Verifier::verify`] remains as an allocating convenience wrapper.
//!
//! ## Sparse-support inputs
//!
//! Tree nodes carry [`NodeDist`]: dense vocab vectors (the equality
//! oracle) or sparse supports (the default — see
//! [`crate::dist::DistStorage`]). Every solver's hot entries run
//! O(|support|) union-merge kernels on sparse inputs and produce verdicts
//! identical to the dense path under the same rng stream (asserted by
//! `tests/sparse_dense.rs`); Khisti densifies its inputs (the same
//! documented exception as its allocating LP).
//!
//! Losslessness of every implementation is validated by the Monte-Carlo
//! harness in `rust/tests/losslessness.rs` (the same validation the paper
//! reports for its calculators).

pub mod bv;
pub mod khisti;
pub mod naive;
pub mod nss;
pub mod specinfer;
pub mod spectr;
pub mod traversal;

use crate::dist::{Dist, NodeDist};
use crate::tree::{CsrChildren, DraftTree};
use crate::util::Pcg64;

/// Outcome of verifying one draft tree.
#[derive(Clone, Debug, Default)]
pub struct Verdict {
    /// Accepted node indices, root-exclusive, in root→leaf order.
    pub accepted: Vec<usize>,
    /// The correction/bonus token appended after the accepted prefix.
    pub correction: u32,
}

impl Verdict {
    /// τ — the depth of the accepted node.
    pub fn tau(&self) -> usize {
        self.accepted.len()
    }
    /// Decoded tokens this block = τ + 1.
    pub fn block_tokens(&self) -> usize {
        self.accepted.len() + 1
    }
}

/// Reusable scratch for one solver invocation: a token multiset and two
/// distribution buffers for residual ping-pong. All capacity persists
/// across calls.
#[derive(Clone, Debug, Default)]
pub struct SolverScratch {
    /// Remaining draft-token multiset (SpecInfer rounds).
    pub tokens: Vec<u32>,
    /// Residual / working distribution buffer. Its representation follows
    /// the inputs' (a stable stream of one representation never
    /// reallocates after warm-up).
    pub dist_a: NodeDist,
    /// Second residual buffer (ping-pong partner of `dist_a`).
    pub dist_b: NodeDist,
    /// Densified p copy for the Khisti LP (the one solver whose per-node
    /// computation stays dense; sparse inputs are scattered here).
    pub dense_p: Dist,
    /// Densified q copy for the Khisti LP.
    pub dense_q: Dist,
}

/// Caller-owned arena backing a verification walk. Create one per sequence
/// (or per bench thread), reuse it across blocks: after warm-up every
/// buffer has its high-water capacity and `verify_into` allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct VerifyScratch {
    /// CSR snapshot of the tree's child lists, rebuilt per walk.
    pub csr: CsrChildren,
    /// Current node path (BV first-child chain).
    pub path: Vec<usize>,
    /// Per-path-draw used flags (Traversal).
    pub used: Vec<bool>,
    /// BV forward weights w_0..w_L.
    pub w: Vec<f64>,
    /// BV expected next-step weights e_0..e_{L-1}.
    pub e: Vec<f64>,
    /// BV backward monotone thresholds W_0..W_L.
    pub thr: Vec<f64>,
    /// Residual-target ping-pong buffer (Traversal / BV corrections).
    /// Representation follows the tree's storage mode.
    pub dist_a: NodeDist,
    /// Second residual-target buffer (ping-pong partner of `dist_a`).
    pub dist_b: NodeDist,
    /// Fallback per-leaf path draws when the tree records none.
    pub fallback_paths: Vec<Vec<usize>>,
    /// Solver-local scratch.
    pub solver: SolverScratch,
}

impl VerifyScratch {
    /// Empty arena (buffers grow to their high-water marks on first use).
    pub fn new() -> VerifyScratch {
        VerifyScratch::default()
    }

    /// Pre-size every buffer for walks over trees with accepted paths of at
    /// most `depth` edges, at most `paths` path draws, and `vocab`-sized
    /// distributions. The distribution buffers are switched to the
    /// process-default storage ([`crate::dist::DistStorage::global`])
    /// before reserving, so the representation the stream will actually
    /// use holds the capacity. After this call even branches first taken
    /// mid-flight (e.g. a solver's second rejection round) allocate
    /// nothing.
    pub fn reserve(&mut self, vocab: usize, depth: usize, paths: usize) {
        let storage = crate::dist::DistStorage::global();
        self.path.reserve(depth);
        self.used.reserve(paths);
        self.w.reserve(depth + 1);
        self.e.reserve(depth + 1);
        self.thr.reserve(depth + 1);
        self.dist_a.reserve_as(vocab, storage);
        self.dist_b.reserve_as(vocab, storage);
        self.solver.tokens.reserve(paths.max(8));
        self.solver.dist_a.reserve_as(vocab, storage);
        self.solver.dist_b.reserve_as(vocab, storage);
        self.solver.dense_p.0.reserve(vocab);
        self.solver.dense_q.0.reserve(vocab);
    }
}

/// A verification algorithm over a draft tree whose nodes carry p and q.
///
/// ```
/// use specdelay::dist::Dist;
/// use specdelay::tree::{DraftTree, Provenance};
/// use specdelay::util::Pcg64;
///
/// let mut t = DraftTree::new(7);
/// let c = t.add_child(0, 1, Provenance::Trunk { step: 1 });
/// t.set_q(0, Dist(vec![0.5, 0.5]));
/// t.set_p(0, Dist(vec![0.4, 0.6]));
/// t.set_p(c, Dist(vec![1.0, 0.0])); // leaf p feeds the bonus token
/// let verifier = specdelay::verify::verifier("SpecInfer").unwrap();
/// let verdict = verifier.verify(&t, &mut Pcg64::seeded(0));
/// assert!(verdict.block_tokens() >= 1, "every block emits ≥ 1 token");
/// ```
pub trait Verifier: Send + Sync {
    /// Paper name of the algorithm (e.g. `"SpecInfer"`).
    fn name(&self) -> &'static str;

    /// Verify one tree, writing the verdict into `out` and drawing all
    /// working memory from `scratch`. Steady-state calls (warm scratch,
    /// reused verdict) perform no heap allocation.
    fn verify_into(
        &self,
        tree: &DraftTree,
        rng: &mut Pcg64,
        scratch: &mut VerifyScratch,
        out: &mut Verdict,
    );

    /// Allocating convenience wrapper over [`Verifier::verify_into`].
    fn verify(&self, tree: &DraftTree, rng: &mut Pcg64) -> Verdict {
        let mut scratch = VerifyScratch::default();
        let mut out = Verdict::default();
        self.verify_into(tree, rng, &mut scratch, &mut out);
        out
    }
}

/// An OTLP solver f_{p,q,k} (paper Definition 3.2): maps i.i.d. draft tokens
/// X_1..X_k ~ q to an output token distributed exactly as p.
///
/// The hot entries (`solve_scratch`, `branching_into`,
/// `branching_prefixes_into`) take [`NodeDist`] and run O(|support|) on
/// sparse inputs (Khisti excepted — its LP densifies). The acceptance-rate
/// calculator is a cold analysis entry and stays dense.
pub trait OtlpSolver: Send + Sync {
    /// Paper name of the solver (e.g. `"SpecTr"`).
    fn name(&self) -> &'static str;

    /// Draw the output token given the realized draft tokens, using
    /// caller-provided scratch for residual buffers — the hot-path entry.
    fn solve_scratch(
        &self,
        p: &NodeDist,
        q: &NodeDist,
        xs: &[u32],
        rng: &mut Pcg64,
        scratch: &mut SolverScratch,
    ) -> u32;

    /// Allocating convenience wrapper over [`OtlpSolver::solve_scratch`].
    fn solve(&self, p: &NodeDist, q: &NodeDist, xs: &[u32], rng: &mut Pcg64) -> u32 {
        let mut scratch = SolverScratch::default();
        self.solve_scratch(p, q, xs, rng, &mut scratch)
    }

    /// Acceptance rate α(f_{p,q,k}) = P(f(X_1..X_k) ∈ {X_1..X_k}) over
    /// X_i ~ q i.i.d. (Algorithms 6–10; Khisti's is a bound, see khisti.rs).
    /// Cold calculator path: dense inputs only (densify sparse storage with
    /// [`NodeDist::to_dense`] first).
    fn acceptance_rate(&self, p: &Dist, q: &Dist, k: usize) -> f64;

    /// Branching probabilities B(f, xs, t) for each *position* i (aligned
    /// with xs; duplicate tokens receive the same total value at each
    /// occurrence — callers sum per distinct token before use), written
    /// into the reusable `out` buffer. Value at position i is P(f outputs
    /// token xs[i]).
    fn branching_into(&self, p: &NodeDist, q: &NodeDist, xs: &[u32], out: &mut Vec<f64>);

    /// Allocating convenience wrapper over [`OtlpSolver::branching_into`].
    fn branching(&self, p: &NodeDist, q: &NodeDist, xs: &[u32]) -> Vec<f64> {
        let mut out = Vec::with_capacity(xs.len());
        self.branching_into(p, q, xs, &mut out);
        out
    }

    /// Branching-cache entry point: probabilities for several leading
    /// prefixes of `xs` in one call, **appended** flat to `out` (prefix i
    /// occupies `prefix_lens[i]` values starting after all earlier
    /// prefixes; the caller records offsets). `tmp` is per-prefix scratch.
    ///
    /// The shared-branching Eq. 3 scorer (`selector::score`) calls this
    /// once per (node, solver) with the distinct child-list prefix lengths
    /// the action space induces, and caches the values for every action —
    /// the sharing that removes the per-action O(vocab) recomputation.
    fn branching_prefixes_into(
        &self,
        p: &NodeDist,
        q: &NodeDist,
        xs: &[u32],
        prefix_lens: &[usize],
        out: &mut Vec<f64>,
        tmp: &mut Vec<f64>,
    ) {
        for &len in prefix_lens {
            self.branching_into(p, q, &xs[..len], tmp);
            out.extend_from_slice(tmp);
        }
    }
}

/// Resolve a (p, q) pair to dense references, scattering sparse inputs into
/// the provided scratch buffers. Khisti's LP (and only it) routes through
/// this — the documented O(vocab) exception to the sparse hot path.
pub(crate) fn densify_pair<'a>(
    p: &'a NodeDist,
    q: &'a NodeDist,
    dp: &'a mut Dist,
    dq: &'a mut Dist,
) -> (&'a Dist, &'a Dist) {
    let p = match p {
        NodeDist::Dense(d) => d,
        s => {
            s.densify_into(dp);
            &*dp
        }
    };
    let q = match q {
        NodeDist::Dense(d) => d,
        s => {
            s.densify_into(dq);
            &*dq
        }
    };
    (p, q)
}

/// Generic top-down OT walk (paper §3.2).
pub struct OtVerifier<S: OtlpSolver> {
    /// The per-node OTLP solver the walk queries.
    pub solver: S,
    name: &'static str,
}

impl<S: OtlpSolver> OtVerifier<S> {
    /// Wrap a solver under a display name (e.g. Naive vs NaiveTree).
    pub fn new(solver: S, name: &'static str) -> Self {
        OtVerifier { solver, name }
    }
}

impl<S: OtlpSolver> Verifier for OtVerifier<S> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn verify_into(
        &self,
        tree: &DraftTree,
        rng: &mut Pcg64,
        scratch: &mut VerifyScratch,
        out: &mut Verdict,
    ) {
        out.accepted.clear();
        // One O(edges) gather over the (≤ ~50-node) tree buys contiguous
        // child/token slices for the whole walk — cheaper than per-node
        // pointer-chasing into `nodes[c].token`, and negligible next to the
        // solvers' vocab-length work at each visited node.
        scratch.csr.build(tree);
        let mut node = 0usize;
        loop {
            let p = tree.nodes[node].p.as_ref().expect("p dist set");
            let xs = scratch.csr.child_tokens(node);
            if xs.is_empty() {
                // Leaf: sample the bonus token directly from p.
                out.correction = p.sample(rng) as u32;
                return;
            }
            let q = tree.nodes[node].q.as_ref().expect("q dist set");
            let y = self.solver.solve_scratch(p, q, xs, rng, &mut scratch.solver);
            let kids = scratch.csr.child_nodes(node);
            let toks = scratch.csr.child_tokens(node);
            let mut next = None;
            for (j, &tok) in toks.iter().enumerate() {
                if tok == y {
                    next = Some(kids[j] as usize);
                    break;
                }
            }
            match next {
                Some(child) => {
                    out.accepted.push(child);
                    node = child;
                }
                None => {
                    out.correction = y;
                    return;
                }
            }
        }
    }
}

/// Reusable scratch for the Eq. 3 reach-probability estimators
/// ([`expected_accepted_into`], `selector::expected_by_depth_into`, and the
/// shared-branching scorer's per-node buffers). All capacity persists
/// across calls, so warm calls allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct Eq3Scratch {
    /// Per-node reach probability (∏ branching along the root path).
    pub reach: Vec<f64>,
    /// Child-token gather buffer.
    pub xs: Vec<u32>,
    /// Branching-probability output buffer.
    pub probs: Vec<f64>,
}

/// Expected number of accepted tokens from walking the tree with a solver's
/// branching probabilities (the inner sum of paper Eq. 3): Σ over non-root
/// nodes of ∏ branching probabilities along the path. All working memory
/// comes from `scratch` — zero allocations once it is warm.
pub fn expected_accepted_into(
    tree: &DraftTree,
    solver: &dyn OtlpSolver,
    scratch: &mut Eq3Scratch,
) -> f64 {
    scratch.reach.clear();
    scratch.reach.resize(tree.len(), 0.0);
    scratch.reach[0] = 1.0;
    let mut total = 0.0f64;
    for node in 0..tree.len() {
        if scratch.reach[node] <= 0.0 || tree.nodes[node].children.is_empty() {
            continue;
        }
        let p = tree.nodes[node].p.as_ref().expect("p dist set");
        let q = tree.nodes[node].q.as_ref().expect("q dist set");
        tree.child_tokens_into(node, &mut scratch.xs);
        solver.branching_into(p, q, &scratch.xs, &mut scratch.probs);
        // Sum duplicate positions per distinct child once: positions carrying
        // the same token all hold the same total probability of the solver
        // outputting that token, so take the value at the first occurrence.
        let reach_node = scratch.reach[node];
        let probs = &scratch.probs;
        let reach = &mut scratch.reach;
        tree.for_each_distinct_child(node, |i, child| {
            let pr = reach_node * probs[i];
            reach[child] += pr;
            total += pr;
        });
    }
    total
}

/// Allocating convenience wrapper over [`expected_accepted_into`].
pub fn expected_accepted(tree: &DraftTree, solver: &dyn OtlpSolver) -> f64 {
    expected_accepted_into(tree, solver, &mut Eq3Scratch::default())
}

/// All eight verifiers by paper name.
pub fn all_verifiers() -> Vec<Box<dyn Verifier>> {
    vec![
        Box::new(OtVerifier::new(nss::Nss, "NSS")),
        Box::new(OtVerifier::new(naive::Naive, "Naive")),
        Box::new(OtVerifier::new(naive::Naive, "NaiveTree")),
        Box::new(OtVerifier::new(spectr::SpecTr, "SpecTr")),
        Box::new(OtVerifier::new(specinfer::SpecInfer, "SpecInfer")),
        Box::new(OtVerifier::new(khisti::Khisti, "Khisti")),
        Box::new(bv::BlockVerify),
        Box::new(traversal::Traversal),
    ]
}

/// OT solvers by name (for NDE, which applies to OT-based methods only).
pub fn ot_solver(name: &str) -> Option<Box<dyn OtlpSolver>> {
    match name {
        "NSS" => Some(Box::new(nss::Nss)),
        "Naive" | "NaiveTree" => Some(Box::new(naive::Naive)),
        "SpecTr" => Some(Box::new(spectr::SpecTr)),
        "SpecInfer" => Some(Box::new(specinfer::SpecInfer)),
        "Khisti" => Some(Box::new(khisti::Khisti)),
        _ => None,
    }
}

/// Verifier lookup by paper name.
pub fn verifier(name: &str) -> Option<Box<dyn Verifier>> {
    match name {
        "NSS" => Some(Box::new(OtVerifier::new(nss::Nss, "NSS"))),
        "Naive" => Some(Box::new(OtVerifier::new(naive::Naive, "Naive"))),
        "NaiveTree" => Some(Box::new(OtVerifier::new(naive::Naive, "NaiveTree"))),
        "SpecTr" => Some(Box::new(OtVerifier::new(spectr::SpecTr, "SpecTr"))),
        "SpecInfer" => Some(Box::new(OtVerifier::new(specinfer::SpecInfer, "SpecInfer"))),
        "Khisti" => Some(Box::new(OtVerifier::new(khisti::Khisti, "Khisti"))),
        "BV" => Some(Box::new(bv::BlockVerify)),
        "Traversal" => Some(Box::new(traversal::Traversal)),
        _ => None,
    }
}
