//! NSS (Miao et al. 2024) — paper Algorithm 1 / 6 / 11.
//!
//! The simplest OTLP solver: ignore the draft tokens and sample Y ~ p.
//! Trivially lossless; acceptance only via collision with drafted tokens.

use super::{OtlpSolver, SolverScratch};
use crate::dist::{Dist, NodeDist};
use crate::util::Pcg64;

/// The NSS OTLP solver (paper Algorithm 1): sample Y ~ p directly.
pub struct Nss;

impl OtlpSolver for Nss {
    fn name(&self) -> &'static str {
        "NSS"
    }

    fn solve_scratch(
        &self,
        p: &NodeDist,
        _q: &NodeDist,
        _xs: &[u32],
        rng: &mut Pcg64,
        _scratch: &mut SolverScratch,
    ) -> u32 {
        p.sample(rng) as u32
    }

    /// Algorithm 6: Σ_t p(t) (1 − (1 − q(t))^k).
    fn acceptance_rate(&self, p: &Dist, q: &Dist, k: usize) -> f64 {
        p.0.iter()
            .zip(&q.0)
            .map(|(&pt, &qt)| pt as f64 * (1.0 - (1.0 - qt as f64).powi(k as i32)))
            .sum()
    }

    /// Algorithm 11: B(X_i) = p(X_i).
    fn branching_into(&self, p: &NodeDist, _q: &NodeDist, xs: &[u32], out: &mut Vec<f64>) {
        out.clear();
        out.extend(xs.iter().map(|&x| p.p(x as usize) as f64));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nd(v: Vec<f32>) -> NodeDist {
        NodeDist::from(Dist(v))
    }

    #[test]
    fn output_follows_p() {
        let p = nd(vec![0.1, 0.2, 0.7]);
        let q = nd(vec![0.5, 0.3, 0.2]);
        let mut rng = Pcg64::seeded(1);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[Nss.solve(&p, &q, &[0, 1], &mut rng) as usize] += 1;
        }
        for t in 0..3 {
            let f = counts[t] as f32 / 30_000.0;
            assert!((f - p.p(t)).abs() < 0.02, "token {t}: {f}");
        }
    }

    #[test]
    fn acceptance_rate_matches_mc() {
        let p = Dist(vec![0.3, 0.3, 0.4]);
        let q = Dist(vec![0.6, 0.2, 0.2]);
        let k = 3;
        let exact = Nss.acceptance_rate(&p, &q, k);
        let (pn, qn) = (nd(p.0.clone()), nd(q.0.clone()));
        let mut rng = Pcg64::seeded(2);
        let mut hits = 0usize;
        let n = 60_000;
        for _ in 0..n {
            let xs: Vec<u32> = (0..k).map(|_| q.sample(&mut rng) as u32).collect();
            let y = Nss.solve(&pn, &qn, &xs, &mut rng);
            if xs.contains(&y) {
                hits += 1;
            }
        }
        let mc = hits as f64 / n as f64;
        assert!((mc - exact).abs() < 0.01, "mc {mc} vs exact {exact}");
    }

    #[test]
    fn branching_matches_mc() {
        let p = nd(vec![0.25, 0.25, 0.5]);
        let q = nd(vec![0.4, 0.4, 0.2]);
        let xs = vec![0u32, 2, 0];
        let b = Nss.branching(&p, &q, &xs);
        assert!((b[0] - 0.25).abs() < 1e-9);
        assert!((b[1] - 0.5).abs() < 1e-9);
        assert!((b[2] - 0.25).abs() < 1e-9);
    }

    #[test]
    fn branching_into_reuses_buffer() {
        let p = nd(vec![0.25, 0.25, 0.5]);
        let q = nd(vec![0.4, 0.4, 0.2]);
        let mut out = Vec::new();
        Nss.branching_into(&p, &q, &[0, 2], &mut out);
        assert_eq!(out, vec![0.25, 0.5]);
        Nss.branching_into(&p, &q, &[1], &mut out);
        assert_eq!(out, vec![0.25]);
    }

    /// The sparse path must replay the dense path's rng stream exactly.
    #[test]
    fn sparse_matches_dense() {
        let p = nd(vec![0.1, 0.0, 0.2, 0.7]);
        let q = nd(vec![0.5, 0.3, 0.0, 0.2]);
        let (ps, qs) = (p.sparsify(), q.sparsify());
        for seed in 0..100 {
            let mut r1 = Pcg64::seeded(seed);
            let mut r2 = Pcg64::seeded(seed);
            assert_eq!(
                Nss.solve(&p, &q, &[0, 3], &mut r1),
                Nss.solve(&ps, &qs, &[0, 3], &mut r2),
                "seed {seed}"
            );
        }
        assert_eq!(Nss.branching(&p, &q, &[0, 3]), Nss.branching(&ps, &qs, &[0, 3]));
    }
}
