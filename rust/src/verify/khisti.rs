//! Khisti et al. (2025) — canonical multi-draft OTLP.
//!
//! The published construction ("canonical decomposition" + tournament
//! selection) attains the optimal multi-draft acceptance. We implement the
//! canonical OTLP *exactly* as a small transportation LP per node: couple the
//! multiset *pattern* of the k i.i.d. draws (counts over the distinct draft
//! tokens plus an "other" bucket) with the output token, maximizing matched
//! mass subject to the marginals P(pattern) and p(t). The LP is solved by
//! dense max-flow (≤ C(k+|D|, k) ≤ 70 patterns for k ≤ 4), giving the exact
//! branching probabilities the paper notes are computable for Khisti.
//!
//! The acceptance-rate calculator uses the canonical closed form
//! Σ_t min(p(t), 1 − (1 − q(t))^k) (paper Algorithm 10 reports a lower
//! bound; this is the matching canonical upper bound — we document the
//! substitution in DESIGN.md and the MC tests bound the gap).
//!
//! **Allocation note:** unlike the other solvers, Khisti rebuilds its
//! pattern/flow coupling per node, which inherently allocates; it is the
//! one verifier excluded from the steady-state zero-allocation guarantee
//! (`tests/alloc_free.rs`) and its allocs/verify are reported as-is by the
//! `verify_hot` bench.
//!
//! **Sparse note:** the transportation LP itself stays dense. Sparse
//! inputs are accepted and scattered into scratch (`verify::densify_pair`)
//! — the one O(vocab) exception to the sparse hot path, documented
//! alongside the allocation exception above.

use super::{densify_pair, OtlpSolver, SolverScratch};
use crate::dist::{Dist, NodeDist};
use crate::util::Pcg64;

/// The canonical multi-draft OTLP solver (Khisti et al. 2025).
pub struct Khisti;

/// Multiset patterns: counts over m distinct tokens + 1 "other" bucket.
fn enumerate_patterns(k: usize, cats: usize) -> Vec<Vec<usize>> {
    fn rec(k: usize, cats: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == cats - 1 {
            cur.push(k);
            out.push(cur.clone());
            cur.pop();
            return;
        }
        for c in 0..=k {
            cur.push(c);
            rec(k - c, cats, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(k, cats, &mut Vec::new(), &mut out);
    out
}

fn multinomial(counts: &[usize]) -> f64 {
    let n: usize = counts.iter().sum();
    let mut num = 1.0f64;
    let mut i = 1usize;
    for &c in counts {
        for j in 1..=c {
            num *= i as f64 / j as f64;
            i += 1;
        }
    }
    let _ = n;
    num
}

/// Dense max-flow (Edmonds–Karp) on a small graph with f64 capacities.
struct Flow {
    n: usize,
    cap: Vec<f64>,
}

impl Flow {
    fn new(n: usize) -> Flow {
        Flow { n, cap: vec![0.0; n * n] }
    }
    fn add(&mut self, a: usize, b: usize, c: f64) {
        self.cap[a * self.n + b] += c;
    }
    fn max_flow(&mut self, s: usize, t: usize) -> f64 {
        let mut total = 0.0;
        loop {
            // BFS for an augmenting path
            let mut prev = vec![usize::MAX; self.n];
            prev[s] = s;
            let mut queue = std::collections::VecDeque::from([s]);
            while let Some(u) = queue.pop_front() {
                if u == t {
                    break;
                }
                for v in 0..self.n {
                    if prev[v] == usize::MAX && self.cap[u * self.n + v] > 1e-12 {
                        prev[v] = u;
                        queue.push_back(v);
                    }
                }
            }
            if prev[t] == usize::MAX {
                return total;
            }
            let mut bottleneck = f64::INFINITY;
            let mut v = t;
            while v != s {
                let u = prev[v];
                bottleneck = bottleneck.min(self.cap[u * self.n + v]);
                v = u;
            }
            let mut v = t;
            while v != s {
                let u = prev[v];
                self.cap[u * self.n + v] -= bottleneck;
                self.cap[v * self.n + u] += bottleneck;
                v = u;
            }
            total += bottleneck;
        }
    }
    /// Flow pushed along a→b = accumulated reverse capacity (no b→a edges
    /// exist in the original graph).
    fn flow_on(&self, a: usize, b: usize) -> f64 {
        self.cap[b * self.n + a].max(0.0)
    }
}

/// The solved canonical coupling for one (p, q, distinct-token set, k).
struct Coupling {
    distinct: Vec<u32>,
    patterns: Vec<Vec<usize>>,
    pattern_prob: Vec<f64>,
    /// matched mass f(pattern, token-index) after max-flow
    matched: Vec<Vec<f64>>,
    /// column sums per distinct token
    colsum: Vec<f64>,
    total_flow: f64,
}

/// Number of canonical match categories (top tokens by q mass). The
/// category set must be a deterministic function of (p, q, k) alone — it
/// cannot depend on the realized draws, or the pattern-conditional mixture
/// becomes incoherent across draws and losslessness breaks.
const M_CATS: usize = 6;

fn build_coupling(p: &Dist, q: &Dist, k: usize) -> Coupling {
    let mut order: Vec<usize> = (0..q.len()).collect();
    order.sort_by(|&a, &b| q.0[b].partial_cmp(&q.0[a]).unwrap().then(a.cmp(&b)));
    let distinct: Vec<u32> = order
        .into_iter()
        .take(M_CATS)
        .filter(|&t| q.0[t] > 0.0)
        .map(|t| t as u32)
        .collect();
    let m = distinct.len();
    let q_other = (1.0
        - distinct.iter().map(|&t| q.p(t as usize) as f64).sum::<f64>())
    .max(0.0);

    let patterns = enumerate_patterns(k, m + 1);
    let pattern_prob: Vec<f64> = patterns
        .iter()
        .map(|c| {
            let mut pr = multinomial(c);
            for (i, &cnt) in c.iter().enumerate() {
                let base = if i < m { q.p(distinct[i] as usize) as f64 } else { q_other };
                pr *= base.powi(cnt as i32);
            }
            pr
        })
        .collect();

    // graph: 0 = source, 1..=P patterns, P+1..=P+m tokens, last = sink
    let np = patterns.len();
    let n = 2 + np + m;
    let sink = n - 1;
    let mut g = Flow::new(n);
    for (i, &pp) in pattern_prob.iter().enumerate() {
        g.add(0, 1 + i, pp);
    }
    for (i, pat) in patterns.iter().enumerate() {
        for (j, &cnt) in pat.iter().take(m).enumerate() {
            if cnt > 0 {
                // capacity 2.0 > any feasible flow (total mass is 1)
                g.add(1 + i, 1 + np + j, 2.0);
            }
        }
    }
    for (j, &t) in distinct.iter().enumerate() {
        g.add(1 + np + j, sink, p.p(t as usize) as f64);
    }
    let total_flow = g.max_flow(0, sink);

    let mut matched = vec![vec![0.0; m]; np];
    let mut colsum = vec![0.0; m];
    for (i, pat) in patterns.iter().enumerate() {
        for (j, &cnt) in pat.iter().take(m).enumerate() {
            if cnt > 0 {
                let f = g.flow_on(1 + i, 1 + np + j);
                matched[i][j] = f;
                colsum[j] += f;
            }
        }
    }
    Coupling { distinct, patterns, pattern_prob, matched, colsum, total_flow }
}

impl Coupling {
    /// Pattern of the realized draws: counts over the canonical categories;
    /// tokens outside them land in the trailing "other" bucket.
    fn pattern_index(&self, xs: &[u32]) -> usize {
        let m = self.distinct.len();
        let mut counts = vec![0usize; m + 1];
        for &x in xs {
            match self.distinct.iter().position(|&t| t == x) {
                Some(j) => counts[j] += 1,
                None => counts[m] += 1,
            }
        }
        self.patterns.iter().position(|p| *p == counts).expect("observed pattern")
    }

    /// Residual over the full vocabulary ∝ (p − matched column mass)_+.
    fn residual(&self, p: &Dist) -> Dist {
        let mut r: Vec<f32> = p.0.iter().map(|&v| v as f32).collect();
        for (j, &t) in self.distinct.iter().enumerate() {
            r[t as usize] = (r[t as usize] - self.colsum[j] as f32).max(0.0);
        }
        let s: f32 = r.iter().sum();
        if s > 0.0 {
            for v in r.iter_mut() {
                *v /= s;
            }
        }
        Dist(r)
    }
}

impl OtlpSolver for Khisti {
    fn name(&self) -> &'static str {
        "Khisti"
    }

    fn solve_scratch(
        &self,
        p: &NodeDist,
        q: &NodeDist,
        xs: &[u32],
        rng: &mut Pcg64,
        scratch: &mut SolverScratch,
    ) -> u32 {
        let (p, q) = densify_pair(p, q, &mut scratch.dense_p, &mut scratch.dense_q);
        let c = build_coupling(p, q, xs.len());
        let pi = c.pattern_index(xs);
        let pp = c.pattern_prob[pi];
        if pp > 0.0 {
            let u = rng.next_f64() * pp;
            let mut acc = 0.0;
            for (j, &f) in c.matched[pi].iter().enumerate() {
                acc += f;
                if u < acc {
                    return c.distinct[j];
                }
            }
        }
        c.residual(p).sample(rng) as u32
    }

    /// Canonical acceptance Σ_t min(p(t), 1 − (1 − q(t))^k).
    fn acceptance_rate(&self, p: &Dist, q: &Dist, k: usize) -> f64 {
        p.0.iter()
            .zip(&q.0)
            .map(|(&pt, &qt)| (pt as f64).min(1.0 - (1.0 - qt as f64).powi(k as i32)))
            .sum::<f64>()
            .min(1.0)
    }

    fn branching_into(&self, p: &NodeDist, q: &NodeDist, xs: &[u32], out: &mut Vec<f64>) {
        let (mut dp, mut dq) = (Dist::default(), Dist::default());
        let (p, q) = densify_pair(p, q, &mut dp, &mut dq);
        branching_dense_into(p, q, xs, out);
    }

    /// Override of the prefix-cache entry: densify once per (node, solver)
    /// call instead of once per prefix (the Eq. 3 scorer calls this with
    /// several prefixes per node under the default sparse storage).
    fn branching_prefixes_into(
        &self,
        p: &NodeDist,
        q: &NodeDist,
        xs: &[u32],
        prefix_lens: &[usize],
        out: &mut Vec<f64>,
        tmp: &mut Vec<f64>,
    ) {
        let (mut dp, mut dq) = (Dist::default(), Dist::default());
        let (p, q) = densify_pair(p, q, &mut dp, &mut dq);
        for &len in prefix_lens {
            branching_dense_into(p, q, &xs[..len], tmp);
            out.extend_from_slice(tmp);
        }
    }
}

/// Dense branching core shared by both trait entries.
fn branching_dense_into(p: &Dist, q: &Dist, xs: &[u32], out: &mut Vec<f64>) {
    let c = build_coupling(p, q, xs.len());
    let pi = c.pattern_index(xs);
    let pp = c.pattern_prob[pi].max(1e-300);
    let matched_total: f64 = c.matched[pi].iter().sum::<f64>() / pp;
    let res = c.residual(p);
    out.clear();
    out.extend(xs.iter().map(|&x| {
        let matched = c
            .distinct
            .iter()
            .position(|&t| t == x)
            .map_or(0.0, |j| c.matched[pi][j] / pp);
        matched + (1.0 - matched_total) * res.p(x as usize) as f64
    }));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pq() -> (Dist, Dist) {
        (
            Dist(vec![0.45, 0.25, 0.2, 0.1]),
            Dist(vec![0.1, 0.3, 0.25, 0.35]),
        )
    }

    #[test]
    fn patterns_count() {
        // compositions of 4 into 3 parts = C(6,2) = 15
        assert_eq!(enumerate_patterns(4, 3).len(), 15);
        let pats = enumerate_patterns(2, 2);
        assert_eq!(pats, vec![vec![0, 2], vec![1, 1], vec![2, 0]]);
    }

    #[test]
    fn pattern_probs_sum_to_one() {
        let (p, q) = pq();
        let c = build_coupling(&p, &q, 3);
        let s: f64 = c.pattern_prob.iter().sum();
        assert!((s - 1.0).abs() < 1e-6, "sum {s}");
    }

    #[test]
    fn output_follows_p() {
        let (p, q) = pq();
        let (pn, qn) = (NodeDist::from(p.clone()), NodeDist::from(q.clone()));
        let mut rng = Pcg64::seeded(8);
        let n = 80_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let xs: Vec<u32> = (0..3).map(|_| q.sample(&mut rng) as u32).collect();
            counts[Khisti.solve(&pn, &qn, &xs, &mut rng) as usize] += 1;
        }
        for t in 0..4 {
            let f = counts[t] as f64 / n as f64;
            assert!((f - p.0[t] as f64).abs() < 0.012, "token {t}: {f}");
        }
    }

    #[test]
    fn k1_reduces_to_naive_acceptance() {
        let (p, q) = pq();
        let (pn, qn) = (NodeDist::from(p.clone()), NodeDist::from(q.clone()));
        let mut rng = Pcg64::seeded(80);
        let n = 60_000;
        let mut hits = 0usize;
        for _ in 0..n {
            let xs = vec![q.sample(&mut rng) as u32];
            if xs.contains(&Khisti.solve(&pn, &qn, &xs, &mut rng)) {
                hits += 1;
            }
        }
        let mc = hits as f64 / n as f64;
        let naive = Dist::overlap(&p, &q) as f64;
        assert!((mc - naive).abs() < 0.01, "mc {mc} vs naive {naive}");
    }

    #[test]
    fn acceptance_dominates_specinfer() {
        // The canonical coupling is optimal: its realized acceptance must be
        // at least SpecInfer's computed rate.
        let (p, q) = pq();
        let (pn, qn) = (NodeDist::from(p.clone()), NodeDist::from(q.clone()));
        for k in 2..=4 {
            let mut rng = Pcg64::seeded(90 + k as u64);
            let n = 60_000;
            let mut hits = 0usize;
            for _ in 0..n {
                let xs: Vec<u32> = (0..k).map(|_| q.sample(&mut rng) as u32).collect();
                if xs.contains(&Khisti.solve(&pn, &qn, &xs, &mut rng)) {
                    hits += 1;
                }
            }
            let mc = hits as f64 / n as f64;
            let si = super::super::specinfer::SpecInfer.acceptance_rate(&p, &q, k);
            assert!(mc > si - 0.012, "k={k}: khisti {mc} < specinfer {si}");
        }
    }

    #[test]
    fn branching_matches_mc() {
        let (p, q) = pq();
        let (pn, qn) = (NodeDist::from(p), NodeDist::from(q));
        let xs = vec![1u32, 3, 1];
        let b = Khisti.branching(&pn, &qn, &xs);
        // the sparse entry must densify to the identical coupling
        assert_eq!(b, Khisti.branching(&pn.sparsify(), &qn.sparsify(), &xs));
        let mut rng = Pcg64::seeded(100);
        let n = 150_000usize;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[Khisti.solve(&pn, &qn, &xs, &mut rng) as usize] += 1;
        }
        for (i, &x) in xs.iter().enumerate() {
            let mc = counts[x as usize] as f64 / n as f64;
            assert!((mc - b[i]).abs() < 0.012, "pos {i}: mc {mc} vs {}", b[i]);
        }
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;

    #[test]
    fn coupling_k1_matches_overlap() {
        // k = 1: the canonical coupling reduces to the maximal coupling,
        // total flow = Σ min(p, q) over the canonical categories.
        let p = Dist(vec![0.45, 0.25, 0.2, 0.1]);
        let q = Dist(vec![0.1, 0.3, 0.25, 0.35]);
        let c = build_coupling(&p, &q, 1);
        let want: f64 = (0..4).map(|t| (p.0[t].min(q.0[t])) as f64).sum();
        assert!((c.total_flow - want).abs() < 1e-6, "flow {} vs {want}", c.total_flow);
    }
}
