//! specdelay — reproduction of "Dynamic Delayed Tree Expansion For Improved
//! Multi-Path Speculative Decoding" as a three-layer rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate) is the serving coordinator: request routing, draft
//! tree construction, verification, KV-cache management, the neural
//! delay-and-branch selector, and the bench harness that regenerates every
//! table and figure of the paper. Layers 1/2 (Pallas kernel + JAX model)
//! live in `python/compile/` and are AOT-lowered to HLO text loaded by
//! [`runtime`]. Python never runs on the request path.
//!
//! Model execution goes through the [`runtime::Backend`] seam: the default
//! build runs the whole serving stack — [`coordinator::SpecEngine`], the
//! TCP [`coordinator::server`], the batched [`coordinator::ServeLoop`],
//! the CLI and the examples — end-to-end on the deterministic
//! [`runtime::CpuRefBackend`]; `--features pjrt` swaps in the compiled-HLO
//! engine without touching anything above the seam.
//!
//! See `docs/ARCHITECTURE.md` for the module map and data flow, and
//! `docs/BENCHES.md` for the machine-readable benchmark reports.

#![warn(missing_docs)]

pub mod benchkit;
pub mod coordinator;
pub mod dist;
pub mod draft;
pub mod kvcache;
pub mod selector;
pub mod runtime;
pub mod tokenizer;
pub mod tree;
pub mod util;
pub mod verify;

/// Crate version (from Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
