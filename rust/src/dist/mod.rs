//! Token distributions and the sampling transforms applied to logits.
//!
//! This is the performance-first kernel layer under the verification walk:
//! every per-block operation the verifiers run (sampling, residuals,
//! overlaps, divergences) lives here, and every op has an `_into` / in-place
//! variant that writes into caller-provided scratch so the steady-state
//! verify path performs **zero heap allocations** (validated by
//! `tests/alloc_free.rs`). The allocating wrappers remain for tests and
//! cold paths.
//!
//! Two representations coexist:
//!
//! * [`Dist`] — dense `f32` over the vocabulary, `f64` accumulations. The
//!   reference implementation and the equality oracle.
//! * [`SparseDist`] — sorted support ids + probabilities, O(|support|)
//!   kernels, bit-identical results (see `sparse.rs` for the exactness
//!   contract). The default for tree/superset storage; the env knob
//!   `SPECDELAY_DENSE_DISTS=1` selects the dense oracle instead (see
//!   [`DistStorage`]).
//!
//! [`NodeDist`] is the storage enum the tree, scorer and verifiers carry,
//! dispatching each kernel to whichever representation a node holds.

mod sparse;

pub use sparse::SparseDist;

use crate::util::Pcg64;

/// Which representation newly constructed node distributions use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistStorage {
    /// Full-vocabulary [`Dist`] storage (the reference/oracle path).
    Dense,
    /// Support-only [`SparseDist`] storage (the default hot path).
    Sparse,
}

impl DistStorage {
    /// Process-wide default storage: sparse, unless `SPECDELAY_DENSE_DISTS`
    /// is set to `1`/`true` (the dense oracle path). Read once and cached.
    pub fn global() -> DistStorage {
        static STORAGE: std::sync::OnceLock<DistStorage> = std::sync::OnceLock::new();
        *STORAGE.get_or_init(|| {
            let dense = std::env::var("SPECDELAY_DENSE_DISTS")
                .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
                .unwrap_or(false);
            if dense {
                DistStorage::Dense
            } else {
                DistStorage::Sparse
            }
        })
    }
}

/// A dense probability distribution over token ids `0..len`.
///
/// The payload is public: verifiers and tests construct `Dist(vec![...])`
/// directly. Invariant (maintained by every constructor here): entries are
/// non-negative and sum to ~1; consumers tolerate small normalization error.
/// ```
/// use specdelay::dist::{Dist, SamplingConfig};
///
/// // softmax + nucleus: the transformed dist is normalized and truncated
/// let d = Dist::from_logits(&[0.0, 1.0, 3.0, 2.0], SamplingConfig::new(1.0, 0.9));
/// assert!((d.0.iter().sum::<f32>() - 1.0).abs() < 1e-5);
/// assert_eq!(d.argmax(), 2);
/// assert_eq!(d.0[0], 0.0, "tail token falls outside the 0.9 nucleus");
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Dist(pub Vec<f32>);

impl Dist {
    /// Dense length (vocabulary size).
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// Whether the distribution has no entries at all.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Probability of token `t` (0 outside the support).
    #[inline]
    pub fn p(&self, t: usize) -> f32 {
        self.0.get(t).copied().unwrap_or(0.0)
    }

    /// Replace contents with a copy of `src`, reusing this allocation.
    pub fn copy_from(&mut self, src: &Dist) {
        self.0.clear();
        self.0.extend_from_slice(&src.0);
    }

    /// Index of the largest entry (first on ties); 0 for the empty dist.
    pub fn argmax(&self) -> usize {
        let mut best = 0usize;
        for i in 1..self.0.len() {
            if self.0[i] > self.0[best] {
                best = i;
            }
        }
        best
    }

    /// Draw a token index by cumulative scan with early exit.
    ///
    /// One uniform draw, one forward pass that stops at the crossing entry —
    /// for the sharp distributions speculative decoding sees, the expected
    /// scan length is far below the vocabulary size. Falls back to the last
    /// positive-mass index on numerical shortfall (mass < 1), matching the
    /// slack handling of `Pcg64::sample_weighted`.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        let mut acc = 0.0f64;
        let mut last = 0usize;
        for (i, &w) in self.0.iter().enumerate() {
            if w > 0.0 {
                last = i;
                acc += w as f64;
                if u < acc {
                    return i;
                }
            }
        }
        last
    }

    /// Shannon entropy in nats.
    pub fn entropy(&self) -> f32 {
        let mut h = 0.0f64;
        for &p in &self.0 {
            if p > 0.0 {
                h -= p as f64 * (p as f64).ln();
            }
        }
        h as f32
    }

    /// KL(self ‖ other) in nats, summed over the common positive support
    /// (terms with `other = 0` are skipped so the feature stays bounded).
    pub fn kl(&self, other: &Dist) -> f32 {
        let mut d = 0.0f64;
        for (i, &p) in self.0.iter().enumerate() {
            let q = other.p(i);
            if p > 0.0 && q > 0.0 {
                d += p as f64 * (p as f64 / q as f64).ln();
            }
        }
        d as f32
    }

    /// Rescale to unit mass in place. Returns false (leaving the contents
    /// untouched) when the total mass is zero or non-finite.
    pub fn normalize_in_place(&mut self) -> bool {
        let mass: f64 = self.0.iter().map(|&v| v.max(0.0) as f64).sum();
        if !(mass > 0.0) || !mass.is_finite() {
            return false;
        }
        let inv = (1.0 / mass) as f32;
        for v in self.0.iter_mut() {
            *v = v.max(0.0) * inv;
        }
        true
    }

    /// Overlap Σ_t min(p(t), q(t)) — the k = 1 naive acceptance rate.
    ///
    /// Runs over the zipped common prefix only: a token past the shorter
    /// dist contributes min(x, 0) = 0 (entries are non-negative), and the
    /// slice zip lets the compiler drop per-element bounds checks.
    pub fn overlap(p: &Dist, q: &Dist) -> f32 {
        let n = p.len().min(q.len());
        let mut s = 0.0f64;
        for (&a, &b) in p.0[..n].iter().zip(&q.0[..n]) {
            s += a.min(b) as f64;
        }
        s as f32
    }

    /// L1 distance Σ_t |p(t) − q(t)|.
    ///
    /// Zipped common prefix plus the remaining tail slice (at most one of
    /// the two tails is non-empty, where the other dist is implicitly 0) —
    /// same accumulation order as the old 0..max(len) loop, without the
    /// bounds-checked `p(t)` accessor.
    pub fn l1(p: &Dist, q: &Dist) -> f32 {
        let n = p.len().min(q.len());
        let mut s = 0.0f64;
        for (&a, &b) in p.0[..n].iter().zip(&q.0[..n]) {
            s += (a - b).abs() as f64;
        }
        for &a in &p.0[n..] {
            s += a.abs() as f64;
        }
        for &b in &q.0[n..] {
            s += b.abs() as f64;
        }
        s as f32
    }

    /// Total variation distance = L1 / 2 = 1 − overlap for normalized dists.
    pub fn tv(p: &Dist, q: &Dist) -> f32 {
        0.5 * Dist::l1(p, q)
    }

    /// Normalized residual ∝ (p − q)_+ written into `out` (contents and
    /// capacity reused; no allocation once `out` has capacity). Returns
    /// false when the residual mass is zero — `out` then holds the
    /// unnormalized (all-zero) values and must not be sampled.
    pub fn residual_into(p: &Dist, q: &Dist, out: &mut Dist) -> bool {
        let o = &mut out.0;
        o.clear();
        o.reserve(p.0.len());
        let mut mass = 0.0f64;
        for (i, &pt) in p.0.iter().enumerate() {
            let r = (pt - q.p(i)).max(0.0);
            o.push(r);
            mass += r as f64;
        }
        if !(mass > 0.0) {
            return false;
        }
        let inv = (1.0 / mass) as f32;
        for v in o.iter_mut() {
            *v *= inv;
        }
        true
    }

    /// Allocating wrapper over [`Dist::residual_into`]: `None` when p ≤ q
    /// pointwise (zero residual mass).
    pub fn residual(p: &Dist, q: &Dist) -> Option<Dist> {
        let mut out = Dist(Vec::with_capacity(p.len()));
        if Dist::residual_into(p, q, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Transform raw logits into the sampled-from distribution, writing into
    /// `out` and using `idx_scratch` for the nucleus sort — allocation-free
    /// once both have capacity.
    pub fn from_logits_into(
        logits: &[f32],
        cfg: SamplingConfig,
        out: &mut Dist,
        idx_scratch: &mut Vec<u32>,
    ) {
        out.0.clear();
        out.0.extend_from_slice(logits);
        let _ = cfg.transform_logits(&mut out.0, idx_scratch);
    }

    /// Allocating wrapper over [`Dist::from_logits_into`].
    pub fn from_logits(logits: &[f32], cfg: SamplingConfig) -> Dist {
        let mut out = Dist(Vec::with_capacity(logits.len()));
        let mut idx = Vec::new();
        Dist::from_logits_into(logits, cfg, &mut out, &mut idx);
        out
    }
}

/// Temperature + nucleus (top-p) sampling configuration (paper §4.1 grid).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingConfig {
    /// Softmax temperature; `<= 0` takes the greedy (argmax one-hot) limit.
    pub temperature: f32,
    /// Nucleus mass; `1.0` disables truncation.
    pub top_p: f32,
}

impl Default for SamplingConfig {
    fn default() -> SamplingConfig {
        SamplingConfig::new(1.0, 1.0)
    }
}

impl SamplingConfig {
    /// Build a configuration from its (temperature, top-p) pair.
    pub fn new(temperature: f32, top_p: f32) -> SamplingConfig {
        SamplingConfig { temperature, top_p }
    }

    /// In-place logits → probabilities: temperature-scaled stable softmax,
    /// then nucleus truncation when `top_p < 1`. `idx_scratch` is only used
    /// (and only grows) on the nucleus path. `temperature <= 0` takes the
    /// greedy limit: a one-hot at the argmax.
    ///
    /// Returns `Some(keep)` when the nucleus ran: `idx_scratch[..keep]`
    /// then holds exactly the kept token ids (unsorted), which is what lets
    /// [`SparseDist::from_logits_into`] gather the support for free.
    pub fn transform_logits(&self, x: &mut [f32], idx_scratch: &mut Vec<u32>) -> Option<usize> {
        if x.is_empty() {
            return None;
        }
        if self.temperature <= 0.0 {
            let mut best = 0usize;
            for i in 1..x.len() {
                if x[i] > x[best] {
                    best = i;
                }
            }
            for v in x.iter_mut() {
                *v = 0.0;
            }
            x[best] = 1.0;
            return None;
        }
        let inv_t = 1.0 / self.temperature;
        let mut max = f32::NEG_INFINITY;
        for &v in x.iter() {
            if v > max {
                max = v;
            }
        }
        if !max.is_finite() {
            // degenerate logits (all -inf / NaN): uniform fallback
            let u = 1.0 / x.len() as f32;
            for v in x.iter_mut() {
                *v = u;
            }
            return None;
        }
        let mut sum = 0.0f64;
        for v in x.iter_mut() {
            let e = (((*v - max) * inv_t) as f64).exp();
            *v = e as f32;
            sum += e;
        }
        let inv = (1.0 / sum) as f32;
        for v in x.iter_mut() {
            *v *= inv;
        }
        if self.top_p < 1.0 {
            Some(nucleus(x, self.top_p, idx_scratch))
        } else {
            None
        }
    }
}

/// Keep the smallest top-probability prefix with cumulative mass ≥ top_p
/// (the token crossing the threshold is included; ties break by token id),
/// zero the rest, and renormalize the kept mass to 1. Returns the number of
/// kept tokens; `idx[..keep]` holds their ids.
///
/// Instead of fully sorting the vocabulary (O(V log V)), this bisects with
/// `select_nth_unstable_by`: each round partitions the live window around
/// its median rank and either commits the top half to the nucleus or
/// discards the bottom half. The window halves every round, so the total
/// partitioning work is O(V) and the cost past the first partition tracks
/// the nucleus size, not the vocabulary size.
fn nucleus(x: &mut [f32], top_p: f32, idx: &mut Vec<u32>) -> usize {
    if x.is_empty() {
        return 0;
    }
    idx.clear();
    idx.extend(0..x.len() as u32);
    let desc = |a: &u32, b: &u32| {
        x[*b as usize].total_cmp(&x[*a as usize]).then(a.cmp(b))
    };
    // Invariant: idx[..lo] is committed to the nucleus (mass `kept`), the
    // crossing token lives in idx[lo..hi], and everything in idx[lo..hi]
    // outranks everything in idx[hi..] under `desc`.
    let mut lo = 0usize;
    let mut hi = idx.len();
    let mut kept = 0.0f64;
    let mut need = top_p as f64;
    while hi - lo > 1 {
        let mid = lo + (hi - lo - 1) / 2;
        idx[lo..hi].select_nth_unstable_by(mid - lo, desc);
        let s: f64 = idx[lo..=mid].iter().map(|&i| x[i as usize] as f64).sum();
        if s >= need {
            hi = mid + 1;
        } else {
            kept += s;
            need -= s;
            lo = mid + 1;
        }
    }
    kept += x[idx[lo] as usize] as f64; // the crossing token, always kept
    let keep = lo + 1;
    for &i in &idx[keep..] {
        x[i as usize] = 0.0;
    }
    let inv = (1.0 / kept.max(1e-30)) as f32;
    for &i in &idx[..keep] {
        x[i as usize] *= inv;
    }
    keep
}

// ---------------------------------------------------------------------------
// NodeDist: the storage enum the tree / scorer / verifiers carry
// ---------------------------------------------------------------------------

/// A node distribution in either representation.
///
/// The hot kernels dispatch on the pair of representations: (dense, dense)
/// runs the [`Dist`] reference kernels, (sparse, sparse) the O(|support|)
/// [`SparseDist`] kernels. Mixed pairs are a construction error everywhere
/// except the Khisti solver (whose transportation LP densifies its inputs)
/// and abort with a clear panic — trees and supersets are always built in
/// one storage mode (see [`DistStorage`]).
#[derive(Clone, Debug, PartialEq)]
pub enum NodeDist {
    /// Dense full-vocabulary storage.
    Dense(Dist),
    /// Sparse support-only storage.
    Sparse(SparseDist),
}

impl Default for NodeDist {
    fn default() -> NodeDist {
        NodeDist::Dense(Dist::default())
    }
}

impl From<Dist> for NodeDist {
    fn from(d: Dist) -> NodeDist {
        NodeDist::Dense(d)
    }
}

impl From<SparseDist> for NodeDist {
    fn from(s: SparseDist) -> NodeDist {
        NodeDist::Sparse(s)
    }
}

/// Abort on a mixed dense/sparse kernel pair (see [`NodeDist`] docs).
#[cold]
#[inline(never)]
pub(crate) fn mixed_repr() -> ! {
    panic!(
        "mixed dense/sparse distribution pair: build each tree/superset in \
         one storage mode (DistStorage) — only the Khisti solver accepts \
         mixed inputs"
    )
}

impl NodeDist {
    /// Dense length (vocabulary size).
    pub fn len(&self) -> usize {
        match self {
            NodeDist::Dense(d) => d.len(),
            NodeDist::Sparse(s) => s.len(),
        }
    }

    /// Whether the distribution has no entries at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this node holds the sparse representation.
    pub fn is_sparse(&self) -> bool {
        matches!(self, NodeDist::Sparse(_))
    }

    /// Number of stored (positive) entries: O(1) sparse, O(vocab) dense.
    pub fn support_len(&self) -> usize {
        match self {
            NodeDist::Dense(d) => d.0.iter().filter(|&&v| v > 0.0).count(),
            NodeDist::Sparse(s) => s.support_len(),
        }
    }

    /// Borrow the dense payload, if this node is dense.
    pub fn as_dense(&self) -> Option<&Dist> {
        match self {
            NodeDist::Dense(d) => Some(d),
            NodeDist::Sparse(_) => None,
        }
    }

    /// Borrow the sparse payload, if this node is sparse.
    pub fn as_sparse(&self) -> Option<&SparseDist> {
        match self {
            NodeDist::Dense(_) => None,
            NodeDist::Sparse(s) => Some(s),
        }
    }

    /// Borrow the dense slot, switching representation if needed (the
    /// switch allocates; a stable stream of one representation never does).
    pub fn make_dense_mut(&mut self) -> &mut Dist {
        if !matches!(self, NodeDist::Dense(_)) {
            *self = NodeDist::Dense(Dist::default());
        }
        match self {
            NodeDist::Dense(d) => d,
            NodeDist::Sparse(_) => unreachable!(),
        }
    }

    /// Borrow the sparse slot, switching representation if needed.
    pub fn make_sparse_mut(&mut self) -> &mut SparseDist {
        if !matches!(self, NodeDist::Sparse(_)) {
            *self = NodeDist::Sparse(SparseDist::default());
        }
        match self {
            NodeDist::Dense(_) => unreachable!(),
            NodeDist::Sparse(s) => s,
        }
    }

    /// Switch to `storage`'s representation (if needed) and pre-size it for
    /// `vocab`-length content — the scratch-warming entry: reserving the
    /// variant the stream will actually use keeps the first real call from
    /// discarding the reservation.
    pub fn reserve_as(&mut self, vocab: usize, storage: DistStorage) {
        match storage {
            DistStorage::Dense => self.make_dense_mut().0.reserve(vocab),
            DistStorage::Sparse => {
                let s = self.make_sparse_mut();
                s.ids.reserve(vocab);
                s.ps.reserve(vocab);
            }
        }
    }

    /// Densify into `out` (copy for dense, scatter for sparse).
    pub fn densify_into(&self, out: &mut Dist) {
        match self {
            NodeDist::Dense(d) => out.copy_from(d),
            NodeDist::Sparse(s) => s.densify_into(out),
        }
    }

    /// Allocating dense copy.
    pub fn to_dense(&self) -> Dist {
        let mut out = Dist::default();
        self.densify_into(&mut out);
        out
    }

    /// Convert to the sparse representation (identity when already sparse).
    pub fn sparsify(&self) -> NodeDist {
        match self {
            NodeDist::Dense(d) => NodeDist::Sparse(SparseDist::from_dense(d)),
            NodeDist::Sparse(s) => NodeDist::Sparse(s.clone()),
        }
    }

    /// Replace contents with a copy of `src`. Representation-preserving and
    /// allocation-free when the variants already match.
    pub fn copy_from(&mut self, src: &NodeDist) {
        match (self, src) {
            (NodeDist::Dense(d), NodeDist::Dense(s)) => d.copy_from(s),
            (NodeDist::Sparse(d), NodeDist::Sparse(s)) => d.copy_from(s),
            (me, src) => *me = src.clone(),
        }
    }

    /// Probability of token `t` (0 outside the support).
    #[inline]
    pub fn p(&self, t: usize) -> f32 {
        match self {
            NodeDist::Dense(d) => d.p(t),
            NodeDist::Sparse(s) => s.p(t),
        }
    }

    /// Draw a token index ([`Dist::sample`] semantics in both reps).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        match self {
            NodeDist::Dense(d) => d.sample(rng),
            NodeDist::Sparse(s) => s.sample(rng),
        }
    }

    /// Index of the largest entry (first on ties).
    pub fn argmax(&self) -> usize {
        match self {
            NodeDist::Dense(d) => d.argmax(),
            NodeDist::Sparse(s) => s.argmax(),
        }
    }

    /// Shannon entropy in nats.
    pub fn entropy(&self) -> f32 {
        match self {
            NodeDist::Dense(d) => d.entropy(),
            NodeDist::Sparse(s) => s.entropy(),
        }
    }

    /// KL(self ‖ other) over the common positive support.
    pub fn kl(&self, other: &NodeDist) -> f32 {
        match (self, other) {
            (NodeDist::Dense(a), NodeDist::Dense(b)) => a.kl(b),
            (NodeDist::Sparse(a), NodeDist::Sparse(b)) => a.kl(b),
            _ => mixed_repr(),
        }
    }

    /// Overlap Σ_t min(p(t), q(t)).
    pub fn overlap(p: &NodeDist, q: &NodeDist) -> f32 {
        match (p, q) {
            (NodeDist::Dense(a), NodeDist::Dense(b)) => Dist::overlap(a, b),
            (NodeDist::Sparse(a), NodeDist::Sparse(b)) => SparseDist::overlap(a, b),
            _ => mixed_repr(),
        }
    }

    /// L1 distance Σ_t |p(t) − q(t)|.
    pub fn l1(p: &NodeDist, q: &NodeDist) -> f32 {
        match (p, q) {
            (NodeDist::Dense(a), NodeDist::Dense(b)) => Dist::l1(a, b),
            (NodeDist::Sparse(a), NodeDist::Sparse(b)) => SparseDist::l1(a, b),
            _ => mixed_repr(),
        }
    }

    /// Total variation distance = L1 / 2.
    pub fn tv(p: &NodeDist, q: &NodeDist) -> f32 {
        0.5 * NodeDist::l1(p, q)
    }

    /// Normalized residual ∝ (p − q)_+ into `out` (representation follows
    /// `p`); false on zero residual mass, matching [`Dist::residual_into`].
    pub fn residual_into(p: &NodeDist, q: &NodeDist, out: &mut NodeDist) -> bool {
        match (p, q) {
            (NodeDist::Dense(a), NodeDist::Dense(b)) => {
                Dist::residual_into(a, b, out.make_dense_mut())
            }
            (NodeDist::Sparse(a), NodeDist::Sparse(b)) => {
                SparseDist::residual_into(a, b, out.make_sparse_mut())
            }
            _ => mixed_repr(),
        }
    }

    /// Allocating wrapper over [`NodeDist::residual_into`].
    pub fn residual(p: &NodeDist, q: &NodeDist) -> Option<NodeDist> {
        let mut out = match p {
            NodeDist::Dense(_) => NodeDist::Dense(Dist::default()),
            NodeDist::Sparse(_) => NodeDist::Sparse(SparseDist::default()),
        };
        if NodeDist::residual_into(p, q, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Gather a dense probability slice into the requested storage.
    pub fn from_probs(probs: &[f32], storage: DistStorage) -> NodeDist {
        match storage {
            DistStorage::Dense => NodeDist::Dense(Dist(probs.to_vec())),
            DistStorage::Sparse => NodeDist::Sparse(SparseDist::from_probs(probs)),
        }
    }

    /// Transform raw logits into the sampled-from distribution in the
    /// requested storage (the nucleus support is gathered for free on the
    /// sparse path).
    pub fn from_logits(logits: &[f32], cfg: SamplingConfig, storage: DistStorage) -> NodeDist {
        match storage {
            DistStorage::Dense => NodeDist::Dense(Dist::from_logits(logits, cfg)),
            DistStorage::Sparse => NodeDist::Sparse(SparseDist::from_logits(logits, cfg)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn softmax_normalizes_and_orders() {
        let d = Dist::from_logits(&[1.0, 3.0, 2.0], SamplingConfig::new(1.0, 1.0));
        let s: f32 = d.0.iter().sum();
        assert!(close(s, 1.0, 1e-5), "sum {s}");
        assert!(d.0[1] > d.0[2] && d.0[2] > d.0[0]);
        // softmax identity: ratios follow exp(logit differences)
        assert!(close(d.0[1] / d.0[2], std::f32::consts::E, 1e-4));
    }

    #[test]
    fn temperature_argmax_limit() {
        let logits = [1.0f32, 3.0, 2.9];
        // cooling the temperature concentrates mass on the argmax...
        let warm = Dist::from_logits(&logits, SamplingConfig::new(1.0, 1.0));
        let cool = Dist::from_logits(&logits, SamplingConfig::new(0.05, 1.0));
        assert!(cool.0[1] > warm.0[1]);
        assert!(cool.0[1] > 0.85, "T=0.05 argmax mass {}", cool.0[1]);
        // ...and T = 0 is the exact one-hot limit
        let greedy = Dist::from_logits(&logits, SamplingConfig::new(0.0, 1.0));
        assert_eq!(greedy.0, vec![0.0, 1.0, 0.0]);
        assert_eq!(greedy.argmax(), 1);
    }

    #[test]
    fn top_p_support_mass() {
        // probs before nucleus: [0.5, 0.3, 0.15, 0.05] (logits = ln p)
        let logits: Vec<f32> = [0.5f32, 0.3, 0.15, 0.05].iter().map(|p| p.ln()).collect();
        let d = Dist::from_logits(&logits, SamplingConfig::new(1.0, 0.75));
        // smallest prefix reaching 0.75 is {0, 1} with mass 0.8
        assert!(d.0[2] == 0.0 && d.0[3] == 0.0, "outside nucleus must be zeroed: {:?}", d.0);
        let s: f32 = d.0.iter().sum();
        assert!(close(s, 1.0, 1e-5), "kept mass renormalized, sum {s}");
        assert!(close(d.0[0], 0.5 / 0.8, 1e-4), "{}", d.0[0]);
        assert!(close(d.0[1], 0.3 / 0.8, 1e-4), "{}", d.0[1]);
        // top_p = 1 keeps everything
        let full = Dist::from_logits(&logits, SamplingConfig::new(1.0, 1.0));
        assert!(full.0.iter().all(|&v| v > 0.0));
    }

    /// The select_nth-based nucleus must keep exactly the same support as
    /// the straightforward full-sort implementation, across sizes, ties,
    /// and thresholds (including one the total mass never reaches).
    #[test]
    fn nucleus_matches_full_sort_reference() {
        fn reference(x: &mut [f32], top_p: f32) {
            let mut idx: Vec<u32> = (0..x.len() as u32).collect();
            idx.sort_unstable_by(|&a, &b| {
                x[b as usize].total_cmp(&x[a as usize]).then(a.cmp(&b))
            });
            let mut cum = 0.0f64;
            let mut keep = idx.len();
            for (rank, &i) in idx.iter().enumerate() {
                cum += x[i as usize] as f64;
                if cum >= top_p as f64 {
                    keep = rank + 1;
                    break;
                }
            }
            for &i in &idx[keep..] {
                x[i as usize] = 0.0;
            }
            let inv = (1.0 / cum.max(1e-30)) as f32;
            for &i in &idx[..keep] {
                x[i as usize] *= inv;
            }
        }
        let mut rng = Pcg64::seeded(0x707);
        let mut idx = Vec::new();
        for case in 0..200usize {
            let v = 1 + (case % 97);
            let mut probs: Vec<f32> = (0..v).map(|_| rng.next_f32().powi(3) + 1e-5).collect();
            if v > 4 {
                probs[1] = probs[3]; // exercise the token-id tie-break
            }
            let sum: f32 = probs.iter().sum();
            for p in probs.iter_mut() {
                *p /= sum;
            }
            for &tp in &[0.1f32, 0.5, 0.75, 0.9, 0.999, 1.5] {
                let mut a = probs.clone();
                let mut b = probs.clone();
                reference(&mut a, tp);
                nucleus(&mut b, tp, &mut idx);
                for (t, (&ra, &rb)) in a.iter().zip(&b).enumerate() {
                    assert_eq!(
                        ra == 0.0,
                        rb == 0.0,
                        "support mismatch: case {case} top_p {tp} token {t}"
                    );
                    assert!(
                        (ra - rb).abs() < 1e-5,
                        "value mismatch: case {case} top_p {tp} token {t}: {ra} vs {rb}"
                    );
                }
            }
        }
    }

    #[test]
    fn sample_within_support() {
        let logits: Vec<f32> = [0.4f32, 0.3, 0.2, 0.1].iter().map(|p| p.ln()).collect();
        let d = Dist::from_logits(&logits, SamplingConfig::new(1.0, 0.65));
        let support: Vec<usize> =
            (0..d.len()).filter(|&t| d.0[t] > 0.0).collect();
        assert_eq!(support, vec![0, 1], "nucleus support {:?}", d.0);
        let mut rng = Pcg64::seeded(5);
        for _ in 0..5_000 {
            let t = d.sample(&mut rng);
            assert!(d.0[t] > 0.0, "sampled token {t} outside support");
        }
    }

    #[test]
    fn sample_matches_distribution() {
        let d = Dist(vec![0.1, 0.2, 0.7]);
        let mut rng = Pcg64::seeded(9);
        let n = 60_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[d.sample(&mut rng)] += 1;
        }
        for t in 0..3 {
            let f = counts[t] as f32 / n as f32;
            assert!(close(f, d.0[t], 0.01), "token {t}: {f} vs {}", d.0[t]);
        }
    }

    #[test]
    fn residual_into_matches_residual() {
        let p = Dist(vec![0.5, 0.3, 0.2]);
        let q = Dist(vec![0.2, 0.5, 0.3]);
        let r = Dist::residual(&p, &q).expect("positive residual");
        let mut buf = Dist::default();
        assert!(Dist::residual_into(&p, &q, &mut buf));
        assert_eq!(r, buf);
        // residual of p against itself has zero mass
        assert!(Dist::residual(&p, &p).is_none());
        assert!(!Dist::residual_into(&p, &p, &mut buf));
        // mass: (0.3)/(0.3) at token 0 only
        assert!(close(r.0[0], 1.0, 1e-6));
        assert_eq!(r.0[1], 0.0);
    }

    #[test]
    fn divergence_helpers() {
        let p = Dist(vec![0.5, 0.5]);
        let q = Dist(vec![0.9, 0.1]);
        assert!(close(Dist::overlap(&p, &q), 0.6, 1e-6));
        assert!(close(Dist::l1(&p, &q), 0.8, 1e-6));
        assert!(close(Dist::tv(&p, &q), 0.4, 1e-6));
        assert!(close(Dist::overlap(&p, &q), 1.0 - Dist::tv(&p, &q), 1e-6));
        assert!(close(p.entropy(), std::f32::consts::LN_2, 1e-6));
        assert!(p.kl(&p).abs() < 1e-7);
        assert!(p.kl(&q) > 0.0);
    }

    #[test]
    fn node_dist_dispatch() {
        let d = Dist(vec![0.0, 0.25, 0.75]);
        let dense = NodeDist::from(d.clone());
        let sparse = dense.sparsify();
        assert!(!dense.is_sparse() && sparse.is_sparse());
        assert_eq!(dense.len(), 3);
        assert_eq!(sparse.len(), 3);
        assert_eq!(dense.support_len(), 2);
        assert_eq!(sparse.support_len(), 2);
        assert_eq!(dense.p(2), sparse.p(2));
        assert_eq!(dense.argmax(), sparse.argmax());
        assert_eq!(dense.entropy(), sparse.entropy());
        assert_eq!(sparse.to_dense(), d);
        // representation-preserving copy_from, plus cross-variant switch
        let mut buf = NodeDist::default();
        buf.copy_from(&sparse);
        assert!(buf.is_sparse());
        buf.copy_from(&dense);
        assert!(!buf.is_sparse());
        assert_eq!(buf, dense);
        // residual follows p's representation
        let q = NodeDist::from(Dist(vec![0.5, 0.5, 0.0]));
        let r = NodeDist::residual(&dense, &q).expect("residual");
        assert!(!r.is_sparse());
        let rs = NodeDist::residual(&dense.sparsify(), &q.sparsify()).expect("residual");
        assert!(rs.is_sparse());
        assert_eq!(rs.to_dense().0, r.to_dense().0);
        // storage-directed constructors
        let logits = [0.0f32, 1.0, 2.0];
        let cfg = SamplingConfig::new(1.0, 0.9);
        let a = NodeDist::from_logits(&logits, cfg, DistStorage::Dense);
        let b = NodeDist::from_logits(&logits, cfg, DistStorage::Sparse);
        assert_eq!(a.to_dense(), b.to_dense());
    }

    #[test]
    #[should_panic(expected = "mixed dense/sparse")]
    fn node_dist_mixed_pair_panics() {
        let dense = NodeDist::from(Dist(vec![0.5, 0.5]));
        let sparse = dense.sparsify();
        let _ = NodeDist::overlap(&dense, &sparse);
    }

    #[test]
    fn copy_from_and_normalize() {
        let src = Dist(vec![0.25, 0.75]);
        let mut dst = Dist(vec![1.0, 2.0, 3.0]);
        dst.copy_from(&src);
        assert_eq!(dst, src);
        let mut un = Dist(vec![2.0, 6.0]);
        assert!(un.normalize_in_place());
        assert!(close(un.0[0], 0.25, 1e-6) && close(un.0[1], 0.75, 1e-6));
        let mut zero = Dist(vec![0.0, 0.0]);
        assert!(!zero.normalize_in_place());
    }
}
