//! Sparse-support distributions: sorted token ids + probabilities.
//!
//! Temperature + top-p sampling produces sharply truncated distributions
//! whose support is orders of magnitude smaller than the vocabulary, and
//! mass outside the nucleus is *identically zero* — so every kernel the
//! verification walk runs (overlap, residuals, divergences, sampling) is
//! exact over the support alone. [`SparseDist`] stores that support as
//! ascending token ids with aligned probabilities, making per-node cost
//! O(|support|) or O(|support_p ∪ support_q|) instead of O(vocab).
//!
//! ## Exactness contract (the dense-equality invariant)
//!
//! Every kernel here accumulates in **ascending token-id order** with the
//! same `f32` element values and `f64` accumulators as its dense
//! counterpart in [`super::Dist`]. Terms the sparse walk skips are exactly
//! `0.0` in the dense loop (adding `0.0` to an `f64` accumulator is the
//! identity), so dense and sparse kernels return **bit-identical** results
//! on equivalent inputs — verified by `tests/sparse_dense.rs`, which also
//! asserts verdict-level equality for all eight verifiers under seeded rng.
//!
//! Construction is free inside the sampling transform: the nucleus
//! bisection already identifies the kept ids, and
//! [`SparseDist::from_logits_into`] gathers them directly.

use super::{Dist, SamplingConfig};
use crate::util::Pcg64;

/// A probability distribution stored as its support.
///
/// Invariants: `ids` strictly ascending, `ps` aligned, every stored
/// probability non-negative, `ids[i] < vocab`, and `mass` tracks the total
/// stored probability. `mass` is maintained *incrementally* (push adds,
/// scale multiplies, normalizing ops set 1) so the hot kernels never pay a
/// second pass for it — it is exact for gather-constructed dists and
/// agrees with Σ ps to f32 rounding after normalization.
#[derive(Clone, Debug, Default)]
pub struct SparseDist {
    /// Support token ids, strictly ascending.
    pub ids: Vec<u32>,
    /// Probabilities aligned with `ids`.
    pub ps: Vec<f32>,
    /// Dense length this distribution is defined over.
    pub vocab: u32,
    /// Total stored mass Σ ps (f64 accumulation).
    pub mass: f64,
}

/// Equality is over the distribution value (support + probabilities +
/// vocab); `mass` is derived bookkeeping and deliberately excluded, so two
/// value-identical dists built through different op histories (incremental
/// pushes vs a normalizing op's exact `1.0`) compare equal.
impl PartialEq for SparseDist {
    fn eq(&self, other: &SparseDist) -> bool {
        self.vocab == other.vocab && self.ids == other.ids && self.ps == other.ps
    }
}

impl SparseDist {
    /// Number of support entries.
    pub fn support_len(&self) -> usize {
        self.ids.len()
    }

    /// Dense length (vocabulary size).
    pub fn len(&self) -> usize {
        self.vocab as usize
    }

    /// Whether the distribution is defined over an empty vocabulary.
    pub fn is_empty(&self) -> bool {
        self.vocab == 0
    }

    /// Probability of token `t` (0 outside the support). O(log |support|).
    #[inline]
    pub fn p(&self, t: usize) -> f32 {
        match self.ids.binary_search(&(t as u32)) {
            Ok(i) => self.ps[i],
            Err(_) => 0.0,
        }
    }

    /// Reset to an empty support over `vocab` tokens, reusing capacity.
    pub fn clear_for(&mut self, vocab: u32) {
        self.ids.clear();
        self.ps.clear();
        self.vocab = vocab;
        self.mass = 0.0;
    }

    /// Append a support entry. `id` must exceed every stored id.
    #[inline]
    pub fn push(&mut self, id: u32, p: f32) {
        debug_assert!(self.ids.last().is_none_or(|&l| l < id), "ids must ascend");
        self.ids.push(id);
        self.ps.push(p);
        self.mass += p as f64;
    }

    /// Multiply every stored probability by `by` (`mass` scales with it).
    pub fn scale(&mut self, by: f32) {
        for v in self.ps.iter_mut() {
            *v *= by;
        }
        self.mass *= by as f64;
    }

    /// Replace contents with a copy of `src`, reusing allocations.
    pub fn copy_from(&mut self, src: &SparseDist) {
        self.ids.clear();
        self.ids.extend_from_slice(&src.ids);
        self.ps.clear();
        self.ps.extend_from_slice(&src.ps);
        self.vocab = src.vocab;
        self.mass = src.mass;
    }

    /// Gather the positive entries of a dense probability slice into `out`.
    pub fn from_probs_into(probs: &[f32], out: &mut SparseDist) {
        out.clear_for(probs.len() as u32);
        for (i, &v) in probs.iter().enumerate() {
            if v > 0.0 {
                out.push(i as u32, v);
            }
        }
    }

    /// Allocating wrapper over [`SparseDist::from_probs_into`].
    pub fn from_probs(probs: &[f32]) -> SparseDist {
        let mut out = SparseDist::default();
        SparseDist::from_probs_into(probs, &mut out);
        out
    }

    /// Sparse view of a dense distribution (positive entries only).
    pub fn from_dense(d: &Dist) -> SparseDist {
        SparseDist::from_probs(&d.0)
    }

    /// Scatter into a dense distribution, reusing `out`'s allocation.
    pub fn densify_into(&self, out: &mut Dist) {
        out.0.clear();
        out.0.resize(self.vocab as usize, 0.0);
        for (&id, &p) in self.ids.iter().zip(&self.ps) {
            out.0[id as usize] = p;
        }
    }

    /// Allocating wrapper over [`SparseDist::densify_into`].
    pub fn to_dense(&self) -> Dist {
        let mut out = Dist::default();
        self.densify_into(&mut out);
        out
    }

    /// Transform raw logits into the sampled-from distribution, stored
    /// sparse. The dense softmax runs in `dense_scratch` (O(vocab), the
    /// same work the dense constructor does); the support gather is free on
    /// the nucleus path because the bisection already isolated the kept ids
    /// in `idx_scratch`. Allocation-free once the scratch buffers and `out`
    /// have capacity.
    pub fn from_logits_into(
        logits: &[f32],
        cfg: SamplingConfig,
        out: &mut SparseDist,
        dense_scratch: &mut Vec<f32>,
        idx_scratch: &mut Vec<u32>,
    ) {
        dense_scratch.clear();
        dense_scratch.extend_from_slice(logits);
        let keep = cfg.transform_logits(dense_scratch, idx_scratch);
        out.clear_for(logits.len() as u32);
        match keep {
            Some(k) => {
                // the nucleus path: idx_scratch[..k] holds exactly the kept
                // token ids — sort ascending and gather
                idx_scratch[..k].sort_unstable();
                for &i in &idx_scratch[..k] {
                    let v = dense_scratch[i as usize];
                    if v > 0.0 {
                        out.push(i, v);
                    }
                }
            }
            None => {
                for (i, &v) in dense_scratch.iter().enumerate() {
                    if v > 0.0 {
                        out.push(i as u32, v);
                    }
                }
            }
        }
    }

    /// Allocating wrapper over [`SparseDist::from_logits_into`].
    pub fn from_logits(logits: &[f32], cfg: SamplingConfig) -> SparseDist {
        let mut out = SparseDist::default();
        let mut dense = Vec::new();
        let mut idx = Vec::new();
        SparseDist::from_logits_into(logits, cfg, &mut out, &mut dense, &mut idx);
        out
    }

    /// Visit this dist's support in ascending id order as `(id, p_t, q_t)`,
    /// where `q_t` is `q`'s probability at the same id (0 when absent).
    /// O(|support_p| + |support_q|).
    #[inline]
    pub fn zip_support<F: FnMut(u32, f32, f32)>(&self, q: &SparseDist, mut f: F) {
        let mut j = 0usize;
        for (i, &id) in self.ids.iter().enumerate() {
            while j < q.ids.len() && q.ids[j] < id {
                j += 1;
            }
            let qt = if j < q.ids.len() && q.ids[j] == id { q.ps[j] } else { 0.0 };
            f(id, self.ps[i], qt);
        }
    }

    /// Visit the union of both supports in ascending id order as
    /// `(id, p_t, q_t)` (0 for the absent side).
    #[inline]
    pub fn zip_union<F: FnMut(u32, f32, f32)>(&self, q: &SparseDist, mut f: F) {
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.ids.len() || j < q.ids.len() {
            let pi = self.ids.get(i).copied().unwrap_or(u32::MAX);
            let qj = q.ids.get(j).copied().unwrap_or(u32::MAX);
            if pi < qj {
                f(pi, self.ps[i], 0.0);
                i += 1;
            } else if qj < pi {
                f(qj, 0.0, q.ps[j]);
                j += 1;
            } else {
                f(pi, self.ps[i], q.ps[j]);
                i += 1;
                j += 1;
            }
        }
    }

    /// Draw a token by cumulative scan with early exit over the support
    /// (identical draw semantics to [`Dist::sample`]).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        let mut acc = 0.0f64;
        let mut last = 0usize;
        for (&id, &w) in self.ids.iter().zip(&self.ps) {
            if w > 0.0 {
                last = id as usize;
                acc += w as f64;
                if u < acc {
                    return id as usize;
                }
            }
        }
        last
    }

    /// Index of the largest entry (first on ties); 0 for empty support.
    pub fn argmax(&self) -> usize {
        let mut best_id = 0usize;
        let mut best_p = f32::NEG_INFINITY;
        for (&id, &p) in self.ids.iter().zip(&self.ps) {
            if p > best_p {
                best_p = p;
                best_id = id as usize;
            }
        }
        if best_p > 0.0 {
            best_id
        } else {
            0
        }
    }

    /// Shannon entropy in nats.
    pub fn entropy(&self) -> f32 {
        let mut h = 0.0f64;
        for &p in &self.ps {
            if p > 0.0 {
                h -= p as f64 * (p as f64).ln();
            }
        }
        h as f32
    }

    /// KL(self ‖ other) over the common positive support.
    pub fn kl(&self, other: &SparseDist) -> f32 {
        let mut d = 0.0f64;
        self.zip_support(other, |_, p, q| {
            if p > 0.0 && q > 0.0 {
                d += p as f64 * (p as f64 / q as f64).ln();
            }
        });
        d as f32
    }

    /// Overlap Σ_t min(p(t), q(t)).
    pub fn overlap(p: &SparseDist, q: &SparseDist) -> f32 {
        let mut s = 0.0f64;
        p.zip_support(q, |_, pt, qt| {
            s += pt.min(qt) as f64;
        });
        s as f32
    }

    /// L1 distance Σ_t |p(t) − q(t)|.
    pub fn l1(p: &SparseDist, q: &SparseDist) -> f32 {
        let mut s = 0.0f64;
        p.zip_union(q, |_, pt, qt| {
            s += (pt - qt).abs() as f64;
        });
        s as f32
    }

    /// Total variation distance = L1 / 2.
    pub fn tv(p: &SparseDist, q: &SparseDist) -> f32 {
        0.5 * SparseDist::l1(p, q)
    }

    /// Rescale to unit mass in place; false (contents untouched) on zero or
    /// non-finite total mass.
    pub fn normalize_in_place(&mut self) -> bool {
        let mass: f64 = self.ps.iter().map(|&v| v.max(0.0) as f64).sum();
        if !(mass > 0.0) || !mass.is_finite() {
            return false;
        }
        let inv = (1.0 / mass) as f32;
        for v in self.ps.iter_mut() {
            *v = v.max(0.0) * inv;
        }
        self.mass = 1.0;
        true
    }

    /// Normalized residual ∝ (p − q)_+ written into `out` (support ⊆
    /// support(p); no allocation once `out` has capacity). Returns false on
    /// zero residual mass, leaving `out` unnormalized and unsampleable —
    /// exactly [`Dist::residual_into`]'s contract.
    pub fn residual_into(p: &SparseDist, q: &SparseDist, out: &mut SparseDist) -> bool {
        out.clear_for(p.vocab);
        let mut mass = 0.0f64;
        p.zip_support(q, |id, pt, qt| {
            let r = (pt - qt).max(0.0);
            if r > 0.0 {
                out.ids.push(id);
                out.ps.push(r);
            }
            mass += r as f64;
        });
        if !(mass > 0.0) {
            return false;
        }
        let inv = (1.0 / mass) as f32;
        for v in out.ps.iter_mut() {
            *v *= inv;
        }
        out.mass = 1.0;
        true
    }

    /// Allocating wrapper over [`SparseDist::residual_into`].
    pub fn residual(p: &SparseDist, q: &SparseDist) -> Option<SparseDist> {
        let mut out = SparseDist::default();
        if SparseDist::residual_into(p, q, &mut out) {
            Some(out)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn from_dense_roundtrip() {
        let d = Dist(vec![0.0, 0.5, 0.0, 0.3, 0.2]);
        let s = SparseDist::from_dense(&d);
        assert_eq!(s.ids, vec![1, 3, 4]);
        assert_eq!(s.ps, vec![0.5, 0.3, 0.2]);
        assert_eq!(s.vocab, 5);
        assert!(close(s.mass as f32, 1.0, 1e-6));
        assert_eq!(s.to_dense(), d);
        assert_eq!(s.p(1), 0.5);
        assert_eq!(s.p(2), 0.0);
        assert_eq!(s.p(99), 0.0);
        assert_eq!(s.support_len(), 3);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn kernels_match_dense() {
        let pd = Dist(vec![0.0, 0.5, 0.0, 0.3, 0.2]);
        let qd = Dist(vec![0.4, 0.0, 0.1, 0.5, 0.0]);
        let ps = SparseDist::from_dense(&pd);
        let qs = SparseDist::from_dense(&qd);
        assert_eq!(SparseDist::overlap(&ps, &qs), Dist::overlap(&pd, &qd));
        assert_eq!(SparseDist::l1(&ps, &qs), Dist::l1(&pd, &qd));
        assert_eq!(SparseDist::tv(&ps, &qs), Dist::tv(&pd, &qd));
        assert_eq!(ps.kl(&qs), pd.kl(&qd));
        assert_eq!(ps.entropy(), pd.entropy());
        assert_eq!(ps.argmax(), pd.argmax());
    }

    #[test]
    fn residual_matches_dense() {
        let pd = Dist(vec![0.5, 0.3, 0.0, 0.2]);
        let qd = Dist(vec![0.2, 0.5, 0.2, 0.1]);
        let ps = SparseDist::from_dense(&pd);
        let qs = SparseDist::from_dense(&qd);
        let mut dense_out = Dist::default();
        let mut sparse_out = SparseDist::default();
        assert!(Dist::residual_into(&pd, &qd, &mut dense_out));
        assert!(SparseDist::residual_into(&ps, &qs, &mut sparse_out));
        assert_eq!(sparse_out.to_dense().0, dense_out.0);
        // zero residual mass: p ≤ q pointwise
        assert!(!SparseDist::residual_into(&ps, &ps, &mut sparse_out));
        // disjoint supports: the residual is p itself
        let a = SparseDist::from_dense(&Dist(vec![0.6, 0.4, 0.0, 0.0]));
        let b = SparseDist::from_dense(&Dist(vec![0.0, 0.0, 0.5, 0.5]));
        let r = SparseDist::residual(&a, &b).expect("disjoint residual");
        assert_eq!(r.ids, a.ids);
        assert!(close(r.mass as f32, 1.0, 1e-6));
    }

    #[test]
    fn sample_matches_dense_stream() {
        let d = Dist(vec![0.0, 0.1, 0.0, 0.2, 0.7]);
        let s = SparseDist::from_dense(&d);
        let mut r1 = Pcg64::seeded(11);
        let mut r2 = Pcg64::seeded(11);
        for _ in 0..5_000 {
            assert_eq!(d.sample(&mut r1), s.sample(&mut r2));
        }
    }

    #[test]
    fn from_logits_matches_dense_support() {
        let mut rng = Pcg64::seeded(0x5d);
        for case in 0..50usize {
            let v = 8 + case % 60;
            let logits: Vec<f32> = (0..v).map(|_| rng.next_f32() * 8.0).collect();
            for &tp in &[0.6f32, 0.9, 1.0] {
                let cfg = SamplingConfig::new(1.0, tp);
                let dense = Dist::from_logits(&logits, cfg);
                let sparse = SparseDist::from_logits(&logits, cfg);
                assert_eq!(sparse.to_dense().0, dense.0, "case {case} top_p {tp}");
                assert!(
                    sparse.support_len() == dense.0.iter().filter(|&&x| x > 0.0).count(),
                    "case {case} top_p {tp}"
                );
            }
        }
        // greedy limit is a singleton support
        let g = SparseDist::from_logits(&[0.1, 2.0, 0.5], SamplingConfig::new(0.0, 1.0));
        assert_eq!(g.ids, vec![1]);
        assert_eq!(g.ps, vec![1.0]);
    }

    #[test]
    fn normalize_and_scale() {
        let mut s = SparseDist::from_dense(&Dist(vec![0.0, 2.0, 6.0]));
        assert!(s.normalize_in_place());
        assert!(close(s.p(1), 0.25, 1e-6) && close(s.p(2), 0.75, 1e-6));
        assert!(close(s.mass as f32, 1.0, 1e-6));
        s.scale(2.0);
        assert!(close(s.mass as f32, 2.0, 1e-6));
        let mut zero = SparseDist::default();
        zero.clear_for(4);
        assert!(!zero.normalize_in_place());
        assert_eq!(zero.sample(&mut Pcg64::seeded(1)), 0);
        assert_eq!(zero.argmax(), 0);
    }

    #[test]
    fn union_and_support_zip() {
        let p = SparseDist::from_dense(&Dist(vec![0.5, 0.0, 0.5, 0.0]));
        let q = SparseDist::from_dense(&Dist(vec![0.0, 0.5, 0.5, 0.0]));
        let mut seen = Vec::new();
        p.zip_union(&q, |id, pt, qt| seen.push((id, pt, qt)));
        assert_eq!(seen, vec![(0, 0.5, 0.0), (1, 0.0, 0.5), (2, 0.5, 0.5)]);
        seen.clear();
        p.zip_support(&q, |id, pt, qt| seen.push((id, pt, qt)));
        assert_eq!(seen, vec![(0, 0.5, 0.0), (2, 0.5, 0.5)]);
    }
}
