//! Shared-branching Eq. 3 scorer over superset samples (paper §6).
//!
//! The action space A = {1..K_MAX} × {0..L1_MAX} × {0..L2_MAX} holds 324
//! actions, but every action tree drawn from one superset sample is a
//! *restriction* of the same drafted material: a trunk prefix plus the
//! first k branch chains attached at trunk depth j, truncated at depth l2.
//! Branching probabilities depend only on (p, q, child-token multiset) at a
//! node, and those multisets coincide across actions almost everywhere:
//!
//! * trunk nodes always have the single trunk continuation child;
//! * a branch-interior node's k-restricted child list is a **prefix** of
//!   its child list in the merged all-K_MAX-chains structure (chains are
//!   inserted in order, and each chain contributes at most one edge per
//!   node, so edges sort by chain id);
//! * only the branch point sees a genuinely different multiset per k.
//!
//! [`score_superset_into`] therefore builds one `MergedBranches`
//! structure per trunk depth (solver-independent, shared by all five OT
//! solvers), computes each node's branching probabilities **once per
//! distinct child-list prefix** through the
//! [`OtlpSolver::branching_prefixes_into`] cache entry point, and derives
//! Ê[τ+1] for every action with a reach-probability prefix DP over the
//! cached scalars — O(nodes·vocab + |A|·nodes) solver work instead of the
//! per-action O(|A|·nodes·vocab) of [`score_superset_per_action`], which
//! is kept (frozen) as the bench baseline and equality oracle.
//!
//! All working memory lives in a caller-owned [`ScoreScratch`] arena (the
//! `verify::VerifyScratch` convention): one arena per worker thread, warm
//! calls reuse every buffer's high-water capacity.

use crate::dist::NodeDist;
use crate::tree::{DraftTree, Provenance};
use crate::verify::{Eq3Scratch, OtlpSolver};

use super::{action_space, K_MAX, L1_MAX, L2_MAX};

/// Cumulative-by-depth row stride: depths 0..=L1_MAX+L2_MAX.
const DEPTHS: usize = L1_MAX + L2_MAX + 1;

/// A drafted superset sample: full trunk + K_MAX branches of L2_MAX at every
/// trunk depth, with p/q at every node (dense or sparse per the
/// construction-time [`crate::dist::DistStorage`]; one sample always uses
/// one representation).
pub struct Superset {
    /// trunk node context tokens (root first)
    pub trunk_tokens: Vec<u32>,
    /// Draft distributions along the trunk (index = trunk depth).
    pub trunk_q: Vec<NodeDist>,
    /// Target distributions along the trunk (index = trunk depth).
    pub trunk_p: Vec<NodeDist>,
    /// per trunk depth j (0..=L1_MAX): per branch b: token/q/p chains
    pub branches: Vec<Vec<BranchChain>>,
}

/// One drafted branch chain below a trunk depth.
pub struct BranchChain {
    /// Chain tokens in draft order.
    pub tokens: Vec<u32>,
    /// Draft distribution used at each chain step.
    pub q: Vec<NodeDist>,
    /// `p[s]` is the target distribution used for branching after `s` chain
    /// tokens (one more entry than `tokens` for the leaf bonus).
    pub p: Vec<NodeDist>,
}

// ---------------------------------------------------------------------------
// Eq. 3 reach DP over an explicit tree (shared with the per-action oracle)
// ---------------------------------------------------------------------------

/// Cumulative expected accepted tokens by depth for one action tree:
/// entry d = Σ over nodes of depth ≤ d of reach probability (Eq. 3 inner sum
/// truncated at depth d). Written into `out` (len `max_depth + 1`), with all
/// working memory drawn from `scratch` — zero allocations once warm.
pub fn expected_by_depth_into(
    tree: &DraftTree,
    solver: &dyn OtlpSolver,
    max_depth: usize,
    scratch: &mut Eq3Scratch,
    out: &mut Vec<f64>,
) {
    scratch.reach.clear();
    scratch.reach.resize(tree.len(), 0.0);
    scratch.reach[0] = 1.0;
    out.clear();
    out.resize(max_depth + 1, 0.0);
    for node in 0..tree.len() {
        if scratch.reach[node] <= 0.0 || tree.nodes[node].children.is_empty() {
            continue;
        }
        let p = tree.nodes[node].p.as_ref().expect("p");
        let q = tree.nodes[node].q.as_ref().expect("q");
        tree.child_tokens_into(node, &mut scratch.xs);
        solver.branching_into(p, q, &scratch.xs, &mut scratch.probs);
        // duplicate child positions carry identical totals: credit each
        // distinct child once, at its first occurrence
        let reach_node = scratch.reach[node];
        let probs = &scratch.probs;
        let reach = &mut scratch.reach;
        tree.for_each_distinct_child(node, |i, child| {
            let pr = reach_node * probs[i];
            reach[child] += pr;
            let d = tree.nodes[child].depth;
            if d <= max_depth {
                out[d] += pr;
            }
        });
    }
    let mut acc = 0.0;
    for v in out.iter_mut() {
        acc += *v;
        *v = acc;
    }
}

/// Allocating convenience wrapper over [`expected_by_depth_into`].
pub fn expected_by_depth(tree: &DraftTree, solver: &dyn OtlpSolver, max_depth: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(max_depth + 1);
    expected_by_depth_into(tree, solver, max_depth, &mut Eq3Scratch::default(), &mut out);
    out
}

// ---------------------------------------------------------------------------
// Frozen per-action reference scorer (bench baseline + equality oracle)
// ---------------------------------------------------------------------------

/// Build the action tree (trunk to depth `j`, first `k` branch chains
/// truncated to `l2` tokens) from a superset sample. `k = 0` gives the bare
/// trunk chain.
fn per_action_tree(ss: &Superset, j: usize, k: usize, l2: usize) -> DraftTree {
    let mut tree = DraftTree::new(ss.trunk_tokens[0]);
    let mut node = 0usize;
    for d in 0..j {
        tree.set_q(node, ss.trunk_q[d].clone());
        tree.set_p(node, ss.trunk_p[d].clone());
        node = tree.add_child(node, ss.trunk_tokens[d + 1], Provenance::Trunk { step: d + 1 });
    }
    let bp = node;
    tree.set_p(bp, ss.trunk_p[j].clone());
    for (b, chain) in ss.branches[j].iter().take(k).enumerate() {
        let mut cur = bp;
        for (s, &tok) in chain.tokens.iter().take(l2).enumerate() {
            if tree.nodes[cur].q.is_none() {
                tree.set_q(cur, chain.q[s].clone());
            }
            if tree.nodes[cur].p.is_none() {
                tree.set_p(cur, chain.p[s].clone());
            }
            cur = tree.add_child(cur, tok, Provenance::Branch { branch: b, step: s + 1 });
        }
        if tree.nodes[cur].p.is_none() && chain.p.len() > l2 {
            tree.set_p(cur, chain.p[l2].clone());
        }
    }
    tree
}

/// **Frozen** per-action scorer: for every one of the 324 actions, rebuild
/// the action tree from the superset sample and recompute every node's
/// branching probabilities from scratch — the O(|A|·nodes·vocab) cost model
/// the shared-branching scorer replaces. `benches/selector_score.rs`
/// measures against this fixed baseline and the determinism tests use it as
/// the equality oracle; keep it naive, do not optimize it.
pub fn score_superset_per_action(
    ss: &Superset,
    solvers: &[(&str, Box<dyn OtlpSolver>)],
) -> Vec<Vec<f64>> {
    let actions = action_space();
    let mut out = vec![vec![0.0f64; actions.len()]; solvers.len()];
    for (si, (_name, solver)) in solvers.iter().enumerate() {
        for (ai, a) in actions.iter().enumerate() {
            let (tree, depth) = if a.k <= 1 || a.l2 == 0 {
                let d = (a.l1 + a.l2).min(L1_MAX);
                (per_action_tree(ss, d, 0, 0), d)
            } else {
                (per_action_tree(ss, a.l1, a.k, a.l2), a.l1 + a.l2)
            };
            let cum = expected_by_depth(&tree, solver.as_ref(), depth);
            out[si][ai] = cum[depth];
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Merged branch structure (solver-independent, one per trunk depth)
// ---------------------------------------------------------------------------

/// Merged view of all K_MAX branch chains below one trunk depth. Node 0 is
/// the branch point; chains insert in order with the same token-merge
/// semantics as [`DraftTree::add_child`], so for every k the (j, k) action
/// tree's branch part is exactly the sub-structure of edges pushed by
/// chains `< k` — a *prefix* of each node's edge list.
#[derive(Clone, Debug, Default)]
struct MergedBranches {
    /// Live node count (buffers below may hold more capacity).
    n: usize,
    token: Vec<u32>,
    /// Depth below the branch point (0 = branch point).
    rel_depth: Vec<u32>,
    /// (chain, step) of the node's first visit — the q/p the action trees
    /// carry there (identical contexts share distributions). Node 0's p
    /// comes from the trunk instead.
    first: Vec<(u32, u32)>,
    /// Child edges with multiplicity in draft order: (child node, chain
    /// that pushed the edge). Chain ids are non-decreasing within a node.
    edges: Vec<Vec<(u32, u32)>>,
}

impl MergedBranches {
    fn push_node(&mut self, token: u32, rel_depth: u32, first: (u32, u32)) -> u32 {
        let idx = self.n;
        if idx == self.token.len() {
            self.token.push(token);
            self.rel_depth.push(rel_depth);
            self.first.push(first);
            self.edges.push(Vec::new());
        } else {
            self.token[idx] = token;
            self.rel_depth[idx] = rel_depth;
            self.first[idx] = first;
            self.edges[idx].clear();
        }
        self.n += 1;
        idx as u32
    }

    /// Rebuild for trunk depth `j`, reusing all capacity.
    fn build(&mut self, ss: &Superset, j: usize) {
        self.n = 0;
        self.push_node(ss.trunk_tokens[j], 0, (0, 0));
        for (b, chain) in ss.branches[j].iter().enumerate() {
            let mut cur = 0usize;
            for (s, &tok) in chain.tokens.iter().enumerate() {
                let existing = self.edges[cur]
                    .iter()
                    .map(|&(c, _)| c)
                    .find(|&c| self.token[c as usize] == tok);
                let child = match existing {
                    Some(c) => c,
                    None => self.push_node(tok, s as u32 + 1, (b as u32, s as u32 + 1)),
                };
                self.edges[cur].push((child, b as u32));
                cur = child as usize;
            }
        }
    }

    /// Draft distribution at an interior node (never called on leaves).
    fn q<'a>(&self, ss: &'a Superset, j: usize, node: usize) -> &'a NodeDist {
        let (b, s) = self.first[node];
        &ss.branches[j][b as usize].q[s as usize]
    }

    /// Target distribution at an interior node.
    fn p<'a>(&self, ss: &'a Superset, j: usize, node: usize) -> &'a NodeDist {
        if node == 0 {
            return &ss.trunk_p[j];
        }
        let (b, s) = self.first[node];
        &ss.branches[j][b as usize].p[s as usize]
    }
}

// ---------------------------------------------------------------------------
// The shared-branching scorer
// ---------------------------------------------------------------------------

/// Caller-owned arena backing [`score_superset_into`] (the `VerifyScratch`
/// convention): create one per worker thread and reuse it across superset
/// samples — after warm-up every buffer holds its high-water capacity.
#[derive(Clone, Debug, Default)]
pub struct ScoreScratch {
    /// Merged branch structures, one per trunk depth j.
    merged: Vec<MergedBranches>,
    /// Trunk branching values v[d] = B(trunk token d+1 | trunk node d).
    v_trunk: Vec<f64>,
    /// Trunk reach prefix products R[d] = ∏_{e<d} v[e] (R[0] = 1).
    r_trunk: Vec<f64>,
    /// Cumulative trunk expectation Σ_{e=1..d} R[e].
    trunk_cum: Vec<f64>,
    /// Child-token / per-call probability buffers.
    eq3: Eq3Scratch,
    /// Flat branching-probability cache for the current (solver, j).
    probs_flat: Vec<f64>,
    /// Per node, per k−2: offset into `probs_flat` (u32::MAX = node absent
    /// from the k-restricted tree) and prefix length.
    cache_off: Vec<[u32; K_MAX - 1]>,
    cache_len: Vec<[u32; K_MAX - 1]>,
    /// Distinct child-list prefix lengths at the current node (ascending).
    prefix_lens: Vec<usize>,
    /// Reach DP state and per-depth accumulators.
    reach: Vec<f64>,
    per_depth: Vec<f64>,
    /// Cumulative-by-depth rows, flat over (j, k−2) with stride `DEPTHS`.
    cum: Vec<f64>,
}

/// Score one superset sample for every (solver, action): Ê accepted tokens,
/// per solver a vector aligned with [`action_space`]. Equal (within fp
/// regrouping noise, ≪ 1e-12) to [`score_superset_per_action`] while doing
/// roughly two orders of magnitude less solver work over the full action
/// space.
pub fn score_superset_into(
    ss: &Superset,
    solvers: &[(&str, Box<dyn OtlpSolver>)],
    scratch: &mut ScoreScratch,
    out: &mut Vec<Vec<f64>>,
) {
    let ScoreScratch {
        merged,
        v_trunk,
        r_trunk,
        trunk_cum,
        eq3,
        probs_flat,
        cache_off,
        cache_len,
        prefix_lens,
        reach,
        per_depth,
        cum,
    } = scratch;

    // Solver-independent merged structures, built once per sample.
    merged.resize_with(L1_MAX + 1, MergedBranches::default);
    for (j, m) in merged.iter_mut().enumerate() {
        m.build(ss, j);
    }

    let n_actions = K_MAX * (L1_MAX + 1) * (L2_MAX + 1);
    out.resize_with(solvers.len(), Vec::new);

    for (si, (_name, solver)) in solvers.iter().enumerate() {
        let solver = solver.as_ref();

        // Trunk chain: one single-child branching call per depth, then the
        // reach prefix products every action tree's trunk part reuses.
        v_trunk.clear();
        for d in 0..L1_MAX {
            solver.branching_into(
                &ss.trunk_p[d],
                &ss.trunk_q[d],
                &ss.trunk_tokens[d + 1..d + 2],
                &mut eq3.probs,
            );
            v_trunk.push(eq3.probs[0]);
        }
        r_trunk.clear();
        trunk_cum.clear();
        r_trunk.push(1.0);
        trunk_cum.push(0.0);
        for d in 1..=L1_MAX {
            let r = r_trunk[d - 1] * v_trunk[d - 1];
            r_trunk.push(r);
            trunk_cum.push(trunk_cum[d - 1] + r);
        }

        // Per (j, k) cumulative rows: cache branching once per distinct
        // child-list prefix, then run the cheap reach DP per k.
        cum.clear();
        cum.resize((L1_MAX + 1) * (K_MAX - 1) * DEPTHS, 0.0);
        for (j, m) in merged.iter().enumerate() {
            // --- branching cache for this (solver, j) ---
            probs_flat.clear();
            cache_off.clear();
            cache_off.resize(m.n, [u32::MAX; K_MAX - 1]);
            cache_len.clear();
            cache_len.resize(m.n, [0u32; K_MAX - 1]);
            for node in 0..m.n {
                let edges = &m.edges[node];
                if edges.is_empty() {
                    continue;
                }
                // k-restricted child-list length = count of edges from
                // chains < k (edge chain ids are non-decreasing).
                let mut lens = [0usize; K_MAX - 1];
                for (ki, lk) in lens.iter_mut().enumerate() {
                    let k = ki + 2;
                    *lk = edges.iter().take_while(|&&(_, b)| (b as usize) < k).count();
                }
                eq3.xs.clear();
                eq3.xs.extend(edges.iter().map(|&(c, _)| m.token[c as usize]));
                // distinct non-zero prefix lengths (lens is non-decreasing)
                prefix_lens.clear();
                for &len in &lens {
                    if len > 0 && prefix_lens.last() != Some(&len) {
                        prefix_lens.push(len);
                    }
                }
                if prefix_lens.is_empty() {
                    continue;
                }
                let base = probs_flat.len();
                solver.branching_prefixes_into(
                    m.p(ss, j, node),
                    m.q(ss, j, node),
                    &eq3.xs,
                    prefix_lens,
                    probs_flat,
                    &mut eq3.probs,
                );
                for (ki, &len) in lens.iter().enumerate() {
                    if len == 0 {
                        continue;
                    }
                    let mut off = base;
                    for &pl in prefix_lens.iter() {
                        if pl == len {
                            break;
                        }
                        off += pl;
                    }
                    cache_off[node][ki] = off as u32;
                    cache_len[node][ki] = len as u32;
                }
            }

            // --- reach DP per k over the cached scalars ---
            for ki in 0..K_MAX - 1 {
                reach.clear();
                reach.resize(m.n, 0.0);
                reach[0] = r_trunk[j];
                per_depth.clear();
                per_depth.resize(DEPTHS, 0.0);
                per_depth[1..=j].copy_from_slice(&r_trunk[1..=j]);
                for node in 0..m.n {
                    if reach[node] <= 0.0 {
                        continue;
                    }
                    let len = cache_len[node][ki] as usize;
                    if len == 0 {
                        continue;
                    }
                    let off = cache_off[node][ki] as usize;
                    let probs = &probs_flat[off..off + len];
                    // first-occurrence dedup by running max (the node-index
                    // invariant holds here for the same reason as in
                    // DraftTree: a child's first edge is its creation).
                    let mut max_seen: Option<u32> = None;
                    for (i, &(c, _)) in m.edges[node][..len].iter().enumerate() {
                        let is_first = match max_seen {
                            Some(mx) => c > mx,
                            None => true,
                        };
                        if is_first {
                            max_seen = Some(c);
                            let pr = reach[node] * probs[i];
                            reach[c as usize] += pr;
                            per_depth[j + m.rel_depth[c as usize] as usize] += pr;
                        }
                    }
                }
                let row = &mut cum[(j * (K_MAX - 1) + ki) * DEPTHS..][..DEPTHS];
                let mut acc = 0.0;
                for (d, slot) in row.iter_mut().enumerate() {
                    acc += per_depth[d];
                    *slot = acc;
                }
            }
        }

        // --- assemble the per-action table (action_space order) ---
        let row_out = &mut out[si];
        row_out.clear();
        row_out.reserve(n_actions);
        for k in 1..=K_MAX {
            for l1 in 0..=L1_MAX {
                for l2 in 0..=L2_MAX {
                    let v = if k <= 1 || l2 == 0 {
                        trunk_cum[(l1 + l2).min(L1_MAX)]
                    } else {
                        let d = (l1 + l2).min(l1 + L2_MAX);
                        cum[(l1 * (K_MAX - 1) + (k - 2)) * DEPTHS + d]
                    };
                    row_out.push(v);
                }
            }
        }
    }
}

/// Allocating convenience wrapper over [`score_superset_into`].
pub fn score_superset(ss: &Superset, solvers: &[(&str, Box<dyn OtlpSolver>)]) -> Vec<Vec<f64>> {
    let mut scratch = ScoreScratch::default();
    let mut out = Vec::new();
    score_superset_into(ss, solvers, &mut scratch, &mut out);
    out
}
