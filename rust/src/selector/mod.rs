//! Neural delay-and-branch predictor (paper §6 + Appendix E).
//!
//! Pipeline: [`collect_traces`] walks target-model trajectories, snapshots a
//! root every 16 tokens, and for every action a = (K, L1, L2) stores the
//! Eq. 3 block-efficiency estimate Ê[τ+1] (averaged over s = 4 superset-tree
//! samples, scored with each OT solver's branching calculator) and the
//! Eq. 11 latency estimate T̂ from the microbenchmarked per-entry costs.
//! [`train`] then fits the MLP policy with the baseline-relative throughput
//! loss (Eq. 12) and [`NeuralPolicy`] serves argmax actions online.

pub mod mlp;
pub mod score;

use std::path::Path;
#[cfg(feature = "pjrt")]
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{ActionPolicy, SpecEngine, StepFeatures};
use crate::dist::{DistStorage, NodeDist, SamplingConfig};
use crate::draft::{Action, DrafterKind};
use crate::runtime::Backend;
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Role};
use crate::tree::{DraftTree, Provenance};
use crate::util::json::{arr, num, obj, Json};
use crate::util::{Pcg64, Json as J};
use crate::verify::{OtlpSolver, Verifier};
use mlp::{softmax, SelectorNet};
pub use score::{
    expected_by_depth, expected_by_depth_into, score_superset, score_superset_into,
    score_superset_per_action, BranchChain, ScoreScratch, Superset,
};

/// Largest branch count K in the action space.
pub const K_MAX: usize = 4;
/// Largest trunk (delay) length L1 in the action space.
pub const L1_MAX: usize = 8;
/// Largest branch length L2 in the action space.
pub const L2_MAX: usize = 8;
/// Scalar feature count (paper Appendix E).
pub const N_SCALARS: usize = 11;
/// Tokens between consecutive trace roots during collection.
pub const TRACE_STRIDE: usize = 16;
/// Superset-tree samples averaged per Ê table (s in Eq. 3).
pub const EQ3_SAMPLES: usize = 4;

/// Enumerate the action space A = {1..4} × {0..8}² (paper §6).
///
/// ```
/// let actions = specdelay::selector::action_space();
/// assert_eq!(actions.len(), 4 * 9 * 9);
/// assert_eq!((actions[0].k, actions[0].l1, actions[0].l2), (1, 0, 0));
/// ```
pub fn action_space() -> Vec<Action> {
    let mut out = Vec::new();
    for k in 1..=K_MAX {
        for l1 in 0..=L1_MAX {
            for l2 in 0..=L2_MAX {
                out.push(Action::new(k, l1, l2));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Latency model (Eq. 11, adapted: entry costs are shape-dependent, not
// context-length-dependent, because the compiled modules are fixed-shape)
// ---------------------------------------------------------------------------

/// Per-entry latency model (Eq. 11): microbenchmarked wall times per
/// compiled shape, from which T̂(a) is assembled for every action.
#[derive(Clone, Debug, Default)]
pub struct LatencyModel {
    /// One draft decode step (the selector feature pass).
    pub t_decode_draft: f64,
    /// Trunk rollout time by L1 (index 0 unused).
    pub t_trunk: Vec<f64>,
    /// Branch rollout time `[k][branch-length bucket index]`.
    pub t_branch: Vec<Vec<f64>>,
    /// Target tree-pass time by tree-size bucket index.
    pub t_tree: Vec<f64>,
    /// Branch-length buckets aligning `t_branch` columns.
    pub branch_lens: Vec<usize>,
    /// Tree-size buckets aligning `t_tree`.
    pub tree_sizes: Vec<usize>,
}

impl LatencyModel {
    /// Microbenchmark every compiled entry ("warm-up run" in the paper).
    #[cfg(feature = "pjrt")]
    pub fn measure(engine: &Engine) -> Result<LatencyModel> {
        let meta = &engine.meta;
        let d = meta.draft;
        let t = meta.target;
        let dk = vec![0.0f32; d.kv_elems()];
        let tk = vec![0.0f32; t.kv_elems()];
        let time_it = |f: &mut dyn FnMut() -> Result<()>| -> Result<f64> {
            f()?; // warmup + compile
            let reps = 3;
            let t0 = Instant::now();
            for _ in 0..reps {
                f()?;
            }
            Ok(t0.elapsed().as_secs_f64() / reps as f64)
        };

        let t_decode_draft = time_it(&mut || {
            engine.decode(Role::Draft, &dk, &dk, 65, 10).map(|_| ())
        })?;

        let mut t_trunk = vec![0.0f64];
        for &l in &meta.trunk_lens {
            let uni = vec![0.5f32; l];
            t_trunk.push(time_it(&mut || {
                engine
                    .rollout(1, l, &dk, &dk, 65, 10, &uni, 1.0, 1.0)
                    .map(|_| ())
            })?);
        }

        let mut t_branch = vec![vec![]; K_MAX + 1];
        for &k in &meta.branch_ks {
            let mut per_bucket = Vec::new();
            for &lb in &meta.branch_lens {
                let uni = vec![0.5f32; k * lb];
                per_bucket.push(time_it(&mut || {
                    engine
                        .rollout(k, lb, &dk, &dk, 65, 10, &uni, 1.0, 1.0)
                        .map(|_| ())
                })?);
            }
            t_branch[k] = per_bucket;
        }

        let mut t_tree = Vec::new();
        for &n in &meta.tree_sizes {
            let toks = vec![65i32; n];
            let pos = vec![10i32; n];
            let mut bias = vec![-1e30f32; n * n];
            for i in 0..n {
                bias[i * n + i] = 0.0;
            }
            t_tree.push(time_it(&mut || {
                engine
                    .tree_verify(n, &tk, &tk, &toks, &pos, &bias, 10)
                    .map(|_| ())
            })?);
        }

        Ok(LatencyModel {
            t_decode_draft,
            t_trunk,
            t_branch,
            t_tree,
            branch_lens: meta.branch_lens.clone(),
            tree_sizes: meta.tree_sizes.clone(),
        })
    }

    /// T̂(a): total model time for one block under action a.
    pub fn estimate(&self, a: Action) -> f64 {
        let a = a.normalized(L1_MAX);
        let mut t = self.t_decode_draft; // selector feature pass
        if a.l1 > 0 {
            t += self.t_trunk.get(a.l1).copied().unwrap_or(0.0);
        }
        if a.k > 1 && a.l2 > 0 {
            let bi = self
                .branch_lens
                .iter()
                .position(|&b| b >= a.l2)
                .unwrap_or(self.branch_lens.len() - 1);
            t += self
                .t_branch
                .get(a.k)
                .and_then(|v| v.get(bi))
                .copied()
                .unwrap_or(0.0);
        }
        let nodes = a.nodes();
        let ti = self
            .tree_sizes
            .iter()
            .position(|&b| b >= nodes)
            .unwrap_or(self.tree_sizes.len() - 1);
        t += self.t_tree.get(ti).copied().unwrap_or(0.0);
        t
    }

    /// Serialize for the checkpoint file.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("t_decode_draft", num(self.t_decode_draft)),
            ("t_trunk", arr(self.t_trunk.iter().map(|&v| num(v)))),
            (
                "t_branch",
                arr(self
                    .t_branch
                    .iter()
                    .map(|row| arr(row.iter().map(|&v| num(v))))),
            ),
            ("t_tree", arr(self.t_tree.iter().map(|&v| num(v)))),
            (
                "branch_lens",
                arr(self.branch_lens.iter().map(|&v| num(v as f64))),
            ),
            (
                "tree_sizes",
                arr(self.tree_sizes.iter().map(|&v| num(v as f64))),
            ),
        ])
    }

    /// Parse from a checkpoint file's `latency` object.
    pub fn from_json(j: &Json) -> Result<LatencyModel> {
        let f = |k: &str| -> Result<Vec<f64>> {
            Ok(j.get(k)
                .map_err(|e| anyhow!(e))?
                .as_arr()
                .context("arr")?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect())
        };
        let t_branch = j
            .get("t_branch")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .context("arr")?
            .iter()
            .map(|row| {
                row.as_arr()
                    .map(|r| r.iter().filter_map(|v| v.as_f64()).collect())
                    .unwrap_or_default()
            })
            .collect();
        Ok(LatencyModel {
            t_decode_draft: j
                .get("t_decode_draft")
                .map_err(|e| anyhow!(e))?
                .as_f64()
                .unwrap_or(0.0),
            t_trunk: f("t_trunk")?,
            t_branch,
            t_tree: f("t_tree")?,
            branch_lens: f("branch_lens")?.iter().map(|&v| v as usize).collect(),
            tree_sizes: f("tree_sizes")?.iter().map(|&v| v as usize).collect(),
        })
    }
}

// ---------------------------------------------------------------------------
// Feature extraction
// ---------------------------------------------------------------------------

/// Scalar feature vector (paper Appendix E: uncertainty, divergence, local
/// parameters, latency estimates).
pub fn scalar_features(f: &StepFeatures<'_>, lat: &LatencyModel, max_seq: usize) -> Vec<f32> {
    vec![
        f.p_prev.entropy(),
        f.q_prev.entropy(),
        f.q_root.entropy(),
        f.p_prev.kl(f.q_prev),
        f.q_prev.kl(f.p_prev),
        NodeDist::l1(f.p_prev, f.q_prev),
        f.ctx_len as f32 / max_seq as f32,
        f.sampling.temperature,
        f.sampling.top_p,
        (lat.t_decode_draft * 1e3) as f32,
        (lat.t_tree.first().copied().unwrap_or(0.0) * 1e3) as f32,
    ]
}

// ---------------------------------------------------------------------------
// Offline Ê[τ+1] estimation via superset trees (Eq. 3)
// ---------------------------------------------------------------------------
//
// The estimators themselves live in [`score`]: `score_superset_into` is the
// shared-branching scorer (build each superset structure once, cache every
// node's branching probabilities per solver, derive all 324 actions by a
// reach-prefix DP) and `score_superset_per_action` is the frozen per-action
// baseline it is benchmarked and equality-tested against.

/// One trace root: features + per-solver Ê table + T̂ table.
pub struct TraceRoot {
    /// Target hidden state at the previous verified root.
    pub hidden_p: Vec<f32>,
    /// Draft hidden state at the previous verified root.
    pub hidden_q_prev: Vec<f32>,
    /// Draft hidden state at the current root.
    pub hidden_q_cur: Vec<f32>,
    /// Raw scalar features ([`scalar_features`]).
    pub scalars: Vec<f32>,
    /// Per-solver Ê[τ+1] tables, aligned with [`action_space`].
    pub e_hat: Vec<(String, Vec<f64>)>,
    /// Latency estimates T̂(a), aligned with [`action_space`].
    pub t_hat: Vec<f64>,
    /// Sampling temperature active at this root.
    pub temperature: f32,
    /// Nucleus mass active at this root.
    pub top_p: f32,
}

// ---------------------------------------------------------------------------
// Trace collection
// ---------------------------------------------------------------------------

/// Collect trace roots along target trajectories for one family (any
/// [`Backend`]: the CPU reference backend makes selector data collection a
/// default-build workload).
#[allow(clippy::too_many_arguments)]
pub fn collect_traces(
    engine: &dyn Backend,
    prompts: &[(String, SamplingConfig)],
    lat: &LatencyModel,
    max_new: usize,
    rng: &mut Pcg64,
    solvers: &[(&str, Box<dyn OtlpSolver>)],
    max_roots: usize,
) -> Result<Vec<TraceRoot>> {
    let actions = action_space();
    let mut roots: Vec<TraceRoot> = Vec::new();
    let t_hat: Vec<f64> = actions.iter().map(|&a| lat.estimate(a)).collect();

    'outer: for (prompt, sampling) in prompts {
        let spec = SpecEngine::new(engine, *sampling);
        let mut seq = spec.start(prompt)?;
        let mut since_root = TRACE_STRIDE; // take the first root immediately
        while !seq.finished && seq.tokens.len() - seq.prompt_len < max_new {
            if since_root >= TRACE_STRIDE {
                since_root = 0;
                let rf = spec.root_features(&mut seq)?;
                let feats = rf.as_features(&seq, *sampling);
                let scalars = scalar_features(&feats, lat, engine.meta().target.max_seq);
                // Ê over s = 4 superset samples. Drafting stays serial (it
                // advances the shared rng stream); scoring — the expensive
                // part — fans out over workers, one ScoreScratch arena
                // each. The accumulation below walks samples in draft
                // order, so the table is bit-identical at any worker count.
                let mut supersets = Vec::with_capacity(EQ3_SAMPLES);
                for _ in 0..EQ3_SAMPLES {
                    supersets.push(draft_superset(engine, &seq, *sampling, rng)?);
                }
                let scored = crate::util::threadpool::par_map_init(
                    supersets,
                    crate::util::threadpool::default_workers(),
                    ScoreScratch::default,
                    |scratch, _i, ss| {
                        let mut table = Vec::new();
                        score_superset_into(&ss, solvers, scratch, &mut table);
                        table
                    },
                );
                let mut e_acc = vec![vec![0.0f64; actions.len()]; solvers.len()];
                for table in &scored {
                    for (si, row) in table.iter().enumerate() {
                        for (ai, v) in row.iter().enumerate() {
                            e_acc[si][ai] += v / EQ3_SAMPLES as f64;
                        }
                    }
                }
                roots.push(TraceRoot {
                    hidden_p: seq.prev_hidden_target.clone(),
                    hidden_q_prev: seq.prev_hidden_draft.clone(),
                    hidden_q_cur: rf.hidden_q_cur.clone(),
                    scalars,
                    e_hat: solvers
                        .iter()
                        .zip(&e_acc)
                        .map(|((n, _), e)| (n.to_string(), e.iter().map(|&v| v + 1.0).collect()))
                        .collect(),
                    t_hat: t_hat.clone(),
                    temperature: sampling.temperature,
                    top_p: sampling.top_p,
                });
                if roots.len() >= max_roots {
                    break 'outer;
                }
            }
            // advance the trajectory with a moderate static speculation step
            let verifier = crate::verify::verifier("SpecInfer").unwrap();
            let b = spec.step(&mut seq, verifier.as_ref(), Action::new(2, 2, 4), rng)?;
            since_root += b.emitted;
            if b.emitted == 0 {
                break;
            }
        }
    }
    Ok(roots)
}

/// Draft one superset sample at the current root: full trunk, branches of
/// L2_MAX at every trunk depth, one big target tree pass for p everywhere.
fn draft_superset(
    engine: &dyn Backend,
    seq: &crate::coordinator::Sequence,
    sampling: SamplingConfig,
    rng: &mut Pcg64,
) -> Result<Superset> {
    let meta = engine.meta();
    let v = meta.draft.vocab;
    let root_token = *seq.tokens.last().unwrap();
    let root_pos = seq.root_pos;

    // trunk
    let uni: Vec<f32> = (0..L1_MAX).map(|_| rng.next_f32()).collect();
    let trunk = engine.rollout(
        1,
        L1_MAX,
        seq.draft_kv.view(),
        root_token,
        root_pos,
        &uni,
        sampling.temperature,
        sampling.top_p,
    )?;
    let storage = DistStorage::global();
    let mut trunk_tokens = vec![root_token];
    trunk_tokens.extend(trunk.tokens.iter().map(|&t| t as u32));
    let trunk_q: Vec<NodeDist> = (0..L1_MAX)
        .map(|s| NodeDist::from_probs(&trunk.dists[s * v..(s + 1) * v], storage))
        .collect();

    // temp draft KV with trunk rows committed so branch rollouts can attend
    let mut kv = seq.draft_kv.clone();
    kv.commit_rollout_rows(&trunk.k_rows, &trunk.v_rows, 1, L1_MAX, 0, L1_MAX - 1, root_pos);

    // branches at every trunk depth
    let mut branches: Vec<Vec<BranchChain>> = Vec::new();
    let mut tree = DraftTree::new(root_token);
    let mut trunk_nodes = vec![0usize];
    {
        let mut node = 0usize;
        for (d, q) in trunk_q.iter().enumerate() {
            tree.set_q(node, q.clone());
            node = tree.add_child(node, trunk_tokens[d + 1], Provenance::Trunk { step: d + 1 });
            trunk_nodes.push(node);
        }
    }
    for j in 0..=L1_MAX {
        let start_tok = trunk_tokens[j];
        let start_pos = root_pos + j;
        let uni: Vec<f32> = (0..K_MAX * L2_MAX).map(|_| rng.next_f32()).collect();
        let out = engine.rollout(
            K_MAX,
            L2_MAX,
            kv.view(),
            start_tok,
            start_pos,
            &uni,
            sampling.temperature,
            sampling.top_p,
        )?;
        let mut per_branch = Vec::new();
        for b in 0..K_MAX {
            let tokens: Vec<u32> = (0..L2_MAX).map(|s| out.tokens[b * L2_MAX + s] as u32).collect();
            let q: Vec<NodeDist> = (0..L2_MAX)
                .map(|s| {
                    NodeDist::from_probs(
                        &out.dists[(b * L2_MAX + s) * v..(b * L2_MAX + s + 1) * v],
                        storage,
                    )
                })
                .collect();
            // extend the merged tree for the big target pass
            let mut cur = trunk_nodes[j];
            for (s, &tok) in tokens.iter().enumerate() {
                if tree.nodes[cur].q.is_none() {
                    tree.set_q(cur, q[s].clone());
                }
                cur = tree.add_child(cur, tok, Provenance::Branch { branch: b, step: s + 1 });
            }
            per_branch.push(BranchChain { tokens, q, p: Vec::new() });
        }
        branches.push(per_branch);
    }

    // one big target pass for p at every superset node
    let n_bucket = meta.tree_big;
    if tree.len() > n_bucket {
        return Err(anyhow!("superset tree {} exceeds bucket {}", tree.len(), n_bucket));
    }
    let (toks, pos) = tree.tokens_positions(n_bucket, root_pos, crate::tokenizer::PAD);
    let bias = tree.attention_bias(n_bucket);
    let out = engine.tree_verify(
        n_bucket,
        seq.target_kv.view(),
        &toks,
        &pos,
        &bias,
        root_pos,
    )?;
    let vt = meta.target.vocab;
    let p_at =
        |node: usize| NodeDist::from_logits(&out.logits[node * vt..(node + 1) * vt], sampling, storage);

    let trunk_p: Vec<NodeDist> = trunk_nodes.iter().map(|&n| p_at(n)).collect();
    // walk the merged tree to recover p along each branch chain
    for (j, per_branch) in branches.iter_mut().enumerate() {
        for chain in per_branch.iter_mut() {
            let mut cur = trunk_nodes[j];
            let mut ps = Vec::with_capacity(chain.tokens.len() + 1);
            for &tok in &chain.tokens {
                cur = tree
                    .child_with_token(cur, tok)
                    .expect("superset tree chain");
                ps.push(p_at(cur));
            }
            // chain.p[s] = p at the node *after* s+1 tokens; the dist used at
            // chain step s (predicting token s+1) is at the previous node —
            // realign: p for branching at node s = p of node with s tokens.
            let mut aligned = Vec::with_capacity(chain.tokens.len());
            let mut cur2 = trunk_nodes[j];
            for &tok in &chain.tokens {
                aligned.push(p_at(cur2));
                cur2 = tree.child_with_token(cur2, tok).unwrap();
            }
            aligned.push(p_at(cur2)); // leaf p (bonus)
            chain.p = aligned;
            let _ = ps;
        }
    }

    Ok(Superset { trunk_tokens, trunk_q, trunk_p, branches })
}

// ---------------------------------------------------------------------------
// Training (Eq. 12)
// ---------------------------------------------------------------------------

/// Selector training hyperparameters (Eq. 12 loss).
pub struct TrainConfig {
    /// Training epochs over the trace roots.
    pub epochs: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Weight of the CVaR penalty term.
    pub lambda: f32,
    /// CVaR tail fraction α.
    pub alpha: f32,
    /// Initialization/shuffle seed.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 30, lr: 1e-3, lambda: 1.0, alpha: 0.2, seed: 0 }
    }
}

/// Trained checkpoint for one (family, solver).
pub struct Checkpoint {
    /// The trained policy network.
    pub net: SelectorNet,
    /// Per-scalar standardization means.
    pub scalar_mean: Vec<f32>,
    /// Per-scalar standardization standard deviations.
    pub scalar_std: Vec<f32>,
    /// Latency model frozen at training time.
    pub lat: LatencyModel,
}

/// Pick the per-sampling-config static baseline action (best mean Ê/T̂ over
/// the i.i.d. static grid, paper §4 style) — returns index into actions.
fn baseline_index(roots: &[&TraceRoot], solver_idx: usize, actions: &[Action]) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::MIN;
    for (ai, a) in actions.iter().enumerate() {
        // static baselines are root-iid multipath or single path
        if a.l1 != 0 && a.k > 1 {
            continue;
        }
        let mut e = 0.0;
        let mut t = 0.0;
        for r in roots {
            e += r.e_hat[solver_idx].1[ai];
            t += r.t_hat[ai];
        }
        let v = e / t.max(1e-12);
        if v > best_v {
            best_v = v;
            best = ai;
        }
    }
    best
}

/// Train one selector on trace roots for one solver. Returns the checkpoint
/// and the mean train objective ratio (TPS_π / TPS_base).
pub fn train(
    roots: &[TraceRoot],
    solver_name: &str,
    d_p: usize,
    d_q: usize,
    lat: &LatencyModel,
    cfg: &TrainConfig,
) -> Result<(Checkpoint, f64)> {
    let actions = action_space();
    let n_a = actions.len();
    let solver_idx = roots
        .first()
        .and_then(|r| r.e_hat.iter().position(|(n, _)| n == solver_name))
        .ok_or_else(|| anyhow!("no traces for solver {solver_name}"))?;

    // scalar standardization
    let n_s = roots[0].scalars.len();
    let mut mean = vec![0.0f32; n_s];
    let mut std = vec![0.0f32; n_s];
    for r in roots {
        for (i, &v) in r.scalars.iter().enumerate() {
            mean[i] += v / roots.len() as f32;
        }
    }
    for r in roots {
        for (i, &v) in r.scalars.iter().enumerate() {
            std[i] += (v - mean[i]) * (v - mean[i]) / roots.len() as f32;
        }
    }
    for v in std.iter_mut() {
        *v = v.sqrt().max(1e-4);
    }
    let norm = |r: &TraceRoot| -> Vec<f32> {
        r.scalars
            .iter()
            .enumerate()
            .map(|(i, &v)| (v - mean[i]) / std[i])
            .collect()
    };

    // per-sampling-config baselines
    let mut base_of_root: Vec<usize> = Vec::with_capacity(roots.len());
    {
        use std::collections::BTreeMap;
        let mut groups: BTreeMap<(u32, u32), Vec<usize>> = BTreeMap::new();
        for (i, r) in roots.iter().enumerate() {
            groups
                .entry((r.temperature.to_bits(), r.top_p.to_bits()))
                .or_default()
                .push(i);
        }
        let mut per_root = vec![0usize; roots.len()];
        for idxs in groups.values() {
            let rs: Vec<&TraceRoot> = idxs.iter().map(|&i| &roots[i]).collect();
            let b = baseline_index(&rs, solver_idx, &actions);
            for &i in idxs {
                per_root[i] = b;
            }
        }
        base_of_root = per_root;
    }

    let mut net = SelectorNet::new(d_p, d_q, n_s, n_a, cfg.seed);
    let mut rng = Pcg64::seeded(cfg.seed + 1);
    let mut t_step = 0usize;
    let batch = 16usize.min(roots.len().max(1));
    let mut final_ratio = 0.0;

    for _epoch in 0..cfg.epochs {
        let mut order: Vec<usize> = (0..roots.len()).collect();
        // shuffle
        for i in (1..order.len()).rev() {
            order.swap(i, rng.next_below(i + 1));
        }
        let mut ratio_sum = 0.0f64;
        for chunk in order.chunks(batch) {
            let mut g = net.zero_grads();
            // first pass: compute penalties for the CVaR top-α selection
            let mut rec = Vec::new();
            for &i in chunk {
                let r = &roots[i];
                let sc = norm(r);
                let (logits, cache) =
                    net.forward(&r.hidden_p, &r.hidden_q_prev, &r.hidden_q_cur, &sc);
                let pi = softmax(&logits);
                let e_row = &r.e_hat[solver_idx].1;
                let e: f64 = pi.iter().zip(e_row).map(|(&p, &v)| p as f64 * v).sum();
                let t: f64 = pi.iter().zip(&r.t_hat).map(|(&p, &v)| p as f64 * v).sum();
                let bi = base_of_root[i];
                let tps_base = e_row[bi] / r.t_hat[bi].max(1e-12);
                let ratio = (e / t.max(1e-12)) / tps_base.max(1e-12);
                rec.push((i, cache, pi, e, t, tps_base, ratio));
            }
            let mut pen: Vec<f64> = rec
                .iter()
                .map(|(_, _, _, _, _, _, r)| (1.0 - r).max(0.0).powi(2))
                .collect();
            let mut pen_sorted = pen.clone();
            pen_sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let n_alpha = ((cfg.alpha * chunk.len() as f32).ceil() as usize).max(1);
            let thresh = pen_sorted.get(n_alpha - 1).copied().unwrap_or(0.0);

            for (ri, (i, cache, pi, e, t, tps_base, ratio)) in rec.iter().enumerate() {
                let r = &roots[*i];
                let e_row = &r.e_hat[solver_idx].1;
                ratio_sum += ratio;
                // dL/dπ_a for -log(ratio) term: -(E_a/E - T_a/T)
                // penalty term (if in top-α): 2·max(1-ratio,0)·ratio·(E_a/E - T_a/T)·(-1)·λ/n_alpha
                let in_alpha = pen[ri] >= thresh && pen[ri] > 0.0;
                let mut dpi = vec![0.0f64; n_a];
                for a in 0..n_a {
                    let s = e_row[a] / e.max(1e-12) - r.t_hat[a] / t.max(1e-12);
                    let mut d = -s / chunk.len() as f64;
                    if in_alpha {
                        let dpen = -2.0 * (1.0 - ratio).max(0.0) * ratio * s;
                        d += cfg.lambda as f64 * dpen / n_alpha as f64;
                    }
                    dpi[a] = d;
                }
                let _ = tps_base;
                // softmax jacobian: dlogit_a = π_a (dπ_a − Σ_b π_b dπ_b)
                let dot: f64 = pi.iter().zip(&dpi).map(|(&p, &d)| p as f64 * d).sum();
                let dlogits: Vec<f32> = pi
                    .iter()
                    .zip(&dpi)
                    .map(|(&p, &d)| (p as f64 * (d - dot)) as f32)
                    .collect();
                net.backward(cache, &dlogits, &mut g);
            }
            pen.clear();
            t_step += 1;
            net.adam_step(&g, cfg.lr, t_step);
        }
        final_ratio = ratio_sum / roots.len() as f64;
    }

    Ok((
        Checkpoint { net, scalar_mean: mean, scalar_std: std, lat: lat.clone() },
        final_ratio,
    ))
}

// ---------------------------------------------------------------------------
// Online policy
// ---------------------------------------------------------------------------

/// Argmax policy over the trained selector (paper §6 inference).
pub struct NeuralPolicy {
    /// The trained checkpoint the policy evaluates.
    pub ckpt: Checkpoint,
    /// Context-length normalizer (the family's `max_seq`).
    pub max_seq: usize,
    actions: Vec<Action>,
}

impl NeuralPolicy {
    /// Wrap a checkpoint as an online [`ActionPolicy`].
    pub fn new(ckpt: Checkpoint, max_seq: usize) -> NeuralPolicy {
        NeuralPolicy { ckpt, max_seq, actions: action_space() }
    }
}

impl ActionPolicy for NeuralPolicy {
    fn choose(&self, f: &StepFeatures<'_>) -> Action {
        let sc_raw = scalar_features(f, &self.ckpt.lat, self.max_seq);
        let sc: Vec<f32> = sc_raw
            .iter()
            .enumerate()
            .map(|(i, &v)| (v - self.ckpt.scalar_mean[i]) / self.ckpt.scalar_std[i])
            .collect();
        let (logits, _) = self
            .ckpt
            .net
            .forward(f.hidden_p_prev, f.hidden_q_prev, f.hidden_q_cur, &sc);
        let mut best = 0usize;
        for i in 1..logits.len() {
            if logits[i] > logits[best] {
                best = i;
            }
        }
        self.actions[best]
    }
}

// ---------------------------------------------------------------------------
// Serving-time online selector
// ---------------------------------------------------------------------------
//
// The offline pipeline above trains a neural policy from superset traces; the
// types below are the *serving* half of the paper's dynamic-selection story:
// a small arm set (verifier × drafter × action) scored per block from live
// [`StepFeatures`], with acceptance-rate priors calibrated online from served
// traffic. `coordinator::batch::ServeLoop` owns the calibration fold (per-lane
// tallies merged in lane order at tick end, so results are worker-count
// independent); the selector itself is a pure function of the features, the
// frozen input priors, and a dedicated decision rng stream.

/// Minimum drafted-token mass a prior needs before it is blended into the
/// acceptance-rate estimate (below this the feature-derived α is used alone).
pub const PRIOR_MIN_DRAFTED: u64 = 64;

/// Documented latency heuristic: relative per-node cost used by
/// [`OnlineSelector::choose`] to normalize expected emitted tokens
/// (`score = Ê / (1 + COST_PER_NODE · nodes)`).
pub const COST_PER_NODE: f64 = 0.02;

/// One candidate the online selector may pick per block: a verifier, a
/// drafting policy, and the expansion action handed to it.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectorArm {
    /// Verifier name, resolvable via [`crate::verify::verifier`].
    pub verifier: String,
    /// Drafting policy for this arm.
    pub drafter: DrafterKind,
    /// Expansion action (shaped per-family by the drafter at draft time).
    pub action: Action,
}

/// Acceptance tallies for one arm, accumulated from served blocks.
///
/// Deterministic regardless of worker count: `ServeLoop` folds per-lane
/// deltas in lane order at tick end, mirroring the `par_map_init` contract.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArmStats {
    /// Blocks served with this arm.
    pub blocks: u64,
    /// Draft tokens proposed (tree nodes minus the root).
    pub drafted: u64,
    /// Draft tokens accepted by verification.
    pub accepted: u64,
    /// Tokens emitted (accepted + bonus/correction).
    pub emitted: u64,
}

impl ArmStats {
    /// Fold one served block into the tally.
    pub fn record(&mut self, drafted: usize, accepted: usize, emitted: usize) {
        self.blocks += 1;
        self.drafted += drafted as u64;
        self.accepted += accepted as u64;
        self.emitted += emitted as u64;
    }

    /// Fold another tally into this one (used for the lane-order merge).
    pub fn merge(&mut self, other: &ArmStats) {
        self.blocks += other.blocks;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.emitted += other.emitted;
    }

    /// Observed per-token acceptance rate, or `None` below
    /// [`PRIOR_MIN_DRAFTED`] drafted tokens.
    pub fn acceptance_rate(&self) -> Option<f64> {
        (self.drafted >= PRIOR_MIN_DRAFTED)
            .then(|| self.accepted as f64 / self.drafted as f64)
    }
}

/// Per-arm acceptance priors, index-aligned with [`SelectorConfig::arms`].
///
/// Produced by one serve run's online calibration and optionally fed back as
/// the next run's input priors.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SelectorPriors {
    /// One tally per arm, in arm order.
    pub arms: Vec<ArmStats>,
}

impl SelectorPriors {
    /// Empty priors sized for `n` arms.
    pub fn zeros(n: usize) -> SelectorPriors {
        SelectorPriors { arms: vec![ArmStats::default(); n] }
    }

    /// Fold another prior set in, extending to the longer arm count.
    pub fn merge(&mut self, other: &SelectorPriors) {
        if self.arms.len() < other.arms.len() {
            self.arms.resize(other.arms.len(), ArmStats::default());
        }
        for (a, b) in self.arms.iter_mut().zip(&other.arms) {
            a.merge(b);
        }
    }
}

/// Configuration for the serving-time selector.
#[derive(Clone, Debug)]
pub struct SelectorConfig {
    /// Candidate arms; empty means "selector engaged but transparent"
    /// (no decisions are made and the static path runs unchanged).
    pub arms: Vec<SelectorArm>,
    /// Seed for the dedicated per-lane decision rng streams
    /// (`Pcg64::new(seed, lane_id)`), independent of token sampling rng.
    pub seed: u64,
    /// ε-greedy exploration probability in `[0, 1)`; `0` is pure argmax.
    pub epsilon: f32,
    /// Optional input priors from a previous run's calibration,
    /// index-aligned with `arms`.
    pub priors: Option<SelectorPriors>,
}

impl Default for SelectorConfig {
    fn default() -> SelectorConfig {
        SelectorConfig { arms: Vec::new(), seed: 0x5e1ec7, epsilon: 0.0, priors: None }
    }
}

impl SelectorConfig {
    /// A documented default arm set spanning the three drafters under the
    /// SpecInfer verifier (used by the CLI `--selector` flag).
    pub fn with_default_arms() -> SelectorConfig {
        let arm = |drafter, k, l1, l2| SelectorArm {
            verifier: "SpecInfer".to_string(),
            drafter,
            action: Action::new(k, l1, l2),
        };
        SelectorConfig {
            arms: vec![
                arm(DrafterKind::Delayed, 1, 4, 0),
                arm(DrafterKind::Delayed, 2, 2, 2),
                arm(DrafterKind::Delayed, 3, 2, 2),
                arm(DrafterKind::Root, 3, 0, 2),
                arm(DrafterKind::Greedy, 2, 2, 2),
            ],
            ..SelectorConfig::default()
        }
    }
}

/// Deterministic closed-form Ê[emitted] for one block under per-token
/// acceptance probability `alpha`, by drafter shape (paper Eq. 3 specialized
/// to i.i.d. acceptance; the `+1` is the bonus/correction token).
///
/// Chains accept geometrically (`Σ αⁱ`); a k-way branch point is survived
/// with probability `β = 1 − (1−α)^k` and then continues down one branch.
/// The drafter's family-specific shaping (bucket clamps) is intentionally
/// ignored here — this is a scoring model, not the drafted geometry.
pub fn expected_emitted(a: Action, kind: DrafterKind, alpha: f64) -> f64 {
    let alpha = alpha.clamp(0.0, 1.0);
    let chain = |l: usize| -> f64 { (1..=l).map(|i| alpha.powi(i as i32)).sum() };
    let k = a.k.max(1) as i32;
    let beta = 1.0 - (1.0 - alpha).powi(k);
    let branch = |l: usize| -> f64 { (1..=l).map(|j| beta * alpha.powi(j as i32 - 1)).sum() };
    let single = a.k <= 1 || a.l2 == 0;
    let e = match kind {
        DrafterKind::Delayed => {
            if single {
                chain(a.l1 + a.l2)
            } else {
                chain(a.l1) + alpha.powi(a.l1 as i32) * branch(a.l2)
            }
        }
        DrafterKind::Root => {
            if single {
                chain(a.l1 + a.l2)
            } else {
                branch(a.l1 + a.l2)
            }
        }
        DrafterKind::Greedy => {
            if single {
                chain(a.l1 + a.l2)
            } else {
                chain(a.l1).max(branch(a.l2))
            }
        }
    };
    1.0 + e
}

/// Draft tree size the arm's drafter actually builds for `a` (before family
/// shaping), used as the latency proxy in [`OnlineSelector::choose`].
pub fn arm_nodes(a: Action, kind: DrafterKind) -> usize {
    let single = a.k <= 1 || a.l2 == 0;
    match kind {
        DrafterKind::Root if !single => 1 + a.k * (a.l1 + a.l2),
        _ => a.nodes(),
    }
}

/// The serving-time online selector: scores every arm per block from live
/// features and (optionally) calibrated priors, with ε-greedy exploration on
/// a dedicated decision rng stream.
pub struct OnlineSelector {
    cfg: SelectorConfig,
    verifiers: Vec<Box<dyn Verifier>>,
}

impl OnlineSelector {
    /// Build a selector, resolving every arm's verifier by name.
    pub fn new(cfg: SelectorConfig) -> Result<OnlineSelector> {
        let verifiers = cfg
            .arms
            .iter()
            .map(|a| {
                crate::verify::verifier(&a.verifier)
                    .ok_or_else(|| anyhow!("unknown verifier {:?} in selector arm", a.verifier))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(OnlineSelector { cfg, verifiers })
    }

    /// The configuration this selector was built from.
    pub fn config(&self) -> &SelectorConfig {
        &self.cfg
    }

    /// The candidate arms, in configuration order.
    pub fn arms(&self) -> &[SelectorArm] {
        &self.cfg.arms
    }

    /// The resolved verifier for arm `i`.
    pub fn verifier(&self, i: usize) -> &dyn Verifier {
        self.verifiers[i].as_ref()
    }

    /// Whether the selector actually makes decisions (has any arms).
    pub fn is_active(&self) -> bool {
        !self.cfg.arms.is_empty()
    }

    /// Pick an arm for the next block, or `None` when no arms are configured.
    ///
    /// Consumes exactly one rng draw per call (the exploration gate) plus one
    /// more when exploring, so the stream stays aligned across ε settings on
    /// non-exploring blocks. The exploit path is a pure function of the
    /// features and the frozen input priors: the feature-derived acceptance
    /// estimate `α = clamp(1 − ½·L1(p_prev, q_prev), 0.05, 0.95)` is blended
    /// 50/50 with an arm's prior acceptance rate once the prior has seen
    /// [`PRIOR_MIN_DRAFTED`] drafted tokens, and each arm is scored as
    /// `expected_emitted / (1 + COST_PER_NODE · arm_nodes)` with first-index
    /// argmax tie-breaking.
    pub fn choose(&self, f: &StepFeatures<'_>, rng: &mut Pcg64) -> Option<usize> {
        if self.cfg.arms.is_empty() {
            return None;
        }
        let gate = rng.next_f32();
        if gate < self.cfg.epsilon {
            return Some(rng.next_below(self.cfg.arms.len()));
        }
        let alpha_feat =
            (1.0 - 0.5 * NodeDist::l1(f.p_prev, f.q_prev) as f64).clamp(0.05, 0.95);
        let mut best = 0usize;
        let mut best_score = f64::MIN;
        for (i, arm) in self.cfg.arms.iter().enumerate() {
            let alpha = match self
                .cfg
                .priors
                .as_ref()
                .and_then(|p| p.arms.get(i))
                .and_then(|s| s.acceptance_rate())
            {
                Some(rate) => 0.5 * (alpha_feat + rate),
                None => alpha_feat,
            };
            let e = expected_emitted(arm.action, arm.drafter, alpha);
            let score = e / (1.0 + COST_PER_NODE * arm_nodes(arm.action, arm.drafter) as f64);
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        Some(best)
    }
}

// ---------------------------------------------------------------------------
// Checkpoint (de)serialization
// ---------------------------------------------------------------------------

fn f32s_json(v: &[f32]) -> Json {
    arr(v.iter().map(|&x| num(x as f64)))
}

fn json_f32s(j: &Json) -> Vec<f32> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
        .unwrap_or_default()
}

/// Write a checkpoint (network weights + standardization + latency model)
/// as pretty-printed JSON.
pub fn save_checkpoint(path: &Path, ckpt: &Checkpoint, d_p: usize, d_q: usize) -> Result<()> {
    let lin = |l: &mlp::Linear| {
        obj(vec![
            ("w", f32s_json(&l.w)),
            ("b", f32s_json(&l.b)),
            ("n_in", num(l.n_in as f64)),
            ("n_out", num(l.n_out as f64)),
        ])
    };
    let j = obj(vec![
        ("d_p", num(d_p as f64)),
        ("d_q", num(d_q as f64)),
        ("proj_p", lin(&ckpt.net.proj_p)),
        ("proj_q_prev", lin(&ckpt.net.proj_q_prev)),
        ("proj_q_cur", lin(&ckpt.net.proj_q_cur)),
        ("fc1", lin(&ckpt.net.fc1)),
        ("fc2", lin(&ckpt.net.fc2)),
        ("head", lin(&ckpt.net.head)),
        ("scalar_mean", f32s_json(&ckpt.scalar_mean)),
        ("scalar_std", f32s_json(&ckpt.scalar_std)),
        ("latency", ckpt.lat.to_json()),
    ]);
    std::fs::create_dir_all(path.parent().unwrap_or(Path::new(".")))?;
    std::fs::write(path, j.to_string_pretty())?;
    Ok(())
}

/// Load a checkpoint written by [`save_checkpoint`].
pub fn load_checkpoint(path: &Path) -> Result<Checkpoint> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    let j = J::parse(&text).map_err(|e| anyhow!("checkpoint parse: {e}"))?;
    let d_p = j.get("d_p").map_err(|e| anyhow!(e))?.as_usize().unwrap();
    let d_q = j.get("d_q").map_err(|e| anyhow!(e))?.as_usize().unwrap();
    let n_s = json_f32s(j.get("scalar_mean").map_err(|e| anyhow!(e))?).len();
    let n_a = action_space().len();
    let mut net = SelectorNet::new(d_p, d_q, n_s, n_a, 0);
    let fill = |l: &mut mlp::Linear, key: &str| -> Result<()> {
        let lj = j.get(key).map_err(|e| anyhow!(e))?;
        l.w = json_f32s(lj.get("w").map_err(|e| anyhow!(e))?);
        l.b = json_f32s(lj.get("b").map_err(|e| anyhow!(e))?);
        Ok(())
    };
    fill(&mut net.proj_p, "proj_p")?;
    fill(&mut net.proj_q_prev, "proj_q_prev")?;
    fill(&mut net.proj_q_cur, "proj_q_cur")?;
    fill(&mut net.fc1, "fc1")?;
    fill(&mut net.fc2, "fc2")?;
    fill(&mut net.head, "head")?;
    Ok(Checkpoint {
        net,
        scalar_mean: json_f32s(j.get("scalar_mean").map_err(|e| anyhow!(e))?),
        scalar_std: json_f32s(j.get("scalar_std").map_err(|e| anyhow!(e))?),
        lat: LatencyModel::from_json(j.get("latency").map_err(|e| anyhow!(e))?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn action_space_size() {
        assert_eq!(action_space().len(), 4 * 9 * 9);
    }

    #[test]
    fn latency_estimate_monotone_in_tree_size() {
        let lat = LatencyModel {
            t_decode_draft: 0.001,
            t_trunk: vec![0.0, 0.002, 0.003, 0.004, 0.005, 0.006, 0.007, 0.008, 0.009],
            t_branch: vec![
                vec![],
                vec![],
                vec![0.004, 0.006, 0.008, 0.010],
                vec![0.005, 0.008, 0.011, 0.014],
                vec![0.006, 0.010, 0.013, 0.016],
            ],
            t_tree: vec![0.01, 0.02, 0.03, 0.05],
            branch_lens: vec![2, 4, 6, 8],
            tree_sizes: vec![8, 16, 32, 48],
        };
        let small = lat.estimate(Action::new(1, 2, 0));
        let big = lat.estimate(Action::new(4, 8, 8));
        assert!(big > small);
    }

    /// Train on synthetic traces where one action dominates; the selector
    /// must learn to pick it.
    #[test]
    fn selector_learns_dominant_action() {
        let actions = action_space();
        let n_a = actions.len();
        let target_action = 77usize;
        let mut rng = Pcg64::seeded(3);
        let mut roots = Vec::new();
        for _ in 0..40 {
            let hidden: Vec<f32> = (0..8).map(|_| rng.next_f32()).collect();
            let mut e = vec![1.0f64; n_a];
            e[target_action] = 5.0;
            roots.push(TraceRoot {
                hidden_p: hidden.clone(),
                hidden_q_prev: hidden.clone(),
                hidden_q_cur: hidden.clone(),
                scalars: (0..N_SCALARS).map(|_| rng.next_f32()).collect(),
                e_hat: vec![("SpecInfer".into(), e)],
                t_hat: vec![1.0; n_a],
                temperature: 1.0,
                top_p: 1.0,
            });
        }
        let lat = LatencyModel::default();
        let cfg = TrainConfig { epochs: 15, lr: 3e-3, ..Default::default() };
        let (ckpt, ratio) = train(&roots, "SpecInfer", 8, 8, &lat, &cfg).unwrap();
        assert!(ratio > 0.9, "train ratio {ratio}");
        // policy should pick the dominant action
        let r = &roots[0];
        let sc: Vec<f32> = r
            .scalars
            .iter()
            .enumerate()
            .map(|(i, &v)| (v - ckpt.scalar_mean[i]) / ckpt.scalar_std[i])
            .collect();
        let (logits, _) = ckpt
            .net
            .forward(&r.hidden_p, &r.hidden_q_prev, &r.hidden_q_cur, &sc);
        let best = (0..n_a).max_by(|&a, &b| logits[a].partial_cmp(&logits[b]).unwrap()).unwrap();
        assert_eq!(best, target_action, "selector picked {:?}", actions[best]);
    }

    fn feats<'a>(p: &'a NodeDist, q: &'a NodeDist, hidden: &'a [f32]) -> StepFeatures<'a> {
        StepFeatures {
            hidden_p_prev: hidden,
            hidden_q_prev: hidden,
            hidden_q_cur: hidden,
            p_prev: p,
            q_prev: q,
            q_root: q,
            ctx_len: 16,
            sampling: SamplingConfig::default(),
        }
    }

    /// Ê[emitted] is monotone in α, rewards branching when α is low on
    /// delayed trees, and collapses to the chain form for single paths.
    #[test]
    fn expected_emitted_shapes() {
        for kind in DrafterKind::ALL {
            let a = Action::new(3, 2, 2);
            let lo = expected_emitted(a, kind, 0.3);
            let hi = expected_emitted(a, kind, 0.9);
            assert!(hi > lo, "{kind:?} not monotone in alpha");
            // single-path collapse: all drafters share the chain form
            let s = expected_emitted(Action::new(1, 4, 0), kind, 0.5);
            let expect = 1.0 + 0.5 + 0.25 + 0.125 + 0.0625;
            assert!((s - expect).abs() < 1e-12, "{kind:?} chain {s}");
        }
        // k-way branching beats a single path at the branch point
        let multi = expected_emitted(Action::new(4, 2, 2), DrafterKind::Delayed, 0.4);
        let single = expected_emitted(Action::new(1, 2, 2), DrafterKind::Delayed, 0.4);
        assert!(multi > single);
        // root drafter spends k× nodes for its resilience
        assert!(
            arm_nodes(Action::new(3, 2, 2), DrafterKind::Root)
                > arm_nodes(Action::new(3, 2, 2), DrafterKind::Delayed)
        );
    }

    /// `choose` is deterministic given the same rng state, consumes exactly
    /// one draw on non-exploring calls, and returns None with no arms.
    #[test]
    fn online_selector_choose_deterministic() {
        let p = NodeDist::from_probs(&[0.5, 0.3, 0.2], DistStorage::Dense);
        let q = NodeDist::from_probs(&[0.4, 0.4, 0.2], DistStorage::Dense);
        let hidden = [0.0f32; 4];
        let f = feats(&p, &q, &hidden);

        let empty = OnlineSelector::new(SelectorConfig::default()).unwrap();
        assert!(!empty.is_active());
        assert_eq!(empty.choose(&f, &mut Pcg64::seeded(1)), None);

        let sel = OnlineSelector::new(SelectorConfig::with_default_arms()).unwrap();
        assert!(sel.is_active());
        let mut r1 = Pcg64::seeded(9);
        let mut r2 = Pcg64::seeded(9);
        let c1 = sel.choose(&f, &mut r1).unwrap();
        let c2 = sel.choose(&f, &mut r2).unwrap();
        assert_eq!(c1, c2);
        // exactly one gating draw consumed: streams stay aligned
        assert_eq!(r1.next_u64(), r2.next_u64());
        // unknown verifier is rejected at construction
        let bad = SelectorConfig {
            arms: vec![SelectorArm {
                verifier: "no-such-verifier".into(),
                drafter: DrafterKind::Delayed,
                action: Action::new(1, 2, 0),
            }],
            ..SelectorConfig::default()
        };
        assert!(OnlineSelector::new(bad).is_err());
    }

    /// A strong prior on one arm shifts the exploit-path decision; ε=1
    /// explores uniformly over the arm index space.
    #[test]
    fn online_selector_priors_and_exploration() {
        // disjoint supports ⇒ L1 = 2 ⇒ α clamps to 0.05: short chains win
        let p = NodeDist::from_probs(&[1.0, 0.0, 0.0], DistStorage::Dense);
        let q = NodeDist::from_probs(&[0.0, 0.0, 1.0], DistStorage::Dense);
        let hidden = [0.0f32; 4];
        let f = feats(&p, &q, &hidden);
        let arm = |drafter, k, l1, l2| SelectorArm {
            verifier: "SpecInfer".into(),
            drafter,
            action: Action::new(k, l1, l2),
        };
        let arms =
            vec![arm(DrafterKind::Delayed, 1, 1, 0), arm(DrafterKind::Delayed, 1, 8, 0)];
        // divergent p/q ⇒ low α ⇒ the short chain wins without priors
        let sel = OnlineSelector::new(SelectorConfig {
            arms: arms.clone(),
            ..SelectorConfig::default()
        })
        .unwrap();
        assert_eq!(sel.choose(&f, &mut Pcg64::seeded(3)), Some(0));
        // a near-perfect prior on the long arm flips the decision
        let mut priors = SelectorPriors::zeros(2);
        priors.arms[1] =
            ArmStats { blocks: 100, drafted: 800, accepted: 790, emitted: 890 };
        let sel = OnlineSelector::new(SelectorConfig {
            arms: arms.clone(),
            priors: Some(priors),
            ..SelectorConfig::default()
        })
        .unwrap();
        assert_eq!(sel.choose(&f, &mut Pcg64::seeded(3)), Some(1));
        // ε = 1 explores: both arms appear over a few draws
        let sel = OnlineSelector::new(SelectorConfig {
            arms,
            epsilon: 1.0,
            ..SelectorConfig::default()
        })
        .unwrap();
        let mut rng = Pcg64::seeded(11);
        let picks: Vec<usize> =
            (0..16).map(|_| sel.choose(&f, &mut rng).unwrap()).collect();
        assert!(picks.contains(&0) && picks.contains(&1));
    }

    /// ArmStats/SelectorPriors merges are order-respecting tallies.
    #[test]
    fn selector_priors_merge() {
        let mut a = ArmStats::default();
        a.record(4, 3, 4);
        a.record(4, 2, 3);
        assert_eq!(a, ArmStats { blocks: 2, drafted: 8, accepted: 5, emitted: 7 });
        assert_eq!(a.acceptance_rate(), None, "below PRIOR_MIN_DRAFTED");
        let mut big = ArmStats { blocks: 10, drafted: 100, accepted: 50, emitted: 60 };
        big.merge(&a);
        assert_eq!(big, ArmStats { blocks: 12, drafted: 108, accepted: 55, emitted: 67 });
        assert!((big.acceptance_rate().unwrap() - 55.0 / 108.0).abs() < 1e-12);
        let mut p = SelectorPriors::zeros(1);
        p.arms[0] = a;
        let mut q = SelectorPriors::zeros(2);
        q.arms[1] = big;
        p.merge(&q);
        assert_eq!(p.arms.len(), 2);
        assert_eq!(p.arms[0], a);
        assert_eq!(p.arms[1], big);
    }
}
