//! The neural delay-and-branch predictor network (paper Appendix E):
//! three per-hidden-state linear projections to d = 128 with layer norm,
//! concatenated with standardized scalar features, followed by a two-layer
//! GELU MLP (512, 32) and a |A|-way logit head. Training is plain Adam;
//! forward and backward are hand-rolled (no autograd in this environment).

use crate::util::Pcg64;

/// Width each hidden-state projection maps to.
pub const PROJ_DIM: usize = 128;
/// First MLP hidden width.
pub const H1: usize = 512;
/// Second MLP hidden width.
pub const H2: usize = 32;

fn gelu(x: f32) -> f32 {
    // tanh approximation (Hendrycks & Gimpel)
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)).tanh()))
}

fn gelu_grad(x: f32) -> f32 {
    let t = (0.7978845608 * (x + 0.044715 * x * x * x)).tanh();
    let dt = (1.0 - t * t) * 0.7978845608 * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * dt
}

/// A dense layer with Adam state.
pub struct Linear {
    /// Weights, row-major `[n_out, n_in]`.
    pub w: Vec<f32>,
    /// Biases `[n_out]`.
    pub b: Vec<f32>,
    /// Input width.
    pub n_in: usize,
    /// Output width.
    pub n_out: usize,
    m_w: Vec<f32>,
    v_w: Vec<f32>,
    m_b: Vec<f32>,
    v_b: Vec<f32>,
}

impl Linear {
    /// Glorot-uniform-ish seeded initialization.
    pub fn new(n_in: usize, n_out: usize, rng: &mut Pcg64) -> Linear {
        let scale = (2.0 / (n_in + n_out) as f32).sqrt();
        let w = (0..n_in * n_out)
            .map(|_| (rng.next_f32() * 2.0 - 1.0) * scale)
            .collect();
        Linear {
            w,
            b: vec![0.0; n_out],
            n_in,
            n_out,
            m_w: vec![0.0; n_in * n_out],
            v_w: vec![0.0; n_in * n_out],
            m_b: vec![0.0; n_out],
            v_b: vec![0.0; n_out],
        }
    }

    /// y = W x + b.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut out = self.b.clone();
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = 0.0f32;
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[o] += acc;
        }
        out
    }

    /// Accumulate grads; returns dL/dx.
    pub fn backward(
        &self,
        x: &[f32],
        dy: &[f32],
        gw: &mut [f32],
        gb: &mut [f32],
    ) -> Vec<f32> {
        let mut dx = vec![0.0f32; self.n_in];
        for o in 0..self.n_out {
            gb[o] += dy[o];
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let grow = &mut gw[o * self.n_in..(o + 1) * self.n_in];
            for i in 0..self.n_in {
                grow[i] += dy[o] * x[i];
                dx[i] += dy[o] * row[i];
            }
        }
        dx
    }

    /// One Adam update on weights and biases.
    pub fn adam(&mut self, gw: &[f32], gb: &[f32], lr: f32, t: usize) {
        adam_update(&mut self.w, &mut self.m_w, &mut self.v_w, gw, lr, t);
        adam_update(&mut self.b, &mut self.m_b, &mut self.v_b, gb, lr, t);
    }
}

fn adam_update(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, t: usize) {
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let c1 = 1.0 - b1.powi(t as i32);
    let c2 = 1.0 - b2.powi(t as i32);
    for i in 0..p.len() {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        p[i] -= lr * (m[i] / c1) / ((v[i] / c2).sqrt() + eps);
    }
}

/// Parameter-free layer norm.
pub fn layer_norm(x: &[f32]) -> Vec<f32> {
    let n = x.len() as f32;
    let mu = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    x.iter().map(|v| (v - mu) * inv).collect()
}

/// dL/dx for parameter-free layer norm.
pub fn layer_norm_backward(x: &[f32], dy: &[f32]) -> Vec<f32> {
    let n = x.len() as f32;
    let mu = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    let xc: Vec<f32> = x.iter().map(|v| (v - mu) * inv).collect();
    let dy_sum: f32 = dy.iter().sum();
    let dyx_sum: f32 = dy.iter().zip(&xc).map(|(a, b)| a * b).sum();
    (0..x.len())
        .map(|i| inv * (dy[i] - dy_sum / n - xc[i] * dyx_sum / n))
        .collect()
}

/// Full selector network.
pub struct SelectorNet {
    /// Projection of the previous-root target hidden state.
    pub proj_p: Linear,
    /// Projection of the previous-root draft hidden state.
    pub proj_q_prev: Linear,
    /// Projection of the current-root draft hidden state.
    pub proj_q_cur: Linear,
    /// First MLP layer over the concatenated features.
    pub fc1: Linear,
    /// Second MLP layer.
    pub fc2: Linear,
    /// |A|-way logit head.
    pub head: Linear,
    /// Scalar feature count.
    pub n_scalars: usize,
    /// Action count |A|.
    pub n_actions: usize,
}

/// Per-example activation cache for backward.
pub struct Cache {
    hp: Vec<f32>,
    hq1: Vec<f32>,
    hq2: Vec<f32>,
    pp: Vec<f32>,
    pq1: Vec<f32>,
    pq2: Vec<f32>,
    concat: Vec<f32>,
    z1: Vec<f32>,
    a1: Vec<f32>,
    z2: Vec<f32>,
    a2: Vec<f32>,
}

/// Gradient buffers matching the network layout (weight, bias) per layer.
pub struct Grads {
    /// Gradients of [`SelectorNet::proj_p`].
    pub proj_p: (Vec<f32>, Vec<f32>),
    /// Gradients of [`SelectorNet::proj_q_prev`].
    pub proj_q_prev: (Vec<f32>, Vec<f32>),
    /// Gradients of [`SelectorNet::proj_q_cur`].
    pub proj_q_cur: (Vec<f32>, Vec<f32>),
    /// Gradients of [`SelectorNet::fc1`].
    pub fc1: (Vec<f32>, Vec<f32>),
    /// Gradients of [`SelectorNet::fc2`].
    pub fc2: (Vec<f32>, Vec<f32>),
    /// Gradients of [`SelectorNet::head`].
    pub head: (Vec<f32>, Vec<f32>),
}

impl SelectorNet {
    /// Seeded initialization for given hidden-state widths and action count.
    pub fn new(d_p: usize, d_q: usize, n_scalars: usize, n_actions: usize, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let concat = 3 * PROJ_DIM + n_scalars;
        SelectorNet {
            proj_p: Linear::new(d_p, PROJ_DIM, &mut rng),
            proj_q_prev: Linear::new(d_q, PROJ_DIM, &mut rng),
            proj_q_cur: Linear::new(d_q, PROJ_DIM, &mut rng),
            fc1: Linear::new(concat, H1, &mut rng),
            fc2: Linear::new(H1, H2, &mut rng),
            head: Linear::new(H2, n_actions, &mut rng),
            n_scalars,
            n_actions,
        }
    }

    /// Fresh zeroed gradient buffers shaped like this network.
    pub fn zero_grads(&self) -> Grads {
        let z = |l: &Linear| (vec![0.0; l.w.len()], vec![0.0; l.b.len()]);
        Grads {
            proj_p: z(&self.proj_p),
            proj_q_prev: z(&self.proj_q_prev),
            proj_q_cur: z(&self.proj_q_cur),
            fc1: z(&self.fc1),
            fc2: z(&self.fc2),
            head: z(&self.head),
        }
    }

    /// Forward pass: action logits plus the activation cache for backward.
    pub fn forward(
        &self,
        h_p: &[f32],
        h_q_prev: &[f32],
        h_q_cur: &[f32],
        scalars: &[f32],
    ) -> (Vec<f32>, Cache) {
        let pp = self.proj_p.forward(h_p);
        let pq1 = self.proj_q_prev.forward(h_q_prev);
        let pq2 = self.proj_q_cur.forward(h_q_cur);
        let np = layer_norm(&pp);
        let nq1 = layer_norm(&pq1);
        let nq2 = layer_norm(&pq2);
        let mut concat = Vec::with_capacity(3 * PROJ_DIM + scalars.len());
        concat.extend_from_slice(&np);
        concat.extend_from_slice(&nq1);
        concat.extend_from_slice(&nq2);
        concat.extend_from_slice(scalars);
        let z1 = self.fc1.forward(&concat);
        let a1: Vec<f32> = z1.iter().map(|&v| gelu(v)).collect();
        let z2 = self.fc2.forward(&a1);
        let a2: Vec<f32> = z2.iter().map(|&v| gelu(v)).collect();
        let logits = self.head.forward(&a2);
        (
            logits,
            Cache {
                hp: h_p.to_vec(),
                hq1: h_q_prev.to_vec(),
                hq2: h_q_cur.to_vec(),
                pp,
                pq1,
                pq2,
                concat,
                z1,
                a1,
                z2,
                a2,
            },
        )
    }

    /// Backward pass: accumulate gradients for one example into `g`.
    pub fn backward(&self, cache: &Cache, dlogits: &[f32], g: &mut Grads) {
        let da2 = self
            .head
            .backward(&cache.a2, dlogits, &mut g.head.0, &mut g.head.1);
        let dz2: Vec<f32> = da2
            .iter()
            .zip(&cache.z2)
            .map(|(d, &z)| d * gelu_grad(z))
            .collect();
        let da1 = self
            .fc2
            .backward(&cache.a1, &dz2, &mut g.fc2.0, &mut g.fc2.1);
        let dz1: Vec<f32> = da1
            .iter()
            .zip(&cache.z1)
            .map(|(d, &z)| d * gelu_grad(z))
            .collect();
        let dconcat = self
            .fc1
            .backward(&cache.concat, &dz1, &mut g.fc1.0, &mut g.fc1.1);
        let dp = layer_norm_backward(&cache.pp, &dconcat[..PROJ_DIM]);
        let dq1 = layer_norm_backward(&cache.pq1, &dconcat[PROJ_DIM..2 * PROJ_DIM]);
        let dq2 = layer_norm_backward(&cache.pq2, &dconcat[2 * PROJ_DIM..3 * PROJ_DIM]);
        self.proj_p
            .backward(&cache.hp, &dp, &mut g.proj_p.0, &mut g.proj_p.1);
        self.proj_q_prev
            .backward(&cache.hq1, &dq1, &mut g.proj_q_prev.0, &mut g.proj_q_prev.1);
        self.proj_q_cur
            .backward(&cache.hq2, &dq2, &mut g.proj_q_cur.0, &mut g.proj_q_cur.1);
    }

    /// Apply one Adam step to every layer.
    pub fn adam_step(&mut self, g: &Grads, lr: f32, t: usize) {
        self.proj_p.adam(&g.proj_p.0, &g.proj_p.1, lr, t);
        self.proj_q_prev.adam(&g.proj_q_prev.0, &g.proj_q_prev.1, lr, t);
        self.proj_q_cur.adam(&g.proj_q_cur.0, &g.proj_q_cur.1, lr, t);
        self.fc1.adam(&g.fc1.0, &g.fc1.1, lr, t);
        self.fc2.adam(&g.fc2.0, &g.fc2.1, lr, t);
        self.head.adam(&g.head.0, &g.head.1, lr, t);
    }
}

/// Numerically stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut e: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
    let s: f32 = e.iter().sum();
    for v in e.iter_mut() {
        *v /= s;
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Numerical gradient check on a small network end-to-end.
    #[test]
    fn gradients_match_finite_differences() {
        let mut net = SelectorNet::new(6, 4, 3, 5, 0);
        let mut rng = Pcg64::seeded(1);
        let hp: Vec<f32> = (0..6).map(|_| rng.next_f32()).collect();
        let hq1: Vec<f32> = (0..4).map(|_| rng.next_f32()).collect();
        let hq2: Vec<f32> = (0..4).map(|_| rng.next_f32()).collect();
        let sc: Vec<f32> = (0..3).map(|_| rng.next_f32()).collect();
        // loss = sum of squared logits (simple, smooth)
        let loss = |net: &SelectorNet| -> f32 {
            let (l, _) = net.forward(&hp, &hq1, &hq2, &sc);
            l.iter().map(|v| v * v).sum()
        };
        let (logits, cache) = net.forward(&hp, &hq1, &hq2, &sc);
        let dlogits: Vec<f32> = logits.iter().map(|&v| 2.0 * v).collect();
        let mut g = net.zero_grads();
        net.backward(&cache, &dlogits, &mut g);

        // check a few weights in each layer
        let eps = 1e-3f32;
        let checks: Vec<(&str, usize)> = vec![("fc1", 10), ("fc2", 3), ("head", 7), ("proj_p", 5)];
        for (layer, idx) in checks {
            let (analytic, ptr): (f32, *mut f32) = match layer {
                "fc1" => (g.fc1.0[idx], &mut net.fc1.w[idx]),
                "fc2" => (g.fc2.0[idx], &mut net.fc2.w[idx]),
                "head" => (g.head.0[idx], &mut net.head.w[idx]),
                _ => (g.proj_p.0[idx], &mut net.proj_p.w[idx]),
            };
            unsafe {
                let orig = *ptr;
                *ptr = orig + eps;
                let lp = loss(&net);
                *ptr = orig - eps;
                let lm = loss(&net);
                *ptr = orig;
                let numeric = (lp - lm) / (2.0 * eps);
                assert!(
                    (analytic - numeric).abs() < 0.02 * (1.0 + numeric.abs()),
                    "{layer}[{idx}]: analytic {analytic} vs numeric {numeric}"
                );
            }
        }
    }

    #[test]
    fn softmax_normalizes() {
        let s = softmax(&[1.0, 2.0, 3.0]);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn adam_reduces_simple_loss() {
        // regression to fixed target logits
        let mut net = SelectorNet::new(4, 4, 2, 3, 7);
        let hp = vec![0.3, -0.2, 0.5, 0.1];
        let sc = vec![1.0, -1.0];
        let target = [1.0f32, -2.0, 0.5];
        let loss_at = |net: &SelectorNet| {
            let (l, _) = net.forward(&hp, &hp, &hp, &sc);
            l.iter()
                .zip(&target)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
        };
        let l0 = loss_at(&net);
        for t in 1..=200 {
            let (l, cache) = net.forward(&hp, &hp, &hp, &sc);
            let dl: Vec<f32> = l.iter().zip(&target).map(|(a, b)| 2.0 * (a - b)).collect();
            let mut g = net.zero_grads();
            net.backward(&cache, &dl, &mut g);
            net.adam_step(&g, 1e-2, t);
        }
        let l1 = loss_at(&net);
        assert!(l1 < 0.05 * l0, "loss {l0} -> {l1}");
    }
}
