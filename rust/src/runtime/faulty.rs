//! Deterministic fault injection over any [`Backend`], plus the
//! dispatch-boundary corruption guards the serving stack uses to detect
//! (never sample from) non-finite model outputs.
//!
//! [`FaultyBackend`] wraps a `&dyn Backend` and injects three failure
//! modes, each driven by a seeded [`FaultPlan`]:
//!
//! * **transient dispatch errors** — the call fails with a typed
//!   [`DispatchFault`] before reaching the inner backend (a stand-in for a
//!   lost RPC, a device reset, a preempted kernel);
//! * **corrupt outputs** — the call succeeds but one element of its
//!   *sampled surface* (logits / rollout distributions) is poisoned to
//!   NaN (a stand-in for silent numerical corruption). Corruption is never
//!   an error at the backend seam — detection is the consumer's job, via
//!   [`guard_finite`] at every dispatch boundary;
//! * **latency spikes** — the call sleeps [`FaultPlan::latency`] before
//!   executing (a stand-in for stragglers; exercises deadline retirement).
//!
//! ## Determinism
//!
//! Every decision is a pure function of `(plan seed, call signature,
//! per-signature attempt index)`. The call signature hashes the dispatch's
//! arguments (op, role, tokens, positions, lengths, uniforms — not the KV
//! contents), and the attempt index counts how many times that exact
//! signature has been issued, so a *retried* dispatch draws a fresh
//! decision while the schedule (which worker, which tick) never matters.
//! Two runs issuing the same multiset of calls see the same multiset of
//! faults. Caveat: two lanes issuing byte-identical calls share a
//! signature, so which of them observes a given attempt's fault is
//! arrival-ordered; tests that need exact per-lane schedules should give
//! lanes distinct prompts or seeds.

use std::collections::HashMap;
use std::fmt;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Error, Result};

use super::{Backend, DecodeOut, FamilyMeta, PrefillOut, Role, RolloutOut, TreeOut};
use crate::kvcache::KvRef;

/// Which backend entry point a fault attaches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultOp {
    /// Prompt prefill.
    Prefill,
    /// Single-token decode.
    Decode,
    /// Fused draft rollout.
    Rollout,
    /// Target tree-verification pass.
    TreeVerify,
}

impl FaultOp {
    fn tag(self) -> u64 {
        match self {
            FaultOp::Prefill => 1,
            FaultOp::Decode => 2,
            FaultOp::Rollout => 3,
            FaultOp::TreeVerify => 4,
        }
    }

    /// Lowercase name for messages and reports.
    pub fn name(self) -> &'static str {
        match self {
            FaultOp::Prefill => "prefill",
            FaultOp::Decode => "decode",
            FaultOp::Rollout => "rollout",
            FaultOp::TreeVerify => "tree_verify",
        }
    }
}

/// The two error-producing fault classes (latency spikes succeed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The dispatch failed outright; a retry may succeed.
    Transient,
    /// The dispatch returned non-finite sampled surfaces.
    Corrupt,
}

/// Typed dispatch-boundary failure. Raised as an `anyhow` error *with a
/// payload* ([`anyhow::Error::new`]) so the serving loop can classify it
/// by downcast instead of string matching.
#[derive(Clone, Copy, Debug)]
pub struct DispatchFault {
    /// Transient vs corrupt.
    pub kind: FaultKind,
    /// Which entry point faulted.
    pub op: FaultOp,
}

impl fmt::Display for DispatchFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            FaultKind::Transient => write!(f, "transient dispatch fault in {}", self.op.name()),
            FaultKind::Corrupt => write!(f, "corrupt output from {}", self.op.name()),
        }
    }
}

impl std::error::Error for DispatchFault {}

/// Reject non-finite values in a dispatch's sampled surface. Called at
/// every dispatch boundary of the serving stack (prefill/decode/tree
/// logits, rollout distributions) so corruption is *detected* — raised as
/// a typed [`DispatchFault`] of kind [`FaultKind::Corrupt`] — instead of
/// silently sampled into a served stream. O(len) scan; the surfaces are
/// vocab-sized, a rounding error next to the forward pass that produced
/// them.
pub fn guard_finite(op: FaultOp, what: &str, xs: &[f32]) -> Result<()> {
    if let Some(i) = xs.iter().position(|x| !x.is_finite()) {
        return Err(Error::new(DispatchFault { kind: FaultKind::Corrupt, op })
            .context(format!("non-finite {what} at index {i}")));
    }
    Ok(())
}

/// Seeded, deterministic fault schedule (see the module docs).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Seed of the decision stream.
    pub seed: u64,
    /// Per-dispatch probability of a transient error.
    pub transient_rate: f64,
    /// Per-dispatch probability of a poisoned output (evaluated only when
    /// the transient draw did not fire).
    pub corrupt_rate: f64,
    /// Per-dispatch probability of an injected latency spike.
    pub latency_rate: f64,
    /// Duration of one latency spike.
    pub latency: Duration,
    /// Restrict faults to these ops; `None` targets every op.
    pub ops: Option<Vec<FaultOp>>,
}

impl FaultPlan {
    /// A plan injecting nothing (rates 0).
    pub fn quiet(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            transient_rate: 0.0,
            corrupt_rate: 0.0,
            latency_rate: 0.0,
            latency: Duration::from_millis(5),
            ops: None,
        }
    }

    /// Set the transient-error rate.
    pub fn with_transient(mut self, rate: f64) -> FaultPlan {
        self.transient_rate = rate;
        self
    }

    /// Set the corrupt-output rate.
    pub fn with_corrupt(mut self, rate: f64) -> FaultPlan {
        self.corrupt_rate = rate;
        self
    }

    /// Set the latency-spike rate and duration.
    pub fn with_latency(mut self, rate: f64, latency: Duration) -> FaultPlan {
        self.latency_rate = rate;
        self.latency = latency;
        self
    }

    /// Restrict faults to the given ops.
    pub fn with_ops(mut self, ops: Vec<FaultOp>) -> FaultPlan {
        self.ops = Some(ops);
        self
    }

    /// Build a plan from the `SPECDELAY_FAULT_*` env knobs:
    /// `SPECDELAY_FAULT_SEED`, `SPECDELAY_FAULT_TRANSIENT`,
    /// `SPECDELAY_FAULT_CORRUPT`, `SPECDELAY_FAULT_LATENCY` (rates as
    /// floats) and `SPECDELAY_FAULT_LATENCY_MS`. Unset knobs default to a
    /// quiet plan, so wrapping a backend with `FaultPlan::from_env()` is a
    /// no-op unless the environment opts in.
    pub fn from_env() -> FaultPlan {
        let f = |k: &str, d: f64| -> f64 {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        FaultPlan {
            seed: f("SPECDELAY_FAULT_SEED", 0.0) as u64,
            transient_rate: f("SPECDELAY_FAULT_TRANSIENT", 0.0),
            corrupt_rate: f("SPECDELAY_FAULT_CORRUPT", 0.0),
            latency_rate: f("SPECDELAY_FAULT_LATENCY", 0.0),
            latency: Duration::from_millis(f("SPECDELAY_FAULT_LATENCY_MS", 5.0) as u64),
            ops: None,
        }
    }

    fn targets(&self, op: FaultOp) -> bool {
        self.ops.as_ref().is_none_or(|ops| ops.contains(&op))
    }
}

/// Injection counters, by class (snapshot via [`FaultyBackend::stats`]).
/// The chaos suite closes the loop against these: every injected transient
/// or corruption must be observed by the serving loop as a classified
/// fault — retried or surfaced, never silently sampled.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Dispatches issued through the wrapper.
    pub dispatches: usize,
    /// Transient errors raised.
    pub transient: usize,
    /// Outputs poisoned with NaN.
    pub corrupt: usize,
    /// Latency spikes slept.
    pub latency: usize,
}

/// Per-call fault decision (resolved before the inner dispatch runs).
struct Decision {
    transient: bool,
    corrupt: bool,
    /// Mixed bits for picking the poisoned element.
    bits: u64,
}

/// A [`Backend`] wrapper injecting deterministic faults per a [`FaultPlan`].
pub struct FaultyBackend<'a> {
    inner: &'a dyn Backend,
    plan: FaultPlan,
    attempts: Mutex<HashMap<u64, u64>>,
    stats: Mutex<FaultStats>,
}

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

fn fnv_u64(h: &mut u64, x: u64) {
    fnv(h, &x.to_le_bytes());
}

fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl<'a> FaultyBackend<'a> {
    /// Wrap a backend with a fault plan.
    pub fn new(inner: &'a dyn Backend, plan: FaultPlan) -> FaultyBackend<'a> {
        FaultyBackend {
            inner,
            plan,
            attempts: Mutex::new(HashMap::new()),
            stats: Mutex::new(FaultStats::default()),
        }
    }

    /// Snapshot of the injection counters.
    pub fn stats(&self) -> FaultStats {
        *self.stats.lock().unwrap()
    }

    /// Reset the injection counters and the attempt memory (so a fresh
    /// run over the same wrapper replays the same fault schedule).
    pub fn reset(&self) {
        self.attempts.lock().unwrap().clear();
        *self.stats.lock().unwrap() = FaultStats::default();
    }

    /// Resolve this call's fault decision, apply any latency spike, and
    /// raise the transient error if one fires. Corruption (if drawn) is
    /// applied by the caller to the successful output.
    fn decide(&self, op: FaultOp, key: u64) -> Result<Decision> {
        {
            let mut st = self.stats.lock().unwrap();
            st.dispatches += 1;
        }
        if !self.plan.targets(op) {
            return Ok(Decision { transient: false, corrupt: false, bits: 0 });
        }
        let attempt = {
            let mut m = self.attempts.lock().unwrap();
            let c = m.entry(key).or_insert(0);
            let a = *c;
            *c += 1;
            a
        };
        let base = mix(self.plan.seed ^ key.rotate_left(17) ^ attempt.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let latency = unit(mix(base ^ 0xA1)) < self.plan.latency_rate;
        let transient = unit(mix(base ^ 0xB2)) < self.plan.transient_rate;
        // corruption is mutually exclusive with transient: a failed
        // dispatch returns no output to poison
        let corrupt = !transient && unit(mix(base ^ 0xC3)) < self.plan.corrupt_rate;
        if latency {
            self.stats.lock().unwrap().latency += 1;
            std::thread::sleep(self.plan.latency);
        }
        if transient {
            self.stats.lock().unwrap().transient += 1;
            return Err(Error::new(DispatchFault { kind: FaultKind::Transient, op })
                .context(format!("injected fault (attempt {attempt})")));
        }
        Ok(Decision { transient: false, corrupt, bits: mix(base ^ 0xD4) })
    }

    /// Poison one element of a successful output's sampled surface.
    fn poison(&self, d: &Decision, xs: &mut [f32]) {
        if d.corrupt && !xs.is_empty() {
            self.stats.lock().unwrap().corrupt += 1;
            let idx = (d.bits as usize) % xs.len();
            xs[idx] = f32::NAN;
        }
    }
}

impl Backend for FaultyBackend<'_> {
    fn meta(&self) -> &FamilyMeta {
        self.inner.meta()
    }

    fn name(&self) -> &'static str {
        "faulty"
    }

    fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> Result<PrefillOut> {
        let mut key = 0xcbf2_9ce4_8422_2325u64;
        fnv_u64(&mut key, FaultOp::Prefill.tag());
        fnv_u64(&mut key, matches!(role, Role::Target) as u64);
        fnv_u64(&mut key, length as u64);
        for &t in tokens {
            fnv(&mut key, &t.to_le_bytes());
        }
        let d = self.decide(FaultOp::Prefill, key)?;
        let mut out = self.inner.prefill(role, tokens, length)?;
        self.poison(&d, &mut out.logits);
        Ok(out)
    }

    // Provided trait methods do NOT forward through wrappers: without this
    // explicit impl, chunked prefill would fall through to the trait
    // default (built on `self.decode`) and every chunk would draw per-row
    // Decode-signature faults instead of one Prefill-signature decision —
    // breaking the chaos suite's fault accounting.
    fn prefill_chunk(
        &self,
        role: Role,
        kv: KvRef<'_>,
        tokens: &[i32],
        start: usize,
        len: usize,
    ) -> Result<PrefillOut> {
        let mut key = 0xcbf2_9ce4_8422_2325u64;
        fnv_u64(&mut key, FaultOp::Prefill.tag());
        fnv_u64(&mut key, matches!(role, Role::Target) as u64);
        fnv_u64(&mut key, start as u64);
        fnv_u64(&mut key, len as u64);
        for &t in tokens {
            fnv(&mut key, &t.to_le_bytes());
        }
        let d = self.decide(FaultOp::Prefill, key)?;
        let mut out = self.inner.prefill_chunk(role, kv, tokens, start, len)?;
        self.poison(&d, &mut out.logits);
        Ok(out)
    }

    fn decode(&self, role: Role, kv: KvRef<'_>, token: u32, pos: usize) -> Result<DecodeOut> {
        let mut key = 0xcbf2_9ce4_8422_2325u64;
        fnv_u64(&mut key, FaultOp::Decode.tag());
        fnv_u64(&mut key, matches!(role, Role::Target) as u64);
        fnv_u64(&mut key, token as u64);
        fnv_u64(&mut key, pos as u64);
        let d = self.decide(FaultOp::Decode, key)?;
        let mut out = self.inner.decode(role, kv, token, pos)?;
        self.poison(&d, &mut out.logits);
        Ok(out)
    }

    fn rollout(
        &self,
        k: usize,
        l: usize,
        kv: KvRef<'_>,
        token: u32,
        pos: usize,
        uniforms: &[f32],
        temperature: f32,
        top_p: f32,
    ) -> Result<RolloutOut> {
        let mut key = 0xcbf2_9ce4_8422_2325u64;
        fnv_u64(&mut key, FaultOp::Rollout.tag());
        fnv_u64(&mut key, k as u64);
        fnv_u64(&mut key, l as u64);
        fnv_u64(&mut key, token as u64);
        fnv_u64(&mut key, pos as u64);
        fnv(&mut key, &temperature.to_le_bytes());
        fnv(&mut key, &top_p.to_le_bytes());
        for &u in uniforms {
            fnv(&mut key, &u.to_le_bytes());
        }
        let d = self.decide(FaultOp::Rollout, key)?;
        let mut out = self.inner.rollout(k, l, kv, token, pos, uniforms, temperature, top_p)?;
        self.poison(&d, &mut out.dists);
        Ok(out)
    }

    fn tree_verify(
        &self,
        n_bucket: usize,
        kv: KvRef<'_>,
        tokens: &[i32],
        positions: &[i32],
        bias: &[f32],
        cache_len: usize,
    ) -> Result<TreeOut> {
        let mut key = 0xcbf2_9ce4_8422_2325u64;
        fnv_u64(&mut key, FaultOp::TreeVerify.tag());
        fnv_u64(&mut key, n_bucket as u64);
        fnv_u64(&mut key, cache_len as u64);
        for &t in tokens {
            fnv(&mut key, &t.to_le_bytes());
        }
        for &p in positions {
            fnv(&mut key, &p.to_le_bytes());
        }
        let d = self.decide(FaultOp::TreeVerify, key)?;
        let mut out = self.inner.tree_verify(n_bucket, kv, tokens, positions, bias, cache_len)?;
        self.poison(&d, &mut out.logits);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_accepts_finite_rejects_nan_and_inf() {
        assert!(guard_finite(FaultOp::Decode, "logits", &[0.0, -1.5, 3.0]).is_ok());
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let e = guard_finite(FaultOp::Decode, "logits", &[0.0, bad]).unwrap_err();
            let f = e.downcast_ref::<DispatchFault>().expect("typed fault");
            assert_eq!(f.kind, FaultKind::Corrupt);
            assert_eq!(f.op, FaultOp::Decode);
            assert!(e.to_string().contains("index 1"), "{e}");
        }
    }

    #[test]
    fn decisions_are_attempt_indexed_and_deterministic() {
        // no backend needed: exercise the decision stream directly
        struct Nothing;
        // a decision sequence for one signature must be reproducible and
        // vary by attempt
        let _ = Nothing;
        let plan = FaultPlan::quiet(7).with_transient(0.5);
        let seq = |key: u64, n: u64| -> Vec<bool> {
            (0..n)
                .map(|attempt| {
                    let base = mix(
                        plan.seed ^ key.rotate_left(17) ^ attempt.wrapping_mul(0x9e37_79b9_7f4a_7c15),
                    );
                    unit(mix(base ^ 0xB2)) < plan.transient_rate
                })
                .collect()
        };
        let a = seq(42, 64);
        assert_eq!(a, seq(42, 64), "decision stream must be reproducible");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "rate 0.5 must mix outcomes");
        assert_ne!(a, seq(43, 64), "different signatures draw different streams");
    }

    #[test]
    fn quiet_plan_targets_nothing() {
        let plan = FaultPlan::quiet(1);
        assert_eq!(plan.transient_rate, 0.0);
        assert!(plan.targets(FaultOp::Rollout));
        let plan = plan.with_ops(vec![FaultOp::Rollout]);
        assert!(plan.targets(FaultOp::Rollout));
        assert!(!plan.targets(FaultOp::Decode));
    }
}
