//! Model runtime: the [`Backend`] execution seam, the always-built
//! [`CpuRefBackend`] reference implementation and its f32x8 sibling
//! [`CpuSimdBackend`] (shared seeded weights, lane-chunked reductions,
//! ≤ 1e-5 relative tolerance — see [`kernels`] for the reduction-order
//! contract), the deterministic fault-injection wrapper [`FaultyBackend`]
//! (plus the [`guard_finite`] dispatch-boundary corruption guard), AOT
//! artifact metadata, weight containers, and (behind the `pjrt` feature)
//! the PJRT engine.
//!
//! The serving stack drives models only through [`Backend`], whose method
//! surface mirrors the compiled-module interface (prefill / decode / fused
//! rollout / tree-verification pass, caller-owned KV caches, caller-owned
//! randomness). The metadata/weights half and the CPU reference backend
//! are pure rust and always built; the `Engine` half is the only code that
//! touches the `xla` crate and is gated behind `--features pjrt`.
//! Everything above this module works with plain `Vec<f32>` tensors.

mod backend;
mod cpu;
mod cpu_simd;
#[cfg(feature = "pjrt")]
mod engine;
mod faulty;
pub mod kernels;
mod weights;

pub use backend::Backend;
pub use cpu::{CpuBackendCore, CpuModelConfig, CpuRefBackend};
pub use cpu_simd::{CpuSimdBackend, SimdKernels};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use faulty::{
    guard_finite, DispatchFault, FaultKind, FaultOp, FaultPlan, FaultStats, FaultyBackend,
};
pub use weights::{read_weights, Tensor};

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// Dimensions of one model (target or draft).
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    /// Transformer blocks.
    pub n_layers: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum sequence length (KV-cache rows per head).
    pub max_seq: usize,
}

impl ModelDims {
    fn from_json(j: &Json) -> Result<ModelDims> {
        let g = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize().ok_or_else(|| format!("{k} not a number")))
                .map_err(|e| anyhow!(e))
        };
        Ok(ModelDims {
            n_layers: g("n_layers")?,
            d_model: g("d_model")?,
            n_heads: g("n_heads")?,
            d_head: g("d_head")?,
            vocab: g("vocab")?,
            max_seq: g("max_seq")?,
        })
    }

    /// Elements in one KV tensor [L, H, S, Dh].
    pub fn kv_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.max_seq * self.d_head
    }
}

/// Family metadata: model dimensions plus the compiled shape buckets.
///
/// For the PJRT engine this is parsed from `artifacts/<family>/meta.json`;
/// [`CpuRefBackend`] synthesizes an equivalent set so the serving stack
/// exercises the same bucket-selection code paths on both backends.
#[derive(Clone, Debug)]
pub struct FamilyMeta {
    /// Family name (e.g. `"qwen-sim"`, `"cpu-ref"`).
    pub family: String,
    /// Target-model dimensions.
    pub target: ModelDims,
    /// Draft-model dimensions.
    pub draft: ModelDims,
    /// Prompt prefill capacity (tokens).
    pub s_pre: usize,
    /// Compiled tree-pass node buckets, ascending.
    pub tree_sizes: Vec<usize>,
    /// Largest compiled tree bucket (superset scoring).
    pub tree_big: usize,
    /// Compiled single-path trunk rollout lengths.
    pub trunk_lens: Vec<usize>,
    /// Compiled branch-rollout path counts.
    pub branch_ks: Vec<usize>,
    /// Compiled branch-rollout length buckets, ascending.
    pub branch_lens: Vec<usize>,
}

impl FamilyMeta {
    /// Parse `<dir>/meta.json`.
    pub fn load(dir: &Path) -> Result<FamilyMeta> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let arr_usize = |k: &str| -> Result<Vec<usize>> {
            Ok(j.get(k)
                .map_err(|e| anyhow!(e))?
                .as_arr()
                .ok_or_else(|| anyhow!("{k} not array"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect())
        };
        let num_usize = |k: &str| -> Result<usize> {
            j.get(k)
                .map_err(|e| anyhow!(e))?
                .as_usize()
                .ok_or_else(|| anyhow!("{k} not a number"))
        };
        Ok(FamilyMeta {
            family: j
                .get("family")
                .map_err(|e| anyhow!(e))?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            target: ModelDims::from_json(j.get("target").map_err(|e| anyhow!(e))?)?,
            draft: ModelDims::from_json(j.get("draft").map_err(|e| anyhow!(e))?)?,
            s_pre: num_usize("s_pre")?,
            tree_sizes: arr_usize("tree_sizes")?,
            tree_big: num_usize("tree_big")?,
            trunk_lens: arr_usize("trunk_lens")?,
            branch_ks: arr_usize("branch_ks")?,
            branch_lens: arr_usize("branch_lens")?,
        })
    }

    /// Smallest compiled tree bucket that fits `n` nodes.
    pub fn tree_bucket(&self, n: usize) -> Result<usize> {
        self.tree_sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .or(if n <= self.tree_big { Some(self.tree_big) } else { None })
            .ok_or_else(|| anyhow!("tree of {n} nodes exceeds all buckets"))
    }

    /// Smallest compiled branch-length bucket ≥ l.
    pub fn branch_bucket(&self, l: usize) -> Result<usize> {
        self.branch_lens
            .iter()
            .copied()
            .find(|&b| b >= l)
            .ok_or_else(|| anyhow!("branch length {l} exceeds buckets"))
    }
}

/// Output of a prefill call.
pub struct PrefillOut {
    /// `[V]` logits at the last valid prompt token.
    pub logits: Vec<f32>,
    /// `[d]` final-LN hidden state at the last valid prompt token.
    pub hidden: Vec<f32>,
    /// `[L, H, s_pre, Dh]` KV rows for every prompt position.
    pub k_rows: Vec<f32>,
    /// Value rows, same layout as `k_rows`.
    pub v_rows: Vec<f32>,
}

/// Output of a decode call.
pub struct DecodeOut {
    /// `[V]` next-token logits.
    pub logits: Vec<f32>,
    /// `[d]` final-LN hidden state.
    pub hidden: Vec<f32>,
    /// `[L, H, Dh]` KV row of the decoded token.
    pub k_row: Vec<f32>,
    /// Value row, same layout as `k_row`.
    pub v_row: Vec<f32>,
}

/// Output of a fused rollout call (K paths × L steps).
pub struct RolloutOut {
    /// Number of i.i.d. paths.
    pub k: usize,
    /// Steps per path.
    pub l: usize,
    /// `[K, L]` sampled continuation tokens.
    pub tokens: Vec<i32>,
    /// `[K, L, V]` transformed draft distributions at each visited node.
    pub dists: Vec<f32>,
    /// `[K, L, d]` final-LN hidden states.
    pub hiddens: Vec<f32>,
    /// `[Lyr, K, L, H, Dh]` KV rows for visited nodes at pos..pos+L-1.
    pub k_rows: Vec<f32>,
    /// Value rows, same layout as `k_rows`.
    pub v_rows: Vec<f32>,
}

/// Output of a target tree pass.
pub struct TreeOut {
    /// Bucketed node count of the pass.
    pub n: usize,
    /// `[N, V]` per-node logits.
    pub logits: Vec<f32>,
    /// `[N, d]` per-node final-LN hidden states.
    pub hidden: Vec<f32>,
    /// `[Lyr, N, H, Dh]` per-node KV rows.
    pub k_rows: Vec<f32>,
    /// Value rows, same layout as `k_rows`.
    pub v_rows: Vec<f32>,
}

/// Which model of the pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    /// The large model whose distribution is served losslessly.
    Target,
    /// The small drafting model.
    Draft,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_json(mutate: &str) -> String {
        let dims = r#"{"n_layers": 2, "d_model": 8, "n_heads": 2, "d_head": 4,
                       "vocab": 259, "max_seq": 64}"#;
        let base = format!(
            r#"{{"family": "t", "target": {dims}, "draft": {dims}, "s_pre": 32,
                "tree_sizes": [8, 16], "tree_big": 48, "trunk_lens": [2, 4],
                "branch_ks": [2, 4], "branch_lens": [2, 4]}}"#
        );
        match mutate {
            "" => base,
            key => base.replace(&format!("\"{key}\": 32"), &format!("\"{key}\": \"bad\"")),
        }
    }

    fn load_from(text: &str) -> Result<FamilyMeta> {
        let dir = std::env::temp_dir().join(format!(
            "specdelay_meta_{}_{:x}",
            std::process::id(),
            text.len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), text).unwrap();
        FamilyMeta::load(&dir)
    }

    #[test]
    fn loads_valid_meta() {
        let m = load_from(&meta_json("")).expect("valid meta must load");
        assert_eq!(m.s_pre, 32);
        assert_eq!(m.tree_big, 48);
        assert_eq!(m.tree_bucket(10).unwrap(), 16);
        assert_eq!(m.tree_bucket(17).unwrap(), 48);
        assert!(m.tree_bucket(100).is_err());
        assert_eq!(m.branch_bucket(3).unwrap(), 4);
        assert!(m.branch_bucket(9).is_err());
    }

    #[test]
    fn bad_s_pre_is_error_not_panic() {
        let err = load_from(&meta_json("s_pre")).expect_err("must error");
        assert!(err.to_string().contains("s_pre"), "{err}");
    }

    #[test]
    fn missing_tree_big_is_error_not_panic() {
        let text = meta_json("").replace("\"tree_big\": 48,", "");
        let err = load_from(&text).expect_err("must error");
        assert!(err.to_string().contains("tree_big"), "{err}");
    }
}
