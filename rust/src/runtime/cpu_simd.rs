//! f32x8 SIMD CPU backend: the fast sibling of the scalar reference.
//!
//! [`CpuSimdBackend`] instantiates the shared
//! [`CpuBackendCore`](super::CpuBackendCore) with the [`SimdKernels`]
//! set: every reduction (attention score dots, softmax denominators,
//! LayerNorm moments, tied-embedding logit dots) runs over eight
//! independent lane accumulators in ascending 8-element chunks —
//! portable `std::simd`-style lane code on stable rust (plain `[f32; 8]`
//! arrays the compiler vectorizes; no nightly features, no new
//! dependencies). Everything else — seeded weights, the canonical
//! key-gather order over [`crate::kvcache::KvRef`] block-table views,
//! shape handling, sampling — is byte-for-byte the reference backend's
//! code, so the only difference between `cpu-ref` and `cpu-simd` outputs
//! is floating-point summation order.
//!
//! That difference is bounded, not bit-exact: the per-op and end-to-end
//! contract (pinned by the tests here and in `tests/backend_simd.rs`) is
//! ≤ 1e-5 *relative* error against [`CpuRefBackend`](super::CpuRefBackend)
//! on every kernel output. Greedy token streams therefore agree with the
//! reference for a bounded horizon but may eventually diverge where two
//! logits sit within rounding distance — the determinism ladder in
//! `docs/ARCHITECTURE.md` spells out which suites require which rung.

use super::cpu::CpuBackendCore;
use super::kernels::{self, gelu, ForwardKernels};

/// The f32x8 kernel set: lane-chunked reductions (see
/// [`kernels::dot_f32x8`] for the exact combine order) plus a chunked
/// GELU whose polynomial part vectorizes.
pub struct SimdKernels;

impl ForwardKernels for SimdKernels {
    const NAME: &'static str = "cpu-simd";

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        kernels::dot_f32x8(a, b)
    }

    fn sum(x: &[f32]) -> f32 {
        kernels::sum_f32x8(x)
    }

    fn sum_sq_diff(x: &[f32], mu: f32) -> f32 {
        kernels::sum_sq_diff_f32x8(x, mu)
    }

    fn gelu_bias(h: &mut [f32], b: &[f32]) {
        // per-element math identical to the scalar default (same tanh
        // call, same polynomial); the 8-chunk structure lets the
        // bias-add and cubic vectorize
        let n = h.len().min(b.len());
        let (hc, ht) = h[..n].split_at_mut(n - n % 8);
        for (ch, cb) in hc.chunks_exact_mut(8).zip(b.chunks_exact(8)) {
            for i in 0..8 {
                ch[i] = gelu(ch[i] + cb[i]);
            }
        }
        for (hv, &bv) in ht.iter_mut().zip(&b[n - n % 8..]) {
            *hv = gelu(*hv + bv);
        }
    }
}

/// The f32x8 SIMD CPU backend — selectable via `--backend cpu-simd` or
/// `SPECDELAY_BACKEND=cpu-simd`; tolerance-tested (≤ 1e-5 relative)
/// against the scalar oracle per op and end-to-end.
pub type CpuSimdBackend = CpuBackendCore<SimdKernels>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{BlockPool, KvCache};
    use crate::runtime::{Backend, CpuModelConfig, CpuRefBackend, Role};

    /// Max relative error of `got` against `want` (absolute floor 1e-6 so
    /// near-zero entries compare sanely).
    fn rel_err(got: &[f32], want: &[f32]) -> f32 {
        assert_eq!(got.len(), want.len());
        got.iter()
            .zip(want)
            .map(|(&g, &w)| (g - w).abs() / w.abs().max(1e-6))
            .fold(0.0f32, f32::max)
    }

    const TOL: f32 = 1e-5;

    /// The SIMD backend's prefill / decode / rollout / tree pass must all
    /// stay within the 1e-5 relative tolerance of the reference, over
    /// both KV storages (same gather order, different summation order).
    #[test]
    fn simd_backend_within_tolerance_of_reference_all_entry_points() {
        let cfg = CpuModelConfig::tiny();
        let rb = CpuRefBackend::new(&cfg, 6);
        let sb = CpuSimdBackend::new(&cfg, 6);
        assert_eq!(rb.name(), "cpu-ref");
        assert_eq!(sb.name(), "cpu-simd");
        let toks = [5i32, 9, 3, 7];
        for role in [Role::Target, Role::Draft] {
            let pr = rb.prefill(role, &toks, 4).unwrap();
            let ps = sb.prefill(role, &toks, 4).unwrap();
            assert!(rel_err(&ps.logits, &pr.logits) <= TOL, "{role:?} prefill logits");
            assert!(rel_err(&ps.k_rows, &pr.k_rows) <= TOL, "{role:?} prefill k_rows");
            for paged in [false, true] {
                // each backend reads its *own* committed rows (a lane
                // served by cpu-simd holds SIMD-computed KV)
                let pool = BlockPool::new(rb.dims(role), 3, None);
                let mut cr = if paged { KvCache::paged(&pool) } else { KvCache::new(rb.dims(role)) };
                let mut cs = if paged { KvCache::paged(&pool) } else { KvCache::new(rb.dims(role)) };
                cr.commit_prefill(&pr.k_rows, &pr.v_rows, cfg.s_pre, 4);
                cs.commit_prefill(&ps.k_rows, &ps.v_rows, cfg.s_pre, 4);
                let dr = rb.decode(role, cr.view(), 7, 4).unwrap();
                let ds = sb.decode(role, cs.view(), 7, 4).unwrap();
                assert!(
                    rel_err(&ds.logits, &dr.logits) <= TOL,
                    "{role:?} paged={paged} decode logits"
                );
                assert!(
                    rel_err(&ds.k_row, &dr.k_row) <= TOL,
                    "{role:?} paged={paged} decode k_row"
                );
            }
        }
        // draft rollout: identical uniforms, per-step dists within
        // tolerance (token draws may only differ at rounding-distance
        // nucleus boundaries — not with this seed)
        let pr = rb.prefill(Role::Draft, &toks, 4).unwrap();
        let ps = sb.prefill(Role::Draft, &toks, 4).unwrap();
        let mut cr = KvCache::new(rb.dims(Role::Draft));
        let mut cs = KvCache::new(sb.dims(Role::Draft));
        cr.commit_prefill(&pr.k_rows, &pr.v_rows, cfg.s_pre, 4);
        cs.commit_prefill(&ps.k_rows, &ps.v_rows, cfg.s_pre, 4);
        let uni = [0.3f32, 0.7, 0.1, 0.9];
        let rr = rb.rollout(2, 2, cr.view(), 7, 4, &uni, 0.8, 0.9).unwrap();
        let rs = sb.rollout(2, 2, cs.view(), 7, 4, &uni, 0.8, 0.9).unwrap();
        let v = rb.dims(Role::Draft).vocab;
        // a draw landing within rounding distance of a nucleus boundary
        // may legitimately pick a different token, after which the
        // contexts (and dists) diverge — compare each branch's per-step
        // dists only while its token prefix still agrees. Step 0 of every
        // branch shares the committed context, so at least those compare.
        for b in 0..2usize {
            for j in 0..2usize {
                let slot = b * 2 + j;
                // sampling zeroes out-of-nucleus entries; compare kept mass
                for (a, s) in rr.dists[slot * v..(slot + 1) * v]
                    .iter()
                    .zip(&rs.dists[slot * v..(slot + 1) * v])
                {
                    if *a > 0.0 && *s > 0.0 {
                        assert!(
                            (a - s).abs() / a.max(1e-6) <= 1e-4,
                            "rollout b={b} j={j} dist entry {a} vs {s}"
                        );
                    }
                }
                if rr.tokens[slot] != rs.tokens[slot] {
                    break; // boundary draw: contexts fork from here
                }
            }
        }
        // target tree pass
        use crate::tree::{DraftTree, Provenance};
        let pr = rb.prefill(Role::Target, &toks, 4).unwrap();
        let ps = sb.prefill(Role::Target, &toks, 4).unwrap();
        let mut cr = KvCache::new(rb.dims(Role::Target));
        let mut cs = KvCache::new(sb.dims(Role::Target));
        cr.commit_prefill(&pr.k_rows, &pr.v_rows, cfg.s_pre, 4);
        cs.commit_prefill(&ps.k_rows, &ps.v_rows, cfg.s_pre, 4);
        let mut tree = DraftTree::new(7);
        let a = tree.add_child(0, 12, Provenance::Trunk { step: 1 });
        let _ = tree.add_child(a, 44, Provenance::Trunk { step: 2 });
        let nb = 4;
        let (tt, tp) = tree.tokens_positions(nb, 3, 63);
        let bias = tree.attention_bias(nb);
        let tr = rb.tree_verify(nb, cr.view(), &tt, &tp, &bias, 3).unwrap();
        let ts = sb.tree_verify(nb, cs.view(), &tt, &tp, &bias, 3).unwrap();
        assert!(rel_err(&ts.logits, &tr.logits) <= TOL, "tree-pass logits");
    }

    /// Both kernel sets must see bit-identical weights for one
    /// `(config, seed)` pair — the SIMD backend is the same model, not a
    /// retrained one. Pinned through the embedding of a prefill at
    /// length 1 (a pure table lookup, no reductions).
    #[test]
    fn simd_and_ref_share_seeded_weights() {
        let cfg = CpuModelConfig::tiny();
        let rb = CpuRefBackend::new(&cfg, 3);
        let sb = CpuSimdBackend::new(&cfg, 3);
        // meta is identical except the family label
        assert_eq!(rb.meta().s_pre, sb.meta().s_pre);
        assert_eq!(rb.meta().tree_sizes, sb.meta().tree_sizes);
        assert_eq!(rb.meta().family, "cpu-ref");
        assert_eq!(sb.meta().family, "cpu-simd");
        // different seeds must still differ under SIMD
        let other = CpuSimdBackend::new(&cfg, 4);
        let a = sb.prefill(Role::Target, &[5, 9], 2).unwrap();
        let b = other.prefill(Role::Target, &[5, 9], 2).unwrap();
        assert_ne!(a.logits, b.logits);
    }

    /// The SIMD backend must read paged lanes bit-identically to
    /// contiguous ones — the gather happens before any lane-chunked
    /// reduction, so the storage contract is kernel-set independent.
    #[test]
    fn simd_paged_reads_bit_identical_to_contiguous() {
        let cfg = CpuModelConfig::tiny();
        let be = CpuSimdBackend::new(&cfg, 6);
        let toks = [5i32, 9, 3, 7];
        for role in [Role::Target, Role::Draft] {
            let pre = be.prefill(role, &toks, 4).unwrap();
            let mut cont = KvCache::new(be.dims(role));
            cont.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 4);
            let pool = BlockPool::new(be.dims(role), 3, None);
            let mut paged = KvCache::paged(&pool);
            paged.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 4);
            let dc = be.decode(role, cont.view(), 7, 4).unwrap();
            let dp = be.decode(role, paged.view(), 7, 4).unwrap();
            assert_eq!(dc.logits, dp.logits, "{role:?}: simd paged decode diverges");
            assert_eq!(dc.k_row, dp.k_row);
        }
    }
}
