//! Shared f32 forward-pass kernels and the reduction-order contract.
//!
//! ## The reduction-order contract
//!
//! Floating-point addition does not associate, so every f32 reduction in
//! the CPU forward pass pins an explicit summation order — that pin is
//! what makes "bit-identical" a meaningful word anywhere else in the
//! crate (paged vs contiguous reads, batched vs serial serving, replayed
//! vs live streams all compare bitwise).
//!
//! * **Scalar (reference) order** — [`ScalarKernels`]: a single
//!   accumulator folded over ascending element index, `acc += a[i]·b[i]`
//!   for `i = 0, 1, 2, …`. Every reduction the reference backend performs
//!   — attention score dots, softmax denominators, LayerNorm mean and
//!   variance, tied-embedding logit dots, and the per-output accumulation
//!   of [`matvec`] (for output `j`, ascending `i` of `x[i]·w[i][j]`) —
//!   realizes exactly this order. Gathered inputs (paged block-table
//!   rows, in-flight rollout rows) are materialized into contiguous
//!   buffers in canonical order *before* any reduction runs, so storage
//!   layout can never change the summation order.
//! * **f32x8 lane order** — [`dot_f32x8`] and friends: eight independent
//!   partial accumulators over ascending 8-element chunks
//!   (`lane[i % 8] += …` within each chunk), combined by the fixed
//!   pairwise tree `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, then the
//!   scalar tail (`len % 8` elements) folded in ascending order. The
//!   independent lanes are what lets LLVM vectorize the loop (the scalar
//!   order forbids reassociation); the result differs from the scalar
//!   order only by rounding, bounded by the ≤ 1e-5 relative tolerance the
//!   SIMD backend is tested to.
//!
//! Elementwise maps ([`axpy`], [`gelu`], [`rope`], the affine tail of
//! [`ln`]) have no reduction and are shared verbatim by both kernel sets.

/// One kernel set for the CPU forward pass: the three reduction
/// primitives every composite op ([`ln`], [`attend`], logit dots) is
/// built from, each pinning its own summation order (see the module
/// docs). Implementations are zero-sized tags — the backend is generic
/// over the set and monomorphizes to straight-line code.
pub trait ForwardKernels {
    /// Backend name this kernel set labels (`"cpu-ref"` / `"cpu-simd"`).
    const NAME: &'static str;

    /// Dot product Σ a\[i\]·b\[i\] over `a.len().min(b.len())` elements.
    fn dot(a: &[f32], b: &[f32]) -> f32;

    /// Plain sum Σ x\[i\].
    fn sum(x: &[f32]) -> f32;

    /// Sum of squared deviations Σ (x\[i\] − mu)² (LayerNorm variance
    /// numerator).
    fn sum_sq_diff(x: &[f32], mu: f32) -> f32;

    /// In-place biased GELU: `h[i] = gelu(h[i] + b[i])`. Elementwise — the
    /// default is shared; kernel sets may restructure it for
    /// vectorization but the per-element math is identical.
    fn gelu_bias(h: &mut [f32], b: &[f32]) {
        for (hv, &bv) in h.iter_mut().zip(b) {
            *hv = gelu(*hv + bv);
        }
    }
}

/// The reference kernel set: single-accumulator ascending-index
/// reductions (the scalar order of the contract above). This is the
/// order every bit-exactness suite in the crate pins.
pub struct ScalarKernels;

impl ForwardKernels for ScalarKernels {
    const NAME: &'static str = "cpu-ref";

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    fn sum(x: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for &v in x {
            acc += v;
        }
        acc
    }

    fn sum_sq_diff(x: &[f32], mu: f32) -> f32 {
        let mut acc = 0.0f32;
        for &v in x {
            let d = v - mu;
            acc += d * d;
        }
        acc
    }
}

/// Horizontal sum of eight lane accumulators in the fixed pairwise order
/// of the contract: `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
#[inline]
fn hsum8(l: [f32; 8]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// f32x8 dot product: eight independent partial sums over ascending
/// 8-chunks, pairwise-combined, scalar tail last. The independent lanes
/// are the whole point — they license the vectorization the scalar order
/// forbids.
pub fn dot_f32x8(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut lanes = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for i in 0..8 {
            lanes[i] += xa[i] * xb[i];
        }
    }
    let mut acc = hsum8(lanes);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += x * y;
    }
    acc
}

/// f32x8 sum (same lane structure as [`dot_f32x8`]).
pub fn sum_f32x8(x: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 8];
    let mut cx = x.chunks_exact(8);
    for xa in &mut cx {
        for i in 0..8 {
            lanes[i] += xa[i];
        }
    }
    let mut acc = hsum8(lanes);
    for &v in cx.remainder() {
        acc += v;
    }
    acc
}

/// f32x8 sum of squared deviations (same lane structure as
/// [`dot_f32x8`]).
pub fn sum_sq_diff_f32x8(x: &[f32], mu: f32) -> f32 {
    let mut lanes = [0.0f32; 8];
    let mut cx = x.chunks_exact(8);
    for xa in &mut cx {
        for i in 0..8 {
            let d = xa[i] - mu;
            lanes[i] += d * d;
        }
    }
    let mut acc = hsum8(lanes);
    for &v in cx.remainder() {
        let d = v - mu;
        acc += d * d;
    }
    acc
}

/// LayerNorm with affine params, written into `out` (same length as
/// `x`). Mean and variance reduce in `K`'s order; the affine tail is
/// elementwise.
pub fn ln<K: ForwardKernels>(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mu = K::sum(x) / n;
    let var = K::sum_sq_diff(x, mu) / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for (((o, &xv), &gv), &bv) in out.iter_mut().zip(x).zip(g).zip(b) {
        *o = (xv - mu) * inv * gv + bv;
    }
}

/// `out[j] += s · v[j]` — the elementwise accumulation step shared by
/// [`matvec`] and the attention weighted sum (independent lanes, no
/// reduction, auto-vectorizable as-is).
#[inline]
pub fn axpy(out: &mut [f32], s: f32, v: &[f32]) {
    for (o, &vv) in out.iter_mut().zip(v) {
        *o += s * vv;
    }
}

/// out = x @ w with `w` row-major `[x.len(), n_out]`. Outer-product
/// accumulation: for every output `j` this realizes the scalar ascending-
/// `i` order of the contract (a single accumulator per output), so its
/// results are bitwise equal to per-output [`ScalarKernels::dot`] against
/// the corresponding weight column.
pub fn matvec(x: &[f32], w: &[f32], n_out: usize, out: &mut [f32]) {
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        axpy(out, xi, &w[i * n_out..(i + 1) * n_out]);
    }
}

/// tanh-approximation GELU (matches `jax.nn.gelu`'s default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.797_884_6 * (x + 0.044715 * x * x * x)).tanh()))
}

/// Rotary position embedding applied in place to a `[H·Dh]` row at
/// absolute position `pos`. Elementwise over (cos, sin) pairs — shared
/// by both kernel sets.
pub fn rope(row: &mut [f32], n_heads: usize, d_head: usize, pos: usize) {
    for h in 0..n_heads {
        let base = h * d_head;
        for j in 0..d_head / 2 {
            let freq = 10000.0f32.powf(-((2 * j) as f32) / d_head as f32);
            let theta = pos as f32 * freq;
            let (sin, cos) = theta.sin_cos();
            let x1 = row[base + 2 * j];
            let x2 = row[base + 2 * j + 1];
            row[base + 2 * j] = x1 * cos - x2 * sin;
            row[base + 2 * j + 1] = x1 * sin + x2 * cos;
        }
    }
}

/// Softmax attention of one query row over gathered keys, per head, with
/// 1/√Dh score scaling; output written into `out` (`[H·Dh]`). Score dots
/// and the softmax denominator reduce in `K`'s order; max-subtraction
/// and the weighted sum are order-insensitive / elementwise.
#[allow(clippy::too_many_arguments)]
pub fn attend<K: ForwardKernels>(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    n_keys: usize,
    n_heads: usize,
    d_head: usize,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let scale = 1.0 / (d_head as f32).sqrt();
    let row = n_heads * d_head;
    for h in 0..n_heads {
        let qh = &q[h * d_head..(h + 1) * d_head];
        scores.clear();
        let mut max = f32::NEG_INFINITY;
        for kidx in 0..n_keys {
            let base = kidx * row + h * d_head;
            let sv = K::dot(qh, &keys[base..base + d_head]) * scale;
            if sv > max {
                max = sv;
            }
            scores.push(sv);
        }
        for sv in scores.iter_mut() {
            *sv = (*sv - max).exp();
        }
        let inv = 1.0 / K::sum(scores);
        let oh = &mut out[h * d_head..(h + 1) * d_head];
        oh.fill(0.0);
        for (kidx, &w) in scores.iter().enumerate() {
            let base = kidx * row + h * d_head;
            axpy(oh, w * inv, &vals[base..base + d_head]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A deterministic pseudo-random f32 vector (no RNG dependency).
    fn vec_n(n: usize, salt: u64) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let h = (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15 ^ salt);
                ((h >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    }

    /// Pin the scalar order bitwise with reorder-sensitive inputs:
    /// sequential ascending folding gives 1.0 here, while any pairwise
    /// regrouping collapses the large terms first and gives 0.0. This is
    /// the regression test for the reduction-order contract — if a
    /// refactor reassociates the reference sum, this fails.
    #[test]
    fn scalar_sum_is_sequential_ascending_bitwise() {
        let xs = [1e8f32, 1.0, -1e8, 1.0];
        // sequential: ((1e8 + 1) + -1e8) + 1 = (1e8 + -1e8) + 1 = 1.0
        assert_eq!(ScalarKernels::sum(&xs).to_bits(), 1.0f32.to_bits());
        // the pairwise regrouping the SIMD tree would apply is different
        assert_eq!(((xs[0] + xs[1]) + (xs[2] + xs[3])), 0.0);
        let ones = [1.0f32; 4];
        assert_eq!(ScalarKernels::dot(&xs, &ones).to_bits(), 1.0f32.to_bits());
    }

    /// The cross-site half of the contract: [`matvec`]'s outer-product
    /// accumulation must equal a per-output ascending-`i` scalar dot
    /// *bitwise* — attention (dot-shaped) and projections (outer-product-
    /// shaped) realize one summation order, not two.
    #[test]
    fn matvec_bitwise_equals_per_output_scalar_dot() {
        let (n_in, n_out) = (13usize, 7usize);
        let x = vec_n(n_in, 1);
        let w = vec_n(n_in * n_out, 2);
        let mut out = vec![0.0f32; n_out];
        matvec(&x, &w, n_out, &mut out);
        for j in 0..n_out {
            let col: Vec<f32> = (0..n_in).map(|i| w[i * n_out + j]).collect();
            assert_eq!(
                out[j].to_bits(),
                ScalarKernels::dot(&x, &col).to_bits(),
                "output {j} disagrees with the scalar dot order"
            );
        }
    }

    /// Reductions over a buffer gathered from several sub-slices must
    /// equal the same reduction over the contiguous original — gathering
    /// (the paged block-table read path) happens *before* the reduction,
    /// so it cannot change the order.
    #[test]
    fn gathered_then_reduced_bitwise_equals_contiguous() {
        let x = vec_n(37, 3);
        let y = vec_n(37, 4);
        let mut gx = Vec::new();
        // gather in canonical ascending order from uneven "blocks"
        for chunk in x.chunks(5) {
            gx.extend_from_slice(chunk);
        }
        assert_eq!(
            ScalarKernels::dot(&gx, &y).to_bits(),
            ScalarKernels::dot(&x, &y).to_bits()
        );
        assert_eq!(ScalarKernels::sum(&gx).to_bits(), ScalarKernels::sum(&x).to_bits());
    }

    /// f32x8 reductions agree with the scalar order within the SIMD
    /// backend's tolerance across lengths that exercise every tail size
    /// (including the empty and the sub-chunk cases).
    #[test]
    fn f32x8_matches_scalar_within_tolerance() {
        for n in 0..40usize {
            let a = vec_n(n, 5);
            let b = vec_n(n, 6);
            let (ds, d8) = (ScalarKernels::dot(&a, &b), dot_f32x8(&a, &b));
            assert!(
                (ds - d8).abs() <= 1e-5 * ds.abs().max(1.0),
                "dot n={n}: scalar {ds} vs f32x8 {d8}"
            );
            let (ss, s8) = (ScalarKernels::sum(&a), sum_f32x8(&a));
            assert!(
                (ss - s8).abs() <= 1e-5 * ss.abs().max(1.0),
                "sum n={n}: scalar {ss} vs f32x8 {s8}"
            );
            let (qs, q8) =
                (ScalarKernels::sum_sq_diff(&a, 0.125), sum_sq_diff_f32x8(&a, 0.125));
            assert!(
                (qs - q8).abs() <= 1e-5 * qs.abs().max(1.0),
                "sum_sq_diff n={n}: scalar {qs} vs f32x8 {q8}"
            );
        }
    }

    /// Exact-chunk inputs exercise the pairwise lane-combine alone; the
    /// f32x8 result must equal the explicitly-written lane tree.
    #[test]
    fn f32x8_lane_combine_order_pinned() {
        let x: Vec<f32> = (0..16).map(|i| (i as f32 + 0.5) * 0.1).collect();
        let mut lanes = [0.0f32; 8];
        for c in x.chunks_exact(8) {
            for i in 0..8 {
                lanes[i] += c[i];
            }
        }
        let want = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        assert_eq!(sum_f32x8(&x).to_bits(), want.to_bits());
    }
}
