//! Deterministic CPU reference backend: a small seeded transformer that
//! implements the full [`Backend`] surface in pure rust, so the whole
//! serving stack — drafting, tree verification, the batched serving loop —
//! builds and runs end-to-end in the hermetic default configuration.
//!
//! The architecture mirrors the layer-2 JAX model (`python/compile/model.py`
//! and the pure-jnp oracle `python/compile/kernels/ref.py`): pre-LN blocks,
//! RoPE positions, softmax attention over an external `[L, H, S, Dh]` KV
//! cache, tanh-GELU MLP, and a tied-embedding logit head. Weights are drawn
//! from a seeded [`Pcg64`] (Box–Muller normals, GPT-style scales), so a
//! `(config, seed)` pair names one reproducible model everywhere.
//!
//! ## Consistency contract (what the unit tests pin down)
//!
//! All four entry points are views of *one* deterministic function of
//! (context tokens, position): a prefill row, a decode step, a rollout step
//! and a tree-pass node with the same context produce **bit-identical**
//! logits, because every path routes through the same layer kernels and
//! assembles its attention keys in the same order (committed cache rows
//! ascending, then in-flight rows ascending, then self). This is the
//! incremental-KV invariant the serving loop relies on, and it is what
//! makes the end-to-end losslessness suite (`tests/e2e_serve.rs`)
//! meaningful: the q recorded by [`Backend::rollout`] is exactly the
//! distribution the draft tokens were sampled from, and the p produced by
//! [`Backend::tree_verify`] is exactly the target conditional.
//!
//! Out-of-vocabulary token ids (e.g. the byte-tokenizer `PAD` = 258 against
//! a truncated test vocabulary) wrap modulo the vocab instead of panicking —
//! padding lanes of a bucketed tree pass are computed and discarded.

use std::marker::PhantomData;

use anyhow::{bail, Result};

use super::backend::Backend;
use super::kernels::{attend, ln, matvec, rope, ForwardKernels, ScalarKernels};
use super::{DecodeOut, FamilyMeta, ModelDims, PrefillOut, Role, RolloutOut, TreeOut};
use crate::dist::SamplingConfig;
use crate::kvcache::KvRef;
use crate::util::Pcg64;

/// Architecture + scale of one CPU reference model pair.
#[derive(Clone, Debug)]
pub struct CpuModelConfig {
    /// Transformer blocks per model.
    pub n_layers: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension (must be even for RoPE).
    pub d_head: usize,
    /// Vocabulary size. Prompt bytes must stay below it; out-of-range ids
    /// wrap modulo the vocab (see the module docs).
    pub vocab: usize,
    /// Maximum sequence length (KV-cache rows per head).
    pub max_seq: usize,
    /// Prompt prefill capacity ([`FamilyMeta::s_pre`]).
    pub s_pre: usize,
    /// MLP expansion factor (d_mlp = ratio · d_model).
    pub mlp_ratio: usize,
    /// Multiplier on the tied-embedding logits. Random-weight logits are
    /// nearly flat; this sharpens them to LM-like entropy so temperature /
    /// top-p sweeps and acceptance dynamics are non-trivial.
    pub logit_scale: f32,
}

impl CpuModelConfig {
    /// Test-scale preset: 1 layer, d = 16, vocab 64 (prompts must use bytes
    /// `< 64`, e.g. digits/punctuation). Fast enough for debug-mode
    /// Monte-Carlo suites.
    pub fn tiny() -> CpuModelConfig {
        CpuModelConfig {
            n_layers: 1,
            d_model: 16,
            n_heads: 2,
            d_head: 8,
            vocab: 64,
            max_seq: 96,
            s_pre: 24,
            mlp_ratio: 2,
            logit_scale: 30.0,
        }
    }

    /// Demo/bench preset: 2 layers, d = 32, the full byte-tokenizer vocab
    /// (so arbitrary text prompts and EOS/PAD emission work).
    pub fn small() -> CpuModelConfig {
        CpuModelConfig {
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            d_head: 16,
            vocab: crate::tokenizer::VOCAB,
            max_seq: 320,
            s_pre: 48,
            mlp_ratio: 2,
            logit_scale: 30.0,
        }
    }

    fn dims(&self) -> ModelDims {
        ModelDims {
            n_layers: self.n_layers,
            d_model: self.d_model,
            n_heads: self.n_heads,
            d_head: self.d_head,
            vocab: self.vocab,
            max_seq: self.max_seq,
        }
    }
}

// ---------------------------------------------------------------------------
// Model weights + kernels
// ---------------------------------------------------------------------------

/// One pre-LN transformer block (layouts match `python/compile/model.py`).
struct Layer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    /// `[d_model, n_heads·d_head]`, row-major (x @ w).
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    /// `[n_heads·d_head, d_model]`.
    wo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    /// `[d_model, d_mlp]`.
    w1: Vec<f32>,
    b1: Vec<f32>,
    /// `[d_mlp, d_model]`.
    w2: Vec<f32>,
    b2: Vec<f32>,
}

struct CpuModel {
    dims: ModelDims,
    d_mlp: usize,
    logit_scale: f32,
    /// `[vocab, d_model]`; also the (tied) output head.
    tok_emb: Vec<f32>,
    layers: Vec<Layer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
}

/// Standard normal via Box–Muller on the seeded stream.
fn normal(rng: &mut Pcg64) -> f32 {
    let u1 = rng.next_f64().max(1e-12);
    let u2 = rng.next_f64();
    (((-2.0 * u1.ln()).sqrt()) * (std::f64::consts::TAU * u2).cos()) as f32
}

fn norm_vec(rng: &mut Pcg64, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| normal(rng) * scale).collect()
}

/// Gathered attention keys/values: one `[H·Dh]` row per visible position,
/// in the canonical order (cache rows ascending, in-flight rows, self).
#[derive(Default)]
struct KeyBuf {
    k: Vec<f32>,
    v: Vec<f32>,
    n: usize,
}

impl KeyBuf {
    fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.n = 0;
    }

    fn push_row(&mut self, k: &[f32], v: &[f32]) {
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
        self.n += 1;
    }

    /// Gather cache position `s` of `layer` through the KV view — offset
    /// arithmetic for contiguous lanes, a block-table lookup for paged
    /// lanes; either way the heads arrive in ascending order, so the
    /// assembled key order (and therefore the forward pass) is
    /// bit-identical across storages.
    fn push_cache_row(&mut self, kv: KvRef<'_>, n_heads: usize, layer: usize, s: usize) {
        for hh in 0..n_heads {
            let (k, v) = kv.row(layer, hh, s);
            self.k.extend_from_slice(k);
            self.v.extend_from_slice(v);
        }
        self.n += 1;
    }
}

/// Inverse-CDF draw from a normalized probability slice with a supplied
/// uniform — the same cumulative-scan semantics as [`crate::dist::Dist::sample`]
/// (skip zero entries, fall back to the last positive-mass index).
fn sample_probs(probs: &[f32], u: f64) -> usize {
    let mut acc = 0.0f64;
    let mut last = 0usize;
    for (i, &w) in probs.iter().enumerate() {
        if w > 0.0 {
            last = i;
            acc += w as f64;
            if u < acc {
                return i;
            }
        }
    }
    last
}

/// Output of one single-token forward pass.
struct StepOut {
    logits: Vec<f32>,
    hidden: Vec<f32>,
    /// `[L, H·Dh]` (RoPE applied).
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
}

/// Output of one batched forward pass over `n` tokens.
struct BatchOut {
    /// `[N, V]`.
    logits: Vec<f32>,
    /// `[N, d]`.
    hidden: Vec<f32>,
    /// `[L, N, H·Dh]` (RoPE applied).
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
}

impl CpuModel {
    fn init(cfg: &CpuModelConfig, rng: &mut Pcg64) -> CpuModel {
        assert!(cfg.d_head % 2 == 0, "d_head must be even for RoPE");
        let d = cfg.d_model;
        let da = cfg.n_heads * cfg.d_head;
        let m = cfg.mlp_ratio * d;
        let out_scale = 0.02 / (2.0 * cfg.n_layers as f32).sqrt();
        let layers: Vec<Layer> = (0..cfg.n_layers)
            .map(|_| Layer {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                wq: norm_vec(rng, d * da, 0.02),
                wk: norm_vec(rng, d * da, 0.02),
                wv: norm_vec(rng, d * da, 0.02),
                wo: norm_vec(rng, da * d, out_scale),
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                w1: norm_vec(rng, d * m, 0.02),
                b1: vec![0.0; m],
                w2: norm_vec(rng, m * d, out_scale),
                b2: vec![0.0; d],
            })
            .collect();
        CpuModel {
            dims: cfg.dims(),
            d_mlp: m,
            logit_scale: cfg.logit_scale,
            tok_emb: norm_vec(rng, cfg.vocab * d, 0.02),
            layers,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
        }
    }

    /// Embedding row for a token id, wrapping out-of-range ids.
    fn embed_row(&self, token: i64) -> &[f32] {
        let d = self.dims.d_model;
        let t = token.rem_euclid(self.dims.vocab as i64) as usize;
        &self.tok_emb[t * d..(t + 1) * d]
    }

    /// Tied-embedding logits of a final-LN hidden state, into `out` (`[V]`).
    fn logits_into<K: ForwardKernels>(&self, hidden: &[f32], out: &mut [f32]) {
        let d = self.dims.d_model;
        for (t, o) in out.iter_mut().enumerate() {
            let row = &self.tok_emb[t * d..(t + 1) * d];
            *o = K::dot(hidden, row) * self.logit_scale;
        }
    }

    /// One token at `pos`: attends committed cache rows `< cache_limit`
    /// (read through the KV view), then `n_own` in-flight path rows (per
    /// layer, `[r·H·Dh..]` slices of `own_k`/`own_v`), then itself.
    #[allow(clippy::too_many_arguments)]
    fn step<K: ForwardKernels>(
        &self,
        kv: KvRef<'_>,
        cache_limit: usize,
        own_k: &[Vec<f32>],
        own_v: &[Vec<f32>],
        n_own: usize,
        token: u32,
        pos: usize,
    ) -> StepOut {
        let d = self.dims.d_model;
        let da = self.dims.n_heads * self.dims.d_head;
        let mut x = self.embed_row(token as i64).to_vec();
        let mut yv = vec![0.0f32; d];
        let mut att = vec![0.0f32; da];
        let mut proj = vec![0.0f32; d];
        let mut h1 = vec![0.0f32; self.d_mlp];
        let mut keys = KeyBuf::default();
        let mut scores: Vec<f32> = Vec::new();
        let mut k_rows = Vec::with_capacity(self.dims.n_layers * da);
        let mut v_rows = Vec::with_capacity(self.dims.n_layers * da);
        for (l, layer) in self.layers.iter().enumerate() {
            ln::<K>(&x, &layer.ln1_g, &layer.ln1_b, &mut yv);
            let mut q = vec![0.0f32; da];
            let mut k = vec![0.0f32; da];
            let mut v = vec![0.0f32; da];
            matvec(&yv, &layer.wq, da, &mut q);
            matvec(&yv, &layer.wk, da, &mut k);
            matvec(&yv, &layer.wv, da, &mut v);
            rope(&mut q, self.dims.n_heads, self.dims.d_head, pos);
            rope(&mut k, self.dims.n_heads, self.dims.d_head, pos);
            keys.clear();
            for s in 0..cache_limit {
                keys.push_cache_row(kv, self.dims.n_heads, l, s);
            }
            for r in 0..n_own {
                keys.push_row(&own_k[l][r * da..(r + 1) * da], &own_v[l][r * da..(r + 1) * da]);
            }
            keys.push_row(&k, &v);
            attend::<K>(
                &q,
                &keys.k,
                &keys.v,
                keys.n,
                self.dims.n_heads,
                self.dims.d_head,
                &mut scores,
                &mut att,
            );
            matvec(&att, &layer.wo, d, &mut proj);
            for (xv, &pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }
            ln::<K>(&x, &layer.ln2_g, &layer.ln2_b, &mut yv);
            matvec(&yv, &layer.w1, self.d_mlp, &mut h1);
            K::gelu_bias(&mut h1, &layer.b1);
            matvec(&h1, &layer.w2, d, &mut proj);
            for ((xv, &pv), &bv) in x.iter_mut().zip(&proj).zip(&layer.b2) {
                *xv += pv + bv;
            }
            k_rows.extend_from_slice(&k);
            v_rows.extend_from_slice(&v);
        }
        let mut hidden = vec![0.0f32; d];
        ln::<K>(&x, &self.lnf_g, &self.lnf_b, &mut hidden);
        let mut logits = vec![0.0f32; self.dims.vocab];
        self.logits_into::<K>(&hidden, &mut logits);
        StepOut { logits, hidden, k_rows, v_rows }
    }

    /// Batched forward over `tokens` at `positions`: each row attends cache
    /// rows `< limit` (when a cache is given) plus every batch row `j` with
    /// `allowed(i, j)` (ascending; `allowed(i, i)` covers self-attention).
    fn batch<K: ForwardKernels>(
        &self,
        cache: Option<(KvRef<'_>, usize)>,
        tokens: &[i32],
        positions: &[i32],
        allowed: &dyn Fn(usize, usize) -> bool,
    ) -> BatchOut {
        let n = tokens.len();
        let d = self.dims.d_model;
        let da = self.dims.n_heads * self.dims.d_head;
        let mut xs: Vec<f32> = Vec::with_capacity(n * d);
        for &t in tokens {
            xs.extend_from_slice(self.embed_row(t as i64));
        }
        let mut k_rows = vec![0.0f32; self.dims.n_layers * n * da];
        let mut v_rows = vec![0.0f32; self.dims.n_layers * n * da];
        let mut qs = vec![0.0f32; n * da];
        let mut yv = vec![0.0f32; d];
        let mut att = vec![0.0f32; da];
        let mut proj = vec![0.0f32; d];
        let mut h1 = vec![0.0f32; self.d_mlp];
        let mut keys = KeyBuf::default();
        let mut scores: Vec<f32> = Vec::new();
        for (l, layer) in self.layers.iter().enumerate() {
            // every row's q/k/v first: attention reads the whole batch's
            // pre-update keys
            for i in 0..n {
                ln::<K>(&xs[i * d..(i + 1) * d], &layer.ln1_g, &layer.ln1_b, &mut yv);
                let pos = positions[i].max(0) as usize;
                let qrow = &mut qs[i * da..(i + 1) * da];
                matvec(&yv, &layer.wq, da, qrow);
                rope(qrow, self.dims.n_heads, self.dims.d_head, pos);
                let base = (l * n + i) * da;
                matvec(&yv, &layer.wk, da, &mut k_rows[base..base + da]);
                rope(&mut k_rows[base..base + da], self.dims.n_heads, self.dims.d_head, pos);
                matvec(&yv, &layer.wv, da, &mut v_rows[base..base + da]);
            }
            for i in 0..n {
                keys.clear();
                if let Some((kv, limit)) = cache {
                    for s in 0..limit {
                        keys.push_cache_row(kv, self.dims.n_heads, l, s);
                    }
                }
                for j in 0..n {
                    if allowed(i, j) {
                        let base = (l * n + j) * da;
                        keys.push_row(&k_rows[base..base + da], &v_rows[base..base + da]);
                    }
                }
                attend::<K>(
                    &qs[i * da..(i + 1) * da],
                    &keys.k,
                    &keys.v,
                    keys.n,
                    self.dims.n_heads,
                    self.dims.d_head,
                    &mut scores,
                    &mut att,
                );
                matvec(&att, &layer.wo, d, &mut proj);
                let x = &mut xs[i * d..(i + 1) * d];
                for (xv, &pv) in x.iter_mut().zip(&proj) {
                    *xv += pv;
                }
                ln::<K>(x, &layer.ln2_g, &layer.ln2_b, &mut yv);
                matvec(&yv, &layer.w1, self.d_mlp, &mut h1);
                K::gelu_bias(&mut h1, &layer.b1);
                matvec(&h1, &layer.w2, d, &mut proj);
                for ((xv, &pv), &bv) in x.iter_mut().zip(&proj).zip(&layer.b2) {
                    *xv += pv + bv;
                }
            }
        }
        let v = self.dims.vocab;
        let mut hidden = vec![0.0f32; n * d];
        let mut logits = vec![0.0f32; n * v];
        for i in 0..n {
            ln::<K>(&xs[i * d..(i + 1) * d], &self.lnf_g, &self.lnf_b, &mut hidden[i * d..(i + 1) * d]);
            self.logits_into::<K>(&hidden[i * d..(i + 1) * d], &mut logits[i * v..(i + 1) * v]);
        }
        BatchOut { logits, hidden, k_rows, v_rows }
    }
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// Always-built CPU backend core: one seeded target/draft model pair
/// behind the [`Backend`] trait, generic over the
/// [`ForwardKernels`] set its forward passes reduce with. The two
/// instantiations — [`CpuRefBackend`] (scalar, the bit-exact oracle) and
/// [`CpuSimdBackend`](super::CpuSimdBackend) (f32x8 lanes, ≤ 1e-5
/// relative tolerance against the oracle) — share *everything* else:
/// identical seeded weights, identical key-gather order, identical shape
/// handling.
///
/// ```
/// use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, Role};
///
/// let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 0);
/// let out = backend.prefill(Role::Target, &[7, 3, 11], 3).unwrap();
/// assert_eq!(out.logits.len(), backend.dims(Role::Target).vocab);
/// ```
pub struct CpuBackendCore<K: ForwardKernels> {
    meta: FamilyMeta,
    target: CpuModel,
    draft: CpuModel,
    _kernels: PhantomData<fn() -> K>,
}

/// The scalar CPU reference backend — the bit-exact oracle every other
/// execution path (paged reads, SIMD lanes, PJRT) is scored against.
pub type CpuRefBackend = CpuBackendCore<ScalarKernels>;

impl<K: ForwardKernels> CpuBackendCore<K> {
    /// Build a target/draft pair from one config: same dimensions,
    /// different seeded weights (streams derived from `seed`), so p ≠ q
    /// with comparable entropy. The weight streams do not depend on `K`
    /// — every kernel set sees bit-identical weights for a given
    /// `(config, seed)` pair.
    pub fn new(cfg: &CpuModelConfig, seed: u64) -> CpuBackendCore<K> {
        let dims = cfg.dims();
        CpuBackendCore {
            meta: FamilyMeta {
                family: K::NAME.to_string(),
                target: dims,
                draft: dims,
                s_pre: cfg.s_pre,
                tree_sizes: vec![4, 8, 16, 32, 48],
                // large enough for selector superset sampling (≤ ~300 nodes)
                tree_big: 384,
                trunk_lens: vec![1, 2, 3, 4, 6, 8],
                branch_ks: vec![2, 3, 4],
                branch_lens: vec![1, 2, 4, 8],
            },
            target: CpuModel::init(cfg, &mut Pcg64::new(seed, 0x7a67)),
            draft: CpuModel::init(cfg, &mut Pcg64::new(seed, 0xd4a7)),
        }
    }

    fn model(&self, role: Role) -> &CpuModel {
        match role {
            Role::Target => &self.target,
            Role::Draft => &self.draft,
        }
    }

    fn check_cache(&self, role: Role, kv: KvRef<'_>) -> Result<()> {
        let want = self.model(role).dims.kv_elems();
        if let Err((klen, vlen)) = kv.check_elems(want) {
            bail!("{}: cache size {klen}/{vlen} != expected {want}", K::NAME);
        }
        Ok(())
    }
}

impl<K: ForwardKernels> Backend for CpuBackendCore<K> {
    fn meta(&self) -> &FamilyMeta {
        &self.meta
    }

    fn name(&self) -> &'static str {
        K::NAME
    }

    fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> Result<PrefillOut> {
        let m = self.model(role);
        let s_pre = self.meta.s_pre;
        if tokens.len() > s_pre || length == 0 || length > tokens.len() {
            bail!("prefill: bad token count {} (s_pre {s_pre})", tokens.len());
        }
        let positions: Vec<i32> = (0..length as i32).collect();
        let out = m.batch::<K>(None, &tokens[..length], &positions, &|i, j| j <= i);
        let dims = m.dims;
        let (h, dh) = (dims.n_heads, dims.d_head);
        let da = h * dh;
        let mut k_rows = vec![0.0f32; dims.n_layers * h * s_pre * dh];
        let mut v_rows = vec![0.0f32; dims.n_layers * h * s_pre * dh];
        for l in 0..dims.n_layers {
            for s in 0..length {
                let src = (l * length + s) * da;
                for hh in 0..h {
                    let dst = ((l * h + hh) * s_pre + s) * dh;
                    k_rows[dst..dst + dh]
                        .copy_from_slice(&out.k_rows[src + hh * dh..src + (hh + 1) * dh]);
                    v_rows[dst..dst + dh]
                        .copy_from_slice(&out.v_rows[src + hh * dh..src + (hh + 1) * dh]);
                }
            }
        }
        let last = length - 1;
        let (v, d) = (dims.vocab, dims.d_model);
        Ok(PrefillOut {
            logits: out.logits[last * v..(last + 1) * v].to_vec(),
            hidden: out.hidden[last * d..(last + 1) * d].to_vec(),
            k_rows,
            v_rows,
        })
    }

    fn prefill_chunk(
        &self,
        role: Role,
        kv: KvRef<'_>,
        tokens: &[i32],
        start: usize,
        len: usize,
    ) -> Result<PrefillOut> {
        self.check_cache(role, kv)?;
        let m = self.model(role);
        if len == 0 || start + len > tokens.len() {
            bail!("prefill_chunk: bad rows {start}..{} of {} tokens", start + len, tokens.len());
        }
        if start + len > m.dims.max_seq {
            bail!("prefill_chunk: rows {start}..{} exceed max_seq {}", start + len, m.dims.max_seq);
        }
        // one batched causal pass over just the chunk, attending committed
        // cache rows < start — the KeyBuf order (cache rows ascending, then
        // batch rows, then self) matches the one-shot prefill summation
        // order exactly, so the chunk rows are bitwise identical to theirs
        let positions: Vec<i32> = (start as i32..(start + len) as i32).collect();
        let out =
            m.batch::<K>(Some((kv, start)), &tokens[start..start + len], &positions, &|i, j| j <= i);
        let dims = m.dims;
        let (h, dh) = (dims.n_heads, dims.d_head);
        let da = h * dh;
        let mut k_rows = vec![0.0f32; dims.n_layers * h * len * dh];
        let mut v_rows = vec![0.0f32; dims.n_layers * h * len * dh];
        for l in 0..dims.n_layers {
            for s in 0..len {
                let src = (l * len + s) * da;
                for hh in 0..h {
                    let dst = ((l * h + hh) * len + s) * dh;
                    k_rows[dst..dst + dh]
                        .copy_from_slice(&out.k_rows[src + hh * dh..src + (hh + 1) * dh]);
                    v_rows[dst..dst + dh]
                        .copy_from_slice(&out.v_rows[src + hh * dh..src + (hh + 1) * dh]);
                }
            }
        }
        let last = len - 1;
        let (v, d) = (dims.vocab, dims.d_model);
        Ok(PrefillOut {
            logits: out.logits[last * v..(last + 1) * v].to_vec(),
            hidden: out.hidden[last * d..(last + 1) * d].to_vec(),
            k_rows,
            v_rows,
        })
    }

    fn decode(&self, role: Role, kv: KvRef<'_>, token: u32, pos: usize) -> Result<DecodeOut> {
        self.check_cache(role, kv)?;
        let m = self.model(role);
        if pos >= m.dims.max_seq {
            bail!("decode: position {pos} exceeds max_seq {}", m.dims.max_seq);
        }
        let no_rows: Vec<Vec<f32>> = vec![Vec::new(); m.dims.n_layers];
        let out = m.step::<K>(kv, pos, &no_rows, &no_rows, 0, token, pos);
        Ok(DecodeOut {
            logits: out.logits,
            hidden: out.hidden,
            k_row: out.k_rows,
            v_row: out.v_rows,
        })
    }

    fn rollout(
        &self,
        k: usize,
        l: usize,
        kv: KvRef<'_>,
        token: u32,
        pos: usize,
        uniforms: &[f32],
        temperature: f32,
        top_p: f32,
    ) -> Result<RolloutOut> {
        if uniforms.len() != k * l {
            bail!("rollout: expected {} uniforms", k * l);
        }
        if k == 0 || l == 0 {
            bail!("rollout: k and l must be positive");
        }
        self.check_cache(Role::Draft, kv)?;
        let m = &self.draft;
        if pos + l > m.dims.max_seq {
            bail!("rollout: positions {pos}..{} exceed max_seq", pos + l);
        }
        let dims = m.dims;
        let (vcb, d) = (dims.vocab, dims.d_model);
        let da = dims.n_heads * dims.d_head;
        let cfg = SamplingConfig::new(temperature, top_p);
        let mut tokens_out = vec![0i32; k * l];
        let mut dists = vec![0.0f32; k * l * vcb];
        let mut hiddens = vec![0.0f32; k * l * d];
        let mut k_rows = vec![0.0f32; dims.n_layers * k * l * da];
        let mut v_rows = vec![0.0f32; dims.n_layers * k * l * da];
        let mut idx_scratch: Vec<u32> = Vec::new();
        for b in 0..k {
            let mut own_k: Vec<Vec<f32>> =
                (0..dims.n_layers).map(|_| Vec::with_capacity(l * da)).collect();
            let mut own_v: Vec<Vec<f32>> =
                (0..dims.n_layers).map(|_| Vec::with_capacity(l * da)).collect();
            let mut cur = token;
            for j in 0..l {
                let out = m.step::<K>(kv, pos, &own_k, &own_v, j, cur, pos + j);
                for lyr in 0..dims.n_layers {
                    let src = lyr * da;
                    let dst = ((lyr * k + b) * l + j) * da;
                    k_rows[dst..dst + da].copy_from_slice(&out.k_rows[src..src + da]);
                    v_rows[dst..dst + da].copy_from_slice(&out.v_rows[src..src + da]);
                    own_k[lyr].extend_from_slice(&out.k_rows[src..src + da]);
                    own_v[lyr].extend_from_slice(&out.v_rows[src..src + da]);
                }
                let slot = b * l + j;
                hiddens[slot * d..(slot + 1) * d].copy_from_slice(&out.hidden);
                let probs = &mut dists[slot * vcb..(slot + 1) * vcb];
                probs.copy_from_slice(&out.logits);
                let _ = cfg.transform_logits(probs, &mut idx_scratch);
                let t = sample_probs(probs, uniforms[slot] as f64);
                tokens_out[slot] = t as i32;
                cur = t as u32;
            }
        }
        Ok(RolloutOut { k, l, tokens: tokens_out, dists, hiddens, k_rows, v_rows })
    }

    fn tree_verify(
        &self,
        n_bucket: usize,
        kv: KvRef<'_>,
        tokens: &[i32],
        positions: &[i32],
        bias: &[f32],
        cache_len: usize,
    ) -> Result<TreeOut> {
        if tokens.len() != n_bucket
            || positions.len() != n_bucket
            || bias.len() != n_bucket * n_bucket
        {
            bail!("tree_verify: shape mismatch for bucket {n_bucket}");
        }
        self.check_cache(Role::Target, kv)?;
        let m = &self.target;
        if cache_len > m.dims.max_seq {
            bail!("tree_verify: cache_len {cache_len} exceeds max_seq");
        }
        let out = m.batch::<K>(Some((kv, cache_len)), tokens, positions, &|i, j| {
            bias[i * n_bucket + j] > -1e29
        });
        Ok(TreeOut {
            n: n_bucket,
            logits: out.logits,
            hidden: out.hidden,
            k_rows: out.k_rows,
            v_rows: out.v_rows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvCache;
    use crate::tree::{DraftTree, Provenance};

    #[test]
    fn prefill_decode_consistency() {
        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 1);
        let toks = [5i32, 9, 3, 7];
        let full = be.prefill(Role::Target, &toks, 4).unwrap();
        let pre = be.prefill(Role::Target, &toks[..3], 3).unwrap();
        let mut cache = KvCache::new(be.dims(Role::Target));
        cache.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 3);
        let dec = be.decode(Role::Target, cache.view(), 7, 3).unwrap();
        assert_eq!(full.logits, dec.logits, "prefill row vs incremental decode");
        assert_eq!(full.hidden, dec.hidden);
        // the decode's fresh KV row equals the full prefill's row at pos 3
        let dims = be.dims(Role::Target);
        for l in 0..dims.n_layers {
            for hh in 0..dims.n_heads {
                let src = ((l * dims.n_heads + hh) * cfg.s_pre + 3) * dims.d_head;
                let dst = (l * dims.n_heads + hh) * dims.d_head;
                assert_eq!(
                    &full.k_rows[src..src + dims.d_head],
                    &dec.k_row[dst..dst + dims.d_head],
                );
            }
        }
    }

    /// Chunked prefill must reproduce the one-shot prefill bitwise — same
    /// last-row logits/hidden and same committed KV rows — for every chunk
    /// schedule, for both roles and both storages.
    #[test]
    fn chunked_prefill_matches_one_shot() {
        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 3);
        let toks = [5i32, 9, 3, 7, 1, 12, 4, 6, 2, 10, 8];
        let n = toks.len();
        for role in [Role::Target, Role::Draft] {
            let dims = be.dims(role);
            let full = be.prefill(role, &toks, n).unwrap();
            let mut oracle = KvCache::new(dims);
            oracle.commit_prefill(&full.k_rows, &full.v_rows, cfg.s_pre, n);
            for chunk in [1usize, 3, 4, 11, 64] {
                for paged in [false, true] {
                    let pool = crate::kvcache::BlockPool::new(dims, 4, None);
                    let mut cache =
                        if paged { KvCache::paged(&pool) } else { KvCache::new(dims) };
                    let mut start = 0usize;
                    let mut last = None;
                    while start < n {
                        let take = chunk.min(n - start);
                        let out = be.prefill_chunk(role, cache.view(), &toks, start, take).unwrap();
                        cache.commit_chunk(&out.k_rows, &out.v_rows, take, start, take);
                        start += take;
                        last = Some(out);
                    }
                    let last = last.unwrap();
                    assert_eq!(last.logits, full.logits, "chunk={chunk} paged={paged}");
                    assert_eq!(last.hidden, full.hidden, "chunk={chunk} paged={paged}");
                    assert_eq!(cache.len(), n);
                    for l in 0..dims.n_layers {
                        for hh in 0..dims.n_heads {
                            for pos in 0..n {
                                assert_eq!(
                                    cache.read_row(l, hh, pos),
                                    oracle.read_row(l, hh, pos),
                                    "chunk={chunk} paged={paged} l={l} h={hh} pos={pos}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// RoPE absolute-position invariant under preemption resume
    /// (release-and-rebuild): a lane preempted at a *non-block-aligned*
    /// offset loses its blocks and later replays its whole context through
    /// `prefill_chunk` with a chunk schedule unrelated to the original
    /// one. Every rebuilt row must be RoPE'd at its absolute sequence
    /// position — bitwise equal to the one-shot prefill — and the next
    /// decode must continue the stream as if the preemption never
    /// happened. An off-by-one in the `pos` passed through a resumed
    /// `prefill_chunk` (e.g. restarting relative positions at the resume
    /// offset) shifts every rotary angle and fails this bitwise.
    #[test]
    fn rope_positions_survive_release_and_rebuild_resume() {
        use crate::kvcache::BlockPool;

        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 9);
        let toks = [5i32, 9, 3, 7, 1, 12, 4, 6, 2, 10, 8];
        let n = toks.len();
        for role in [Role::Target, Role::Draft] {
            let dims = be.dims(role);
            let full = be.prefill(role, &toks, n).unwrap();
            let mut oracle = KvCache::new(dims);
            oracle.commit_prefill(&full.k_rows, &full.v_rows, cfg.s_pre, n);

            // original lane progresses in chunks over paged storage with
            // block size 4, reaching row 8 before preemption
            let pool = BlockPool::new(dims, 4, None);
            let mut lane = KvCache::paged(&pool);
            for (start, len) in [(0usize, 5usize), (5, 3)] {
                let out = be.prefill_chunk(role, lane.view(), &toks, start, len).unwrap();
                lane.commit_chunk(&out.k_rows, &out.v_rows, len, start, len);
            }
            // preempt: release every block, then rebuild with a different
            // schedule whose resume offsets (3, 7) are not block-aligned
            drop(lane);
            let mut rebuilt = KvCache::paged(&pool);
            let mut last = None;
            for (start, len) in [(0usize, 3usize), (3, 4), (7, 4)] {
                let out = be.prefill_chunk(role, rebuilt.view(), &toks, start, len).unwrap();
                rebuilt.commit_chunk(&out.k_rows, &out.v_rows, len, start, len);
                last = Some(out);
            }
            let last = last.unwrap();
            assert_eq!(last.logits, full.logits, "{role:?}: resumed logits diverge");
            assert_eq!(last.hidden, full.hidden, "{role:?}: resumed hidden diverges");
            for l in 0..dims.n_layers {
                for hh in 0..dims.n_heads {
                    for pos in 0..n {
                        assert_eq!(
                            rebuilt.read_row(l, hh, pos),
                            oracle.read_row(l, hh, pos),
                            "{role:?}: rebuilt row l={l} h={hh} pos={pos} not bitwise equal"
                        );
                    }
                }
            }
            // the stream continues exactly where it would have
            let d_oracle = be.decode(role, oracle.view(), 13, n).unwrap();
            let d_rebuilt = be.decode(role, rebuilt.view(), 13, n).unwrap();
            assert_eq!(d_oracle.logits, d_rebuilt.logits, "{role:?}: post-resume decode diverges");
        }
    }

    /// The provided (decode-based) `prefill_chunk` implementation must
    /// agree bitwise with the CPU backend's native batched override — the
    /// guarantee any non-overriding backend relies on.
    #[test]
    fn default_prefill_chunk_impl_matches_native() {
        /// Forwards everything except `prefill_chunk`, which it inherits
        /// from the trait's provided implementation.
        struct NoOverride<'a>(&'a CpuRefBackend);
        impl Backend for NoOverride<'_> {
            fn meta(&self) -> &FamilyMeta {
                self.0.meta()
            }
            fn name(&self) -> &'static str {
                "no-override"
            }
            fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> Result<PrefillOut> {
                self.0.prefill(role, tokens, length)
            }
            fn decode(&self, role: Role, kv: KvRef<'_>, token: u32, pos: usize) -> Result<DecodeOut> {
                self.0.decode(role, kv, token, pos)
            }
            #[allow(clippy::too_many_arguments)]
            fn rollout(
                &self,
                k: usize,
                l: usize,
                kv: KvRef<'_>,
                token: u32,
                pos: usize,
                uniforms: &[f32],
                temperature: f32,
                top_p: f32,
            ) -> Result<RolloutOut> {
                self.0.rollout(k, l, kv, token, pos, uniforms, temperature, top_p)
            }
            #[allow(clippy::too_many_arguments)]
            fn tree_verify(
                &self,
                n_bucket: usize,
                kv: KvRef<'_>,
                tokens: &[i32],
                positions: &[i32],
                bias: &[f32],
                cache_len: usize,
            ) -> Result<TreeOut> {
                self.0.tree_verify(n_bucket, kv, tokens, positions, bias, cache_len)
            }
        }
        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 4);
        let wrap = NoOverride(&be);
        let toks = [2i32, 7, 5, 1, 9, 3, 8];
        let dims = be.dims(Role::Target);
        let mut native_cache = KvCache::new(dims);
        let mut default_cache = KvCache::new(dims);
        for (start, len) in [(0usize, 3usize), (3, 2), (5, 2)] {
            let a = be.prefill_chunk(Role::Target, native_cache.view(), &toks, start, len).unwrap();
            let b = wrap.prefill_chunk(Role::Target, default_cache.view(), &toks, start, len).unwrap();
            assert_eq!(a.logits, b.logits, "start={start}");
            assert_eq!(a.hidden, b.hidden, "start={start}");
            assert_eq!(a.k_rows, b.k_rows, "start={start}");
            assert_eq!(a.v_rows, b.v_rows, "start={start}");
            native_cache.commit_chunk(&a.k_rows, &a.v_rows, len, start, len);
            default_cache.commit_chunk(&b.k_rows, &b.v_rows, len, start, len);
        }
    }

    #[test]
    fn rollout_matches_decode_chain() {
        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 2);
        let toks = [4i32, 8, 15];
        let pre = be.prefill(Role::Draft, &toks, 3).unwrap();
        let mut cache = KvCache::new(be.dims(Role::Draft));
        cache.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 3);
        let v = be.dims(Role::Draft).vocab;
        let d = be.dims(Role::Draft).d_model;
        let sampling = SamplingConfig::new(0.8, 0.9);
        let uni = [0.37f32, 0.81];
        let ro = be.rollout(1, 2, cache.view(), 15, 2, &uni, 0.8, 0.9).unwrap();
        // step 0 == a plain decode of the root token
        let dec0 = be.decode(Role::Draft, cache.view(), 15, 2).unwrap();
        let mut idx = Vec::new();
        let mut probs0 = dec0.logits.clone();
        let _ = sampling.transform_logits(&mut probs0, &mut idx);
        assert_eq!(&ro.dists[..v], &probs0[..], "rollout step-0 dist");
        let t0 = sample_probs(&probs0, uni[0] as f64);
        assert_eq!(ro.tokens[0], t0 as i32);
        // commit step 0's KV row; a plain decode then reproduces step 1
        let mut c2 = cache.clone();
        c2.commit_rollout_rows(&ro.k_rows, &ro.v_rows, 1, 2, 0, 0, 2);
        let dec1 = be.decode(Role::Draft, c2.view(), t0 as u32, 3).unwrap();
        assert_eq!(&ro.hiddens[d..2 * d], &dec1.hidden[..]);
        let mut probs1 = dec1.logits.clone();
        let _ = sampling.transform_logits(&mut probs1, &mut idx);
        assert_eq!(&ro.dists[v..2 * v], &probs1[..], "rollout step-1 dist");
        // two branches share the step-0 context → identical step-0 dists
        let uni4 = [0.1f32, 0.6, 0.9, 0.2];
        let rb = be.rollout(2, 2, cache.view(), 15, 2, &uni4, 0.8, 0.9).unwrap();
        assert_eq!(&rb.dists[..v], &rb.dists[2 * v..3 * v]);
    }

    #[test]
    fn tree_verify_matches_decode_chain() {
        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 3);
        let toks = [6i32, 2, 11, 30];
        let len = 4;
        let pre = be.prefill(Role::Target, &toks, len).unwrap();
        let mut cache = KvCache::new(be.dims(Role::Target));
        cache.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, len);
        let root_pos = len - 1; // the root's row is recomputed by the pass
        let mut tree = DraftTree::new(30);
        let a = tree.add_child(0, 12, Provenance::Trunk { step: 1 });
        let b = tree.add_child(a, 44, Provenance::Trunk { step: 2 });
        let nb = 4;
        let (tt, tp) = tree.tokens_positions(nb, root_pos, 63);
        let bias = tree.attention_bias(nb);
        let out = be.tree_verify(nb, cache.view(), &tt, &tp, &bias, root_pos).unwrap();
        let v = be.dims(Role::Target).vocab;
        // node 0 == a plain decode of the root token
        let dec0 = be.decode(Role::Target, cache.view(), 30, root_pos).unwrap();
        assert_eq!(&out.logits[..v], &dec0.logits[..], "tree root vs decode");
        // deeper chain nodes == sequential decodes over committed rows
        let mut c2 = cache.clone();
        c2.commit_tree_row(&out.k_rows, &out.v_rows, nb, 0, root_pos);
        let dec1 = be.decode(Role::Target, c2.view(), 12, root_pos + 1).unwrap();
        assert_eq!(&out.logits[a * v..(a + 1) * v], &dec1.logits[..]);
        c2.commit_tree_row(&out.k_rows, &out.v_rows, nb, a, root_pos + 1);
        let dec2 = be.decode(Role::Target, c2.view(), 44, root_pos + 2).unwrap();
        assert_eq!(&out.logits[b * v..(b + 1) * v], &dec2.logits[..]);
    }

    #[test]
    fn seeded_determinism_and_distinct_models() {
        let cfg = CpuModelConfig::tiny();
        let b1 = CpuRefBackend::new(&cfg, 5);
        let b2 = CpuRefBackend::new(&cfg, 5);
        let b3 = CpuRefBackend::new(&cfg, 6);
        let toks = [1i32, 2, 3];
        let p1 = b1.prefill(Role::Target, &toks, 3).unwrap();
        let p2 = b2.prefill(Role::Target, &toks, 3).unwrap();
        let p3 = b3.prefill(Role::Target, &toks, 3).unwrap();
        assert_eq!(p1.logits, p2.logits, "same seed must be bit-identical");
        assert_ne!(p1.logits, p3.logits, "different seeds must differ");
        let pd = b1.prefill(Role::Draft, &toks, 3).unwrap();
        assert_ne!(p1.logits, pd.logits, "target and draft must differ");
        // logit_scale gives LM-like sharpness: not a uniform distribution
        let d = crate::dist::Dist::from_logits(&p1.logits, SamplingConfig::new(1.0, 1.0));
        let max = d.0.iter().cloned().fold(0.0f32, f32::max);
        assert!(max > 2.0 / cfg.vocab as f32, "logits too flat: max prob {max}");
    }

    #[test]
    fn out_of_vocab_tokens_wrap() {
        // PAD (258) exceeds the tiny vocab: bucketed padding lanes must
        // compute (and be discarded), not panic
        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 4);
        let out = be.prefill(Role::Target, &[258i32, 5], 2).unwrap();
        assert_eq!(out.logits.len(), cfg.vocab);
    }

    /// The backend must read paged lanes bit-identically to contiguous
    /// ones: same committed rows → same gathered keys → same logits, KV
    /// rows and hidden states, for decode, rollout and the tree pass.
    #[test]
    fn paged_cache_reads_bit_identical_to_contiguous() {
        use crate::kvcache::BlockPool;

        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 6);
        let toks = [5i32, 9, 3, 7];
        for role in [Role::Target, Role::Draft] {
            let pre = be.prefill(role, &toks, 4).unwrap();
            let mut cont = KvCache::new(be.dims(role));
            cont.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 4);
            // block size 3 cuts the 4-row prefix across two blocks
            let pool = BlockPool::new(be.dims(role), 3, None);
            let mut paged = KvCache::paged(&pool);
            paged.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 4);

            let dc = be.decode(role, cont.view(), 7, 4).unwrap();
            let dp = be.decode(role, paged.view(), 7, 4).unwrap();
            assert_eq!(dc.logits, dp.logits, "decode logits diverge");
            assert_eq!(dc.hidden, dp.hidden);
            assert_eq!(dc.k_row, dp.k_row);
            assert_eq!(dc.v_row, dp.v_row);
        }
        // draft rollout + target tree pass over the same two lanes
        let pre = be.prefill(Role::Draft, &toks, 4).unwrap();
        let mut cont = KvCache::new(be.dims(Role::Draft));
        cont.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 4);
        let pool = BlockPool::new(be.dims(Role::Draft), 3, None);
        let mut paged = KvCache::paged(&pool);
        paged.commit_prefill(&pre.k_rows, &pre.v_rows, cfg.s_pre, 4);
        let uni = [0.3f32, 0.7, 0.1, 0.9];
        let rc = be.rollout(2, 2, cont.view(), 7, 4, &uni, 0.8, 0.9).unwrap();
        let rp = be.rollout(2, 2, paged.view(), 7, 4, &uni, 0.8, 0.9).unwrap();
        assert_eq!(rc.tokens, rp.tokens, "rollout tokens diverge");
        assert_eq!(rc.dists, rp.dists);
        assert_eq!(rc.k_rows, rp.k_rows);

        let pre_t = be.prefill(Role::Target, &toks, 4).unwrap();
        let mut cont_t = KvCache::new(be.dims(Role::Target));
        cont_t.commit_prefill(&pre_t.k_rows, &pre_t.v_rows, cfg.s_pre, 4);
        let pool_t = BlockPool::new(be.dims(Role::Target), 3, None);
        let mut paged_t = KvCache::paged(&pool_t);
        paged_t.commit_prefill(&pre_t.k_rows, &pre_t.v_rows, cfg.s_pre, 4);
        let mut tree = DraftTree::new(7);
        let a = tree.add_child(0, 12, Provenance::Trunk { step: 1 });
        let _b = tree.add_child(a, 44, Provenance::Trunk { step: 2 });
        let nb = 4;
        let (tt, tp) = tree.tokens_positions(nb, 3, 63);
        let bias = tree.attention_bias(nb);
        let tc = be.tree_verify(nb, cont_t.view(), &tt, &tp, &bias, 3).unwrap();
        let tpg = be.tree_verify(nb, paged_t.view(), &tt, &tp, &bias, 3).unwrap();
        assert_eq!(tc.logits, tpg.logits, "tree-pass logits diverge");
        assert_eq!(tc.k_rows, tpg.k_rows);
    }

    #[test]
    fn shape_validation() {
        let cfg = CpuModelConfig::tiny();
        let be = CpuRefBackend::new(&cfg, 0);
        let too_long = vec![0i32; cfg.s_pre + 1];
        assert!(be.prefill(Role::Target, &too_long, cfg.s_pre + 1).is_err());
        let empty = crate::kvcache::KvRef::contiguous(be.dims(Role::Draft), &[], &[]);
        assert!(be.rollout(2, 2, empty, 0, 0, &[0.5; 3], 1.0, 1.0).is_err());
        let empty_t = crate::kvcache::KvRef::contiguous(be.dims(Role::Target), &[], &[]);
        assert!(be.decode(Role::Target, empty_t, 0, 0).is_err());
    }
}
