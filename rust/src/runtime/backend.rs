//! The model-invocation seam between the serving stack and a concrete
//! execution engine.
//!
//! Everything downstream of verification — [`crate::coordinator::SpecEngine`],
//! the batched [`crate::coordinator::ServeLoop`], [`crate::draft::draft_delayed`],
//! the CLI and the examples — drives models exclusively through this trait,
//! so the whole serving stack builds and runs in the hermetic default
//! configuration. Two implementations exist:
//!
//! * [`super::CpuRefBackend`] — a deterministic pure-rust reference
//!   transformer (always built). This is what tier-1 tests, the examples
//!   and `benches/serve_loop.rs` exercise end-to-end.
//! * `runtime::Engine` (behind the `pjrt` feature) — the AOT/PJRT engine
//!   executing compiled HLO.
//!
//! The method surface is exactly the compiled-module interface of the AOT
//! artifacts (see `python/compile/model.py`): KV caches are caller-owned
//! host lanes passed as a read-only [`KvRef`] view — either flat
//! `[L, H, S, Dh]` buffers or a paged block table
//! ([`crate::kvcache::PagedKvCache`]); the CPU backend gathers attention
//! rows directly through the view (block tables included), while the PJRT
//! engine materialises paged lanes into contiguous scratch before upload.
//! Every call is pure (new KV rows come back as outputs and are committed
//! by the caller via [`crate::kvcache::KvCache`]), and all randomness is
//! injected by the caller (rollouts sample from caller-supplied uniforms),
//! so any backend is exactly reproducible given a seed.

use anyhow::Result;

use super::{DecodeOut, FamilyMeta, ModelDims, PrefillOut, Role, RolloutOut, TreeOut};
use crate::kvcache::{ContiguousKv, KvRef};

/// A model-execution backend for one target/draft family.
///
/// `Send + Sync` is part of the contract: one backend instance is shared by
/// every worker of a data-parallel sweep and every lane of the batched
/// serving loop. Implementations must be pure functions of their inputs
/// (plus immutable model state) — the determinism guarantees of the
/// harness ([`crate::util::threadpool::par_map_init`]) rely on it.
pub trait Backend: Send + Sync {
    /// Family metadata: model dimensions and compiled shape buckets.
    fn meta(&self) -> &FamilyMeta;

    /// Short backend name for logs and bench reports (e.g. `"cpu-ref"`).
    fn name(&self) -> &'static str;

    /// Dimensions of one model of the pair.
    fn dims(&self, role: Role) -> ModelDims {
        match role {
            Role::Target => self.meta().target,
            Role::Draft => self.meta().draft,
        }
    }

    /// Prompt prefill: run `tokens[..length]` through the model and return
    /// the last valid token's logits/hidden plus KV rows for every prompt
    /// position (layout `[L, H, s_pre, Dh]`, rows past `length` undefined).
    fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> Result<PrefillOut>;

    /// One *chunk* of an incremental prefill: run `tokens[start..start+len]`
    /// with each chunk row attending the committed cache rows `< start`
    /// (read through `kv`), the earlier rows of this chunk, and itself —
    /// exactly the causal mask the one-shot [`Backend::prefill`] applies.
    /// Returns the last chunk row's logits/hidden plus KV rows laid out
    /// `[L, H, len, Dh]` (the step stride is `len`, **not** `s_pre`); the
    /// caller commits them at positions `start..start+len` via
    /// [`crate::kvcache::KvCache::commit_chunk`].
    ///
    /// Under the backend consistency contract (a prefill row, a decode
    /// step, and a tree-pass node are bitwise identical given the same
    /// context) chunked prefill reproduces the one-shot prefill rows,
    /// logits and hidden state bit-for-bit for any chunk schedule — pinned
    /// by `chunked_prefill_matches_one_shot` in the CPU backend tests.
    ///
    /// Unlike `prefill`, `start + len` is bounded by `max_seq` rather than
    /// `s_pre`: preemption recovery replays *generated* context through
    /// this entry point, not just the prompt.
    ///
    /// The provided implementation re-materialises the prefix into a
    /// private contiguous lane and decodes the chunk one row at a time —
    /// correct for any conforming backend but O(context) per chunk;
    /// backends with a batched prefill path should override it.
    fn prefill_chunk(
        &self,
        role: Role,
        kv: KvRef<'_>,
        tokens: &[i32],
        start: usize,
        len: usize,
    ) -> Result<PrefillOut> {
        let dims = self.dims(role);
        anyhow::ensure!(len >= 1, "prefill_chunk: empty chunk");
        anyhow::ensure!(
            start + len <= tokens.len(),
            "prefill_chunk: rows {start}..{} past the {} prompt tokens",
            start + len,
            tokens.len()
        );
        anyhow::ensure!(
            start + len <= dims.max_seq,
            "prefill_chunk: rows {start}..{} past max_seq {}",
            start + len,
            dims.max_seq
        );
        let (lyr, h, dh) = (dims.n_layers, dims.n_heads, dims.d_head);
        let mut tmp = ContiguousKv::new(dims);
        let mut k_row = vec![0.0f32; lyr * h * dh];
        let mut v_row = vec![0.0f32; lyr * h * dh];
        for pos in 0..start {
            for l in 0..lyr {
                for hh in 0..h {
                    let (ks, vs) = kv.row(l, hh, pos);
                    let off = (l * h + hh) * dh;
                    k_row[off..off + dh].copy_from_slice(ks);
                    v_row[off..off + dh].copy_from_slice(vs);
                }
            }
            tmp.commit_row(&k_row, &v_row, pos);
        }
        let mut out = PrefillOut {
            logits: Vec::new(),
            hidden: Vec::new(),
            k_rows: vec![0.0; lyr * h * len * dh],
            v_rows: vec![0.0; lyr * h * len * dh],
        };
        for i in 0..len {
            let pos = start + i;
            let tok = tokens[pos];
            anyhow::ensure!(tok >= 0, "prefill_chunk: negative token id {tok} at {pos}");
            let view = KvRef::contiguous(dims, &tmp.k, &tmp.v);
            let step = self.decode(role, view, tok as u32, pos)?;
            for l in 0..lyr {
                for hh in 0..h {
                    let src = (l * h + hh) * dh;
                    let dst = ((l * h + hh) * len + i) * dh;
                    out.k_rows[dst..dst + dh].copy_from_slice(&step.k_row[src..src + dh]);
                    out.v_rows[dst..dst + dh].copy_from_slice(&step.v_row[src..src + dh]);
                }
            }
            tmp.commit_row(&step.k_row, &step.v_row, pos);
            if i + 1 == len {
                out.logits = step.logits;
                out.hidden = step.hidden;
            }
        }
        Ok(out)
    }

    /// One autoregressive step: `token` at position `pos`, attending to
    /// committed cache rows `< pos` plus itself.
    fn decode(&self, role: Role, kv: KvRef<'_>, token: u32, pos: usize) -> Result<DecodeOut>;

    /// Fused draft rollout (draft model only): `k` i.i.d. continuation
    /// paths of `l` steps from `token` at `pos`. Sampling (temperature +
    /// nucleus) happens inside, driven by the `k·l` caller-supplied
    /// `uniforms`, so the caller retains full control of randomness; the
    /// transformed per-step distributions come back in
    /// [`RolloutOut::dists`] and are exactly what the tokens were sampled
    /// from (the q-side losslessness requirement).
    #[allow(clippy::too_many_arguments)]
    fn rollout(
        &self,
        k: usize,
        l: usize,
        kv: KvRef<'_>,
        token: u32,
        pos: usize,
        uniforms: &[f32],
        temperature: f32,
        top_p: f32,
    ) -> Result<RolloutOut>;

    /// Target tree-verification pass over `n_bucket` nodes: one batched
    /// forward with tree attention — node `i` attends committed cache rows
    /// `< cache_len` plus every node `j` with `bias[i·n + j] == 0`
    /// (ancestor-or-self).
    #[allow(clippy::too_many_arguments)]
    fn tree_verify(
        &self,
        n_bucket: usize,
        kv: KvRef<'_>,
        tokens: &[i32],
        positions: &[i32],
        bias: &[f32],
        cache_len: usize,
    ) -> Result<TreeOut>;
}
