//! Reader for the flat binary tensor container written by
//! python/compile/weights_io.py (magic "SPDW", version 1). Tensors appear in
//! the exact order the HLO entry points expect their parameter buffers.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One named tensor from the weights container.
pub struct Tensor {
    /// Parameter name (e.g. `l0.wq`).
    pub name: String,
    /// Shape (empty for scalars).
    pub dims: Vec<usize>,
    /// Row-major f32 payload.
    pub data: Vec<f32>,
}

const MAGIC: u32 = 0x5350_4457;

/// Read every tensor of a weights file, in stored (HLO argument) order.
pub fn read_weights(path: &Path) -> Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening weights {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    let mut off = 0usize;
    let u32_at = |b: &[u8], o: usize| -> Result<u32> {
        Ok(u32::from_le_bytes(
            b.get(o..o + 4).context("truncated weights")?.try_into()?,
        ))
    };
    let magic = u32_at(&buf, 0)?;
    if magic != MAGIC {
        bail!("bad weights magic {magic:#x}");
    }
    let version = u32_at(&buf, 4)?;
    if version != 1 {
        bail!("unsupported weights version {version}");
    }
    let count = u32_at(&buf, 8)? as usize;
    off += 12;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = u32_at(&buf, off)? as usize;
        off += 4;
        let name = String::from_utf8(
            buf.get(off..off + name_len).context("truncated name")?.to_vec(),
        )?;
        off += name_len;
        let ndim = u32_at(&buf, off)? as usize;
        off += 4;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(u32_at(&buf, off)? as usize);
            off += 4;
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        let bytes = buf.get(off..off + 4 * n).context("truncated data")?;
        off += 4 * n;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push(Tensor { name, dims, data });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&MAGIC.to_le_bytes()).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // tensor "ab": dims [2,2], data 1..4
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(b"ab").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        // scalar tensor "s"
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"s").unwrap();
        f.write_all(&0u32.to_le_bytes()).unwrap();
        f.write_all(&7.5f32.to_le_bytes()).unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("specdelay_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_fixture(&path);
        let t = read_weights(&path).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].name, "ab");
        assert_eq!(t[0].dims, vec![2, 2]);
        assert_eq!(t[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t[1].name, "s");
        assert!(t[1].dims.is_empty());
        assert_eq!(t[1].data, vec![7.5]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("specdelay_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 16]).unwrap();
        assert!(read_weights(&path).is_err());
    }
}
