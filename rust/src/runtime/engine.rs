//! PJRT execution engine (behind the `pjrt` feature): loads the AOT
//! artifacts (HLO text + weights) and executes them on the CPU client.
//! This is the only module in the crate that touches the `xla` crate.
//!
//! Weights are uploaded to device buffers once per model and reused via
//! `execute_b`; per-call inputs (KV caches, tokens, uniforms) are uploaded
//! per call. Executables are compiled lazily on first use and cached.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::weights::read_weights;
use super::{DecodeOut, FamilyMeta, ModelDims, PrefillOut, Role, RolloutOut, TreeOut};

impl Role {
    fn prefix(self) -> &'static str {
        match self {
            Role::Target => "target",
            Role::Draft => "draft",
        }
    }
}

/// A loaded model family: PJRT client, weight buffers, lazy executables.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Parsed family metadata (dims, compiled shape buckets).
    pub meta: FamilyMeta,
    target_weights: Vec<xla::PjRtBuffer>,
    draft_weights: Vec<xla::PjRtBuffer>,
    /// Lazily compiled executables. A `Mutex` (not `RefCell`) so one
    /// `Engine` can be shared across the data-parallel bench workers; the
    /// lock is held across a cold-start compile (so racing workers don't
    /// duplicate it) but never across a dispatch.
    execs: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Load a family from `artifacts/<family>`.
    pub fn load(family_dir: &Path) -> Result<Engine> {
        let meta = FamilyMeta::load(family_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        let upload = |file: &str| -> Result<Vec<xla::PjRtBuffer>> {
            let tensors = read_weights(&family_dir.join(file))?;
            tensors
                .iter()
                .map(|t| {
                    client
                        .buffer_from_host_buffer(&t.data, &t.dims, None)
                        .map_err(|e| anyhow!("upload {}: {e:?}", t.name))
                })
                .collect()
        };
        let target_weights = upload("target.bin")?;
        let draft_weights = upload("draft.bin")?;
        Ok(Engine {
            client,
            dir: family_dir.to_path_buf(),
            meta,
            target_weights,
            draft_weights,
            execs: Mutex::new(HashMap::new()),
        })
    }

    /// Dimensions of one model of the pair.
    pub fn dims(&self, role: Role) -> ModelDims {
        match role {
            Role::Target => self.meta.target,
            Role::Draft => self.meta.draft,
        }
    }

    fn weights(&self, role: Role) -> &[xla::PjRtBuffer] {
        match role {
            Role::Target => &self.target_weights,
            Role::Draft => &self.draft_weights,
        }
    }

    /// Compile (or fetch) an executable by entry name. The cache lock is
    /// held across the compile so concurrent workers hitting the same cold
    /// entry wait for one compilation instead of each redoing it; warm
    /// calls only take the lock for a map lookup.
    fn exec_for(&self, name: &str) -> Result<()> {
        let mut execs = self.execs.lock().unwrap();
        if execs.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join("hlo").join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        execs.insert(name.to_string(), Arc::new(exe));
        Ok(())
    }

    /// Run an entry: weights ++ extra args (uploaded here), untuple outputs.
    fn run(&self, role: Role, name: &str, args: Vec<ArgSpec>) -> Result<Vec<xla::Literal>> {
        self.exec_for(name)?;
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            let b = match a {
                ArgSpec::F32(data, dims) => self
                    .client
                    .buffer_from_host_buffer(data, &dims, None)
                    .map_err(|e| anyhow!("arg upload: {e:?}"))?,
                ArgSpec::I32(data, dims) => self
                    .client
                    .buffer_from_host_buffer(data, &dims, None)
                    .map_err(|e| anyhow!("arg upload: {e:?}"))?,
                ArgSpec::ScalarI32(v) => self
                    .client
                    .buffer_from_host_buffer(&[v], &[], None)
                    .map_err(|e| anyhow!("scalar upload: {e:?}"))?,
                ArgSpec::ScalarF32(v) => self
                    .client
                    .buffer_from_host_buffer(&[v], &[], None)
                    .map_err(|e| anyhow!("scalar upload: {e:?}"))?,
            };
            bufs.push(b);
        }
        let exe = Arc::clone(self.execs.lock().unwrap().get(name).expect("compiled above"));
        let mut all: Vec<&xla::PjRtBuffer> = self.weights(role).iter().collect();
        all.extend(bufs.iter());
        let out = exe
            .execute_b(&all)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Prompt prefill over the compiled entry (pads to s_pre internally).
    pub fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> Result<PrefillOut> {
        let s_pre = self.meta.s_pre;
        if tokens.len() > s_pre || length == 0 || length > tokens.len() {
            bail!("prefill: bad token count {} (s_pre {s_pre})", tokens.len());
        }
        let mut padded = tokens.to_vec();
        padded.resize(s_pre, crate::tokenizer::PAD as i32);
        let name = format!("{}_prefill", role.prefix());
        let out = self.run(
            role,
            &name,
            vec![
                ArgSpec::I32(&padded, vec![s_pre]),
                ArgSpec::ScalarI32(length as i32),
            ],
        )?;
        let [logits, hidden, k_rows, v_rows] = take4(out)?;
        Ok(PrefillOut {
            logits: to_f32(&logits)?,
            hidden: to_f32(&hidden)?,
            k_rows: to_f32(&k_rows)?,
            v_rows: to_f32(&v_rows)?,
        })
    }

    /// One autoregressive decode step over the compiled entry.
    pub fn decode(
        &self,
        role: Role,
        k_cache: &[f32],
        v_cache: &[f32],
        token: u32,
        pos: usize,
    ) -> Result<DecodeOut> {
        let d = self.dims(role);
        let kv_dims = vec![d.n_layers, d.n_heads, d.max_seq, d.d_head];
        let name = format!("{}_decode", role.prefix());
        let out = self.run(
            role,
            &name,
            vec![
                ArgSpec::F32(k_cache, kv_dims.clone()),
                ArgSpec::F32(v_cache, kv_dims),
                ArgSpec::ScalarI32(token as i32),
                ArgSpec::ScalarI32(pos as i32),
            ],
        )?;
        let [logits, hidden, k_row, v_row] = take4(out)?;
        Ok(DecodeOut {
            logits: to_f32(&logits)?,
            hidden: to_f32(&hidden)?,
            k_row: to_f32(&k_row)?,
            v_row: to_f32(&v_row)?,
        })
    }

    /// Fused draft rollout (draft model only).
    #[allow(clippy::too_many_arguments)]
    pub fn rollout(
        &self,
        k: usize,
        l: usize,
        k_cache: &[f32],
        v_cache: &[f32],
        token: u32,
        pos: usize,
        uniforms: &[f32],
        temperature: f32,
        top_p: f32,
    ) -> Result<RolloutOut> {
        let d = self.meta.draft;
        let kv_dims = vec![d.n_layers, d.n_heads, d.max_seq, d.d_head];
        if uniforms.len() != k * l {
            bail!("rollout: expected {} uniforms", k * l);
        }
        let name = format!("draft_rollout_k{k}_l{l}");
        let out = self.run(
            Role::Draft,
            &name,
            vec![
                ArgSpec::F32(k_cache, kv_dims.clone()),
                ArgSpec::F32(v_cache, kv_dims),
                ArgSpec::ScalarI32(token as i32),
                ArgSpec::ScalarI32(pos as i32),
                ArgSpec::F32(uniforms, vec![k, l]),
                ArgSpec::ScalarF32(temperature),
                ArgSpec::ScalarF32(top_p),
            ],
        )?;
        let [tokens, dists, hiddens, k_rows, v_rows] = take5(out)?;
        Ok(RolloutOut {
            k,
            l,
            tokens: to_i32(&tokens)?,
            dists: to_f32(&dists)?,
            hiddens: to_f32(&hiddens)?,
            k_rows: to_f32(&k_rows)?,
            v_rows: to_f32(&v_rows)?,
        })
    }

    /// Target tree-verification pass over `n_bucket` nodes.
    #[allow(clippy::too_many_arguments)]
    pub fn tree_verify(
        &self,
        n_bucket: usize,
        k_cache: &[f32],
        v_cache: &[f32],
        tokens: &[i32],
        positions: &[i32],
        bias: &[f32],
        cache_len: usize,
    ) -> Result<TreeOut> {
        let d = self.meta.target;
        let kv_dims = vec![d.n_layers, d.n_heads, d.max_seq, d.d_head];
        let name = format!("target_tree_n{n_bucket}");
        let out = self.run(
            Role::Target,
            &name,
            vec![
                ArgSpec::F32(k_cache, kv_dims.clone()),
                ArgSpec::F32(v_cache, kv_dims),
                ArgSpec::I32(tokens, vec![n_bucket]),
                ArgSpec::I32(positions, vec![n_bucket]),
                ArgSpec::F32(bias, vec![n_bucket, n_bucket]),
                ArgSpec::ScalarI32(cache_len as i32),
            ],
        )?;
        let [logits, hidden, k_rows, v_rows] = take4(out)?;
        Ok(TreeOut {
            n: n_bucket,
            logits: to_f32(&logits)?,
            hidden: to_f32(&hidden)?,
            k_rows: to_f32(&k_rows)?,
            v_rows: to_f32(&v_rows)?,
        })
    }
}

/// Borrowed-or-gathered host buffers for one dispatch: contiguous views
/// upload zero-copy, paged lanes are materialised into owned scratch first
/// (compiled modules take flat `[L, H, S, Dh]` operands — the gather cost
/// is the price of the compiled interface, paid per dispatch, and it is
/// why the CPU reference backend reads block tables directly instead).
enum HostKv<'a> {
    Borrowed(&'a [f32], &'a [f32]),
    Gathered(Vec<f32>, Vec<f32>),
}

impl<'a> HostKv<'a> {
    fn resolve(kv: crate::kvcache::KvRef<'a>) -> HostKv<'a> {
        match kv.as_contiguous() {
            Some((k, v)) => HostKv::Borrowed(k, v),
            None => match kv {
                crate::kvcache::KvRef::Paged(p) => {
                    let (k, v) = p.gather();
                    HostKv::Gathered(k, v)
                }
                crate::kvcache::KvRef::Contiguous { .. } => unreachable!(),
            },
        }
    }

    fn slices(&self) -> (&[f32], &[f32]) {
        match self {
            HostKv::Borrowed(k, v) => (k, v),
            HostKv::Gathered(k, v) => (k, v),
        }
    }
}

/// The PJRT engine exposes the same surface through the [`Backend`] seam
/// the serving stack is written against; every method delegates to the
/// inherent (contiguous-slice) implementation above, gathering paged lanes
/// into contiguous scratch first.
impl super::Backend for Engine {
    fn meta(&self) -> &FamilyMeta {
        &self.meta
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> Result<PrefillOut> {
        Engine::prefill(self, role, tokens, length)
    }

    fn decode(
        &self,
        role: Role,
        kv: crate::kvcache::KvRef<'_>,
        token: u32,
        pos: usize,
    ) -> Result<DecodeOut> {
        let host = HostKv::resolve(kv);
        let (k_cache, v_cache) = host.slices();
        Engine::decode(self, role, k_cache, v_cache, token, pos)
    }

    fn rollout(
        &self,
        k: usize,
        l: usize,
        kv: crate::kvcache::KvRef<'_>,
        token: u32,
        pos: usize,
        uniforms: &[f32],
        temperature: f32,
        top_p: f32,
    ) -> Result<RolloutOut> {
        let host = HostKv::resolve(kv);
        let (k_cache, v_cache) = host.slices();
        Engine::rollout(self, k, l, k_cache, v_cache, token, pos, uniforms, temperature, top_p)
    }

    fn tree_verify(
        &self,
        n_bucket: usize,
        kv: crate::kvcache::KvRef<'_>,
        tokens: &[i32],
        positions: &[i32],
        bias: &[f32],
        cache_len: usize,
    ) -> Result<TreeOut> {
        let host = HostKv::resolve(kv);
        let (k_cache, v_cache) = host.slices();
        Engine::tree_verify(self, n_bucket, k_cache, v_cache, tokens, positions, bias, cache_len)
    }
}

enum ArgSpec<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
    ScalarI32(i32),
    ScalarF32(f32),
}

fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

fn to_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    l.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
}

fn take4(mut v: Vec<xla::Literal>) -> Result<[xla::Literal; 4]> {
    if v.len() != 4 {
        bail!("expected 4 outputs, got {}", v.len());
    }
    let d = v.pop().unwrap();
    let c = v.pop().unwrap();
    let b = v.pop().unwrap();
    let a = v.pop().unwrap();
    Ok([a, b, c, d])
}

fn take5(mut v: Vec<xla::Literal>) -> Result<[xla::Literal; 5]> {
    if v.len() != 5 {
        bail!("expected 5 outputs, got {}", v.len());
    }
    let e = v.pop().unwrap();
    let d = v.pop().unwrap();
    let c = v.pop().unwrap();
    let b = v.pop().unwrap();
    let a = v.pop().unwrap();
    Ok([a, b, c, d, e])
}
