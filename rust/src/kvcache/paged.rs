//! Paged KV storage: a block allocator plus copy-on-write block tables.
//!
//! The contiguous [`ContiguousKv`](super::ContiguousKv) lane reserves
//! `max_seq` rows per sequence up front, so the batched serving loop's
//! memory ceiling is `lanes × max_seq` whether or not the rows are ever
//! written, and every trunk→branch handoff in
//! [`draft_delayed`](crate::draft::draft_delayed) physically copies the
//! committed prefix. This module replaces both costs:
//!
//! * [`BlockPool`] — a process-shared allocator of fixed-size *token
//!   blocks* (`block_tokens` rows of `[L, H, Dh]` KV each). Blocks are
//!   reference-counted ([`std::sync::Arc`]), recycled through a free list,
//!   and optionally capped (`max_blocks`) so a serving loop can trade lanes
//!   for a hard block budget with queue-side backpressure.
//! * [`PagedKvCache`] — one sequence's lane as a *block table*: an array of
//!   `ceil(max_seq / block_tokens)` slots, each `None` (reads as zero,
//!   like a freshly zeroed contiguous cache) or a refcounted block. Blocks
//!   are allocated lazily on first write, so resident memory tracks the
//!   tokens a lane actually committed, not `max_seq`.
//!
//! ## Copy-on-write forking
//!
//! [`PagedKvCache::copy_prefix_from`] and `Clone` do **no** row copies:
//! they share the source's blocks by bumping refcounts (O(blocks) of the
//! prefix). The first write to a shared block forks it — one block copy
//! drawn from the free list — and later writes to the now-unique block are
//! in place. The trunk→branch handoff therefore shares the whole committed
//! prefix and pays one boundary-block fork; serving lanes that snapshot a
//! sequence (`Sequence: Clone`) share everything until they diverge.
//!
//! ## Block layout and commit coalescing
//!
//! Inside a block the layout is `[L, H, T, Dh]` with `T = block_tokens` —
//! the contiguous cache's `[L, H, S, Dh]` with the position axis cut into
//! block-sized tiles. The position axis therefore stays adjacent to `Dh`
//! *within a block*, so the rollout-commit span coalescing of the
//! contiguous path (single-head source and destination both
//! step-contiguous → one `copy_from_slice` per (layer, head)) is preserved
//! per block: a commit of `n` steps does at most
//! `ceil(n / block_tokens) + 1` span copies per (layer, head) instead of
//! one, and the per-head stride walk is hoisted identically.
//!
//! ## Determinism contract
//!
//! Paged storage is a *bit-exact* drop-in for the contiguous oracle: reads
//! go through [`PagedKvCache::row`], which returns exactly the bytes the
//! commit ops stored (commits are pure copies on both representations, and
//! unallocated blocks read as zeros exactly like the zero-initialised
//! contiguous buffers). `tests/paged_kv.rs` fuzzes random
//! alloc/fork/write/retire interleavings against a contiguous shadow and
//! asserts bitwise equality after every op, plus the allocator invariants
//! (`created == free + live`, free blocks unreferenced).

use std::sync::{Arc, Mutex, OnceLock};

use crate::runtime::ModelDims;

/// Which KV-cache representation newly created sequences use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvStorage {
    /// Full `[L, H, S, Dh]` buffers per lane (the reference/oracle path).
    Contiguous,
    /// Block tables over a shared [`BlockPool`] with copy-on-write forking.
    Paged,
}

impl KvStorage {
    /// Process-wide default storage: contiguous, unless `SPECDELAY_PAGED_KV`
    /// is set to `1`/`true` (the paged hot path). Read once and cached —
    /// mirrors [`DistStorage::global`](crate::dist::DistStorage::global).
    pub fn global() -> KvStorage {
        static STORAGE: OnceLock<KvStorage> = OnceLock::new();
        *STORAGE.get_or_init(|| {
            KvStorage::from_env_value(std::env::var("SPECDELAY_PAGED_KV").ok().as_deref())
        })
    }

    /// Parse the `SPECDELAY_PAGED_KV` value (`1`/`true` → paged); factored
    /// out so the knob's parsing is unit-testable despite the cached global.
    pub fn from_env_value(value: Option<&str>) -> KvStorage {
        let paged = value
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        if paged {
            KvStorage::Paged
        } else {
            KvStorage::Contiguous
        }
    }
}

/// Default tokens per block: 16, unless `SPECDELAY_KV_BLOCK` overrides it
/// (values < 1 are ignored). Read once and cached.
pub fn default_block_tokens() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("SPECDELAY_KV_BLOCK")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(16)
    })
}

/// One fixed-size KV block: `block_tokens` rows of `[L, H, Dh]` keys and
/// values, laid out `[L, H, T, Dh]`. Uniquely owned while being written;
/// shared (refcount > 1) after a copy-on-write fork.
pub(crate) struct KvBlock {
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
}

impl KvBlock {
    fn zeroed(elems: usize) -> KvBlock {
        KvBlock { k: vec![0.0; elems], v: vec![0.0; elems] }
    }

    fn zero(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
    }

    fn copy_from(&mut self, src: &KvBlock) {
        self.k.copy_from_slice(&src.k);
        self.v.copy_from_slice(&src.v);
    }
}

/// Allocator metadata guarded by the pool mutex. Block *data* is never
/// behind the lock — reads deref shared [`Arc`]s and writes go through
/// uniquely owned blocks — so the lock is held only for free-list pushes,
/// pops and the accounting counters.
struct PoolInner {
    /// Recycled blocks, each uniquely owned by this list.
    free: Vec<Arc<KvBlock>>,
    /// Unique blocks ever created (monotone).
    created: usize,
    /// Unique blocks currently held by caches (`created - free.len()`).
    live: usize,
    /// High-water mark of `live` (bench: peak resident blocks).
    peak_live: usize,
}

/// A shared pool of fixed-size KV blocks for one model's dimensions.
///
/// Every [`PagedKvCache`] lane of a serving loop draws from (and returns
/// to) one pool, so total resident memory is proportional to the *unique*
/// tokens across all lanes — shared prefixes are counted once. With
/// `max_blocks` set, allocation fails once the budget is exhausted; the
/// batched [`ServeLoop`](crate::coordinator::ServeLoop) sizes lane
/// admission against this budget so in-flight lanes never hit the cap
/// (out-of-blocks backpressure queues requests instead).
pub struct BlockPool {
    dims: ModelDims,
    block_tokens: usize,
    block_elems: usize,
    max_blocks: Option<usize>,
    inner: Mutex<PoolInner>,
    /// Read-only zero block backing reads of unallocated table slots.
    zero: KvBlock,
}

impl BlockPool {
    /// A pool of `[L, H, block_tokens, Dh]` blocks for `dims`, optionally
    /// capped at `max_blocks` unique blocks. `block_tokens` is clamped to
    /// at least 1.
    pub fn new(dims: ModelDims, block_tokens: usize, max_blocks: Option<usize>) -> Arc<BlockPool> {
        let bt = block_tokens.max(1);
        let block_elems = dims.n_layers * dims.n_heads * bt * dims.d_head;
        Arc::new(BlockPool {
            dims,
            block_tokens: bt,
            block_elems,
            max_blocks,
            inner: Mutex::new(PoolInner { free: Vec::new(), created: 0, live: 0, peak_live: 0 }),
            zero: KvBlock::zeroed(block_elems),
        })
    }

    /// Model dimensions this pool's blocks are sized for.
    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    /// Tokens per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// The budget, if this pool is capped.
    pub fn max_blocks(&self) -> Option<usize> {
        self.max_blocks
    }

    /// Blocks a full `max_seq`-row lane needs (the worst-case reservation
    /// unit for admission control).
    pub fn blocks_per_seq(&self) -> usize {
        self.dims.max_seq.div_ceil(self.block_tokens)
    }

    /// Unique blocks ever created.
    pub fn created(&self) -> usize {
        self.inner.lock().unwrap().created
    }

    /// Blocks currently in the free list.
    pub fn free_blocks(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    /// Unique blocks currently held by caches.
    pub fn live_blocks(&self) -> usize {
        self.inner.lock().unwrap().live
    }

    /// High-water mark of [`BlockPool::live_blocks`].
    pub fn peak_live_blocks(&self) -> usize {
        self.inner.lock().unwrap().peak_live
    }

    /// Check the allocator invariants: `created == free + live`, and every
    /// free-list block is referenced by nothing but the list itself (no
    /// cache can read or fork a retired block). Returns a description of
    /// the first violation, if any.
    pub fn validate(&self) -> Result<(), String> {
        let inner = self.inner.lock().unwrap();
        if inner.created != inner.free.len() + inner.live {
            return Err(format!(
                "block conservation violated: created {} != free {} + live {}",
                inner.created,
                inner.free.len(),
                inner.live
            ));
        }
        for (i, b) in inner.free.iter().enumerate() {
            let rc = Arc::strong_count(b);
            if rc != 1 {
                return Err(format!("free block {i} still referenced (strong_count {rc})"));
            }
        }
        Ok(())
    }

    /// Pop a recycled block or create a fresh one; `None` when a capped
    /// pool is exhausted. The returned block is zeroed (matching the
    /// zero-initialised contiguous buffers) and uniquely owned.
    pub(crate) fn try_alloc_zeroed(&self) -> Option<Arc<KvBlock>> {
        let mut blk = self.pop_or_create()?;
        Arc::get_mut(&mut blk).expect("pool blocks are uniquely owned at alloc").zero();
        Some(blk)
    }

    /// Like [`BlockPool::try_alloc_zeroed`] but initialised as a copy of
    /// `src` (the copy-on-write fork path — zeroing first would be wasted).
    pub(crate) fn try_alloc_copy(&self, src: &KvBlock) -> Option<Arc<KvBlock>> {
        let mut blk = self.pop_or_create()?;
        Arc::get_mut(&mut blk).expect("pool blocks are uniquely owned at alloc").copy_from(src);
        Some(blk)
    }

    /// Allocation decision + accounting under the lock; block data is
    /// initialised by the callers after the lock is released.
    fn pop_or_create(&self) -> Option<Arc<KvBlock>> {
        let mut inner = self.inner.lock().unwrap();
        let blk = match inner.free.pop() {
            Some(b) => b,
            None => {
                if let Some(max) = self.max_blocks {
                    if inner.created >= max {
                        return None;
                    }
                }
                inner.created += 1;
                Arc::new(KvBlock::zeroed(self.block_elems))
            }
        };
        inner.live += 1;
        inner.peak_live = inner.peak_live.max(inner.live);
        Some(blk)
    }

    /// Panicking wrapper for the cache write path: exhaustion here means
    /// the caller admitted more work than it reserved blocks for.
    pub(crate) fn alloc_zeroed(&self) -> Arc<KvBlock> {
        self.try_alloc_zeroed().unwrap_or_else(|| self.exhausted())
    }

    pub(crate) fn alloc_copy(&self, src: &KvBlock) -> Arc<KvBlock> {
        self.try_alloc_copy(src).unwrap_or_else(|| self.exhausted())
    }

    fn exhausted(&self) -> ! {
        panic!(
            "kv block pool exhausted (budget {:?} blocks of {} tokens): \
             lane admission must reserve worst-case blocks before writing",
            self.max_blocks, self.block_tokens
        )
    }

    /// Return one table reference. If it was the last reference the block
    /// is recycled onto the free list; otherwise the refcount just drops.
    /// The drop happens under the pool lock so two racing releases of the
    /// same block cannot both observe "still shared" and leak it.
    pub(crate) fn release(&self, blk: Arc<KvBlock>) {
        let mut inner = self.inner.lock().unwrap();
        if Arc::strong_count(&blk) == 1 {
            inner.live -= 1;
            inner.free.push(blk);
        } else {
            drop(blk);
        }
    }
}

/// One sequence's KV lane as a copy-on-write block table over a shared
/// [`BlockPool`]. See the module docs for layout and forking semantics.
pub struct PagedKvCache {
    pool: Arc<BlockPool>,
    /// One slot per `block_tokens` positions; `None` reads as zeros.
    table: Vec<Option<Arc<KvBlock>>>,
    /// Committed rows, i.e. where the next row will be written.
    len: usize,
}

impl Clone for PagedKvCache {
    /// Fork the whole lane: shares every block (refcount bumps, no row
    /// copies); the first write to either copy forks the touched block.
    fn clone(&self) -> PagedKvCache {
        PagedKvCache { pool: Arc::clone(&self.pool), table: self.table.clone(), len: self.len }
    }
}

impl Drop for PagedKvCache {
    /// Retiring a lane returns every block reference to its pool, so the
    /// last lane holding a block recycles it onto the free list.
    fn drop(&mut self) {
        for slot in self.table.iter_mut() {
            if let Some(blk) = slot.take() {
                self.pool.release(blk);
            }
        }
    }
}

impl PagedKvCache {
    /// An empty lane over `pool` (no blocks allocated until first write).
    pub fn new(pool: &Arc<BlockPool>) -> PagedKvCache {
        let slots = pool.dims.max_seq.div_ceil(pool.block_tokens);
        PagedKvCache { pool: Arc::clone(pool), table: vec![None; slots], len: 0 }
    }

    /// Model dimensions fixing the logical `[L, H, S, Dh]` layout.
    pub fn dims(&self) -> ModelDims {
        self.pool.dims
    }

    /// The pool this lane draws from.
    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// Number of committed rows.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no rows are committed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocated table slots (resident blocks referenced by this lane).
    pub fn resident_blocks(&self) -> usize {
        self.table.iter().filter(|s| s.is_some()).count()
    }

    /// Resident blocks currently shared with another lane (refcount > 1) —
    /// the copy-on-write savings this lane enjoys.
    pub fn cow_shared_blocks(&self) -> usize {
        self.table.iter().flatten().filter(|b| Arc::strong_count(b) > 1).count()
    }

    #[inline]
    fn block_tokens(&self) -> usize {
        self.pool.block_tokens
    }

    /// Offset of `(layer, head, t)` inside a block's `[L, H, T, Dh]` data.
    #[inline]
    fn block_offset(&self, layer: usize, head: usize, t: usize) -> usize {
        ((layer * self.pool.dims.n_heads + head) * self.pool.block_tokens + t)
            * self.pool.dims.d_head
    }

    /// Read the `d_head` K/V slices at `(layer, head, pos)`. Unallocated
    /// blocks read as zeros, exactly like a zero-initialised contiguous
    /// cache.
    #[inline]
    pub fn row(&self, layer: usize, head: usize, pos: usize) -> (&[f32], &[f32]) {
        let bt = self.block_tokens();
        let blk: &KvBlock = match &self.table[pos / bt] {
            Some(b) => b,
            None => &self.pool.zero,
        };
        let off = self.block_offset(layer, head, pos % bt);
        let dh = self.pool.dims.d_head;
        (&blk.k[off..off + dh], &blk.v[off..off + dh])
    }

    /// Unique write access to block `bi`, allocating on first touch and
    /// forking (one block copy off the free list) when the block is shared.
    fn block_mut(&mut self, bi: usize) -> &mut KvBlock {
        enum Need {
            Ready,
            Alloc,
            Fork,
        }
        let need = match &self.table[bi] {
            None => Need::Alloc,
            Some(b) if Arc::strong_count(b) > 1 => Need::Fork,
            Some(_) => Need::Ready,
        };
        match need {
            Need::Alloc => self.table[bi] = Some(self.pool.alloc_zeroed()),
            Need::Fork => {
                let fresh = self.pool.alloc_copy(self.table[bi].as_deref().unwrap());
                let old = std::mem::replace(&mut self.table[bi], Some(fresh)).unwrap();
                self.pool.release(old);
            }
            Need::Ready => {}
        }
        Arc::get_mut(self.table[bi].as_mut().unwrap())
            .expect("block uniquely owned after copy-on-write")
    }

    /// Clone the first `n_blocks` table entries — the refcount-bump export
    /// the radix prefix cache stores on lane retirement. `None` when any of
    /// those slots is unallocated (a lane that never committed the rows).
    pub(crate) fn block_arcs(&self, n_blocks: usize) -> Option<Vec<Arc<KvBlock>>> {
        if n_blocks > self.table.len() {
            return None;
        }
        self.table[..n_blocks].iter().cloned().collect()
    }

    /// Install `blocks` as this lane's leading table entries and mark
    /// `rows` committed — the adoption half of a prefix-cache hit. Existing
    /// entries in the overwritten slots are released; the adopted blocks
    /// are shared (refcount bumps), so the first divergent write forks them
    /// exactly like any other copy-on-write fork.
    pub(crate) fn adopt_blocks(&mut self, blocks: Vec<Arc<KvBlock>>, rows: usize) {
        assert_eq!(blocks.len(), rows.div_ceil(self.block_tokens()), "run/row mismatch");
        assert!(blocks.len() <= self.table.len(), "adopted run exceeds lane table");
        for (slot, blk) in self.table.iter_mut().zip(blocks) {
            if let Some(old) = slot.replace(blk) {
                self.pool.release(old);
            }
        }
        self.len = self.len.max(rows);
    }

    /// Raw single-(layer, head) row write — the cross-storage fallback path
    /// of [`KvCache::copy_prefix_from`](super::KvCache::copy_prefix_from).
    pub(crate) fn write_row(&mut self, layer: usize, head: usize, pos: usize, k: &[f32], v: &[f32]) {
        let bt = self.block_tokens();
        let off = self.block_offset(layer, head, pos % bt);
        let dh = self.pool.dims.d_head;
        let blk = self.block_mut(pos / bt);
        blk.k[off..off + dh].copy_from_slice(k);
        blk.v[off..off + dh].copy_from_slice(v);
    }

    /// Overwrite the committed-row count (cross-storage fallback path).
    pub(crate) fn set_len(&mut self, len: usize) {
        self.len = len;
    }

    /// Commit prefill rows laid out `[L, H, s_pre, Dh]` for positions
    /// `0..len` — one span copy per (block, layer, head).
    pub fn commit_prefill(&mut self, k_rows: &[f32], v_rows: &[f32], s_pre: usize, len: usize) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_rows.len(), lyr * h * s_pre * dh);
        let bt = self.block_tokens();
        let mut pos = 0usize;
        while pos < len {
            let bi = pos / bt;
            let t = pos % bt;
            let run = (len - pos).min(bt - t);
            let block_off = |l: usize, hh: usize| ((l * h + hh) * bt + t) * dh;
            let blk = self.block_mut(bi);
            for l in 0..lyr {
                for hh in 0..h {
                    let src = ((l * h + hh) * s_pre + pos) * dh;
                    let dst = block_off(l, hh);
                    blk.k[dst..dst + run * dh].copy_from_slice(&k_rows[src..src + run * dh]);
                    blk.v[dst..dst + run * dh].copy_from_slice(&v_rows[src..src + run * dh]);
                }
            }
            pos += run;
        }
        self.len = len;
    }

    /// Commit a prefill chunk laid out `[L, H, stride, Dh]`: the first
    /// `len` source rows land at positions `start..start + len` — the paged
    /// twin of [`ContiguousKv::commit_chunk`](super::ContiguousKv::commit_chunk),
    /// walking whole block runs like `commit_prefill` but offset by `start`
    /// and growing (never resetting) the committed row count.
    pub fn commit_chunk(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        stride: usize,
        start: usize,
        len: usize,
    ) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert!(len <= stride, "chunk rows {len} exceed source stride {stride}");
        assert!(start + len <= self.pool.dims.max_seq, "chunk past max_seq");
        assert_eq!(k_rows.len(), lyr * h * stride * dh);
        let bt = self.block_tokens();
        let mut i = 0usize;
        while i < len {
            let pos = start + i;
            let bi = pos / bt;
            let t = pos % bt;
            let run = (len - i).min(bt - t);
            let block_off = |l: usize, hh: usize| ((l * h + hh) * bt + t) * dh;
            let blk = self.block_mut(bi);
            for l in 0..lyr {
                for hh in 0..h {
                    let src = ((l * h + hh) * stride + i) * dh;
                    let dst = block_off(l, hh);
                    blk.k[dst..dst + run * dh].copy_from_slice(&k_rows[src..src + run * dh]);
                    blk.v[dst..dst + run * dh].copy_from_slice(&v_rows[src..src + run * dh]);
                }
            }
            i += run;
        }
        self.len = self.len.max(start + len);
    }

    /// Commit one row laid out `[L, H, Dh]` at `pos`.
    pub fn commit_row(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_row.len(), lyr * h * dh);
        let bt = self.block_tokens();
        let t = pos % bt;
        let dst_head_stride = bt * dh;
        let blk = self.block_mut(pos / bt);
        for l in 0..lyr {
            let mut src = l * h * dh;
            let mut dst = ((l * h) * bt + t) * dh;
            for _hh in 0..h {
                blk.k[dst..dst + dh].copy_from_slice(&k_row[src..src + dh]);
                blk.v[dst..dst + dh].copy_from_slice(&v_row[src..src + dh]);
                src += dh;
                dst += dst_head_stride;
            }
        }
        self.len = self.len.max(pos + 1);
    }

    /// Commit rollout rows `[Lyr, K, L, H, Dh]`: path `branch`, steps
    /// `0..=last_step`, at positions `base_pos + step` — the paged twin of
    /// [`ContiguousKv::commit_rollout_rows`](super::ContiguousKv::commit_rollout_rows),
    /// with the single-head span coalescing applied per block.
    #[allow(clippy::too_many_arguments)]
    pub fn commit_rollout_rows(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_rows.len(), lyr * k_paths * l_steps * h * dh);
        let steps = last_step + 1;
        let bt = self.block_tokens();
        let src_step_stride = h * dh;
        let mut step = 0usize;
        while step < steps {
            let pos = base_pos + step;
            let bi = pos / bt;
            let t = pos % bt;
            let run = (steps - step).min(bt - t);
            let blk = self.block_mut(bi);
            for l in 0..lyr {
                for hh in 0..h {
                    let src0 = ((((l * k_paths + branch) * l_steps) + step) * h + hh) * dh;
                    let dst0 = ((l * h + hh) * bt + t) * dh;
                    if h == 1 {
                        // src and dst both step-contiguous: one span copy
                        let n = run * dh;
                        blk.k[dst0..dst0 + n].copy_from_slice(&k_rows[src0..src0 + n]);
                        blk.v[dst0..dst0 + n].copy_from_slice(&v_rows[src0..src0 + n]);
                    } else {
                        let (mut src, mut dst) = (src0, dst0);
                        for _s in 0..run {
                            blk.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                            blk.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
                            src += src_step_stride;
                            dst += dh;
                        }
                    }
                }
            }
            step += run;
        }
        self.len = self.len.max(base_pos + steps);
    }

    /// Commit tree-pass rows `[Lyr, N, H, Dh]` for node `node_idx` at `pos`.
    pub fn commit_tree_row(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        n_bucket: usize,
        node_idx: usize,
        pos: usize,
    ) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_rows.len(), lyr * n_bucket * h * dh);
        let bt = self.block_tokens();
        let t = pos % bt;
        let dst_head_stride = bt * dh;
        let blk = self.block_mut(pos / bt);
        for l in 0..lyr {
            let mut src = (l * n_bucket + node_idx) * h * dh;
            let mut dst = ((l * h) * bt + t) * dh;
            for _hh in 0..h {
                blk.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                blk.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
                src += dh;
                dst += dst_head_stride;
            }
        }
        self.len = self.len.max(pos + 1);
    }

    /// Refresh this lane as a prefix fork of `src`: blocks covering rows
    /// `< rows` are *shared* (refcount bumps — no row copies; the first
    /// divergent write forks), blocks past the prefix are released back to
    /// the pool. Rows past the prefix inside the boundary block keep the
    /// source's contents and **must not be read** — the same contract as
    /// the contiguous [`copy_prefix_from`](super::ContiguousKv::copy_prefix_from).
    ///
    /// Lanes on different pools (same dims) fall back to a deep row copy.
    pub fn copy_prefix_from(&mut self, src: &PagedKvCache, rows: usize) {
        debug_assert_eq!(
            self.pool.dims.kv_elems(),
            src.pool.dims.kv_elems(),
            "prefix copy across dims"
        );
        let rows = rows.min(self.pool.dims.max_seq);
        if Arc::ptr_eq(&self.pool, &src.pool) {
            let nb = rows.div_ceil(self.block_tokens());
            for (bi, slot) in self.table.iter_mut().enumerate() {
                let share = if bi < nb { src.table[bi].clone() } else { None };
                let old = std::mem::replace(slot, share);
                if let Some(blk) = old {
                    self.pool.release(blk);
                }
            }
        } else {
            // cross-pool: deep copy row by row (cold path, kept for safety)
            let (lyr, h, dh) =
                (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
            let bt = self.block_tokens();
            for pos in 0..rows {
                let t = pos % bt;
                let bi = pos / bt;
                for l in 0..lyr {
                    for hh in 0..h {
                        let (ks, vs) = src.row(l, hh, pos);
                        let (ks, vs) = (ks.to_vec(), vs.to_vec());
                        let off = ((l * h + hh) * bt + t) * dh;
                        let blk = self.block_mut(bi);
                        blk.k[off..off + dh].copy_from_slice(&ks);
                        blk.v[off..off + dh].copy_from_slice(&vs);
                    }
                }
            }
            for slot in self.table.iter_mut().skip(rows.div_ceil(bt)) {
                if let Some(blk) = slot.take() {
                    self.pool.release(blk);
                }
            }
        }
        self.len = src.len.min(rows);
    }

    /// Forked lane holding only rows `< rows` — O(prefix blocks) refcount
    /// bumps, no row copies.
    pub fn clone_prefix(&self, rows: usize) -> PagedKvCache {
        let mut out = PagedKvCache::new(&self.pool);
        out.copy_prefix_from(self, rows);
        out
    }

    /// Materialise the full `[L, H, S, Dh]` contiguous buffers (zeros where
    /// unallocated) — the gather shim the PJRT engine uses to feed compiled
    /// modules that expect contiguous host caches.
    pub fn gather(&self) -> (Vec<f32>, Vec<f32>) {
        let d = self.pool.dims;
        let (lyr, h, dh, s) = (d.n_layers, d.n_heads, d.d_head, d.max_seq);
        let bt = self.block_tokens();
        let mut k = vec![0.0f32; d.kv_elems()];
        let mut v = vec![0.0f32; d.kv_elems()];
        for (bi, slot) in self.table.iter().enumerate() {
            let Some(blk) = slot else { continue };
            let t0 = bi * bt;
            let run = bt.min(s - t0);
            for l in 0..lyr {
                for hh in 0..h {
                    let src = ((l * h + hh) * bt) * dh;
                    let dst = ((l * h + hh) * s + t0) * dh;
                    k[dst..dst + run * dh].copy_from_slice(&blk.k[src..src + run * dh]);
                    v[dst..dst + run * dh].copy_from_slice(&blk.v[src..src + run * dh]);
                }
            }
        }
        (k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { n_layers: 2, d_model: 8, n_heads: 2, d_head: 4, vocab: 10, max_seq: 16 }
    }

    #[test]
    fn lazy_alloc_and_zero_reads() {
        let pool = BlockPool::new(dims(), 4, None);
        let c = PagedKvCache::new(&pool);
        assert_eq!(pool.created(), 0);
        assert_eq!(c.resident_blocks(), 0);
        let (k, v) = c.row(1, 1, 7);
        assert_eq!(k, &[0.0; 4]);
        assert_eq!(v, &[0.0; 4]);
        pool.validate().unwrap();
    }

    #[test]
    fn commit_row_allocates_one_block() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut c = PagedKvCache::new(&pool);
        let row: Vec<f32> = (0..16).map(|x| x as f32).collect(); // [2,2,4]
        c.commit_row(&row, &row, 5); // block 1
        assert_eq!(c.len(), 6);
        assert_eq!(c.resident_blocks(), 1);
        assert_eq!(pool.live_blocks(), 1);
        // layer 1, head 1 slice = row[12..16]
        let (k, _) = c.row(1, 1, 5);
        assert_eq!(k, &[12.0, 13.0, 14.0, 15.0]);
        // neighbours in the same block read zero
        let (k, _) = c.row(1, 1, 4);
        assert_eq!(k, &[0.0; 4]);
        pool.validate().unwrap();
    }

    #[test]
    fn cow_fork_shares_until_write() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut a = PagedKvCache::new(&pool);
        let row: Vec<f32> = (0..16).map(|x| x as f32 + 1.0).collect();
        for pos in 0..6 {
            a.commit_row(&row, &row, pos);
        }
        assert_eq!(pool.live_blocks(), 2);
        let mut b = a.clone_prefix(6);
        // sharing: no new blocks, both lanes fully resident
        assert_eq!(pool.live_blocks(), 2);
        assert_eq!(b.cow_shared_blocks(), 2);
        assert_eq!(b.len(), 6);
        // first divergent write forks exactly the touched block
        let row2: Vec<f32> = (0..16).map(|x| x as f32 * 2.0).collect();
        b.commit_row(&row2, &row2, 5);
        assert_eq!(pool.live_blocks(), 3);
        assert_eq!(b.cow_shared_blocks(), 1);
        // a unaffected; b sees old rows + the new write
        let (ka, _) = a.row(0, 0, 5);
        assert_eq!(ka, &row[..4]);
        let (kb, _) = b.row(0, 0, 5);
        assert_eq!(kb, &row2[..4]);
        let (kb4, _) = b.row(0, 0, 4);
        assert_eq!(kb4, &row[..4], "fork preserves the rest of the block");
        pool.validate().unwrap();
    }

    #[test]
    fn retire_returns_blocks_to_free_list() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut a = PagedKvCache::new(&pool);
        let row = vec![1.0f32; 16];
        for pos in 0..8 {
            a.commit_row(&row, &row, pos);
        }
        let b = a.clone();
        assert_eq!(pool.live_blocks(), 2);
        drop(a);
        assert_eq!(pool.live_blocks(), 2, "blocks still held by the clone");
        assert_eq!(pool.free_blocks(), 0);
        drop(b);
        assert_eq!(pool.live_blocks(), 0);
        assert_eq!(pool.free_blocks(), 2);
        pool.validate().unwrap();
        // recycled blocks come back zeroed
        let mut c = PagedKvCache::new(&pool);
        c.commit_row(&row, &row, 0);
        assert_eq!(pool.created(), 2, "reuse, not growth");
        let (k, _) = c.row(0, 0, 1);
        assert_eq!(k, &[0.0; 4]);
    }

    #[test]
    fn budget_exhaustion_fails_cleanly() {
        let pool = BlockPool::new(dims(), 4, Some(1));
        assert!(pool.try_alloc_zeroed().is_some());
        assert!(pool.try_alloc_zeroed().is_none(), "budget must cap creation");
        // note: the first block is now live but unreachable by any cache —
        // this is a raw-allocator test, not a cache-lifecycle test
    }

    #[test]
    fn copy_prefix_releases_tail_blocks() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut a = PagedKvCache::new(&pool);
        let row = vec![3.0f32; 16];
        for pos in 0..12 {
            a.commit_row(&row, &row, pos);
        }
        let mut b = a.clone();
        assert_eq!(pool.live_blocks(), 3);
        b.copy_prefix_from(&a, 5); // keeps blocks 0..2 shared, drops block 2's tail ref
        assert_eq!(b.len(), 5);
        assert_eq!(b.resident_blocks(), 2);
        assert_eq!(pool.live_blocks(), 3, "a still holds all three");
        drop(a);
        assert_eq!(pool.live_blocks(), 2);
        assert_eq!(pool.free_blocks(), 1);
        pool.validate().unwrap();
    }

    #[test]
    fn gather_matches_rows() {
        let d = dims();
        let pool = BlockPool::new(d, 3, None); // uneven block size
        let mut c = PagedKvCache::new(&pool);
        let n = d.n_layers * d.n_heads * d.d_head;
        for pos in [0usize, 4, 7] {
            let row: Vec<f32> = (0..n).map(|x| (x + pos * 100) as f32).collect();
            c.commit_row(&row, &row, pos);
        }
        let (k, v) = c.gather();
        assert_eq!(k.len(), d.kv_elems());
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                for pos in 0..d.max_seq {
                    let (rk, rv) = c.row(l, hh, pos);
                    let off = ((l * d.n_heads + hh) * d.max_seq + pos) * d.d_head;
                    assert_eq!(&k[off..off + d.d_head], rk, "l={l} h={hh} p={pos}");
                    assert_eq!(&v[off..off + d.d_head], rv);
                }
            }
        }
    }

    #[test]
    fn storage_knob_parsing() {
        assert_eq!(KvStorage::from_env_value(None), KvStorage::Contiguous);
        assert_eq!(KvStorage::from_env_value(Some("0")), KvStorage::Contiguous);
        assert_eq!(KvStorage::from_env_value(Some("1")), KvStorage::Paged);
        assert_eq!(KvStorage::from_env_value(Some("true")), KvStorage::Paged);
        assert_eq!(KvStorage::from_env_value(Some("TRUE")), KvStorage::Paged);
    }
}
