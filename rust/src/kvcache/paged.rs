//! Paged KV storage: a block allocator plus copy-on-write block tables.
//!
//! The contiguous [`ContiguousKv`](super::ContiguousKv) lane reserves
//! `max_seq` rows per sequence up front, so the batched serving loop's
//! memory ceiling is `lanes × max_seq` whether or not the rows are ever
//! written, and every trunk→branch handoff in
//! [`draft_delayed`](crate::draft::draft_delayed) physically copies the
//! committed prefix. This module replaces both costs:
//!
//! * [`BlockPool`] — a process-shared allocator of fixed-size *token
//!   blocks* (`block_tokens` rows of `[L, H, Dh]` KV each). Blocks are
//!   reference-counted ([`std::sync::Arc`]), recycled through a free list,
//!   and optionally capped (`max_blocks`) so a serving loop can trade lanes
//!   for a hard block budget with queue-side backpressure.
//! * [`PagedKvCache`] — one sequence's lane as a *block table*: an array of
//!   `ceil(max_seq / block_tokens)` slots, each `None` (reads as zero,
//!   like a freshly zeroed contiguous cache) or a refcounted block. Blocks
//!   are allocated lazily on first write, so resident memory tracks the
//!   tokens a lane actually committed, not `max_seq`.
//!
//! ## Copy-on-write forking
//!
//! [`PagedKvCache::copy_prefix_from`] and `Clone` do **no** row copies:
//! they share the source's blocks by bumping refcounts (O(blocks) of the
//! prefix). The first write to a shared block forks it — one block copy
//! drawn from the free list — and later writes to the now-unique block are
//! in place. The trunk→branch handoff therefore shares the whole committed
//! prefix and pays one boundary-block fork; serving lanes that snapshot a
//! sequence (`Sequence: Clone`) share everything until they diverge.
//!
//! ## Block layout and commit coalescing
//!
//! Inside a block the layout is `[L, H, T, Dh]` with `T = block_tokens` —
//! the contiguous cache's `[L, H, S, Dh]` with the position axis cut into
//! block-sized tiles. The position axis therefore stays adjacent to `Dh`
//! *within a block*, so the rollout-commit span coalescing of the
//! contiguous path (single-head source and destination both
//! step-contiguous → one `copy_from_slice` per (layer, head)) is preserved
//! per block: a commit of `n` steps does at most
//! `ceil(n / block_tokens) + 1` span copies per (layer, head) instead of
//! one, and the per-head stride walk is hoisted identically.
//!
//! ## Element precision
//!
//! A pool stores its rows at a selectable [`KvDtype`] (`SPECDELAY_KV_DTYPE`):
//! full f32, IEEE half (round-to-nearest-even per element), or affine int8
//! with per-(block, layer·head, token-row) scale/zero-point. Commits
//! quantize on write and reads return the dequantized values through the
//! unchanged f32 `row()` surface, so every backend (CPU reference, SIMD,
//! PJRT gather) is dtype-transparent. A capped pool's budget is stated in
//! f32-equivalent blocks and scaled by the dtype's byte ratio
//! ([`BlockPool::effective_max_blocks`]): the same byte budget holds 2×
//! the blocks at f16 and 4× at int8.
//!
//! ## Determinism contract
//!
//! At the default [`KvDtype::F32`], paged storage is a *bit-exact* drop-in
//! for the contiguous oracle: reads go through [`PagedKvCache::row`],
//! which returns exactly the bytes the commit ops stored (commits are pure
//! copies on both representations, and unallocated blocks read as zeros
//! exactly like the zero-initialised contiguous buffers).
//! `tests/paged_kv.rs` fuzzes random alloc/fork/write/retire
//! interleavings against a contiguous shadow and asserts bitwise equality
//! after every op, plus the allocator invariants (`created == free +
//! live`, free blocks unreferenced). The lossy dtypes weaken "bytes
//! stored" to "committed bytes through the codec" but keep every
//! *structural* guarantee bit-exact: quantization is content-pure (a row's
//! stored value is a function of that row's committed f32 content alone),
//! so identical per-lane commit sequences still produce identical reads —
//! batched == serial, fork == source, replay == original.

use std::sync::{Arc, Mutex, OnceLock};

use super::quant::{affine_dequantize, affine_params, affine_quantize, f16_round, Affine};
use crate::runtime::ModelDims;

/// Which KV-cache representation newly created sequences use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvStorage {
    /// Full `[L, H, S, Dh]` buffers per lane (the reference/oracle path).
    Contiguous,
    /// Block tables over a shared [`BlockPool`] with copy-on-write forking.
    Paged,
}

impl KvStorage {
    /// Process-wide default storage: contiguous, unless `SPECDELAY_PAGED_KV`
    /// is set to `1`/`true` (the paged hot path). Read once and cached —
    /// mirrors [`DistStorage::global`](crate::dist::DistStorage::global).
    pub fn global() -> KvStorage {
        static STORAGE: OnceLock<KvStorage> = OnceLock::new();
        *STORAGE.get_or_init(|| {
            KvStorage::from_env_value(std::env::var("SPECDELAY_PAGED_KV").ok().as_deref())
        })
    }

    /// Parse the `SPECDELAY_PAGED_KV` value (`1`/`true` → paged); factored
    /// out so the knob's parsing is unit-testable despite the cached global.
    pub fn from_env_value(value: Option<&str>) -> KvStorage {
        let paged = value
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        if paged {
            KvStorage::Paged
        } else {
            KvStorage::Contiguous
        }
    }
}

/// Element precision of a [`BlockPool`]'s stored KV rows.
///
/// The logical row space stays f32 everywhere — commits take f32 rows and
/// [`PagedKvCache::row`] returns f32 slices — but a reduced-precision pool
/// stores each written element through its codec and serves the
/// *dequantized* value back (quantize-on-write, dequantize-on-read, like a
/// device cache holding half/int8 KV). Reads are backed by a per-block f32
/// mirror holding exactly the dequantized codes, so the borrow-based
/// `row()` surface (and every backend gathering through
/// [`KvRef`](super::KvRef), PJRT `gather` included) is unchanged.
///
/// * [`KvDtype::F32`] — lossless; the mirror *is* the storage and every
///   bit-exactness contract of the module docs holds verbatim.
/// * [`KvDtype::F16`] — IEEE 754 binary16 with round-to-nearest-even,
///   per element (see [`super::quant`]). 2 bytes/element on a device.
/// * [`KvDtype::Int8`] — affine 8-bit codes with per-(block, layer·head,
///   token-row) `scale`/`zero_point` over each `d_head` span.
///   1 byte/element (+ 8 bytes of parameters per row span) on a device.
///
/// Both lossy codecs are *content-pure*: a stored row's dequantized value
/// is a function of that row's committed f32 content alone (parameters are
/// per row span, never pooled across rows), so writes never perturb other
/// rows, batched == serial determinism survives, and copy-on-write forks
/// reproduce the source block bit-for-bit. All-zero (never written) rows
/// dequantize to exactly `0.0`, preserving the zero-fill contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvDtype {
    /// Full-precision storage (the default, and the bit-exact oracle).
    F32,
    /// IEEE 754 half precision, round-to-nearest-even per element.
    F16,
    /// Affine int8 with per-row-span scale/zero-point.
    Int8,
}

impl KvDtype {
    /// Process-wide default dtype: [`KvDtype::F32`], unless
    /// `SPECDELAY_KV_DTYPE` selects `f16` or `int8`. Read once and cached —
    /// mirrors [`KvStorage::global`].
    pub fn global() -> KvDtype {
        static DTYPE: OnceLock<KvDtype> = OnceLock::new();
        *DTYPE.get_or_init(|| {
            KvDtype::from_env_value(std::env::var("SPECDELAY_KV_DTYPE").ok().as_deref())
        })
    }

    /// Parse the `SPECDELAY_KV_DTYPE` value (`f16`/`fp16`/`half` → F16,
    /// `int8`/`i8`/`q8` → Int8, anything else → F32); factored out so the
    /// knob's parsing is unit-testable despite the cached global.
    pub fn from_env_value(value: Option<&str>) -> KvDtype {
        match value.map(|v| v.to_ascii_lowercase()).as_deref() {
            Some("f16") | Some("fp16") | Some("half") => KvDtype::F16,
            Some("int8") | Some("i8") | Some("q8") => KvDtype::Int8,
            _ => KvDtype::F32,
        }
    }

    /// How many blocks of this dtype fit in the bytes of one f32 block:
    /// 4, 2 and 1 bytes per element give 1×, 2× and 4×. (Int8's per-row
    /// parameter overhead is 8 bytes per `d_head` span — under 13% at
    /// `d_head = 16` and shrinking with head size; the multiplier states
    /// the element-payload ratio, the convention block-budget accounting
    /// is stated in.) A capped pool's budget is configured in f32-block
    /// units and scaled by this factor — see
    /// [`BlockPool::effective_max_blocks`].
    pub fn capacity_multiplier(self) -> usize {
        match self {
            KvDtype::F32 => 1,
            KvDtype::F16 => 2,
            KvDtype::Int8 => 4,
        }
    }

    /// Stable lowercase name (CLI/stats/bench labels).
    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Int8 => "int8",
        }
    }
}

/// Default tokens per block: 16, unless `SPECDELAY_KV_BLOCK` overrides it
/// (values < 1 are ignored). Read once and cached.
pub fn default_block_tokens() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("SPECDELAY_KV_BLOCK")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(16)
    })
}

/// Affine int8 payload of one quantized block: the codes are the ground
/// truth the f32 mirror is dequantized from, with one [`Affine`] parameter
/// pair per `d_head` row span (see [`KvDtype::Int8`]).
struct Int8State {
    k_q: Vec<u8>,
    v_q: Vec<u8>,
    k_aff: Vec<Affine>,
    v_aff: Vec<Affine>,
}

/// One fixed-size KV block: `block_tokens` rows of `[L, H, Dh]` keys and
/// values, laid out `[L, H, T, Dh]`. Uniquely owned while being written;
/// shared (refcount > 1) after a copy-on-write fork.
///
/// `k`/`v` hold what reads return. For [`KvDtype::F32`] that is exactly
/// the committed bytes; for the lossy dtypes it is the *dequantized
/// mirror* — every element is the round trip of the committed f32 through
/// the pool's codec, updated on write so `row()` can keep returning
/// borrowed f32 slices. (An f16 mirror element is exactly
/// binary16-representable, so its codes are bit-recoverable from the
/// mirror itself; int8 additionally carries its codes and per-span
/// parameters in [`Int8State`].)
pub(crate) struct KvBlock {
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    /// Element precision of the owning pool.
    dtype: KvDtype,
    /// Elements per quantization span (`d_head` — one (layer, head, token)
    /// row), the granularity of int8 parameters.
    span: usize,
    /// Int8 codes + parameters; `None` for f32/f16 blocks.
    int8: Option<Box<Int8State>>,
}

impl KvBlock {
    fn zeroed(elems: usize, span: usize, dtype: KvDtype) -> KvBlock {
        let int8 = match dtype {
            KvDtype::Int8 => Some(Box::new(Int8State {
                k_q: vec![0; elems],
                v_q: vec![0; elems],
                k_aff: vec![Affine::ZERO; elems / span],
                v_aff: vec![Affine::ZERO; elems / span],
            })),
            _ => None,
        };
        KvBlock { k: vec![0.0; elems], v: vec![0.0; elems], dtype, span, int8 }
    }

    fn zero(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
        if let Some(q) = self.int8.as_mut() {
            q.k_q.fill(0);
            q.v_q.fill(0);
            q.k_aff.fill(Affine::ZERO);
            q.v_aff.fill(Affine::ZERO);
        }
    }

    fn copy_from(&mut self, src: &KvBlock) {
        self.k.copy_from_slice(&src.k);
        self.v.copy_from_slice(&src.v);
        if let (Some(dst), Some(sq)) = (self.int8.as_mut(), src.int8.as_ref()) {
            dst.k_q.copy_from_slice(&sq.k_q);
            dst.v_q.copy_from_slice(&sq.v_q);
            dst.k_aff.copy_from_slice(&sq.k_aff);
            dst.v_aff.copy_from_slice(&sq.v_aff);
        }
    }

    /// Store `k`/`v` rows at element offset `off` through the pool's
    /// codec. Every commit path funnels here; the span is always a whole
    /// number of `d_head` rows inside one (layer, head) tile of the
    /// `[L, H, T, Dh]` layout, which is exactly the int8 parameter
    /// granularity.
    pub(crate) fn write(&mut self, off: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), v.len());
        match self.dtype {
            KvDtype::F32 => {
                self.k[off..off + k.len()].copy_from_slice(k);
                self.v[off..off + v.len()].copy_from_slice(v);
            }
            KvDtype::F16 => {
                for (d, &x) in self.k[off..off + k.len()].iter_mut().zip(k) {
                    *d = f16_round(x);
                }
                for (d, &x) in self.v[off..off + v.len()].iter_mut().zip(v) {
                    *d = f16_round(x);
                }
            }
            KvDtype::Int8 => {
                debug_assert!(off % self.span == 0 && k.len() % self.span == 0, "partial span");
                let q = self.int8.as_mut().expect("int8 blocks carry codes");
                let r0 = off / self.span;
                for (r, (ks, vs)) in
                    k.chunks_exact(self.span).zip(v.chunks_exact(self.span)).enumerate()
                {
                    let lo = (r0 + r) * self.span;
                    let ka = affine_params(ks);
                    let va = affine_params(vs);
                    q.k_aff[r0 + r] = ka;
                    q.v_aff[r0 + r] = va;
                    for i in 0..self.span {
                        q.k_q[lo + i] = affine_quantize(ks[i], ka);
                        self.k[lo + i] = affine_dequantize(q.k_q[lo + i], ka);
                        q.v_q[lo + i] = affine_quantize(vs[i], va);
                        self.v[lo + i] = affine_dequantize(q.v_q[lo + i], va);
                    }
                }
            }
        }
    }
}

/// Allocator metadata guarded by the pool mutex. Block *data* is never
/// behind the lock — reads deref shared [`Arc`]s and writes go through
/// uniquely owned blocks — so the lock is held only for free-list pushes,
/// pops and the accounting counters.
struct PoolInner {
    /// Recycled blocks, each uniquely owned by this list.
    free: Vec<Arc<KvBlock>>,
    /// Unique blocks ever created (monotone).
    created: usize,
    /// Unique blocks currently held by caches (`created - free.len()`).
    live: usize,
    /// High-water mark of `live` (bench: peak resident blocks).
    peak_live: usize,
}

/// A shared pool of fixed-size KV blocks for one model's dimensions.
///
/// Every [`PagedKvCache`] lane of a serving loop draws from (and returns
/// to) one pool, so total resident memory is proportional to the *unique*
/// tokens across all lanes — shared prefixes are counted once. With
/// `max_blocks` set, allocation fails once the budget is exhausted; the
/// batched [`ServeLoop`](crate::coordinator::ServeLoop) sizes lane
/// admission against this budget so in-flight lanes never hit the cap
/// (out-of-blocks backpressure queues requests instead).
pub struct BlockPool {
    dims: ModelDims,
    block_tokens: usize,
    block_elems: usize,
    /// Configured budget in *f32-equivalent* block units (bytes-of-f32
    /// accounting); reduced-precision pools admit
    /// [`BlockPool::effective_max_blocks`] actual blocks.
    max_blocks: Option<usize>,
    dtype: KvDtype,
    inner: Mutex<PoolInner>,
    /// Read-only zero block backing reads of unallocated table slots.
    zero: KvBlock,
}

impl BlockPool {
    /// A pool of `[L, H, block_tokens, Dh]` blocks for `dims`, optionally
    /// capped at `max_blocks` f32-equivalent blocks. `block_tokens` is
    /// clamped to at least 1. Element precision follows
    /// [`KvDtype::global`] (env knob `SPECDELAY_KV_DTYPE`); use
    /// [`BlockPool::with_dtype`] to pick one explicitly.
    pub fn new(dims: ModelDims, block_tokens: usize, max_blocks: Option<usize>) -> Arc<BlockPool> {
        BlockPool::with_dtype(dims, block_tokens, max_blocks, KvDtype::global())
    }

    /// [`BlockPool::new`] with an explicit element precision (tests and
    /// benches cover every dtype in one process this way).
    pub fn with_dtype(
        dims: ModelDims,
        block_tokens: usize,
        max_blocks: Option<usize>,
        dtype: KvDtype,
    ) -> Arc<BlockPool> {
        let bt = block_tokens.max(1);
        let block_elems = dims.n_layers * dims.n_heads * bt * dims.d_head;
        Arc::new(BlockPool {
            dims,
            block_tokens: bt,
            block_elems,
            max_blocks,
            dtype,
            inner: Mutex::new(PoolInner { free: Vec::new(), created: 0, live: 0, peak_live: 0 }),
            zero: KvBlock::zeroed(block_elems, dims.d_head, dtype),
        })
    }

    /// Model dimensions this pool's blocks are sized for.
    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    /// Tokens per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Element precision of this pool's blocks.
    pub fn kv_dtype(&self) -> KvDtype {
        self.dtype
    }

    /// The configured budget in f32-equivalent block units, if capped.
    pub fn max_blocks(&self) -> Option<usize> {
        self.max_blocks
    }

    /// Actual blocks a capped pool admits: the f32-equivalent budget
    /// scaled by the dtype's [`KvDtype::capacity_multiplier`] — the same
    /// byte budget holds 2× the blocks at f16 and 4× at int8. This is the
    /// bound [`BlockPool::try_alloc_zeroed`] enforces and the capacity the
    /// serving loop's admission control schedules against.
    pub fn effective_max_blocks(&self) -> Option<usize> {
        self.max_blocks.map(|m| m.saturating_mul(self.dtype.capacity_multiplier()))
    }

    /// Blocks a full `max_seq`-row lane needs (the worst-case reservation
    /// unit for admission control).
    pub fn blocks_per_seq(&self) -> usize {
        self.dims.max_seq.div_ceil(self.block_tokens)
    }

    /// Unique blocks ever created.
    pub fn created(&self) -> usize {
        self.inner.lock().unwrap().created
    }

    /// Blocks currently in the free list.
    pub fn free_blocks(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    /// Unique blocks currently held by caches.
    pub fn live_blocks(&self) -> usize {
        self.inner.lock().unwrap().live
    }

    /// High-water mark of [`BlockPool::live_blocks`].
    pub fn peak_live_blocks(&self) -> usize {
        self.inner.lock().unwrap().peak_live
    }

    /// Check the allocator invariants: `created == free + live`, and every
    /// free-list block is referenced by nothing but the list itself (no
    /// cache can read or fork a retired block). Returns a description of
    /// the first violation, if any.
    pub fn validate(&self) -> Result<(), String> {
        let inner = self.inner.lock().unwrap();
        if inner.created != inner.free.len() + inner.live {
            return Err(format!(
                "block conservation violated: created {} != free {} + live {}",
                inner.created,
                inner.free.len(),
                inner.live
            ));
        }
        for (i, b) in inner.free.iter().enumerate() {
            let rc = Arc::strong_count(b);
            if rc != 1 {
                return Err(format!("free block {i} still referenced (strong_count {rc})"));
            }
        }
        Ok(())
    }

    /// Pop a recycled block or create a fresh one; `None` when a capped
    /// pool is exhausted. The returned block is zeroed (matching the
    /// zero-initialised contiguous buffers) and uniquely owned.
    pub(crate) fn try_alloc_zeroed(&self) -> Option<Arc<KvBlock>> {
        let mut blk = self.pop_or_create()?;
        Arc::get_mut(&mut blk).expect("pool blocks are uniquely owned at alloc").zero();
        Some(blk)
    }

    /// Like [`BlockPool::try_alloc_zeroed`] but initialised as a copy of
    /// `src` (the copy-on-write fork path — zeroing first would be wasted).
    pub(crate) fn try_alloc_copy(&self, src: &KvBlock) -> Option<Arc<KvBlock>> {
        let mut blk = self.pop_or_create()?;
        Arc::get_mut(&mut blk).expect("pool blocks are uniquely owned at alloc").copy_from(src);
        Some(blk)
    }

    /// Allocation decision + accounting under the lock; block data is
    /// initialised by the callers after the lock is released.
    fn pop_or_create(&self) -> Option<Arc<KvBlock>> {
        let mut inner = self.inner.lock().unwrap();
        let blk = match inner.free.pop() {
            Some(b) => b,
            None => {
                if let Some(max) = self.effective_max_blocks() {
                    if inner.created >= max {
                        return None;
                    }
                }
                inner.created += 1;
                Arc::new(KvBlock::zeroed(self.block_elems, self.dims.d_head, self.dtype))
            }
        };
        inner.live += 1;
        inner.peak_live = inner.peak_live.max(inner.live);
        Some(blk)
    }

    /// Panicking wrapper for the cache write path: exhaustion here means
    /// the caller admitted more work than it reserved blocks for.
    pub(crate) fn alloc_zeroed(&self) -> Arc<KvBlock> {
        self.try_alloc_zeroed().unwrap_or_else(|| self.exhausted())
    }

    pub(crate) fn alloc_copy(&self, src: &KvBlock) -> Arc<KvBlock> {
        self.try_alloc_copy(src).unwrap_or_else(|| self.exhausted())
    }

    fn exhausted(&self) -> ! {
        panic!(
            "kv block pool exhausted (budget {:?} f32-equivalent = {:?} {} blocks \
             of {} tokens): lane admission must reserve worst-case blocks before writing",
            self.max_blocks,
            self.effective_max_blocks(),
            self.dtype.name(),
            self.block_tokens
        )
    }

    /// Return one table reference. If it was the last reference the block
    /// is recycled onto the free list; otherwise the refcount just drops.
    /// The drop happens under the pool lock so two racing releases of the
    /// same block cannot both observe "still shared" and leak it.
    pub(crate) fn release(&self, blk: Arc<KvBlock>) {
        let mut inner = self.inner.lock().unwrap();
        if Arc::strong_count(&blk) == 1 {
            inner.live -= 1;
            inner.free.push(blk);
        } else {
            drop(blk);
        }
    }
}

/// One sequence's KV lane as a copy-on-write block table over a shared
/// [`BlockPool`]. See the module docs for layout and forking semantics.
pub struct PagedKvCache {
    pool: Arc<BlockPool>,
    /// One slot per `block_tokens` positions; `None` reads as zeros.
    table: Vec<Option<Arc<KvBlock>>>,
    /// Committed rows, i.e. where the next row will be written.
    len: usize,
}

impl Clone for PagedKvCache {
    /// Fork the whole lane: shares every block (refcount bumps, no row
    /// copies); the first write to either copy forks the touched block.
    fn clone(&self) -> PagedKvCache {
        PagedKvCache { pool: Arc::clone(&self.pool), table: self.table.clone(), len: self.len }
    }
}

impl Drop for PagedKvCache {
    /// Retiring a lane returns every block reference to its pool, so the
    /// last lane holding a block recycles it onto the free list.
    fn drop(&mut self) {
        for slot in self.table.iter_mut() {
            if let Some(blk) = slot.take() {
                self.pool.release(blk);
            }
        }
    }
}

impl PagedKvCache {
    /// An empty lane over `pool` (no blocks allocated until first write).
    pub fn new(pool: &Arc<BlockPool>) -> PagedKvCache {
        let slots = pool.dims.max_seq.div_ceil(pool.block_tokens);
        PagedKvCache { pool: Arc::clone(pool), table: vec![None; slots], len: 0 }
    }

    /// Model dimensions fixing the logical `[L, H, S, Dh]` layout.
    pub fn dims(&self) -> ModelDims {
        self.pool.dims
    }

    /// The pool this lane draws from.
    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// Number of committed rows.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no rows are committed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocated table slots (resident blocks referenced by this lane).
    pub fn resident_blocks(&self) -> usize {
        self.table.iter().filter(|s| s.is_some()).count()
    }

    /// Resident blocks currently shared with another lane (refcount > 1) —
    /// the copy-on-write savings this lane enjoys.
    pub fn cow_shared_blocks(&self) -> usize {
        self.table.iter().flatten().filter(|b| Arc::strong_count(b) > 1).count()
    }

    #[inline]
    fn block_tokens(&self) -> usize {
        self.pool.block_tokens
    }

    /// Offset of `(layer, head, t)` inside a block's `[L, H, T, Dh]` data.
    #[inline]
    fn block_offset(&self, layer: usize, head: usize, t: usize) -> usize {
        ((layer * self.pool.dims.n_heads + head) * self.pool.block_tokens + t)
            * self.pool.dims.d_head
    }

    /// Read the `d_head` K/V slices at `(layer, head, pos)`. Unallocated
    /// blocks read as zeros, exactly like a zero-initialised contiguous
    /// cache.
    #[inline]
    pub fn row(&self, layer: usize, head: usize, pos: usize) -> (&[f32], &[f32]) {
        let bt = self.block_tokens();
        let blk: &KvBlock = match &self.table[pos / bt] {
            Some(b) => b,
            None => &self.pool.zero,
        };
        let off = self.block_offset(layer, head, pos % bt);
        let dh = self.pool.dims.d_head;
        (&blk.k[off..off + dh], &blk.v[off..off + dh])
    }

    /// Unique write access to block `bi`, allocating on first touch and
    /// forking (one block copy off the free list) when the block is shared.
    fn block_mut(&mut self, bi: usize) -> &mut KvBlock {
        enum Need {
            Ready,
            Alloc,
            Fork,
        }
        let need = match &self.table[bi] {
            None => Need::Alloc,
            Some(b) if Arc::strong_count(b) > 1 => Need::Fork,
            Some(_) => Need::Ready,
        };
        match need {
            Need::Alloc => self.table[bi] = Some(self.pool.alloc_zeroed()),
            Need::Fork => {
                let fresh = self.pool.alloc_copy(self.table[bi].as_deref().unwrap());
                let old = std::mem::replace(&mut self.table[bi], Some(fresh)).unwrap();
                self.pool.release(old);
            }
            Need::Ready => {}
        }
        Arc::get_mut(self.table[bi].as_mut().unwrap())
            .expect("block uniquely owned after copy-on-write")
    }

    /// Clone the first `n_blocks` table entries — the refcount-bump export
    /// the radix prefix cache stores on lane retirement. `None` when any of
    /// those slots is unallocated (a lane that never committed the rows).
    pub(crate) fn block_arcs(&self, n_blocks: usize) -> Option<Vec<Arc<KvBlock>>> {
        if n_blocks > self.table.len() {
            return None;
        }
        self.table[..n_blocks].iter().cloned().collect()
    }

    /// Install `blocks` as this lane's leading table entries and mark
    /// `rows` committed — the adoption half of a prefix-cache hit. Existing
    /// entries in the overwritten slots are released; the adopted blocks
    /// are shared (refcount bumps), so the first divergent write forks them
    /// exactly like any other copy-on-write fork.
    pub(crate) fn adopt_blocks(&mut self, blocks: Vec<Arc<KvBlock>>, rows: usize) {
        assert_eq!(blocks.len(), rows.div_ceil(self.block_tokens()), "run/row mismatch");
        assert!(blocks.len() <= self.table.len(), "adopted run exceeds lane table");
        for (slot, blk) in self.table.iter_mut().zip(blocks) {
            if let Some(old) = slot.replace(blk) {
                self.pool.release(old);
            }
        }
        self.len = self.len.max(rows);
    }

    /// Raw single-(layer, head) row write — the cross-storage fallback path
    /// of [`KvCache::copy_prefix_from`](super::KvCache::copy_prefix_from).
    pub(crate) fn write_row(&mut self, layer: usize, head: usize, pos: usize, k: &[f32], v: &[f32]) {
        let bt = self.block_tokens();
        let off = self.block_offset(layer, head, pos % bt);
        debug_assert_eq!(k.len(), self.pool.dims.d_head);
        let blk = self.block_mut(pos / bt);
        blk.write(off, k, v);
    }

    /// Overwrite the committed-row count (cross-storage fallback path).
    pub(crate) fn set_len(&mut self, len: usize) {
        self.len = len;
    }

    /// Commit prefill rows laid out `[L, H, s_pre, Dh]` for positions
    /// `0..len` — one span copy per (block, layer, head).
    pub fn commit_prefill(&mut self, k_rows: &[f32], v_rows: &[f32], s_pre: usize, len: usize) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_rows.len(), lyr * h * s_pre * dh);
        let bt = self.block_tokens();
        let mut pos = 0usize;
        while pos < len {
            let bi = pos / bt;
            let t = pos % bt;
            let run = (len - pos).min(bt - t);
            let block_off = |l: usize, hh: usize| ((l * h + hh) * bt + t) * dh;
            let blk = self.block_mut(bi);
            for l in 0..lyr {
                for hh in 0..h {
                    let src = ((l * h + hh) * s_pre + pos) * dh;
                    let dst = block_off(l, hh);
                    blk.write(dst, &k_rows[src..src + run * dh], &v_rows[src..src + run * dh]);
                }
            }
            pos += run;
        }
        self.len = len;
    }

    /// Commit a prefill chunk laid out `[L, H, stride, Dh]`: the first
    /// `len` source rows land at positions `start..start + len` — the paged
    /// twin of [`ContiguousKv::commit_chunk`](super::ContiguousKv::commit_chunk),
    /// walking whole block runs like `commit_prefill` but offset by `start`
    /// and growing (never resetting) the committed row count.
    pub fn commit_chunk(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        stride: usize,
        start: usize,
        len: usize,
    ) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert!(len <= stride, "chunk rows {len} exceed source stride {stride}");
        assert!(start + len <= self.pool.dims.max_seq, "chunk past max_seq");
        assert_eq!(k_rows.len(), lyr * h * stride * dh);
        let bt = self.block_tokens();
        let mut i = 0usize;
        while i < len {
            let pos = start + i;
            let bi = pos / bt;
            let t = pos % bt;
            let run = (len - i).min(bt - t);
            let block_off = |l: usize, hh: usize| ((l * h + hh) * bt + t) * dh;
            let blk = self.block_mut(bi);
            for l in 0..lyr {
                for hh in 0..h {
                    let src = ((l * h + hh) * stride + i) * dh;
                    let dst = block_off(l, hh);
                    blk.write(dst, &k_rows[src..src + run * dh], &v_rows[src..src + run * dh]);
                }
            }
            i += run;
        }
        self.len = self.len.max(start + len);
    }

    /// Commit one row laid out `[L, H, Dh]` at `pos`.
    pub fn commit_row(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_row.len(), lyr * h * dh);
        let bt = self.block_tokens();
        let t = pos % bt;
        let dst_head_stride = bt * dh;
        let blk = self.block_mut(pos / bt);
        for l in 0..lyr {
            let mut src = l * h * dh;
            let mut dst = ((l * h) * bt + t) * dh;
            for _hh in 0..h {
                blk.write(dst, &k_row[src..src + dh], &v_row[src..src + dh]);
                src += dh;
                dst += dst_head_stride;
            }
        }
        self.len = self.len.max(pos + 1);
    }

    /// Commit rollout rows `[Lyr, K, L, H, Dh]`: path `branch`, steps
    /// `0..=last_step`, at positions `base_pos + step` — the paged twin of
    /// [`ContiguousKv::commit_rollout_rows`](super::ContiguousKv::commit_rollout_rows),
    /// with the single-head span coalescing applied per block.
    #[allow(clippy::too_many_arguments)]
    pub fn commit_rollout_rows(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_rows.len(), lyr * k_paths * l_steps * h * dh);
        let steps = last_step + 1;
        let bt = self.block_tokens();
        let src_step_stride = h * dh;
        let mut step = 0usize;
        while step < steps {
            let pos = base_pos + step;
            let bi = pos / bt;
            let t = pos % bt;
            let run = (steps - step).min(bt - t);
            let blk = self.block_mut(bi);
            for l in 0..lyr {
                for hh in 0..h {
                    let src0 = ((((l * k_paths + branch) * l_steps) + step) * h + hh) * dh;
                    let dst0 = ((l * h + hh) * bt + t) * dh;
                    if h == 1 {
                        // src and dst both step-contiguous: one span write
                        let n = run * dh;
                        blk.write(dst0, &k_rows[src0..src0 + n], &v_rows[src0..src0 + n]);
                    } else {
                        let (mut src, mut dst) = (src0, dst0);
                        for _s in 0..run {
                            blk.write(dst, &k_rows[src..src + dh], &v_rows[src..src + dh]);
                            src += src_step_stride;
                            dst += dh;
                        }
                    }
                }
            }
            step += run;
        }
        self.len = self.len.max(base_pos + steps);
    }

    /// Commit tree-pass rows `[Lyr, N, H, Dh]` for node `node_idx` at `pos`.
    pub fn commit_tree_row(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        n_bucket: usize,
        node_idx: usize,
        pos: usize,
    ) {
        let (lyr, h, dh) = (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
        assert_eq!(k_rows.len(), lyr * n_bucket * h * dh);
        let bt = self.block_tokens();
        let t = pos % bt;
        let dst_head_stride = bt * dh;
        let blk = self.block_mut(pos / bt);
        for l in 0..lyr {
            let mut src = (l * n_bucket + node_idx) * h * dh;
            let mut dst = ((l * h) * bt + t) * dh;
            for _hh in 0..h {
                blk.write(dst, &k_rows[src..src + dh], &v_rows[src..src + dh]);
                src += dh;
                dst += dst_head_stride;
            }
        }
        self.len = self.len.max(pos + 1);
    }

    /// Refresh this lane as a prefix fork of `src`: blocks covering rows
    /// `< rows` are *shared* (refcount bumps — no row copies; the first
    /// divergent write forks), blocks past the prefix are released back to
    /// the pool. Rows past the prefix inside the boundary block keep the
    /// source's contents and **must not be read** — the same contract as
    /// the contiguous [`copy_prefix_from`](super::ContiguousKv::copy_prefix_from).
    ///
    /// Lanes on different pools (same dims) fall back to a deep row copy.
    pub fn copy_prefix_from(&mut self, src: &PagedKvCache, rows: usize) {
        debug_assert_eq!(
            self.pool.dims.kv_elems(),
            src.pool.dims.kv_elems(),
            "prefix copy across dims"
        );
        let rows = rows.min(self.pool.dims.max_seq);
        if Arc::ptr_eq(&self.pool, &src.pool) {
            let nb = rows.div_ceil(self.block_tokens());
            for (bi, slot) in self.table.iter_mut().enumerate() {
                let share = if bi < nb { src.table[bi].clone() } else { None };
                let old = std::mem::replace(slot, share);
                if let Some(blk) = old {
                    self.pool.release(blk);
                }
            }
        } else {
            // cross-pool: deep copy row by row (cold path, kept for safety)
            let (lyr, h, dh) =
                (self.pool.dims.n_layers, self.pool.dims.n_heads, self.pool.dims.d_head);
            let bt = self.block_tokens();
            for pos in 0..rows {
                let t = pos % bt;
                let bi = pos / bt;
                for l in 0..lyr {
                    for hh in 0..h {
                        let (ks, vs) = src.row(l, hh, pos);
                        let (ks, vs) = (ks.to_vec(), vs.to_vec());
                        let off = ((l * h + hh) * bt + t) * dh;
                        let blk = self.block_mut(bi);
                        // re-quantizes under *this* pool's dtype when the
                        // pools differ (cold cross-pool path)
                        blk.write(off, &ks, &vs);
                    }
                }
            }
            for slot in self.table.iter_mut().skip(rows.div_ceil(bt)) {
                if let Some(blk) = slot.take() {
                    self.pool.release(blk);
                }
            }
        }
        self.len = src.len.min(rows);
    }

    /// Forked lane holding only rows `< rows` — O(prefix blocks) refcount
    /// bumps, no row copies.
    pub fn clone_prefix(&self, rows: usize) -> PagedKvCache {
        let mut out = PagedKvCache::new(&self.pool);
        out.copy_prefix_from(self, rows);
        out
    }

    /// Materialise the full `[L, H, S, Dh]` contiguous buffers (zeros where
    /// unallocated) — the gather shim the PJRT engine uses to feed compiled
    /// modules that expect contiguous host caches.
    pub fn gather(&self) -> (Vec<f32>, Vec<f32>) {
        let d = self.pool.dims;
        let (lyr, h, dh, s) = (d.n_layers, d.n_heads, d.d_head, d.max_seq);
        let bt = self.block_tokens();
        let mut k = vec![0.0f32; d.kv_elems()];
        let mut v = vec![0.0f32; d.kv_elems()];
        for (bi, slot) in self.table.iter().enumerate() {
            let Some(blk) = slot else { continue };
            let t0 = bi * bt;
            let run = bt.min(s - t0);
            for l in 0..lyr {
                for hh in 0..h {
                    let src = ((l * h + hh) * bt) * dh;
                    let dst = ((l * h + hh) * s + t0) * dh;
                    k[dst..dst + run * dh].copy_from_slice(&blk.k[src..src + run * dh]);
                    v[dst..dst + run * dh].copy_from_slice(&blk.v[src..src + run * dh]);
                }
            }
        }
        (k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { n_layers: 2, d_model: 8, n_heads: 2, d_head: 4, vocab: 10, max_seq: 16 }
    }

    #[test]
    fn lazy_alloc_and_zero_reads() {
        let pool = BlockPool::new(dims(), 4, None);
        let c = PagedKvCache::new(&pool);
        assert_eq!(pool.created(), 0);
        assert_eq!(c.resident_blocks(), 0);
        let (k, v) = c.row(1, 1, 7);
        assert_eq!(k, &[0.0; 4]);
        assert_eq!(v, &[0.0; 4]);
        pool.validate().unwrap();
    }

    #[test]
    fn commit_row_allocates_one_block() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut c = PagedKvCache::new(&pool);
        let row: Vec<f32> = (0..16).map(|x| x as f32).collect(); // [2,2,4]
        c.commit_row(&row, &row, 5); // block 1
        assert_eq!(c.len(), 6);
        assert_eq!(c.resident_blocks(), 1);
        assert_eq!(pool.live_blocks(), 1);
        // layer 1, head 1 slice = row[12..16]
        let (k, _) = c.row(1, 1, 5);
        assert_eq!(k, &[12.0, 13.0, 14.0, 15.0]);
        // neighbours in the same block read zero
        let (k, _) = c.row(1, 1, 4);
        assert_eq!(k, &[0.0; 4]);
        pool.validate().unwrap();
    }

    #[test]
    fn cow_fork_shares_until_write() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut a = PagedKvCache::new(&pool);
        let row: Vec<f32> = (0..16).map(|x| x as f32 + 1.0).collect();
        for pos in 0..6 {
            a.commit_row(&row, &row, pos);
        }
        assert_eq!(pool.live_blocks(), 2);
        let mut b = a.clone_prefix(6);
        // sharing: no new blocks, both lanes fully resident
        assert_eq!(pool.live_blocks(), 2);
        assert_eq!(b.cow_shared_blocks(), 2);
        assert_eq!(b.len(), 6);
        // first divergent write forks exactly the touched block
        let row2: Vec<f32> = (0..16).map(|x| x as f32 * 2.0).collect();
        b.commit_row(&row2, &row2, 5);
        assert_eq!(pool.live_blocks(), 3);
        assert_eq!(b.cow_shared_blocks(), 1);
        // a unaffected; b sees old rows + the new write
        let (ka, _) = a.row(0, 0, 5);
        assert_eq!(ka, &row[..4]);
        let (kb, _) = b.row(0, 0, 5);
        assert_eq!(kb, &row2[..4]);
        let (kb4, _) = b.row(0, 0, 4);
        assert_eq!(kb4, &row[..4], "fork preserves the rest of the block");
        pool.validate().unwrap();
    }

    #[test]
    fn retire_returns_blocks_to_free_list() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut a = PagedKvCache::new(&pool);
        let row = vec![1.0f32; 16];
        for pos in 0..8 {
            a.commit_row(&row, &row, pos);
        }
        let b = a.clone();
        assert_eq!(pool.live_blocks(), 2);
        drop(a);
        assert_eq!(pool.live_blocks(), 2, "blocks still held by the clone");
        assert_eq!(pool.free_blocks(), 0);
        drop(b);
        assert_eq!(pool.live_blocks(), 0);
        assert_eq!(pool.free_blocks(), 2);
        pool.validate().unwrap();
        // recycled blocks come back zeroed
        let mut c = PagedKvCache::new(&pool);
        c.commit_row(&row, &row, 0);
        assert_eq!(pool.created(), 2, "reuse, not growth");
        let (k, _) = c.row(0, 0, 1);
        assert_eq!(k, &[0.0; 4]);
    }

    #[test]
    fn budget_exhaustion_fails_cleanly() {
        let pool = BlockPool::new(dims(), 4, Some(1));
        assert!(pool.try_alloc_zeroed().is_some());
        assert!(pool.try_alloc_zeroed().is_none(), "budget must cap creation");
        // note: the first block is now live but unreachable by any cache —
        // this is a raw-allocator test, not a cache-lifecycle test
    }

    #[test]
    fn copy_prefix_releases_tail_blocks() {
        let pool = BlockPool::new(dims(), 4, None);
        let mut a = PagedKvCache::new(&pool);
        let row = vec![3.0f32; 16];
        for pos in 0..12 {
            a.commit_row(&row, &row, pos);
        }
        let mut b = a.clone();
        assert_eq!(pool.live_blocks(), 3);
        b.copy_prefix_from(&a, 5); // keeps blocks 0..2 shared, drops block 2's tail ref
        assert_eq!(b.len(), 5);
        assert_eq!(b.resident_blocks(), 2);
        assert_eq!(pool.live_blocks(), 3, "a still holds all three");
        drop(a);
        assert_eq!(pool.live_blocks(), 2);
        assert_eq!(pool.free_blocks(), 1);
        pool.validate().unwrap();
    }

    #[test]
    fn gather_matches_rows() {
        let d = dims();
        let pool = BlockPool::new(d, 3, None); // uneven block size
        let mut c = PagedKvCache::new(&pool);
        let n = d.n_layers * d.n_heads * d.d_head;
        for pos in [0usize, 4, 7] {
            let row: Vec<f32> = (0..n).map(|x| (x + pos * 100) as f32).collect();
            c.commit_row(&row, &row, pos);
        }
        let (k, v) = c.gather();
        assert_eq!(k.len(), d.kv_elems());
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                for pos in 0..d.max_seq {
                    let (rk, rv) = c.row(l, hh, pos);
                    let off = ((l * d.n_heads + hh) * d.max_seq + pos) * d.d_head;
                    assert_eq!(&k[off..off + d.d_head], rk, "l={l} h={hh} p={pos}");
                    assert_eq!(&v[off..off + d.d_head], rv);
                }
            }
        }
    }

    #[test]
    fn storage_knob_parsing() {
        assert_eq!(KvStorage::from_env_value(None), KvStorage::Contiguous);
        assert_eq!(KvStorage::from_env_value(Some("0")), KvStorage::Contiguous);
        assert_eq!(KvStorage::from_env_value(Some("1")), KvStorage::Paged);
        assert_eq!(KvStorage::from_env_value(Some("true")), KvStorage::Paged);
        assert_eq!(KvStorage::from_env_value(Some("TRUE")), KvStorage::Paged);
    }

    #[test]
    fn dtype_knob_parsing() {
        assert_eq!(KvDtype::from_env_value(None), KvDtype::F32);
        assert_eq!(KvDtype::from_env_value(Some("f32")), KvDtype::F32);
        assert_eq!(KvDtype::from_env_value(Some("garbage")), KvDtype::F32);
        assert_eq!(KvDtype::from_env_value(Some("f16")), KvDtype::F16);
        assert_eq!(KvDtype::from_env_value(Some("FP16")), KvDtype::F16);
        assert_eq!(KvDtype::from_env_value(Some("half")), KvDtype::F16);
        assert_eq!(KvDtype::from_env_value(Some("int8")), KvDtype::Int8);
        assert_eq!(KvDtype::from_env_value(Some("I8")), KvDtype::Int8);
        assert_eq!(KvDtype::F32.capacity_multiplier(), 1);
        assert_eq!(KvDtype::F16.capacity_multiplier(), 2);
        assert_eq!(KvDtype::Int8.capacity_multiplier(), 4);
    }

    /// An f16 pool serves back exactly the half-precision rounding of each
    /// committed element — and nothing else changes (zero reads, lengths).
    #[test]
    fn f16_pool_rounds_rows_to_half_precision() {
        use super::super::quant::{f16_round, f32_to_f16_bits, f16_bits_to_f32};
        let pool = BlockPool::with_dtype(dims(), 4, None, KvDtype::F16);
        assert_eq!(pool.kv_dtype(), KvDtype::F16);
        let mut c = PagedKvCache::new(&pool);
        let row: Vec<f32> = (0..16).map(|x| x as f32 * 0.1003 + 0.017).collect();
        c.commit_row(&row, &row, 5);
        for hh in 0..2 {
            for l in 0..2 {
                let (k, v) = c.row(l, hh, 5);
                for (i, &got) in k.iter().enumerate() {
                    let want = f16_round(row[(l * 2 + hh) * 4 + i]);
                    assert_eq!(got.to_bits(), want.to_bits(), "l={l} h={hh} i={i}");
                    // the mirror value is exactly binary16-representable
                    assert_eq!(f16_bits_to_f32(f32_to_f16_bits(got)).to_bits(), got.to_bits());
                }
                assert_eq!(k, v);
            }
        }
        let (kz, _) = c.row(1, 1, 4);
        assert_eq!(kz, &[0.0; 4], "unwritten rows still read exact zeros");
    }

    /// Int8 storage is content-pure: a row's dequantized value depends only
    /// on that row's committed content, so (a) rewriting one row never
    /// perturbs a neighbour in the same block, (b) the same content
    /// committed through different op sequences reads identically, and
    /// (c) the error is bounded by half a quantization step.
    #[test]
    fn int8_pool_content_pure_and_bounded_error() {
        let d = dims();
        let n = d.n_layers * d.n_heads * d.d_head;
        let row_a: Vec<f32> = (0..n).map(|x| (x as f32 * 0.7).sin() * 3.0).collect();
        let row_b: Vec<f32> = (0..n).map(|x| (x as f32 * 1.3).cos() * 40.0).collect();

        let pool = BlockPool::with_dtype(d, 4, None, KvDtype::Int8);
        let mut c = PagedKvCache::new(&pool);
        c.commit_row(&row_a, &row_a, 0);
        let before: Vec<f32> = c.row(0, 0, 0).0.to_vec();
        // error bound: half a step of this row's span (range 6.0 / 255)
        for (got, want) in before.iter().zip(&row_a[..4]) {
            assert!((got - want).abs() <= 6.0 / 255.0 * 0.5 + 1e-5, "{got} vs {want}");
        }
        // (a) a much larger neighbour row in the same block must not
        // disturb the first row's dequantized values (per-row params)
        c.commit_row(&row_b, &row_b, 1);
        assert_eq!(c.row(0, 0, 0).0, before.as_slice(), "neighbour write perturbed row 0");

        // (b) same logical content via a different op sequence
        let mut c2 = PagedKvCache::new(&pool);
        c2.commit_row(&row_b, &row_b, 1); // reverse order
        c2.commit_row(&row_a, &row_a, 0);
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                for pos in 0..2 {
                    assert_eq!(c.row(l, hh, pos).0, c2.row(l, hh, pos).0, "order-dependent reads");
                    assert_eq!(c.row(l, hh, pos).1, c2.row(l, hh, pos).1);
                }
            }
        }

        // constant rows (scale 0) dequantize exactly
        let flat = vec![2.5f32; n];
        c.commit_row(&flat, &flat, 2);
        assert_eq!(c.row(1, 1, 2).0, &[2.5; 4]);
    }

    /// A quantized fork reads bit-identically to its source, and a
    /// recycled quantized block comes back fully zeroed (codes and params
    /// included).
    #[test]
    fn quantized_fork_and_recycle_preserve_contract() {
        let d = dims();
        let n = d.n_layers * d.n_heads * d.d_head;
        for dtype in [KvDtype::F16, KvDtype::Int8] {
            let pool = BlockPool::with_dtype(d, 4, None, dtype);
            let mut a = PagedKvCache::new(&pool);
            let row: Vec<f32> = (0..n).map(|x| x as f32 * 0.31 - 2.0).collect();
            for pos in 0..6 {
                a.commit_row(&row, &row, pos);
            }
            let b = a.clone_prefix(6);
            for pos in 0..6 {
                assert_eq!(a.row(1, 1, pos), b.row(1, 1, pos), "{dtype:?} fork diverges");
            }
            // divergent write forks; the source still reads its own codes
            let mut b = b;
            let row2: Vec<f32> = row.iter().map(|x| x * 10.0).collect();
            b.commit_row(&row2, &row2, 5);
            assert_ne!(a.row(0, 0, 5).0, b.row(0, 0, 5).0);
            drop(a);
            drop(b);
            // recycled blocks must read as zeros again
            let mut c = PagedKvCache::new(&pool);
            c.commit_row(&row, &row, 0);
            let (kz, vz) = c.row(0, 0, 2);
            assert_eq!(kz, &[0.0; 4], "{dtype:?} recycled block not zeroed");
            assert_eq!(vz, &[0.0; 4]);
            let _ = a5;
        }
    }

    /// The same f32-equivalent budget admits `capacity_multiplier()` times
    /// the blocks on a reduced-precision pool — the lane-capacity win the
    /// serving loop's admission schedules against.
    #[test]
    fn effective_capacity_scales_with_dtype() {
        for (dtype, want) in [(KvDtype::F32, 2), (KvDtype::F16, 4), (KvDtype::Int8, 8)] {
            let pool = BlockPool::with_dtype(dims(), 4, Some(2), dtype);
            assert_eq!(pool.max_blocks(), Some(2), "budget stays in f32 units");
            assert_eq!(pool.effective_max_blocks(), Some(want));
            let mut held = Vec::new();
            for i in 0..want {
                held.push(pool.try_alloc_zeroed().unwrap_or_else(|| {
                    panic!("{dtype:?}: block {i} of {want} must fit the budget")
                }));
            }
            assert!(pool.try_alloc_zeroed().is_none(), "{dtype:?}: budget must cap at {want}");
        }
    }
}
