//! Cross-request radix prefix cache over the paged [`BlockPool`].
//!
//! The copy-on-write block table ([`PagedKvCache`]) shares committed KV
//! blocks *within* one request — trunk→branch handoffs are refcount bumps.
//! Production traffic (shared system prompts, few-shot templates,
//! conversation turns) shares long prefixes *across* requests, and without
//! an index every admission re-prefills tokens whose KV rows already sit
//! in the pool. This module adds that index:
//!
//! * [`PrefixCache`] — a radix tree keyed on token ids. Each node owns one
//!   path-compressed edge (`key`, a whole number of `block_tokens`-sized
//!   token blocks) plus the matching refcounted runs of committed target
//!   **and** draft blocks. Retiring lanes [`insert`](PrefixCache::insert)
//!   their committed prompt prefix (Arc clones — no row copies); admission
//!   [`match_into`](PrefixCache::match_into)s an incoming prompt and adopts
//!   the longest cached block run into the fresh lanes, so chunked prefill
//!   starts at the first token past the cached rows.
//! * [`PrefixCacheCounters`] — observability for the serving loop's
//!   `{"stats":true}` reply and the prefix-cache bench.
//!
//! ## Block-aligned matching
//!
//! Blocks are the unit of sharing, so the tree only caches and matches
//! *whole* blocks: inserted token runs are truncated to a multiple of
//! `block_tokens`, edges split only on block boundaries, and a child is
//! entered only when its entire first block matches the probe. The
//! resulting invariant — the first blocks of a node's children are pairwise
//! distinct — keeps descent unambiguous without per-token child fan-out.
//!
//! ## Refcounts, reclaimability and eviction
//!
//! The cache holds plain [`Arc`] clones of lane table entries, so a cached
//! block stays live in its pool. Blocks whose only reference is the cache
//! itself (`strong_count == 1`) are *reclaimable*: the serving loop never
//! counts them against admission headroom, and under budget pressure
//! [`reclaim`](PrefixCache::reclaim) evicts LRU leaf runs tail-first,
//! releasing the block pairs back to their pools. Because lanes adopt
//! root-contiguous runs, reclaimable blocks always form suffixes of leaf
//! paths, so repeated tail truncation can reach every reclaimable block.
//!
//! ## Determinism contract
//!
//! Cached rows come from committed prefill/decode rows, which the backend
//! consistency contract pins bit-identical to a cold prefill of the same
//! tokens. Adopting a cached run therefore yields exactly the bytes a cold
//! chunked prefill would have produced, and the warm path stays
//! bit-identical to the cold-cache oracle (asserted across the e2e grid
//! and `benches/prefix_cache.rs`).

use std::sync::{Arc, OnceLock};

use super::paged::KvBlock;
use super::{BlockPool, KvCache, PagedKvCache};

/// Whether cross-request prefix caching is enabled process-wide: off,
/// unless `SPECDELAY_PREFIX_CACHE` is set to `1`/`true`. Read once and
/// cached — mirrors [`KvStorage::global`](super::KvStorage::global).
pub fn prefix_cache_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        prefix_cache_from_env_value(std::env::var("SPECDELAY_PREFIX_CACHE").ok().as_deref())
    })
}

/// Parse the `SPECDELAY_PREFIX_CACHE` value (`1`/`true` → enabled);
/// factored out so the knob's parsing is unit-testable despite the cached
/// global.
pub fn prefix_cache_from_env_value(value: Option<&str>) -> bool {
    value.map(|v| v == "1" || v.eq_ignore_ascii_case("true")).unwrap_or(false)
}

/// Observability counters for one [`PrefixCache`], surfaced through
/// `ServeLoop::prefix_counters` and the server `{"stats":true}` reply.
/// Misses are derived: `lookups - hits`.
#[derive(Clone, Debug, Default)]
pub struct PrefixCacheCounters {
    /// Prompt lookups against the tree (paged admissions only; contiguous
    /// fallbacks count under [`skipped_contiguous`](Self::skipped_contiguous)
    /// instead).
    pub lookups: u64,
    /// Lookups that matched at least one whole cached block.
    pub hits: u64,
    /// Total KV rows adopted from the cache across all hits (prefill rows
    /// the serving loop did not recompute).
    pub matched_rows: u64,
    /// Insertions that stored at least one new block run in the tree
    /// (re-inserting an already-cached prefix keeps the existing run and
    /// does not count).
    pub inserted_runs: u64,
    /// Blocks released back to their pools by eviction or
    /// [`PrefixCache::clear`], counted across both the target and draft
    /// pools.
    pub evicted_blocks: u64,
    /// The subset of [`evicted_blocks`](Self::evicted_blocks) released by
    /// [`PrefixCache::reclaim`] under admission/dispatch budget pressure.
    pub reclaimed_under_pressure: u64,
    /// Admissions that skipped the cache because the lane storage is
    /// contiguous (graceful degradation — see `ServeLoop` docs).
    pub skipped_contiguous: u64,
}

/// One path-compressed radix node: a token edge (`key.len()` is a multiple
/// of the pool block size) plus the paired target/draft block runs backing
/// it (`key.len() / block_tokens` blocks each). The root has an empty key
/// and no runs.
struct Node {
    key: Vec<u32>,
    target_run: Vec<Arc<KvBlock>>,
    draft_run: Vec<Arc<KvBlock>>,
    children: Vec<Node>,
    /// Monotone LRU stamp (a logical clock, not wall time — eviction order
    /// must be deterministic for the equality oracle).
    last_touch: u64,
}

impl Node {
    fn empty() -> Node {
        Node {
            key: Vec::new(),
            target_run: Vec::new(),
            draft_run: Vec::new(),
            children: Vec::new(),
            last_touch: 0,
        }
    }
}

/// A cross-request radix index of committed KV block runs over one
/// (target pool, draft pool) pair. See the module docs for matching,
/// refcount and eviction semantics.
pub struct PrefixCache {
    target_pool: Arc<BlockPool>,
    draft_pool: Arc<BlockPool>,
    bt: usize,
    root: Node,
    clock: u64,
    counters: PrefixCacheCounters,
}

impl PrefixCache {
    /// An empty cache over the two pools a serving loop's lanes draw from.
    /// Both pools must use the same block size.
    pub fn new(target_pool: &Arc<BlockPool>, draft_pool: &Arc<BlockPool>) -> PrefixCache {
        assert_eq!(
            target_pool.block_tokens(),
            draft_pool.block_tokens(),
            "prefix cache requires matching block sizes"
        );
        PrefixCache {
            target_pool: Arc::clone(target_pool),
            draft_pool: Arc::clone(draft_pool),
            bt: target_pool.block_tokens(),
            root: Node::empty(),
            clock: 0,
            counters: PrefixCacheCounters::default(),
        }
    }

    /// Tokens per cached block (the match/insert granularity).
    pub fn block_tokens(&self) -> usize {
        self.bt
    }

    /// Counter snapshot.
    pub fn counters(&self) -> PrefixCacheCounters {
        self.counters.clone()
    }

    /// Cache `tokens` (truncated to whole blocks) with the committed block
    /// runs of a retiring lane's target and draft caches. The runs are
    /// shared by Arc clone — no row copies — and overlapping prefixes keep
    /// the runs already in the tree (bit-identical by the determinism
    /// contract). Returns the number of newly cached rows; lanes on foreign
    /// pools or with unallocated prefix blocks are skipped (0).
    pub fn insert(&mut self, tokens: &[u32], target: &PagedKvCache, draft: &PagedKvCache) -> usize {
        if !Arc::ptr_eq(target.pool(), &self.target_pool)
            || !Arc::ptr_eq(draft.pool(), &self.draft_pool)
        {
            return 0;
        }
        let rows = (tokens.len() / self.bt) * self.bt;
        if rows == 0 || rows > target.len() || rows > draft.len() {
            return 0;
        }
        let nb = rows / self.bt;
        let (Some(t_run), Some(d_run)) = (target.block_arcs(nb), draft.block_arcs(nb)) else {
            return 0;
        };
        self.clock += 1;
        let stored = Self::insert_rec(
            &mut self.root,
            &self.target_pool,
            &self.draft_pool,
            &tokens[..rows],
            t_run,
            d_run,
            self.bt,
            self.clock,
        );
        if stored > 0 {
            self.counters.inserted_runs += 1;
        }
        stored * self.bt
    }

    /// Recursive insert below `node`; `tokens` is block-aligned and `t_run`
    /// / `d_run` carry one block per token block. Returns newly stored
    /// blocks (per pool). Runs for already-cached prefixes are released
    /// back through the pools (refcount drops — the lane still holds them).
    #[allow(clippy::too_many_arguments)]
    fn insert_rec(
        node: &mut Node,
        target_pool: &BlockPool,
        draft_pool: &BlockPool,
        tokens: &[u32],
        mut t_run: Vec<Arc<KvBlock>>,
        mut d_run: Vec<Arc<KvBlock>>,
        bt: usize,
        clock: u64,
    ) -> usize {
        node.last_touch = clock;
        if tokens.is_empty() {
            Self::release_runs(target_pool, draft_pool, t_run, d_run);
            return 0;
        }
        let slot = node.children.iter().position(|c| c.key[..bt] == tokens[..bt]);
        let Some(ci) = slot else {
            // No child shares the first block: attach the whole remainder
            // as a fresh leaf (keeps the distinct-first-block invariant).
            let stored = t_run.len();
            node.children.push(Node {
                key: tokens.to_vec(),
                target_run: t_run,
                draft_run: d_run,
                children: Vec::new(),
                last_touch: clock,
            });
            return stored;
        };
        // Count whole matching blocks along the child's edge.
        let child = &mut node.children[ci];
        let mut nb = 1;
        while (nb + 1) * bt <= child.key.len()
            && (nb + 1) * bt <= tokens.len()
            && child.key[nb * bt..(nb + 1) * bt] == tokens[nb * bt..(nb + 1) * bt]
        {
            nb += 1;
        }
        let pb = nb * bt;
        // The matched prefix is already cached: keep the tree's runs and
        // drop ours (content is bit-identical by the determinism contract).
        let t_rest = t_run.split_off(nb);
        let d_rest = d_run.split_off(nb);
        Self::release_runs(target_pool, draft_pool, t_run, d_run);
        if pb == child.key.len() {
            return Self::insert_rec(
                child,
                target_pool,
                draft_pool,
                &tokens[pb..],
                t_rest,
                d_rest,
                bt,
                clock,
            );
        }
        // Divergence inside the edge: split the child at the block
        // boundary, demoting its tail (and subtree) under a new
        // intermediate node that keeps the matched prefix.
        let tail_key = child.key.split_off(pb);
        let tail_t = child.target_run.split_off(nb);
        let tail_d = child.draft_run.split_off(nb);
        let demoted = Node {
            key: tail_key,
            target_run: tail_t,
            draft_run: tail_d,
            children: std::mem::take(&mut child.children),
            last_touch: child.last_touch,
        };
        child.children.push(demoted);
        child.last_touch = clock;
        let rest = &tokens[pb..];
        if rest.is_empty() {
            Self::release_runs(target_pool, draft_pool, t_rest, d_rest);
            return 0;
        }
        let stored = t_rest.len();
        child.children.push(Node {
            key: rest.to_vec(),
            target_run: t_rest,
            draft_run: d_rest,
            children: Vec::new(),
            last_touch: clock,
        });
        stored
    }

    /// Match `tokens` against the tree and adopt the longest cached block
    /// run into the `target` / `draft` lanes (Arc clones installed in their
    /// block tables; committed length set to the matched rows). Returns the
    /// matched row count — always a multiple of the block size, and 0 for
    /// contiguous lanes (graceful degradation, counted under
    /// `skipped_contiguous`) or lanes on foreign pools.
    pub fn match_into(&mut self, tokens: &[u32], target: &mut KvCache, draft: &mut KvCache) -> usize {
        let (KvCache::Paged(t), KvCache::Paged(d)) = (target, draft) else {
            self.counters.skipped_contiguous += 1;
            return 0;
        };
        if !Arc::ptr_eq(t.pool(), &self.target_pool) || !Arc::ptr_eq(d.pool(), &self.draft_pool) {
            self.counters.lookups += 1;
            return 0;
        }
        self.counters.lookups += 1;
        self.clock += 1;
        let mut t_run: Vec<Arc<KvBlock>> = Vec::new();
        let mut d_run: Vec<Arc<KvBlock>> = Vec::new();
        Self::match_rec(&mut self.root, tokens, self.bt, self.clock, &mut t_run, &mut d_run);
        let rows = t_run.len() * self.bt;
        if rows == 0 {
            return 0;
        }
        t.adopt_blocks(t_run, rows);
        d.adopt_blocks(d_run, rows);
        self.counters.hits += 1;
        self.counters.matched_rows += rows as u64;
        rows
    }

    /// Recursive descent for [`PrefixCache::match_into`], collecting the
    /// cached block run for the longest block-aligned prefix of `tokens`
    /// and LRU-touching every node on the path.
    fn match_rec(
        node: &mut Node,
        tokens: &[u32],
        bt: usize,
        clock: u64,
        t_out: &mut Vec<Arc<KvBlock>>,
        d_out: &mut Vec<Arc<KvBlock>>,
    ) {
        node.last_touch = clock;
        if tokens.len() < bt {
            return;
        }
        let slot = node.children.iter().position(|c| c.key[..bt] == tokens[..bt]);
        let Some(ci) = slot else { return };
        let child = &mut node.children[ci];
        let mut nb = 1;
        while (nb + 1) * bt <= child.key.len()
            && (nb + 1) * bt <= tokens.len()
            && child.key[nb * bt..(nb + 1) * bt] == tokens[nb * bt..(nb + 1) * bt]
        {
            nb += 1;
        }
        for i in 0..nb {
            t_out.push(Arc::clone(&child.target_run[i]));
            d_out.push(Arc::clone(&child.draft_run[i]));
        }
        if nb * bt == child.key.len() {
            Self::match_rec(child, &tokens[nb * bt..], bt, clock, t_out, d_out);
        } else {
            child.last_touch = clock;
        }
    }

    /// Cached block pairs whose only remaining reference is the cache
    /// itself — the blocks admission may treat as free-able headroom.
    pub fn reclaimable_pairs(&self) -> usize {
        let mut pairs = 0usize;
        let mut stack: Vec<&Node> = vec![&self.root];
        while let Some(n) = stack.pop() {
            for (t, d) in n.target_run.iter().zip(&n.draft_run) {
                if Arc::strong_count(t) == 1 && Arc::strong_count(d) == 1 {
                    pairs += 1;
                }
            }
            stack.extend(n.children.iter());
        }
        pairs
    }

    /// Total block pairs held by the tree (reclaimable or not).
    pub fn cached_pairs(&self) -> usize {
        let mut pairs = 0usize;
        let mut stack: Vec<&Node> = vec![&self.root];
        while let Some(n) = stack.pop() {
            pairs += n.target_run.len();
            stack.extend(n.children.iter());
        }
        pairs
    }

    /// Evict under budget pressure: release up to `need_pairs` reclaimable
    /// block pairs back to the pools, LRU leaf first, tail blocks first
    /// (emptied nodes are removed, which may expose their parents as the
    /// next LRU leaves). Returns the pairs actually freed — fewer than
    /// requested only when nothing else is reclaimable.
    pub fn reclaim(&mut self, need_pairs: usize) -> usize {
        let mut freed = 0usize;
        while freed < need_pairs {
            let mut best: Option<(u64, Vec<usize>)> = None;
            let mut path = Vec::new();
            Self::find_lru_leaf(&self.root, &mut path, &mut best);
            let Some((_, path)) = best else { break };
            let mut parent = &mut self.root;
            for &i in &path[..path.len() - 1] {
                parent = &mut parent.children[i];
            }
            let li = *path.last().expect("root is never an evictable leaf");
            let leaf = &mut parent.children[li];
            while freed < need_pairs
                && leaf
                    .target_run
                    .last()
                    .is_some_and(|b| Arc::strong_count(b) == 1)
                && leaf.draft_run.last().is_some_and(|b| Arc::strong_count(b) == 1)
            {
                let t = leaf.target_run.pop().expect("checked non-empty");
                let d = leaf.draft_run.pop().expect("runs stay paired");
                leaf.key.truncate(leaf.key.len() - self.bt);
                self.target_pool.release(t);
                self.draft_pool.release(d);
                freed += 1;
            }
            if leaf.target_run.is_empty() {
                parent.children.swap_remove(li);
            }
        }
        self.counters.evicted_blocks += (freed * 2) as u64;
        self.counters.reclaimed_under_pressure += (freed * 2) as u64;
        freed
    }

    /// Locate the least-recently-touched leaf whose tail block pair is
    /// reclaimable (both refcounts 1); `path` is the child-index route from
    /// the root.
    fn find_lru_leaf(node: &Node, path: &mut Vec<usize>, best: &mut Option<(u64, Vec<usize>)>) {
        if node.children.is_empty() {
            let tail_free = node.target_run.last().is_some_and(|b| Arc::strong_count(b) == 1)
                && node.draft_run.last().is_some_and(|b| Arc::strong_count(b) == 1);
            if tail_free && best.as_ref().is_none_or(|(t, _)| node.last_touch < *t) {
                *best = Some((node.last_touch, path.clone()));
            }
            return;
        }
        for (i, c) in node.children.iter().enumerate() {
            path.push(i);
            Self::find_lru_leaf(c, path, best);
            path.pop();
        }
    }

    /// Drop every cached run, releasing all block references back to their
    /// pools (blocks still adopted by live lanes just lose the cache's
    /// refcount). Also invoked by `Drop`, so a retired cache can never leak
    /// pool accounting.
    pub fn clear(&mut self) {
        let mut released = 0usize;
        let mut stack = std::mem::take(&mut self.root.children);
        while let Some(mut n) = stack.pop() {
            for b in n.target_run.drain(..) {
                self.target_pool.release(b);
                released += 1;
            }
            for b in n.draft_run.drain(..) {
                self.draft_pool.release(b);
                released += 1;
            }
            stack.append(&mut n.children);
        }
        self.counters.evicted_blocks += released as u64;
    }

    fn release_runs(
        target_pool: &BlockPool,
        draft_pool: &BlockPool,
        t_run: Vec<Arc<KvBlock>>,
        d_run: Vec<Arc<KvBlock>>,
    ) {
        for b in t_run {
            target_pool.release(b);
        }
        for b in d_run {
            draft_pool.release(b);
        }
    }
}

impl Drop for PrefixCache {
    fn drop(&mut self) {
        self.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelDims;

    fn dims() -> ModelDims {
        ModelDims { n_layers: 2, d_model: 8, n_heads: 2, d_head: 4, vocab: 300, max_seq: 64 }
    }

    /// Deterministic committed-row content: a function of the position and
    /// the token at that position, so any lane that committed the same
    /// token prefix holds bit-identical rows (the determinism contract the
    /// real engine provides).
    fn committed_lane(pool: &Arc<BlockPool>, tokens: &[u32], salt: f32) -> PagedKvCache {
        let d = pool.dims();
        let n = d.n_layers * d.n_heads * d.d_head;
        let mut c = PagedKvCache::new(pool);
        for (pos, &tok) in tokens.iter().enumerate() {
            let row: Vec<f32> =
                (0..n).map(|e| salt + tok as f32 * 1000.0 + (pos * n + e) as f32).collect();
            c.commit_row(&row, &row, pos);
        }
        c
    }

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| (i * 7 + seed) % 256).collect()
    }

    #[test]
    fn knob_parsing() {
        assert!(!prefix_cache_from_env_value(None));
        assert!(!prefix_cache_from_env_value(Some("0")));
        assert!(prefix_cache_from_env_value(Some("1")));
        assert!(prefix_cache_from_env_value(Some("true")));
        assert!(prefix_cache_from_env_value(Some("TRUE")));
    }

    #[test]
    fn insert_match_roundtrip_is_bitwise() {
        let tp = BlockPool::new(dims(), 4, None);
        let dp = BlockPool::new(dims(), 4, None);
        let mut cache = PrefixCache::new(&tp, &dp);
        let tokens = toks(11, 3); // 2 whole blocks + 3 spare tokens
        let t_lane = committed_lane(&tp, &tokens, 0.25);
        let d_lane = committed_lane(&dp, &tokens, 0.75);
        let stored = cache.insert(&tokens, &t_lane, &d_lane);
        assert_eq!(stored, 8, "truncated to whole blocks");
        assert_eq!(cache.cached_pairs(), 2);

        let mut wt = KvCache::paged(&tp);
        let mut wd = KvCache::paged(&dp);
        let matched = cache.match_into(&tokens, &mut wt, &mut wd);
        assert_eq!(matched, 8);
        assert_eq!(wt.len(), 8);
        assert_eq!(wd.len(), 8);
        let d = dims();
        for l in 0..d.n_layers {
            for h in 0..d.n_heads {
                for pos in 0..8 {
                    assert_eq!(wt.read_row(l, h, pos), t_lane.row(l, h, pos), "target row {pos}");
                    assert_eq!(wd.read_row(l, h, pos), d_lane.row(l, h, pos), "draft row {pos}");
                }
            }
        }
        // adoption shares blocks, it does not copy them
        assert_eq!(wt.as_paged().unwrap().cow_shared_blocks(), 2);
        let c = cache.counters();
        assert_eq!((c.lookups, c.hits, c.matched_rows, c.inserted_runs), (1, 1, 8, 1));
        drop((wt, wd, t_lane, d_lane));
        drop(cache);
        assert_eq!(tp.live_blocks(), 0, "cache drop releases every reference");
        assert_eq!(dp.live_blocks(), 0);
        tp.validate().unwrap();
        dp.validate().unwrap();
    }

    #[test]
    fn diverging_prompts_split_on_block_boundary() {
        let tp = BlockPool::new(dims(), 4, None);
        let dp = BlockPool::new(dims(), 4, None);
        let mut cache = PrefixCache::new(&tp, &dp);
        let mut a = toks(16, 9);
        let mut b = a.clone();
        b[10] = 255; // diverge inside block 2
        let (ta, da) = (committed_lane(&tp, &a, 1.0), committed_lane(&dp, &a, 2.0));
        let (tb, db) = (committed_lane(&tp, &b, 1.0), committed_lane(&dp, &b, 2.0));
        assert_eq!(cache.insert(&a, &ta, &da), 16);
        // b shares blocks 0..2; blocks 2..4 are new
        assert_eq!(cache.insert(&b, &tb, &db), 8);
        assert_eq!(cache.cached_pairs(), 6);
        // each prompt matches its own full run
        for (toksv, lane) in [(&a, &ta), (&b, &tb)] {
            let mut wt = KvCache::paged(&tp);
            let mut wd = KvCache::paged(&dp);
            assert_eq!(cache.match_into(toksv, &mut wt, &mut wd), 16);
            assert_eq!(wt.read_row(1, 1, 11), lane.row(1, 1, 11));
        }
        // a probe diverging inside block 1 matches exactly one block
        a[5] = 254;
        let mut wt = KvCache::paged(&tp);
        let mut wd = KvCache::paged(&dp);
        assert_eq!(cache.match_into(&a, &mut wt, &mut wd), 4);
        let c = cache.counters();
        assert_eq!(c.lookups, 3);
        assert_eq!(c.hits, 3);
        drop((ta, da, tb, db, wt, wd));
        drop(cache);
        assert_eq!(tp.live_blocks(), 0);
        tp.validate().unwrap();
        dp.validate().unwrap();
    }

    #[test]
    fn sub_block_probe_misses() {
        let tp = BlockPool::new(dims(), 8, None);
        let dp = BlockPool::new(dims(), 8, None);
        let mut cache = PrefixCache::new(&tp, &dp);
        let tokens = toks(16, 1);
        let (t, d) = (committed_lane(&tp, &tokens, 0.0), committed_lane(&dp, &tokens, 0.5));
        cache.insert(&tokens, &t, &d);
        let mut wt = KvCache::paged(&tp);
        let mut wd = KvCache::paged(&dp);
        assert_eq!(cache.match_into(&tokens[..5], &mut wt, &mut wd), 0, "needs a whole block");
        let c = cache.counters();
        assert_eq!(c.lookups, 1);
        assert_eq!(c.hits, 0);
        assert_eq!(c.lookups - c.hits, 1, "misses derive from lookups - hits");
    }

    #[test]
    fn contiguous_lanes_skip_gracefully() {
        let tp = BlockPool::new(dims(), 4, None);
        let dp = BlockPool::new(dims(), 4, None);
        let mut cache = PrefixCache::new(&tp, &dp);
        let mut ct = KvCache::new(dims());
        let mut cd = KvCache::new(dims());
        assert_eq!(cache.match_into(&toks(8, 0), &mut ct, &mut cd), 0);
        let c = cache.counters();
        assert_eq!(c.skipped_contiguous, 1);
        assert_eq!(c.lookups, 0, "skips are not lookups");
    }

    #[test]
    fn reclaim_evicts_lru_unreferenced_runs_only() {
        let tp = BlockPool::new(dims(), 4, None);
        let dp = BlockPool::new(dims(), 4, None);
        let mut cache = PrefixCache::new(&tp, &dp);
        let a = toks(8, 11);
        let b = toks(8, 200); // distinct first block → sibling leaf
        let (ta, da) = (committed_lane(&tp, &a, 1.0), committed_lane(&dp, &a, 2.0));
        let (tb, db) = (committed_lane(&tp, &b, 1.0), committed_lane(&dp, &b, 2.0));
        cache.insert(&a, &ta, &da);
        cache.insert(&b, &tb, &db);
        // lanes still hold every block: nothing reclaimable
        assert_eq!(cache.reclaimable_pairs(), 0);
        assert_eq!(cache.reclaim(4), 0);
        drop((ta, da)); // a's run becomes cache-only
        assert_eq!(cache.reclaimable_pairs(), 2);
        // touch b so a is the LRU leaf, then free one pair: a's tail block
        let mut wt = KvCache::paged(&tp);
        let mut wd = KvCache::paged(&dp);
        cache.match_into(&b, &mut wt, &mut wd);
        let live_before = tp.live_blocks();
        assert_eq!(cache.reclaim(1), 1);
        assert_eq!(tp.live_blocks(), live_before - 1);
        assert_eq!(cache.cached_pairs(), 3);
        // a still matches its first (surviving) block
        let mut xt = KvCache::paged(&tp);
        let mut xd = KvCache::paged(&dp);
        assert_eq!(cache.match_into(&a, &mut xt, &mut xd), 4);
        // drain everything reclaimable
        drop((wt, wd, xt, xd, tb, db));
        let freed = cache.reclaim(usize::MAX);
        assert_eq!(freed, 3);
        assert_eq!(cache.cached_pairs(), 0);
        assert_eq!(tp.live_blocks(), 0);
        assert_eq!(dp.live_blocks(), 0);
        let c = cache.counters();
        assert_eq!(c.evicted_blocks, 8);
        assert_eq!(c.reclaimed_under_pressure, 8);
        tp.validate().unwrap();
        dp.validate().unwrap();
    }

    #[test]
    fn repeated_insert_keeps_existing_runs() {
        let tp = BlockPool::new(dims(), 4, None);
        let dp = BlockPool::new(dims(), 4, None);
        let mut cache = PrefixCache::new(&tp, &dp);
        let tokens = toks(12, 5);
        let (t1, d1) = (committed_lane(&tp, &tokens, 3.0), committed_lane(&dp, &tokens, 4.0));
        assert_eq!(cache.insert(&tokens, &t1, &d1), 12);
        let live = tp.live_blocks();
        let (t2, d2) = (committed_lane(&tp, &tokens, 3.0), committed_lane(&dp, &tokens, 4.0));
        assert_eq!(cache.insert(&tokens, &t2, &d2), 0, "fully cached prefix stores nothing");
        assert_eq!(cache.cached_pairs(), 3);
        assert_eq!(cache.counters().inserted_runs, 1);
        drop((t2, d2));
        assert_eq!(tp.live_blocks(), live, "duplicate insert leaks no references");
        drop((t1, d1));
        drop(cache);
        assert_eq!(tp.live_blocks(), 0);
        tp.validate().unwrap();
    }
}
