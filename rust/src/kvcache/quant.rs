//! Scalar quantization primitives for reduced-precision KV blocks.
//!
//! Two codecs, both pure and deterministic:
//!
//! * **f16** — IEEE 754 binary16, converted manually (no nightly `f16`,
//!   no new dependencies) with round-to-nearest-even, the hardware
//!   rounding mode. Conversion is per-element and stateless, so a stored
//!   f16 value is a pure function of the single f32 written.
//! * **int8** — affine (asymmetric) 8-bit codes `q ∈ [0, 255]` with
//!   per-span `scale`/`zero_point` chosen from the span's min/max:
//!   `x̂ = zero + scale·q`. The KV pool applies this per
//!   (block, layer·head, token-row) `d_head` span, so writing one row
//!   never perturbs the dequantized contents of any other row — the
//!   content-purity property the paged cache's determinism contract
//!   (batched == serial, write-order independence) relies on.
//!
//! Both codecs map an all-zero span to exactly `0.0`, matching the
//! "unallocated blocks read as zeros" contract of
//! [`PagedKvCache`](super::PagedKvCache).

/// Convert an `f32` to IEEE 754 binary16 bits with round-to-nearest-even.
/// Overflow saturates to ±infinity; NaN payloads are quietened.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // infinity (mantissa 0) or NaN (quietened)
        return sign | if abs > 0x7f80_0000 { 0x7e00 } else { 0x7c00 };
    }
    let exp = (abs >> 23) as i32 - 127;
    let mant = (abs & 0x007f_ffff) | 0x0080_0000; // 24-bit significand
    if exp < -25 {
        // below half the smallest subnormal: rounds to (signed) zero
        return sign;
    }
    // Bits shifted off the 24-bit significand: 13 for normals, more as
    // the value sinks into the subnormal range.
    let shift: u32 = if exp < -14 { (13 + (-14 - exp)) as u32 } else { 13 };
    let halfway = 1u32 << (shift - 1);
    let rest = mant & ((1u32 << shift) - 1);
    let mut out = mant >> shift;
    if rest > halfway || (rest == halfway && (out & 1) == 1) {
        out += 1; // round to nearest, ties to even
    }
    if exp < -14 {
        // subnormal result; a rounding carry into bit 10 promotes to the
        // smallest normal, which the bit pattern encodes naturally
        return sign | out as u16;
    }
    // normal result: remove the implicit bit and add the exponent field;
    // a rounding carry propagates into the exponent via the addition
    let val = (((exp + 15) as u32) << 10) + (out - (1 << 10));
    if val >= 0x7c00 {
        return sign | 0x7c00; // rounded past the largest finite half
    }
    sign | val as u16
}

/// Convert IEEE 754 binary16 bits back to `f32` (exact — every half value
/// is representable in single precision).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // subnormal: value = (mant/1024)·2^-14 — normalize into f32
            let mut e = -14i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (((e + 127) as u32) << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an `f32` through binary16 and back: the value a reduced-precision
/// KV pool actually stores for a written element.
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Affine int8 parameters for one quantized span: `x̂ = zero + scale·q`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Affine {
    /// Step between adjacent codes; `0.0` for a constant span (every code
    /// dequantizes to `zero` exactly).
    pub scale: f32,
    /// Value of code 0 (the span minimum).
    pub zero: f32,
}

impl Affine {
    /// The parameters of an all-zero (never written) span.
    pub const ZERO: Affine = Affine { scale: 0.0, zero: 0.0 };
}

/// Choose affine parameters covering `xs` exactly at the extremes:
/// `scale = (max − min)/255`, `zero = min`. A constant (or empty) span
/// gets `scale = 0`, so dequantization reproduces the constant exactly —
/// in particular an all-zero span dequantizes to exact zeros.
pub fn affine_params(xs: &[f32]) -> Affine {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    if !min.is_finite() || !max.is_finite() || max <= min {
        return Affine { scale: 0.0, zero: if min.is_finite() { min } else { 0.0 } };
    }
    Affine { scale: (max - min) / 255.0, zero: min }
}

/// Quantize one element under `a` (round to nearest code, clamped).
#[inline]
pub fn affine_quantize(x: f32, a: Affine) -> u8 {
    if a.scale == 0.0 {
        return 0;
    }
    ((x - a.zero) / a.scale).round().clamp(0.0, 255.0) as u8
}

/// Dequantize one code under `a`.
#[inline]
pub fn affine_dequantize(q: u8, a: Affine) -> f32 {
    a.zero + a.scale * q as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trip_exact_for_representable_values() {
        for x in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, -2.5, 0.25, 1024.0, 65504.0, -65504.0,
            6.103515625e-5,          // smallest normal
            5.960464477539063e-8,    // smallest subnormal
        ] {
            let r = f16_round(x);
            assert_eq!(r.to_bits(), x.to_bits(), "{x} not preserved (got {r})");
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1.0 + 2^-11 sits exactly halfway between 1.0 and the next half
        // (1.0 + 2^-10); ties go to the even mantissa, i.e. 1.0.
        let tie = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f16_round(tie), 1.0);
        // 1.0 + 3·2^-11 is halfway between odd 1.0+2^-10 and even 1.0+2^-9
        let tie_up = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f16_round(tie_up), 1.0 + 2.0f32.powi(-9));
        // just above halfway rounds up
        assert_eq!(f16_round(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20)), 1.0 + 2.0f32.powi(-10));
        // relative error of any normal-range value is bounded by 2^-11
        for i in 0..200 {
            let x = 0.37f32 * i as f32 + 0.013;
            let r = f16_round(x);
            assert!((r - x).abs() <= x.abs() * 2.0f32.powi(-11) + 1e-12, "{x} -> {r}");
        }
    }

    #[test]
    fn f16_specials_and_overflow() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // past the largest finite half: saturate to infinity
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e9), 0xfc00);
        // largest value that still rounds down to 65504
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(65519.0)), 65504.0);
        // underflow to zero keeps the sign
        assert_eq!(f32_to_f16_bits(1e-12), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-12), 0x8000);
        // subnormal round trip through the bit patterns
        for bits in [0x0001u16, 0x0155, 0x03ff, 0x8001, 0x83ff] {
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(bits)), bits);
        }
    }

    #[test]
    fn affine_constant_and_zero_spans_are_exact() {
        let a = affine_params(&[0.0; 8]);
        assert_eq!(a, Affine::ZERO);
        assert_eq!(affine_dequantize(affine_quantize(0.0, a), a), 0.0);
        let c = affine_params(&[-3.25; 5]);
        assert_eq!(c.scale, 0.0);
        assert_eq!(affine_dequantize(affine_quantize(-3.25, c), c), -3.25);
        assert_eq!(affine_params(&[]), Affine { scale: 0.0, zero: 0.0 });
    }

    #[test]
    fn affine_error_bounded_by_half_step_and_exact_at_extremes() {
        let xs: Vec<f32> = (0..32).map(|i| (i as f32 * 0.77).sin() * 4.0 - 1.0).collect();
        let a = affine_params(&xs);
        assert!(a.scale > 0.0);
        let min = xs.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for &x in &xs {
            let xhat = affine_dequantize(affine_quantize(x, a), a);
            assert!((xhat - x).abs() <= a.scale * 0.5 + 1e-6, "{x} -> {xhat} (scale {})", a.scale);
        }
        // the span extremes are codes 0 and 255 (up to fp rounding)
        let rmin = affine_dequantize(affine_quantize(min, a), a);
        let rmax = affine_dequantize(affine_quantize(max, a), a);
        assert!((rmin - min).abs() <= a.scale * 1e-3);
        assert!((rmax - max).abs() <= a.scale * 1e-3);
    }

    #[test]
    fn affine_codes_monotone() {
        let a = Affine { scale: 0.1, zero: -1.0 };
        let mut last = 0u8;
        for i in 0..=100 {
            let q = affine_quantize(-1.0 + i as f32 * 0.02, a);
            assert!(q >= last, "codes must be monotone in the input");
            last = q;
        }
        assert_eq!(affine_quantize(-5.0, a), 0, "clamped below");
        assert_eq!(affine_quantize(500.0, a), 255, "clamped above");
    }
}
