//! Host-resident KV cache with row-level commit.
//!
//! The AOT entry points are pure: caches go in as arguments and new rows
//! come back as outputs. The manager owns the canonical [L, H, S, Dh] f32
//! buffers per sequence, scatters accepted rows after verification, and
//! rolls back simply by *not* committing rejected rows.

use crate::runtime::ModelDims;

#[derive(Clone)]
pub struct KvCache {
    pub dims: ModelDims,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Number of committed rows (tokens with valid KV), i.e. the position
    /// where the next row will be written.
    pub len: usize,
}

impl KvCache {
    pub fn new(dims: ModelDims) -> KvCache {
        let n = dims.kv_elems();
        KvCache { dims, k: vec![0.0; n], v: vec![0.0; n], len: 0 }
    }

    #[inline]
    fn row_offset(&self, layer: usize, head: usize, pos: usize) -> usize {
        ((layer * self.dims.n_heads + head) * self.dims.max_seq + pos) * self.dims.d_head
    }

    /// Commit prefill rows laid out [L, H, s_pre, Dh] for positions 0..len.
    pub fn commit_prefill(&mut self, k_rows: &[f32], v_rows: &[f32], s_pre: usize, len: usize) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * h * s_pre * dh);
        for l in 0..lyr {
            for hh in 0..h {
                let src = ((l * h + hh) * s_pre) * dh;
                let dst = self.row_offset(l, hh, 0);
                self.k[dst..dst + len * dh].copy_from_slice(&k_rows[src..src + len * dh]);
                self.v[dst..dst + len * dh].copy_from_slice(&v_rows[src..src + len * dh]);
            }
        }
        self.len = len;
    }

    /// Commit one row laid out [L, H, Dh] at `pos`.
    pub fn commit_row(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_row.len(), lyr * h * dh);
        for l in 0..lyr {
            for hh in 0..h {
                let src = (l * h + hh) * dh;
                let dst = self.row_offset(l, hh, pos);
                self.k[dst..dst + dh].copy_from_slice(&k_row[src..src + dh]);
                self.v[dst..dst + dh].copy_from_slice(&v_row[src..src + dh]);
            }
        }
        self.len = self.len.max(pos + 1);
    }

    /// Commit rollout rows [Lyr, K, L, H, Dh]: path `branch`, steps
    /// 0..=last_step, at positions base_pos + step.
    #[allow(clippy::too_many_arguments)]
    pub fn commit_rollout_rows(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * k_paths * l_steps * h * dh);
        for l in 0..lyr {
            for step in 0..=last_step {
                for hh in 0..h {
                    let src = ((((l * k_paths + branch) * l_steps) + step) * h + hh) * dh;
                    let dst = self.row_offset(l, hh, base_pos + step);
                    self.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                    self.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
                }
            }
        }
        self.len = self.len.max(base_pos + last_step + 1);
    }

    /// Commit tree-pass rows [Lyr, N, H, Dh] for node `node_idx` at `pos`.
    #[allow(clippy::too_many_arguments)]
    pub fn commit_tree_row(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        n_bucket: usize,
        node_idx: usize,
        pos: usize,
    ) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * n_bucket * h * dh);
        for l in 0..lyr {
            for hh in 0..h {
                let src = ((l * n_bucket + node_idx) * h + hh) * dh;
                let dst = self.row_offset(l, hh, pos);
                self.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                self.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
            }
        }
        self.len = self.len.max(pos + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { n_layers: 2, d_model: 8, n_heads: 2, d_head: 4, vocab: 10, max_seq: 16 }
    }

    #[test]
    fn commit_row_places_values() {
        let mut c = KvCache::new(dims());
        let row: Vec<f32> = (0..16).map(|x| x as f32).collect(); // [2,2,4]
        c.commit_row(&row, &row, 3);
        assert_eq!(c.len, 4);
        // layer 1, head 1 slice = row[12..16]
        let off = c.row_offset(1, 1, 3);
        assert_eq!(&c.k[off..off + 4], &[12.0, 13.0, 14.0, 15.0]);
        // untouched rows remain zero
        let off2 = c.row_offset(1, 1, 2);
        assert_eq!(&c.k[off2..off2 + 4], &[0.0; 4]);
    }

    #[test]
    fn commit_prefill_layout() {
        let d = dims();
        let mut c = KvCache::new(d);
        let s_pre = 4;
        let n = d.n_layers * d.n_heads * s_pre * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_prefill(&rows, &rows, s_pre, 3);
        assert_eq!(c.len, 3);
        // layer 0, head 1, pos 2 = src offset ((0*2+1)*4+2)*4 = 24
        let off = c.row_offset(0, 1, 2);
        assert_eq!(c.k[off], 24.0);
    }

    #[test]
    fn commit_rollout_rows_branch_selection() {
        let d = dims();
        let mut c = KvCache::new(d);
        let (kp, ls) = (3, 2);
        let n = d.n_layers * kp * ls * d.n_heads * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_rollout_rows(&rows, &rows, kp, ls, 1, 1, 5);
        assert_eq!(c.len, 7);
        // layer 0, branch 1, step 0, head 0: src ((0*3+1)*2+0)*2*4 + 0 = 16
        let off = c.row_offset(0, 0, 5);
        assert_eq!(c.k[off], 16.0);
    }

    #[test]
    fn commit_tree_row_layout() {
        let d = dims();
        let mut c = KvCache::new(d);
        let nb = 4;
        let n = d.n_layers * nb * d.n_heads * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_tree_row(&rows, &rows, nb, 2, 7);
        // layer 1, node 2, head 0: src ((1*4+2)*2+0)*4 = 48
        let off = c.row_offset(1, 0, 7);
        assert_eq!(c.k[off], 48.0);
        assert_eq!(c.len, 8);
    }
}
