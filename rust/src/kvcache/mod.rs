//! Host-resident KV cache with row-level commit.
//!
//! The AOT entry points are pure: caches go in as arguments and new rows
//! come back as outputs. The manager owns the canonical [L, H, S, Dh] f32
//! buffers per sequence, scatters accepted rows after verification, and
//! rolls back simply by *not* committing rejected rows.
//!
//! ## Copy coalescing
//!
//! The [L, H, S, Dh] destination layout is part of the compiled-module
//! interface, and it places a token's heads `max_seq·d_head` apart — so a
//! head-spanning `n_heads·d_head` copy per (layer, step/node) is only legal
//! when the layout degenerates (`KvCache::heads_contiguous`: one head, or
//! `max_seq == 1`). What the layout *does* make contiguous is the step
//! axis: positions are adjacent per (layer, head), so the rollout commit
//! coalesces all accepted steps into one span copy whenever the source
//! rollout is also step-contiguous (single-head models), and otherwise
//! walks hoisted strides instead of recomputing `row_offset` per
//! (step, head). Equivalence against the naive per-element scatter is
//! asserted in the tests below.

use crate::runtime::ModelDims;

/// One sequence's host-resident KV cache (one lane of the batched loop).
#[derive(Clone)]
pub struct KvCache {
    /// Model dimensions fixing the `[L, H, S, Dh]` layout.
    pub dims: ModelDims,
    /// Key buffer, `[L, H, S, Dh]` flat.
    pub k: Vec<f32>,
    /// Value buffer, `[L, H, S, Dh]` flat.
    pub v: Vec<f32>,
    /// Number of committed rows (tokens with valid KV), i.e. the position
    /// where the next row will be written.
    pub len: usize,
}

impl KvCache {
    /// Zeroed cache sized by the model's dimensions.
    pub fn new(dims: ModelDims) -> KvCache {
        let n = dims.kv_elems();
        KvCache { dims, k: vec![0.0; n], v: vec![0.0; n], len: 0 }
    }

    #[inline]
    fn row_offset(&self, layer: usize, head: usize, pos: usize) -> usize {
        ((layer * self.dims.n_heads + head) * self.dims.max_seq + pos) * self.dims.d_head
    }

    /// Whether a token's heads are adjacent in the cache layout, making a
    /// single `n_heads·d_head` copy per (layer, step/node) legal. With the
    /// canonical [L, H, S, Dh] layout that is exactly the degenerate cases.
    #[inline]
    fn heads_contiguous(&self) -> bool {
        self.dims.n_heads == 1 || self.dims.max_seq == 1
    }

    /// Refresh this cache as a prefix copy of `src`: rows `< rows` are
    /// copied (one contiguous span per (layer, head), so the cost tracks
    /// the committed context, not `max_seq`), rows past the prefix keep
    /// their previous contents and **must not be read**. Allocation-free —
    /// the scratch-reuse half of [`KvCache::clone_prefix`]; dims must
    /// match.
    pub fn copy_prefix_from(&mut self, src: &KvCache, rows: usize) {
        debug_assert_eq!(self.k.len(), src.k.len(), "prefix copy across dims");
        let rows = rows.min(self.dims.max_seq);
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        let n = rows * dh;
        for l in 0..lyr {
            for hh in 0..h {
                let off = self.row_offset(l, hh, 0);
                self.k[off..off + n].copy_from_slice(&src.k[off..off + n]);
                self.v[off..off + n].copy_from_slice(&src.v[off..off + n]);
            }
        }
        self.len = src.len.min(rows);
    }

    /// Freshly allocated copy of this cache holding only rows `< rows`
    /// (later rows zero). Allocating convenience wrapper over
    /// [`KvCache::copy_prefix_from`].
    pub fn clone_prefix(&self, rows: usize) -> KvCache {
        let mut out = KvCache::new(self.dims);
        out.copy_prefix_from(self, rows);
        out
    }

    /// Commit prefill rows laid out [L, H, s_pre, Dh] for positions 0..len.
    pub fn commit_prefill(&mut self, k_rows: &[f32], v_rows: &[f32], s_pre: usize, len: usize) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * h * s_pre * dh);
        for l in 0..lyr {
            for hh in 0..h {
                let src = ((l * h + hh) * s_pre) * dh;
                let dst = self.row_offset(l, hh, 0);
                self.k[dst..dst + len * dh].copy_from_slice(&k_rows[src..src + len * dh]);
                self.v[dst..dst + len * dh].copy_from_slice(&v_rows[src..src + len * dh]);
            }
        }
        self.len = len;
    }

    /// Commit one row laid out [L, H, Dh] at `pos`. The source heads are
    /// contiguous; when the cache layout agrees the row commits as one
    /// `n_heads·d_head` copy per layer.
    pub fn commit_row(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_row.len(), lyr * h * dh);
        let dst_head_stride = self.dims.max_seq * dh;
        for l in 0..lyr {
            let src0 = l * h * dh;
            let dst0 = self.row_offset(l, 0, pos);
            if self.heads_contiguous() {
                let n = h * dh;
                self.k[dst0..dst0 + n].copy_from_slice(&k_row[src0..src0 + n]);
                self.v[dst0..dst0 + n].copy_from_slice(&v_row[src0..src0 + n]);
            } else {
                let (mut src, mut dst) = (src0, dst0);
                for _hh in 0..h {
                    self.k[dst..dst + dh].copy_from_slice(&k_row[src..src + dh]);
                    self.v[dst..dst + dh].copy_from_slice(&v_row[src..src + dh]);
                    src += dh;
                    dst += dst_head_stride;
                }
            }
        }
        self.len = self.len.max(pos + 1);
    }

    /// Commit rollout rows [Lyr, K, L, H, Dh]: path `branch`, steps
    /// 0..=last_step, at positions base_pos + step.
    ///
    /// Per (layer, head) the destination span `base_pos..=base_pos+last_step`
    /// is one contiguous slice (the S axis sits next to Dh). The source's
    /// step stride is `n_heads·d_head`, so for single-head models the whole
    /// accepted span is one `copy_from_slice`; otherwise the inner loop
    /// advances both strides directly instead of recomputing `row_offset`
    /// per (step, head).
    #[allow(clippy::too_many_arguments)]
    pub fn commit_rollout_rows(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * k_paths * l_steps * h * dh);
        let steps = last_step + 1;
        let src_step_stride = h * dh;
        for l in 0..lyr {
            for hh in 0..h {
                // step 0 of this (layer, branch, head) in the rollout output
                let src0 = (((l * k_paths + branch) * l_steps) * h + hh) * dh;
                let dst0 = self.row_offset(l, hh, base_pos);
                if h == 1 {
                    // src and dst are both step-contiguous: one span copy
                    let n = steps * dh;
                    self.k[dst0..dst0 + n].copy_from_slice(&k_rows[src0..src0 + n]);
                    self.v[dst0..dst0 + n].copy_from_slice(&v_rows[src0..src0 + n]);
                } else {
                    let (mut src, mut dst) = (src0, dst0);
                    for _step in 0..steps {
                        self.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                        self.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
                        src += src_step_stride;
                        dst += dh;
                    }
                }
            }
        }
        self.len = self.len.max(base_pos + last_step + 1);
    }

    /// Commit tree-pass rows [Lyr, N, H, Dh] for node `node_idx` at `pos`.
    ///
    /// The source places a node's heads contiguously, so when the cache
    /// layout agrees (`KvCache::heads_contiguous`) the whole node commits
    /// as one `n_heads·d_head` copy per layer; otherwise the per-head loop
    /// advances hoisted strides.
    #[allow(clippy::too_many_arguments)]
    pub fn commit_tree_row(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        n_bucket: usize,
        node_idx: usize,
        pos: usize,
    ) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * n_bucket * h * dh);
        let dst_head_stride = self.dims.max_seq * dh;
        for l in 0..lyr {
            let src0 = (l * n_bucket + node_idx) * h * dh;
            let dst0 = self.row_offset(l, 0, pos);
            if self.heads_contiguous() {
                let n = h * dh;
                self.k[dst0..dst0 + n].copy_from_slice(&k_rows[src0..src0 + n]);
                self.v[dst0..dst0 + n].copy_from_slice(&v_rows[src0..src0 + n]);
            } else {
                let (mut src, mut dst) = (src0, dst0);
                for _hh in 0..h {
                    self.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                    self.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
                    src += dh;
                    dst += dst_head_stride;
                }
            }
        }
        self.len = self.len.max(pos + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { n_layers: 2, d_model: 8, n_heads: 2, d_head: 4, vocab: 10, max_seq: 16 }
    }

    #[test]
    fn commit_row_places_values() {
        let mut c = KvCache::new(dims());
        let row: Vec<f32> = (0..16).map(|x| x as f32).collect(); // [2,2,4]
        c.commit_row(&row, &row, 3);
        assert_eq!(c.len, 4);
        // layer 1, head 1 slice = row[12..16]
        let off = c.row_offset(1, 1, 3);
        assert_eq!(&c.k[off..off + 4], &[12.0, 13.0, 14.0, 15.0]);
        // untouched rows remain zero
        let off2 = c.row_offset(1, 1, 2);
        assert_eq!(&c.k[off2..off2 + 4], &[0.0; 4]);
    }

    #[test]
    fn clone_prefix_copies_only_prefix_rows() {
        let d = dims();
        let mut c = KvCache::new(d);
        for (i, v) in c.k.iter_mut().enumerate() {
            *v = i as f32 + 1.0;
        }
        c.v.copy_from_slice(&c.k);
        c.len = 6;
        let p = c.clone_prefix(3);
        assert_eq!(p.len, 3);
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                for pos in 0..d.max_seq {
                    let off = p.row_offset(l, hh, pos);
                    let want = if pos < 3 { c.k[off] } else { 0.0 };
                    assert_eq!(p.k[off], want, "l={l} h={hh} pos={pos}");
                    assert_eq!(p.v[off], want, "l={l} h={hh} pos={pos}");
                }
            }
        }
        // clamps past max_seq
        let full = c.clone_prefix(d.max_seq + 5);
        assert_eq!(full.k, c.k);
        assert_eq!(full.len, 6);
        // the reusing entry refreshes the prefix in place (stale tail kept)
        let mut reuse = KvCache::new(d);
        reuse.k.fill(-1.0);
        reuse.v.fill(-1.0);
        reuse.copy_prefix_from(&c, 3);
        assert_eq!(reuse.len, 3);
        let off_in = reuse.row_offset(1, 1, 2);
        let off_out = reuse.row_offset(1, 1, 3);
        assert_eq!(reuse.k[off_in], c.k[off_in]);
        assert_eq!(reuse.k[off_out], -1.0, "tail rows keep stale contents");
    }

    #[test]
    fn commit_prefill_layout() {
        let d = dims();
        let mut c = KvCache::new(d);
        let s_pre = 4;
        let n = d.n_layers * d.n_heads * s_pre * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_prefill(&rows, &rows, s_pre, 3);
        assert_eq!(c.len, 3);
        // layer 0, head 1, pos 2 = src offset ((0*2+1)*4+2)*4 = 24
        let off = c.row_offset(0, 1, 2);
        assert_eq!(c.k[off], 24.0);
    }

    #[test]
    fn commit_rollout_rows_branch_selection() {
        let d = dims();
        let mut c = KvCache::new(d);
        let (kp, ls) = (3, 2);
        let n = d.n_layers * kp * ls * d.n_heads * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_rollout_rows(&rows, &rows, kp, ls, 1, 1, 5);
        assert_eq!(c.len, 7);
        // layer 0, branch 1, step 0, head 0: src ((0*3+1)*2+0)*2*4 + 0 = 16
        let off = c.row_offset(0, 0, 5);
        assert_eq!(c.k[off], 16.0);
    }

    #[test]
    fn commit_tree_row_layout() {
        let d = dims();
        let mut c = KvCache::new(d);
        let nb = 4;
        let n = d.n_layers * nb * d.n_heads * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_tree_row(&rows, &rows, nb, 2, 7);
        // layer 1, node 2, head 0: src ((1*4+2)*2+0)*4 = 48
        let off = c.row_offset(1, 0, 7);
        assert_eq!(c.k[off], 48.0);
        assert_eq!(c.len, 8);
    }

    /// Naive per-element reference for the rollout scatter.
    fn reference_rollout(
        c: &mut KvCache,
        rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        let (lyr, h, dh) = (c.dims.n_layers, c.dims.n_heads, c.dims.d_head);
        for l in 0..lyr {
            for step in 0..=last_step {
                for hh in 0..h {
                    for e in 0..dh {
                        let src = ((((l * k_paths + branch) * l_steps) + step) * h + hh) * dh + e;
                        let dst = c.row_offset(l, hh, base_pos + step) + e;
                        c.k[dst] = rows[src];
                        c.v[dst] = rows[src];
                    }
                }
            }
        }
        c.len = c.len.max(base_pos + last_step + 1);
    }

    /// The coalesced commits must scatter exactly like the per-element
    /// reference, across head counts (incl. the single-head span-copy fast
    /// path), branches and partial step extents.
    #[test]
    fn coalesced_commits_match_reference() {
        for n_heads in [1usize, 2, 3] {
            let d = ModelDims {
                n_layers: 2,
                d_model: 8,
                n_heads,
                d_head: 4,
                vocab: 10,
                max_seq: 16,
            };
            let (kp, ls) = (3, 4);
            let n = d.n_layers * kp * ls * n_heads * d.d_head;
            let rows: Vec<f32> = (0..n).map(|x| (x as f32) * 0.5 + 1.0).collect();
            for branch in 0..kp {
                for last_step in 0..ls {
                    let mut fast = KvCache::new(d);
                    let mut slow = KvCache::new(d);
                    fast.commit_rollout_rows(&rows, &rows, kp, ls, branch, last_step, 5);
                    reference_rollout(&mut slow, &rows, kp, ls, branch, last_step, 5);
                    assert_eq!(fast.k, slow.k, "h={n_heads} b={branch} s={last_step}");
                    assert_eq!(fast.v, slow.v, "h={n_heads} b={branch} s={last_step}");
                    assert_eq!(fast.len, slow.len);
                }
            }
            // tree-row and single-row commits against the same reference idea
            let nb = 4;
            let nt = d.n_layers * nb * n_heads * d.d_head;
            let trows: Vec<f32> = (0..nt).map(|x| x as f32 + 0.25).collect();
            let mut fast = KvCache::new(d);
            fast.commit_tree_row(&trows, &trows, nb, 1, 3);
            let mut slow = KvCache::new(d);
            for l in 0..d.n_layers {
                for hh in 0..n_heads {
                    for e in 0..d.d_head {
                        let src = ((l * nb + 1) * n_heads + hh) * d.d_head + e;
                        let dst = slow.row_offset(l, hh, 3) + e;
                        slow.k[dst] = trows[src];
                        slow.v[dst] = trows[src];
                    }
                }
            }
            slow.len = 4;
            assert_eq!(fast.k, slow.k, "tree h={n_heads}");
            assert_eq!(fast.len, slow.len);

            let nr = d.n_layers * n_heads * d.d_head;
            let rrow: Vec<f32> = (0..nr).map(|x| x as f32 + 0.75).collect();
            let mut fast = KvCache::new(d);
            fast.commit_row(&rrow, &rrow, 2);
            let mut slow = KvCache::new(d);
            for l in 0..d.n_layers {
                for hh in 0..n_heads {
                    for e in 0..d.d_head {
                        let src = (l * n_heads + hh) * d.d_head + e;
                        let dst = slow.row_offset(l, hh, 2) + e;
                        slow.k[dst] = rrow[src];
                        slow.v[dst] = rrow[src];
                    }
                }
            }
            slow.len = 3;
            assert_eq!(fast.k, slow.k, "row h={n_heads}");
            assert_eq!(fast.len, slow.len);
        }
    }
}
