//! Host-resident KV caches with row-level commit, in two storage
//! representations behind one surface.
//!
//! The AOT entry points are pure: caches go in as arguments and new rows
//! come back as outputs. A cache owns the canonical `[L, H, S, Dh]` f32
//! row space per sequence, scatters accepted rows after verification, and
//! rolls back simply by *not* committing rejected rows. Two storages
//! implement that contract:
//!
//! * [`ContiguousKv`] — one flat buffer per lane, rows resolved by offset
//!   arithmetic. The reference implementation and the bit-exact oracle.
//! * [`PagedKvCache`] — a copy-on-write block table over a shared
//!   [`BlockPool`] (see [`paged`]): resident memory tracks committed
//!   tokens, prefix forks are refcount bumps, and a serving loop can cap
//!   the pool for admission-level backpressure.
//!
//! [`KvCache`] is the storage enum the serving stack carries (selected by
//! [`KvStorage::global`], env knob `SPECDELAY_PAGED_KV`; paged pools
//! additionally pick an element precision via [`KvDtype::global`], env
//! knob `SPECDELAY_KV_DTYPE` — quantize-on-write, dequantize-on-read, see
//! [`quant`]), and [`KvRef`] is
//! the read-only view the [`Backend`](crate::runtime::Backend) entry
//! points take: the CPU backend gathers attention rows *through* it (block
//! tables included), while the PJRT engine materialises paged lanes into
//! contiguous scratch before upload.
//!
//! ## Copy coalescing
//!
//! The `[L, H, S, Dh]` destination layout is part of the compiled-module
//! interface, and it places a token's heads `max_seq·d_head` apart — so a
//! head-spanning `n_heads·d_head` copy per (layer, step/node) is only legal
//! when the layout degenerates (`ContiguousKv::heads_contiguous`: one head,
//! or `max_seq == 1`). What the layout *does* make contiguous is the step
//! axis: positions are adjacent per (layer, head), so the rollout commit
//! coalesces all accepted steps into one span copy whenever the source
//! rollout is also step-contiguous (single-head models), and otherwise
//! walks hoisted strides instead of recomputing `row_offset` per
//! (step, head). The paged storage preserves exactly this coalescing per
//! block (its position axis is tiled, not reordered). Equivalence against
//! the naive per-element scatter is asserted in the tests below;
//! paged-vs-contiguous bitwise equality is fuzzed in `tests/paged_kv.rs`.

pub mod paged;
pub mod quant;
pub mod radix;

pub use paged::{default_block_tokens, BlockPool, KvDtype, KvStorage, PagedKvCache};
pub use radix::{prefix_cache_enabled, PrefixCache, PrefixCacheCounters};

use crate::runtime::ModelDims;

/// One sequence's contiguous KV lane: flat `[L, H, S, Dh]` buffers.
#[derive(Clone)]
pub struct ContiguousKv {
    /// Model dimensions fixing the `[L, H, S, Dh]` layout.
    pub dims: ModelDims,
    /// Key buffer, `[L, H, S, Dh]` flat.
    pub k: Vec<f32>,
    /// Value buffer, `[L, H, S, Dh]` flat.
    pub v: Vec<f32>,
    /// Number of committed rows (tokens with valid KV), i.e. the position
    /// where the next row will be written.
    pub len: usize,
}

impl ContiguousKv {
    /// Zeroed cache sized by the model's dimensions.
    pub fn new(dims: ModelDims) -> ContiguousKv {
        let n = dims.kv_elems();
        ContiguousKv { dims, k: vec![0.0; n], v: vec![0.0; n], len: 0 }
    }

    #[inline]
    fn row_offset(&self, layer: usize, head: usize, pos: usize) -> usize {
        ((layer * self.dims.n_heads + head) * self.dims.max_seq + pos) * self.dims.d_head
    }

    /// Read the `d_head` K/V slices at `(layer, head, pos)`.
    #[inline]
    pub fn row(&self, layer: usize, head: usize, pos: usize) -> (&[f32], &[f32]) {
        let off = self.row_offset(layer, head, pos);
        let dh = self.dims.d_head;
        (&self.k[off..off + dh], &self.v[off..off + dh])
    }

    /// Whether a token's heads are adjacent in the cache layout, making a
    /// single `n_heads·d_head` copy per (layer, step/node) legal. With the
    /// canonical [L, H, S, Dh] layout that is exactly the degenerate cases.
    #[inline]
    fn heads_contiguous(&self) -> bool {
        self.dims.n_heads == 1 || self.dims.max_seq == 1
    }

    /// Refresh this cache as a prefix copy of `src`: rows `< rows` are
    /// copied (one contiguous span per (layer, head), so the cost tracks
    /// the committed context, not `max_seq`), rows past the prefix keep
    /// their previous contents and **must not be read**. Allocation-free —
    /// the scratch-reuse half of [`ContiguousKv::clone_prefix`]; dims must
    /// match.
    pub fn copy_prefix_from(&mut self, src: &ContiguousKv, rows: usize) {
        debug_assert_eq!(self.k.len(), src.k.len(), "prefix copy across dims");
        let rows = rows.min(self.dims.max_seq);
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        let n = rows * dh;
        for l in 0..lyr {
            for hh in 0..h {
                let off = self.row_offset(l, hh, 0);
                self.k[off..off + n].copy_from_slice(&src.k[off..off + n]);
                self.v[off..off + n].copy_from_slice(&src.v[off..off + n]);
            }
        }
        self.len = src.len.min(rows);
    }

    /// Freshly allocated copy of this cache holding only rows `< rows`
    /// (later rows zero). Allocating convenience wrapper over
    /// [`ContiguousKv::copy_prefix_from`].
    pub fn clone_prefix(&self, rows: usize) -> ContiguousKv {
        let mut out = ContiguousKv::new(self.dims);
        out.copy_prefix_from(self, rows);
        out
    }

    /// Commit prefill rows laid out [L, H, s_pre, Dh] for positions 0..len.
    pub fn commit_prefill(&mut self, k_rows: &[f32], v_rows: &[f32], s_pre: usize, len: usize) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * h * s_pre * dh);
        for l in 0..lyr {
            for hh in 0..h {
                let src = ((l * h + hh) * s_pre) * dh;
                let dst = self.row_offset(l, hh, 0);
                self.k[dst..dst + len * dh].copy_from_slice(&k_rows[src..src + len * dh]);
                self.v[dst..dst + len * dh].copy_from_slice(&v_rows[src..src + len * dh]);
            }
        }
        self.len = len;
    }

    /// Commit a prefill *chunk*: rows laid out [L, H, stride, Dh] where the
    /// first `len` source rows land at positions `start..start + len`. This
    /// is the incremental sibling of [`ContiguousKv::commit_prefill`]
    /// (which always starts at position 0 and resets `len`): chunked
    /// prefill commits each chunk as it is produced, and the committed row
    /// count only ever grows.
    pub fn commit_chunk(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        stride: usize,
        start: usize,
        len: usize,
    ) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert!(len <= stride, "chunk rows {len} exceed source stride {stride}");
        assert!(start + len <= self.dims.max_seq, "chunk past max_seq");
        assert_eq!(k_rows.len(), lyr * h * stride * dh);
        for l in 0..lyr {
            for hh in 0..h {
                let src = ((l * h + hh) * stride) * dh;
                let dst = self.row_offset(l, hh, start);
                self.k[dst..dst + len * dh].copy_from_slice(&k_rows[src..src + len * dh]);
                self.v[dst..dst + len * dh].copy_from_slice(&v_rows[src..src + len * dh]);
            }
        }
        self.len = self.len.max(start + len);
    }

    /// Commit one row laid out [L, H, Dh] at `pos`. The source heads are
    /// contiguous; when the cache layout agrees the row commits as one
    /// `n_heads·d_head` copy per layer.
    pub fn commit_row(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_row.len(), lyr * h * dh);
        let dst_head_stride = self.dims.max_seq * dh;
        for l in 0..lyr {
            let src0 = l * h * dh;
            let dst0 = self.row_offset(l, 0, pos);
            if self.heads_contiguous() {
                let n = h * dh;
                self.k[dst0..dst0 + n].copy_from_slice(&k_row[src0..src0 + n]);
                self.v[dst0..dst0 + n].copy_from_slice(&v_row[src0..src0 + n]);
            } else {
                let (mut src, mut dst) = (src0, dst0);
                for _hh in 0..h {
                    self.k[dst..dst + dh].copy_from_slice(&k_row[src..src + dh]);
                    self.v[dst..dst + dh].copy_from_slice(&v_row[src..src + dh]);
                    src += dh;
                    dst += dst_head_stride;
                }
            }
        }
        self.len = self.len.max(pos + 1);
    }

    /// Commit rollout rows [Lyr, K, L, H, Dh]: path `branch`, steps
    /// 0..=last_step, at positions base_pos + step.
    ///
    /// Per (layer, head) the destination span `base_pos..=base_pos+last_step`
    /// is one contiguous slice (the S axis sits next to Dh). The source's
    /// step stride is `n_heads·d_head`, so for single-head models the whole
    /// accepted span is one `copy_from_slice`; otherwise the inner loop
    /// advances both strides directly instead of recomputing `row_offset`
    /// per (step, head).
    #[allow(clippy::too_many_arguments)]
    pub fn commit_rollout_rows(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * k_paths * l_steps * h * dh);
        let steps = last_step + 1;
        let src_step_stride = h * dh;
        for l in 0..lyr {
            for hh in 0..h {
                // step 0 of this (layer, branch, head) in the rollout output
                let src0 = (((l * k_paths + branch) * l_steps) * h + hh) * dh;
                let dst0 = self.row_offset(l, hh, base_pos);
                if h == 1 {
                    // src and dst are both step-contiguous: one span copy
                    let n = steps * dh;
                    self.k[dst0..dst0 + n].copy_from_slice(&k_rows[src0..src0 + n]);
                    self.v[dst0..dst0 + n].copy_from_slice(&v_rows[src0..src0 + n]);
                } else {
                    let (mut src, mut dst) = (src0, dst0);
                    for _step in 0..steps {
                        self.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                        self.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
                        src += src_step_stride;
                        dst += dh;
                    }
                }
            }
        }
        self.len = self.len.max(base_pos + last_step + 1);
    }

    /// Commit tree-pass rows [Lyr, N, H, Dh] for node `node_idx` at `pos`.
    ///
    /// The source places a node's heads contiguously, so when the cache
    /// layout agrees (`ContiguousKv::heads_contiguous`) the whole node
    /// commits as one `n_heads·d_head` copy per layer; otherwise the
    /// per-head loop advances hoisted strides.
    pub fn commit_tree_row(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        n_bucket: usize,
        node_idx: usize,
        pos: usize,
    ) {
        let (lyr, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        assert_eq!(k_rows.len(), lyr * n_bucket * h * dh);
        let dst_head_stride = self.dims.max_seq * dh;
        for l in 0..lyr {
            let src0 = (l * n_bucket + node_idx) * h * dh;
            let dst0 = self.row_offset(l, 0, pos);
            if self.heads_contiguous() {
                let n = h * dh;
                self.k[dst0..dst0 + n].copy_from_slice(&k_rows[src0..src0 + n]);
                self.v[dst0..dst0 + n].copy_from_slice(&v_rows[src0..src0 + n]);
            } else {
                let (mut src, mut dst) = (src0, dst0);
                for _hh in 0..h {
                    self.k[dst..dst + dh].copy_from_slice(&k_rows[src..src + dh]);
                    self.v[dst..dst + dh].copy_from_slice(&v_rows[src..src + dh]);
                    src += dh;
                    dst += dst_head_stride;
                }
            }
        }
        self.len = self.len.max(pos + 1);
    }
}

// ---------------------------------------------------------------------------
// The storage enum + read view
// ---------------------------------------------------------------------------

/// One sequence's KV lane in whichever storage the stack selected — the
/// commit/fork surface the serving coordinator writes through. See the
/// module docs for the two representations and their equivalence contract.
#[derive(Clone)]
pub enum KvCache {
    /// Flat per-lane buffers (the bit-exact oracle).
    Contiguous(ContiguousKv),
    /// Copy-on-write block table over a shared pool.
    Paged(PagedKvCache),
}

impl KvCache {
    /// Zeroed *contiguous* cache sized by the model's dimensions (the
    /// historical constructor; storage-selected construction goes through
    /// [`crate::coordinator::SpecEngine`] or [`KvCache::paged`]).
    pub fn new(dims: ModelDims) -> KvCache {
        KvCache::Contiguous(ContiguousKv::new(dims))
    }

    /// Empty paged lane over `pool`.
    pub fn paged(pool: &std::sync::Arc<BlockPool>) -> KvCache {
        KvCache::Paged(PagedKvCache::new(pool))
    }

    /// Empty cache of the same storage (and, for paged lanes, the same
    /// pool — so prefix copies between the two are copy-on-write forks).
    pub fn new_like(&self) -> KvCache {
        match self {
            KvCache::Contiguous(c) => KvCache::Contiguous(ContiguousKv::new(c.dims)),
            KvCache::Paged(p) => KvCache::Paged(PagedKvCache::new(p.pool())),
        }
    }

    /// Which representation this lane uses.
    pub fn storage(&self) -> KvStorage {
        match self {
            KvCache::Contiguous(_) => KvStorage::Contiguous,
            KvCache::Paged(_) => KvStorage::Paged,
        }
    }

    /// Model dimensions fixing the logical `[L, H, S, Dh]` layout.
    pub fn dims(&self) -> ModelDims {
        match self {
            KvCache::Contiguous(c) => c.dims,
            KvCache::Paged(p) => p.dims(),
        }
    }

    /// Number of committed rows.
    pub fn len(&self) -> usize {
        match self {
            KvCache::Contiguous(c) => c.len,
            KvCache::Paged(p) => p.len(),
        }
    }

    /// Whether no rows are committed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read-only view for [`Backend`](crate::runtime::Backend) dispatch.
    pub fn view(&self) -> KvRef<'_> {
        match self {
            KvCache::Contiguous(c) => KvRef::Contiguous { dims: c.dims, k: &c.k, v: &c.v },
            KvCache::Paged(p) => KvRef::Paged(p),
        }
    }

    /// Read the `d_head` K/V slices at `(layer, head, pos)` — test hook for
    /// bitwise row assertions across storages.
    pub fn read_row(&self, layer: usize, head: usize, pos: usize) -> (&[f32], &[f32]) {
        match self {
            KvCache::Contiguous(c) => c.row(layer, head, pos),
            KvCache::Paged(p) => p.row(layer, head, pos),
        }
    }

    /// The paged representation, when this lane uses it.
    pub fn as_paged(&self) -> Option<&PagedKvCache> {
        match self {
            KvCache::Paged(p) => Some(p),
            KvCache::Contiguous(_) => None,
        }
    }

    /// The contiguous representation, when this lane uses it.
    pub fn as_contiguous(&self) -> Option<&ContiguousKv> {
        match self {
            KvCache::Contiguous(c) => Some(c),
            KvCache::Paged(_) => None,
        }
    }

    /// Commit prefill rows laid out `[L, H, s_pre, Dh]` for positions
    /// `0..len`.
    pub fn commit_prefill(&mut self, k_rows: &[f32], v_rows: &[f32], s_pre: usize, len: usize) {
        match self {
            KvCache::Contiguous(c) => c.commit_prefill(k_rows, v_rows, s_pre, len),
            KvCache::Paged(p) => p.commit_prefill(k_rows, v_rows, s_pre, len),
        }
    }

    /// Commit a prefill chunk laid out `[L, H, stride, Dh]`: the first
    /// `len` source rows land at positions `start..start + len`.
    pub fn commit_chunk(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        stride: usize,
        start: usize,
        len: usize,
    ) {
        match self {
            KvCache::Contiguous(c) => c.commit_chunk(k_rows, v_rows, stride, start, len),
            KvCache::Paged(p) => p.commit_chunk(k_rows, v_rows, stride, start, len),
        }
    }

    /// Commit one row laid out `[L, H, Dh]` at `pos`.
    pub fn commit_row(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        match self {
            KvCache::Contiguous(c) => c.commit_row(k_row, v_row, pos),
            KvCache::Paged(p) => p.commit_row(k_row, v_row, pos),
        }
    }

    /// Commit rollout rows `[Lyr, K, L, H, Dh]`: path `branch`, steps
    /// `0..=last_step`, at positions `base_pos + step`.
    #[allow(clippy::too_many_arguments)]
    pub fn commit_rollout_rows(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        match self {
            KvCache::Contiguous(c) => {
                c.commit_rollout_rows(k_rows, v_rows, k_paths, l_steps, branch, last_step, base_pos)
            }
            KvCache::Paged(p) => {
                p.commit_rollout_rows(k_rows, v_rows, k_paths, l_steps, branch, last_step, base_pos)
            }
        }
    }

    /// Commit tree-pass rows `[Lyr, N, H, Dh]` for node `node_idx` at `pos`.
    pub fn commit_tree_row(
        &mut self,
        k_rows: &[f32],
        v_rows: &[f32],
        n_bucket: usize,
        node_idx: usize,
        pos: usize,
    ) {
        match self {
            KvCache::Contiguous(c) => c.commit_tree_row(k_rows, v_rows, n_bucket, node_idx, pos),
            KvCache::Paged(p) => p.commit_tree_row(k_rows, v_rows, n_bucket, node_idx, pos),
        }
    }

    /// Refresh this cache as a prefix of `src`: rows `< rows` become
    /// readable as `src`'s, rows past the prefix must not be read.
    /// Contiguous lanes copy the spans; paged lanes on the same pool share
    /// blocks (O(blocks) refcount bumps — the copy-on-write fork). Mixed
    /// storages fall back to a per-row deep copy.
    pub fn copy_prefix_from(&mut self, src: &KvCache, rows: usize) {
        match (self, src) {
            (KvCache::Contiguous(a), KvCache::Contiguous(b)) => a.copy_prefix_from(b, rows),
            (KvCache::Paged(a), KvCache::Paged(b)) => a.copy_prefix_from(b, rows),
            (me, other) => {
                // cross-storage deep copy (cold path, kept for safety)
                let d = me.dims();
                let rows = rows.min(d.max_seq);
                for pos in 0..rows {
                    for l in 0..d.n_layers {
                        for hh in 0..d.n_heads {
                            let (ks, vs) = other.read_row(l, hh, pos);
                            let (ks, vs) = (ks.to_vec(), vs.to_vec());
                            me.write_row_raw(l, hh, pos, &ks, &vs);
                        }
                    }
                }
                me.set_len(other.len().min(rows));
            }
        }
    }

    /// Fresh cache of the same storage holding only rows `< rows`.
    pub fn clone_prefix(&self, rows: usize) -> KvCache {
        match self {
            KvCache::Contiguous(c) => KvCache::Contiguous(c.clone_prefix(rows)),
            KvCache::Paged(p) => KvCache::Paged(p.clone_prefix(rows)),
        }
    }

    /// Raw single-(layer, head) row write — only used by the cross-storage
    /// fallback above.
    fn write_row_raw(&mut self, layer: usize, head: usize, pos: usize, k: &[f32], v: &[f32]) {
        match self {
            KvCache::Contiguous(c) => {
                let off = c.row_offset(layer, head, pos);
                let dh = c.dims.d_head;
                c.k[off..off + dh].copy_from_slice(k);
                c.v[off..off + dh].copy_from_slice(v);
            }
            KvCache::Paged(p) => p.write_row(layer, head, pos, k, v),
        }
    }

    fn set_len(&mut self, len: usize) {
        match self {
            KvCache::Contiguous(c) => c.len = len,
            KvCache::Paged(p) => p.set_len(len),
        }
    }
}

/// Read-only KV view passed through the [`Backend`](crate::runtime::Backend)
/// entry points: either borrowed contiguous `[L, H, S, Dh]` buffers or a
/// paged lane read through its block table. Construct via
/// [`KvCache::view`], or [`KvRef::contiguous`] for raw buffers.
#[derive(Clone, Copy)]
pub enum KvRef<'a> {
    /// Borrowed flat buffers plus the dims fixing their layout.
    Contiguous {
        /// Model dimensions fixing the `[L, H, S, Dh]` layout.
        dims: ModelDims,
        /// Key buffer, `[L, H, S, Dh]` flat.
        k: &'a [f32],
        /// Value buffer, same layout.
        v: &'a [f32],
    },
    /// A paged lane, read through its block table.
    Paged(&'a PagedKvCache),
}

impl<'a> KvRef<'a> {
    /// View over raw contiguous buffers (the historical two-slice calling
    /// convention).
    pub fn contiguous(dims: ModelDims, k: &'a [f32], v: &'a [f32]) -> KvRef<'a> {
        KvRef::Contiguous { dims, k, v }
    }

    /// Model dimensions of the viewed lane.
    pub fn dims(&self) -> ModelDims {
        match self {
            KvRef::Contiguous { dims, .. } => *dims,
            KvRef::Paged(p) => p.dims(),
        }
    }

    /// Whether the view's element capacity matches `want` `[L, H, S, Dh]`
    /// elements (backend shape validation; reports the actual size).
    pub fn check_elems(&self, want: usize) -> Result<(), (usize, usize)> {
        match self {
            KvRef::Contiguous { k, v, .. } => {
                if k.len() != want || v.len() != want {
                    return Err((k.len(), v.len()));
                }
                Ok(())
            }
            KvRef::Paged(p) => {
                let have = p.dims().kv_elems();
                if have != want {
                    return Err((have, have));
                }
                Ok(())
            }
        }
    }

    /// Read the `d_head` K/V slices at `(layer, head, pos)`. The slices
    /// borrow the underlying lane (`'a`), so gathered attention rows can
    /// outlive the `KvRef` value itself.
    #[inline]
    pub fn row(self, layer: usize, head: usize, pos: usize) -> (&'a [f32], &'a [f32]) {
        match self {
            KvRef::Contiguous { dims, k, v } => {
                let off = ((layer * dims.n_heads + head) * dims.max_seq + pos) * dims.d_head;
                let dh = dims.d_head;
                (&k[off..off + dh], &v[off..off + dh])
            }
            KvRef::Paged(p) => p.row(layer, head, pos),
        }
    }

    /// Contiguous host buffers when the view already is one (the PJRT
    /// zero-copy path); paged views return `None` and must be gathered via
    /// [`PagedKvCache::gather`].
    pub fn as_contiguous(&self) -> Option<(&'a [f32], &'a [f32])> {
        match self {
            KvRef::Contiguous { k, v, .. } => Some((k, v)),
            KvRef::Paged(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { n_layers: 2, d_model: 8, n_heads: 2, d_head: 4, vocab: 10, max_seq: 16 }
    }

    #[test]
    fn commit_row_places_values() {
        let mut c = ContiguousKv::new(dims());
        let row: Vec<f32> = (0..16).map(|x| x as f32).collect(); // [2,2,4]
        c.commit_row(&row, &row, 3);
        assert_eq!(c.len, 4);
        // layer 1, head 1 slice = row[12..16]
        let off = c.row_offset(1, 1, 3);
        assert_eq!(&c.k[off..off + 4], &[12.0, 13.0, 14.0, 15.0]);
        // untouched rows remain zero
        let off2 = c.row_offset(1, 1, 2);
        assert_eq!(&c.k[off2..off2 + 4], &[0.0; 4]);
    }

    #[test]
    fn clone_prefix_copies_only_prefix_rows() {
        let d = dims();
        let mut c = ContiguousKv::new(d);
        for (i, v) in c.k.iter_mut().enumerate() {
            *v = i as f32 + 1.0;
        }
        c.v.copy_from_slice(&c.k);
        c.len = 6;
        let p = c.clone_prefix(3);
        assert_eq!(p.len, 3);
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                for pos in 0..d.max_seq {
                    let off = p.row_offset(l, hh, pos);
                    let want = if pos < 3 { c.k[off] } else { 0.0 };
                    assert_eq!(p.k[off], want, "l={l} h={hh} pos={pos}");
                    assert_eq!(p.v[off], want, "l={l} h={hh} pos={pos}");
                }
            }
        }
        // clamps past max_seq
        let full = c.clone_prefix(d.max_seq + 5);
        assert_eq!(full.k, c.k);
        assert_eq!(full.len, 6);
        // the reusing entry refreshes the prefix in place (stale tail kept)
        let mut reuse = ContiguousKv::new(d);
        reuse.k.fill(-1.0);
        reuse.v.fill(-1.0);
        reuse.copy_prefix_from(&c, 3);
        assert_eq!(reuse.len, 3);
        let off_in = reuse.row_offset(1, 1, 2);
        let off_out = reuse.row_offset(1, 1, 3);
        assert_eq!(reuse.k[off_in], c.k[off_in]);
        assert_eq!(reuse.k[off_out], -1.0, "tail rows keep stale contents");
    }

    #[test]
    fn commit_prefill_layout() {
        let d = dims();
        let mut c = ContiguousKv::new(d);
        let s_pre = 4;
        let n = d.n_layers * d.n_heads * s_pre * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_prefill(&rows, &rows, s_pre, 3);
        assert_eq!(c.len, 3);
        // layer 0, head 1, pos 2 = src offset ((0*2+1)*4+2)*4 = 24
        let off = c.row_offset(0, 1, 2);
        assert_eq!(c.k[off], 24.0);
    }

    /// Committing a prefill in chunks (any chunk sizes, any block tiling)
    /// must reproduce the one-shot `commit_prefill` buffers bitwise, for
    /// both storages.
    #[test]
    fn commit_chunk_matches_one_shot_prefill() {
        let d = dims();
        let s_pre = 11;
        let n = d.n_layers * d.n_heads * s_pre * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32 * 0.25 + 1.0).collect();
        let len = 9;
        let mut oracle = ContiguousKv::new(d);
        oracle.commit_prefill(&rows, &rows, s_pre, len);
        for chunk in [1usize, 2, 4, 9, 16] {
            let mut c = ContiguousKv::new(d);
            let pool = BlockPool::new(d, 3, None);
            let mut p = PagedKvCache::new(&pool);
            let mut start = 0usize;
            while start < len {
                let take = chunk.min(len - start);
                // repack this chunk's rows into a [L, H, take, Dh] buffer,
                // as a chunked prefill dispatch would return them
                let m = d.n_layers * d.n_heads * take * d.d_head;
                let mut sub = vec![0.0f32; m];
                for l in 0..d.n_layers {
                    for hh in 0..d.n_heads {
                        for i in 0..take {
                            let src = ((l * d.n_heads + hh) * s_pre + start + i) * d.d_head;
                            let dst = ((l * d.n_heads + hh) * take + i) * d.d_head;
                            sub[dst..dst + d.d_head].copy_from_slice(&rows[src..src + d.d_head]);
                        }
                    }
                }
                c.commit_chunk(&sub, &sub, take, start, take);
                p.commit_chunk(&sub, &sub, take, start, take);
                start += take;
            }
            assert_eq!(c.len, oracle.len, "chunk={chunk}");
            assert_eq!(c.k, oracle.k, "chunk={chunk}");
            assert_eq!(c.v, oracle.v, "chunk={chunk}");
            assert_eq!(p.len(), oracle.len, "paged chunk={chunk}");
            for l in 0..d.n_layers {
                for hh in 0..d.n_heads {
                    for pos in 0..len {
                        let off = oracle.row_offset(l, hh, pos);
                        let (pk, pv) = p.row(l, hh, pos);
                        assert_eq!(pk, &oracle.k[off..off + d.d_head], "chunk={chunk} pos={pos}");
                        assert_eq!(pv, &oracle.v[off..off + d.d_head], "chunk={chunk} pos={pos}");
                    }
                }
            }
        }
    }

    #[test]
    fn commit_rollout_rows_branch_selection() {
        let d = dims();
        let mut c = ContiguousKv::new(d);
        let (kp, ls) = (3, 2);
        let n = d.n_layers * kp * ls * d.n_heads * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_rollout_rows(&rows, &rows, kp, ls, 1, 1, 5);
        assert_eq!(c.len, 7);
        // layer 0, branch 1, step 0, head 0: src ((0*3+1)*2+0)*2*4 = 16
        let off = c.row_offset(0, 0, 5);
        assert_eq!(c.k[off], 16.0);
    }

    #[test]
    fn commit_tree_row_layout() {
        let d = dims();
        let mut c = ContiguousKv::new(d);
        let nb = 4;
        let n = d.n_layers * nb * d.n_heads * d.d_head;
        let rows: Vec<f32> = (0..n).map(|x| x as f32).collect();
        c.commit_tree_row(&rows, &rows, nb, 2, 7);
        // layer 1, node 2, head 0: src ((1*4+2)*2+0)*4 = 48
        let off = c.row_offset(1, 0, 7);
        assert_eq!(c.k[off], 48.0);
        assert_eq!(c.len, 8);
    }

    /// Naive per-element reference for the rollout scatter.
    fn reference_rollout(
        c: &mut ContiguousKv,
        rows: &[f32],
        k_paths: usize,
        l_steps: usize,
        branch: usize,
        last_step: usize,
        base_pos: usize,
    ) {
        let (lyr, h, dh) = (c.dims.n_layers, c.dims.n_heads, c.dims.d_head);
        for l in 0..lyr {
            for step in 0..=last_step {
                for hh in 0..h {
                    for e in 0..dh {
                        let src = ((((l * k_paths + branch) * l_steps) + step) * h + hh) * dh + e;
                        let dst = c.row_offset(l, hh, base_pos + step) + e;
                        c.k[dst] = rows[src];
                        c.v[dst] = rows[src];
                    }
                }
            }
        }
        c.len = c.len.max(base_pos + last_step + 1);
    }

    /// The coalesced commits must scatter exactly like the per-element
    /// reference, across head counts (incl. the single-head span-copy fast
    /// path), branches and partial step extents — and the paged storage
    /// must match the contiguous result bitwise for every shape, across
    /// block sizes that tile the span unevenly.
    #[test]
    fn coalesced_commits_match_reference() {
        for n_heads in [1usize, 2, 3] {
            let d = ModelDims {
                n_layers: 2,
                d_model: 8,
                n_heads,
                d_head: 4,
                vocab: 10,
                max_seq: 16,
            };
            let (kp, ls) = (3, 4);
            let n = d.n_layers * kp * ls * n_heads * d.d_head;
            let rows: Vec<f32> = (0..n).map(|x| (x as f32) * 0.5 + 1.0).collect();
            for branch in 0..kp {
                for last_step in 0..ls {
                    let mut fast = ContiguousKv::new(d);
                    let mut slow = ContiguousKv::new(d);
                    fast.commit_rollout_rows(&rows, &rows, kp, ls, branch, last_step, 5);
                    reference_rollout(&mut slow, &rows, kp, ls, branch, last_step, 5);
                    assert_eq!(fast.k, slow.k, "h={n_heads} b={branch} s={last_step}");
                    assert_eq!(fast.v, slow.v, "h={n_heads} b={branch} s={last_step}");
                    assert_eq!(fast.len, slow.len);
                    // paged twin, block sizes cutting the span unevenly
                    for bt in [1usize, 3, 16] {
                        let pool = BlockPool::new(d, bt, None);
                        let mut pg = PagedKvCache::new(&pool);
                        pg.commit_rollout_rows(&rows, &rows, kp, ls, branch, last_step, 5);
                        assert_eq!(pg.len(), slow.len);
                        for l in 0..d.n_layers {
                            for hh in 0..n_heads {
                                for pos in 0..d.max_seq {
                                    let (pk, pv) = pg.row(l, hh, pos);
                                    let off = slow.row_offset(l, hh, pos);
                                    assert_eq!(
                                        pk,
                                        &slow.k[off..off + d.d_head],
                                        "paged bt={bt} h={n_heads} b={branch} s={last_step} l={l} hh={hh} pos={pos}"
                                    );
                                    assert_eq!(pv, &slow.v[off..off + d.d_head]);
                                }
                            }
                        }
                    }
                }
            }
            // tree-row and single-row commits against the same reference idea
            let nb = 4;
            let nt = d.n_layers * nb * n_heads * d.d_head;
            let trows: Vec<f32> = (0..nt).map(|x| x as f32 + 0.25).collect();
            let mut fast = ContiguousKv::new(d);
            fast.commit_tree_row(&trows, &trows, nb, 1, 3);
            let mut slow = ContiguousKv::new(d);
            for l in 0..d.n_layers {
                for hh in 0..n_heads {
                    for e in 0..d.d_head {
                        let src = ((l * nb + 1) * n_heads + hh) * d.d_head + e;
                        let dst = slow.row_offset(l, hh, 3) + e;
                        slow.k[dst] = trows[src];
                        slow.v[dst] = trows[src];
                    }
                }
            }
            slow.len = 4;
            assert_eq!(fast.k, slow.k, "tree h={n_heads}");
            assert_eq!(fast.len, slow.len);

            let nr = d.n_layers * n_heads * d.d_head;
            let rrow: Vec<f32> = (0..nr).map(|x| x as f32 + 0.75).collect();
            let mut fast = ContiguousKv::new(d);
            fast.commit_row(&rrow, &rrow, 2);
            let mut slow = ContiguousKv::new(d);
            for l in 0..d.n_layers {
                for hh in 0..n_heads {
                    for e in 0..d.d_head {
                        let src = (l * n_heads + hh) * d.d_head + e;
                        let dst = slow.row_offset(l, hh, 2) + e;
                        slow.k[dst] = rrow[src];
                        slow.v[dst] = rrow[src];
                    }
                }
            }
            slow.len = 3;
            assert_eq!(fast.k, slow.k, "row h={n_heads}");
            assert_eq!(fast.len, slow.len);
        }
    }

    /// The enum surface dispatches identically for both storages, and the
    /// view's row reads agree with `read_row`.
    #[test]
    fn enum_surface_storage_equivalence() {
        let d = dims();
        let pool = BlockPool::new(d, 4, None);
        let mut cont = KvCache::new(d);
        let mut page = KvCache::paged(&pool);
        assert_eq!(cont.storage(), KvStorage::Contiguous);
        assert_eq!(page.storage(), KvStorage::Paged);
        let n = d.n_layers * d.n_heads * d.d_head;
        let row: Vec<f32> = (0..n).map(|x| x as f32 * 1.5).collect();
        for pos in 0..7 {
            cont.commit_row(&row, &row, pos);
            page.commit_row(&row, &row, pos);
        }
        assert_eq!(cont.len(), page.len());
        // forked prefixes agree with the sources
        let cf = cont.clone_prefix(5);
        let pf = page.clone_prefix(5);
        assert_eq!(cf.len(), 5);
        assert_eq!(pf.len(), 5);
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                for pos in 0..5 {
                    assert_eq!(cf.read_row(l, hh, pos).0, pf.read_row(l, hh, pos).0);
                    let via_view = cf.view().row(l, hh, pos).0.to_vec();
                    assert_eq!(via_view.as_slice(), pf.view().row(l, hh, pos).0);
                }
            }
        }
        // new_like follows storage and pool
        assert_eq!(cont.new_like().storage(), KvStorage::Contiguous);
        let nl = page.new_like();
        assert_eq!(nl.storage(), KvStorage::Paged);
        assert!(std::sync::Arc::ptr_eq(nl.as_paged().unwrap().pool(), &pool));
        // cross-storage fallback copy
        let mut mixed = KvCache::paged(&pool);
        mixed.copy_prefix_from(&cont, 4);
        assert_eq!(mixed.len(), 4);
        for pos in 0..4 {
            assert_eq!(mixed.read_row(1, 1, pos).0, cont.read_row(1, 1, pos).0);
        }
    }
}
