//! Serving coordinator: per-sequence speculative decoding over the PJRT
//! runtime. One speculation block = draft (≤2 fused rollouts) → target tree
//! pass (1 dispatch, Pallas tree-attention inside) → verification (pure
//! rust) → KV commit. Python is never on this path.
//!
//! The policy-facing types (block statistics, step features, action
//! policies) are pure rust and always built; the engine half
//! ([`SpecEngine`], [`Sequence`], the TCP [`server`]) needs a PJRT runtime
//! and is gated behind the `pjrt` feature.

#[cfg(feature = "pjrt")]
pub mod server;
#[cfg(feature = "pjrt")]
mod spec;

#[cfg(feature = "pjrt")]
pub use spec::{generate_autoregressive, RootFeatures, Sequence, SpecEngine};

use crate::dist::{NodeDist, SamplingConfig};
use crate::draft::Action;

/// Per-block statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockStats {
    pub accepted: usize,
    pub emitted: usize,
    pub draft_secs: f64,
    pub tree_secs: f64,
    pub verify_secs: f64,
    pub tree_nodes: usize,
}

/// Whole-generation statistics.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub blocks: usize,
    pub tokens: usize,
    pub wall_secs: f64,
    pub draft_secs: f64,
    pub tree_secs: f64,
    pub verify_secs: f64,
    pub sum_accepted: usize,
}

impl GenStats {
    /// Block efficiency E[τ + 1].
    pub fn block_efficiency(&self) -> f64 {
        if self.blocks == 0 {
            return 0.0;
        }
        self.tokens as f64 / self.blocks as f64
    }
    /// Decode throughput, tokens per second.
    pub fn tps(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.wall_secs
    }
}

/// Root-step features handed to action policies (paper §6 / Appendix E).
pub struct StepFeatures<'a> {
    pub hidden_p_prev: &'a [f32],
    pub hidden_q_prev: &'a [f32],
    pub hidden_q_cur: &'a [f32],
    pub p_prev: &'a NodeDist,
    pub q_prev: &'a NodeDist,
    pub q_root: &'a NodeDist,
    pub ctx_len: usize,
    pub sampling: SamplingConfig,
}

/// Chooses the delayed-expansion action each block. `Send + Sync` so one
/// policy can drive every worker of a data-parallel prompt sweep.
pub trait ActionPolicy: Send + Sync {
    fn choose(&self, feats: &StepFeatures<'_>) -> Action;
    /// Whether the policy needs the extra root draft-decode for features.
    fn needs_features(&self) -> bool {
        true
    }
}

/// Static (K, L1, L2) — the paper's fixed-configuration baselines.
pub struct FixedPolicy(pub Action);

impl ActionPolicy for FixedPolicy {
    fn choose(&self, _f: &StepFeatures<'_>) -> Action {
        self.0
    }
    fn needs_features(&self) -> bool {
        false
    }
}
