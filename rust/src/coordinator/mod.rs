//! Serving coordinator: per-sequence speculative decoding over any
//! [`runtime::Backend`](crate::runtime::Backend). One speculation block =
//! draft (≤2 fused rollouts) → target tree pass (1 dispatch) → verification
//! (pure rust) → KV commit. Python is never on this path.
//!
//! The whole stack builds in the hermetic default configuration and runs
//! end-to-end on [`crate::runtime::CpuRefBackend`]; with `--features pjrt`
//! the same code drives the compiled-HLO engine. Three serving shapes:
//!
//! * [`SpecEngine::generate`] — one sequence, serial blocks;
//! * [`server`] — the TCP line-protocol front-end (single lane);
//! * [`ServeLoop`] — the multi-request continuous-batching loop with
//!   per-request KV-cache lanes, data-parallel per-tick block work, and an
//!   opt-in recovery layer ([`ServeLoop::with_resilience`]): per-lane
//!   checkpoints with deterministic retry, per-request deadlines, the
//!   [`ServeError`] failure taxonomy, and a [`BackendHealth`] circuit
//!   breaker that falls back to lossless autoregressive decoding. With
//!   [`ServeLoop::with_selector`] the loop serves the paper's dynamic
//!   policy: per-block (verifier × drafter × action) selection from live
//!   [`StepFeatures`], with online-calibrated acceptance priors.

mod batch;
pub mod server;
mod spec;

pub use batch::{
    BackendHealth, Priority, RecoveryCounters, ResilienceConfig, SchedConfig, SchedCounters,
    ServeError, ServeLoop, ServeOutput, ServeRequest,
};
pub use crate::kvcache::PrefixCacheCounters;
pub use spec::{
    generate_autoregressive, KvPools, PrefillState, RootFeatures, Sequence, SpecEngine,
};

use crate::dist::{NodeDist, SamplingConfig};
use crate::draft::Action;

/// Per-block statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockStats {
    /// Accepted draft tokens τ.
    pub accepted: usize,
    /// Emitted tokens this block (τ + 1, or 0 on a no-op block).
    pub emitted: usize,
    /// Wall time of the draft rollouts.
    pub draft_secs: f64,
    /// Wall time of the target tree pass.
    pub tree_secs: f64,
    /// Wall time of verification.
    pub verify_secs: f64,
    /// Nodes in the drafted tree.
    pub tree_nodes: usize,
}

/// Whole-generation statistics.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    /// Speculation blocks run.
    pub blocks: usize,
    /// Tokens emitted.
    pub tokens: usize,
    /// End-to-end wall time.
    pub wall_secs: f64,
    /// Total draft-rollout wall time.
    pub draft_secs: f64,
    /// Total target-tree-pass wall time.
    pub tree_secs: f64,
    /// Total verification wall time.
    pub verify_secs: f64,
    /// Total accepted draft tokens (Σ τ).
    pub sum_accepted: usize,
}

impl GenStats {
    /// Fold one block's statistics in — the single accumulation point
    /// shared by the serial loop ([`SpecEngine::generate`]) and the
    /// batched [`ServeLoop`], so their stats can never drift apart.
    pub fn add_block(&mut self, b: &BlockStats) {
        self.blocks += 1;
        self.tokens += b.emitted;
        self.sum_accepted += b.accepted;
        self.draft_secs += b.draft_secs;
        self.tree_secs += b.tree_secs;
        self.verify_secs += b.verify_secs;
    }

    /// Block efficiency E[τ + 1].
    pub fn block_efficiency(&self) -> f64 {
        if self.blocks == 0 {
            return 0.0;
        }
        self.tokens as f64 / self.blocks as f64
    }
    /// Decode throughput, tokens per second.
    pub fn tps(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.wall_secs
    }
}

/// Root-step features handed to action policies (paper §6 / Appendix E).
pub struct StepFeatures<'a> {
    /// Target hidden state at the previous verified root.
    pub hidden_p_prev: &'a [f32],
    /// Draft hidden state at the previous verified root.
    pub hidden_q_prev: &'a [f32],
    /// Draft hidden state at the current root.
    pub hidden_q_cur: &'a [f32],
    /// Target distribution at the previous root.
    pub p_prev: &'a NodeDist,
    /// Draft distribution at the previous root.
    pub q_prev: &'a NodeDist,
    /// Draft distribution at the current root.
    pub q_root: &'a NodeDist,
    /// Current context length in tokens.
    pub ctx_len: usize,
    /// Active sampling configuration.
    pub sampling: SamplingConfig,
}

/// Chooses the delayed-expansion action each block. `Send + Sync` so one
/// policy can drive every worker of a data-parallel prompt sweep.
pub trait ActionPolicy: Send + Sync {
    /// Pick the (K, L1, L2) action for the next block.
    fn choose(&self, feats: &StepFeatures<'_>) -> Action;
    /// Whether the policy needs the extra root draft-decode for features.
    fn needs_features(&self) -> bool {
        true
    }
}

/// Static (K, L1, L2) — the paper's fixed-configuration baselines.
pub struct FixedPolicy(pub Action);

impl ActionPolicy for FixedPolicy {
    fn choose(&self, _f: &StepFeatures<'_>) -> Action {
        self.0
    }
    fn needs_features(&self) -> bool {
        false
    }
}
