//! Multi-request batched serving loop over any [`Backend`].
//!
//! [`ServeLoop`] is a continuous-batching scheduler: a FIFO request queue,
//! up to `max_batch` concurrently active sequences, and per-request
//! KV-cache lanes (each [`Sequence`](super::Sequence) owns its own target
//! and draft caches, so lanes never alias). Every scheduler tick runs one
//! speculation block — draft → tree pass → verify → commit — for every
//! active lane, fanned out over
//! [`par_map_init`](crate::util::threadpool::par_map_init); finished lanes
//! retire and queued requests are admitted in their place, so the batch
//! stays full until the queue drains.
//!
//! ## Memory: lanes vs blocks
//!
//! With contiguous KV storage every admitted lane pins `max_seq` rows per
//! model whether it uses them or not, so `max_batch` is the memory
//! ceiling. With paged storage ([`ServeLoop::with_kv_storage`], env knob
//! `SPECDELAY_PAGED_KV`) lanes allocate fixed-size blocks lazily from
//! shared per-role [`BlockPool`](crate::kvcache::BlockPool)s and share
//! trunk prefixes copy-on-write, so resident memory tracks committed
//! tokens. [`ServeLoop::with_block_budget`] caps those pools and turns the
//! ceiling into admission-level *backpressure*: a request is admitted only
//! when its worst-case block reservation fits both pools, and otherwise
//! waits in the queue until running lanes retire and return their blocks —
//! no in-flight lane can fail for lack of blocks, and streams stay
//! bit-identical to an uncapped (or contiguous) run. With
//! [`ServeLoop::with_resilience`] enabled the per-lane reservation doubles:
//! a lane's checkpoint is a copy-on-write fork of its sequence, so lane +
//! checkpoint together are bounded by twice the single-lane worst case.
//!
//! ## Determinism contract
//!
//! A lane's speculation stream is driven entirely by lane-local state: its
//! own rng (seeded from the request seed and the admission-order id), its
//! own [`Sequence`](super::Sequence), and the shared immutable backend.
//! Nothing a lane computes depends on which other lanes are in flight or
//! on the worker schedule, so **per-request token streams are
//! bit-identical for every batch size and worker count**, and identical to
//! a serial [`SpecEngine::generate`] call driven by the same
//! `Pcg64::new(seed, id)` stream. `tests/e2e_serve.rs` asserts both; the
//! `serve_loop` bench re-asserts them before timing anything.
//!
//! ## Failure model & recovery
//!
//! Backend dispatches can fail (transient errors), return corrupted
//! surfaces (caught by the [`guard_finite`](crate::runtime::guard_finite)
//! boundary guards and raised as typed faults), straggle, or panic. The
//! loop always isolates panics — per-lane tick work runs under
//! `catch_unwind`, so one poisoned lane never takes down the batch — and
//! classifies every lane failure into the structured [`ServeError`]
//! taxonomy instead of a bare string.
//!
//! With [`ServeLoop::with_resilience`] the loop additionally *recovers*:
//!
//! * **checkpoint + deterministic retry** — after every successful tick a
//!   lane snapshots `(Sequence, rng)`; under paged KV the sequence
//!   snapshot is a copy-on-write fork (O(blocks) refcount bumps, see
//!   `kvcache::paged`). A faulting tick restores the snapshot — returning
//!   any partially-committed blocks to the pools — and re-executes with
//!   the *same rng stream state*, so a recovered stream is bit-identical
//!   to the fault-free oracle. Bounded by
//!   [`ResilienceConfig::max_retries`] consecutive attempts, then the
//!   lane retires as [`ServeError::Exhausted`].
//! * **deadlines** — a lane whose wall clock exceeds
//!   [`ResilienceConfig::deadline`] retires as [`ServeError::Deadline`]
//!   with whatever partial stream it has.
//! * **health state machine** — `Healthy → Degraded → Failed` with a
//!   consecutive-fault circuit breaker ([`BackendHealth`]). While
//!   `Degraded`, lanes switch from speculation to plain autoregressive
//!   decoding ([`SpecEngine::step_autoregressive`]): slower, but each
//!   token is still sampled from the exact target conditional, so the
//!   served stream stays lossless (degraded outputs are flagged via
//!   [`ServeOutput::degraded`]). Every
//!   [`ResilienceConfig::probe_interval`]-th degraded tick re-probes the
//!   speculative path; a clean probe returns the loop to `Healthy`.
//!   Consecutive faults *in degraded mode* trip the breaker fully open
//!   (`Failed`): all in-flight and queued requests retire with
//!   [`ServeError::Failed`] rather than spinning forever.
//!
//! ## Overload-robust scheduling
//!
//! [`ServeLoop::with_scheduler`] (or `SPECDELAY_SCHED=1`) upgrades the
//! FIFO loop into a preemptive priority scheduler:
//!
//! * **chunked prefill** — long prompts prefill in fixed-size chunks
//!   ([`SchedConfig::prefill_chunk`], env `SPECDELAY_PREFILL_CHUNK`)
//!   interleaved with the decode ticks of the other lanes, so one long
//!   prompt no longer stalls the batch for a whole prefill. Chunking runs
//!   through [`Backend::prefill_chunk`], which is bit-identical to the
//!   one-shot prefill under the backend consistency contract, so streams
//!   are unchanged for any chunk schedule.
//! * **priority classes + weighted admission** — requests carry a
//!   [`Priority`]; admission is stride-scheduled across the per-class
//!   queues with [`SchedConfig::weights`], so high-priority work is
//!   favoured without starving the lower classes.
//! * **preempt-and-requeue** — under a block budget the scheduler admits
//!   against *committed* blocks plus a per-tick worst-case margin instead
//!   of the whole-lifetime worst case, so more lanes run concurrently; on
//!   pool pressure it parks the lowest-priority/youngest lane (dropping
//!   its checkpoint, keeping its committed prefix resident) and, if still
//!   short, releases the parked lane's blocks entirely and later rebuilds
//!   its context via chunked prefill — the replay is bitwise identical to
//!   the original rows, so a preempted-and-resumed stream matches the
//!   never-preempted oracle.
//! * **deadline-aware shedding** — per-request deadlines are checked
//!   before every dispatch, and queued requests whose deadline already
//!   expired (or that overflow [`SchedConfig::max_queue`]) retire as
//!   structured [`ServeError::Shed`] instead of consuming backend work.
//!
//! Every submitted request is accounted for:
//! `submitted == completed + shed + failed` — shedding returns an output,
//! it never silently drops a request. `tests/serve_sched.rs` pins the
//! scheduler losslessness oracle and the accounting identity;
//! `benches/serve_sched.rs` measures tail latency against FIFO on a
//! bursty arrival trace.
//!
//! ## Online dynamic selection
//!
//! [`ServeLoop::with_selector`] (or `SPECDELAY_SELECTOR=1`) replaces the
//! static verifier/policy pair with the paper's serving-time dynamic
//! policy: every speculative tick scores a configured arm set
//! (verifier × drafter × action) from the lane's live root features
//! ([`OnlineSelector::choose`]) and runs the winning arm via
//! [`SpecEngine::step_drafted`]. Decisions draw from a *dedicated*
//! per-lane rng stream (`Pcg64::new(selector seed, lane id)`), so the
//! token-sampling stream is never perturbed by policy or seed changes;
//! both streams are checkpointed and restored together, keeping recovered
//! streams bit-identical. Acceptance tallies from every served block fold
//! into per-arm priors in lane order at tick end — worker-count
//! independent by the same argument as the health fold — and can be fed
//! back as the next run's [`SelectorConfig::priors`]. A selector with no
//! arms (the `SPECDELAY_SELECTOR=1` default) is engaged but transparent:
//! no decisions, no extra rng draws, streams byte-for-byte the static
//! path. `tests/selector_serve.rs` pins all of this.
//!
//! Each tick currently pays one scoped-thread spawn/join round
//! ([`par_map_init`](crate::util::threadpool::par_map_init)); for model
//! sizes where a block is sub-millisecond that overhead is visible in
//! `BENCH_serve_loop.json`. Because results are index-addressed (never
//! schedule-dependent), swapping in a persistent
//! [`ThreadPool`](crate::util::threadpool::ThreadPool) would preserve the
//! determinism contract — left as a follow-up.

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::spec::PrefillState;
use super::{ActionPolicy, GenStats, Sequence, SpecEngine};
use crate::dist::SamplingConfig;
use crate::draft::DrafterKind;
use crate::kvcache::{
    default_block_tokens, prefix_cache_enabled, KvDtype, KvStorage, PrefixCache,
    PrefixCacheCounters,
};
use crate::runtime::{Backend, DispatchFault, FaultKind};
use crate::selector::{ArmStats, OnlineSelector, SelectorConfig, SelectorPriors};
use crate::tokenizer;
use crate::util::threadpool;
use crate::util::Pcg64;
use crate::verify::Verifier;

/// Service class of a [`ServeRequest`]. Priorities shape *scheduling*
/// (admission order, preemption victims, shed order) — never content: a
/// request's token stream is identical at every priority.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive: favoured at admission, preempted last.
    High,
    /// The default class.
    #[default]
    Normal,
    /// Throughput/batch work: admitted opportunistically, preempted and
    /// shed first under overload.
    Low,
}

impl Priority {
    /// All classes, highest first (index order).
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    /// Dense class index: `High = 0`, `Normal = 1`, `Low = 2`.
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    /// Stable lowercase name (wire format and reports).
    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Parse the wire name back; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }
}

/// One queued generation request.
#[derive(Clone)]
pub struct ServeRequest {
    /// Prompt text (byte-tokenized; truncated to the family's `s_pre`).
    pub prompt: String,
    /// Generation budget: the lane stops once it has emitted at least this
    /// many tokens (the final block may overshoot, exactly like
    /// [`SpecEngine::generate`]).
    pub max_new: usize,
    /// Seed of this request's private rng stream (the admission id is the
    /// stream selector, so equal seeds still draw independent streams).
    pub seed: u64,
    /// Service class (scheduler mode only; FIFO mode ignores it).
    pub priority: Priority,
    /// Per-request wall-clock deadline measured from *arrival* (not
    /// admission). Checked before every dispatch; an expired queued
    /// request is shed, an expired running lane retires with its partial
    /// stream as [`ServeError::Deadline`]. `None` disables it.
    pub deadline: Option<Duration>,
}

impl ServeRequest {
    /// A normal-priority request with no deadline.
    pub fn new(prompt: impl Into<String>, max_new: usize, seed: u64) -> ServeRequest {
        ServeRequest {
            prompt: prompt.into(),
            max_new,
            seed,
            priority: Priority::default(),
            deadline: None,
        }
    }

    /// Set the service class.
    pub fn with_priority(mut self, priority: Priority) -> ServeRequest {
        self.priority = priority;
        self
    }

    /// Set the per-request deadline (from arrival).
    pub fn with_deadline(mut self, deadline: Duration) -> ServeRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// Structured lane-failure taxonomy: why a request retired without (or
/// with only part of) its stream. Carried on [`ServeOutput::error`].
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// A dispatch failed outright (injected or real); retryable.
    Transient {
        /// Human-readable cause.
        message: String,
    },
    /// A dispatch returned a non-finite sampled surface, caught by the
    /// boundary guards before anything was sampled from it.
    Corrupt {
        /// Human-readable cause.
        message: String,
    },
    /// The request exceeded its per-request deadline and retired with a
    /// partial stream.
    Deadline {
        /// Wall-clock seconds from admission to retirement.
        elapsed_secs: f64,
    },
    /// Consecutive retries exceeded [`ResilienceConfig::max_retries`].
    Exhausted {
        /// Consecutive retries spent before giving up.
        retries: usize,
        /// The final failure's description.
        last: String,
    },
    /// The lane's tick panicked (isolated; the batch was unaffected).
    Panic {
        /// The panic payload, when it was a string.
        message: String,
    },
    /// The backend circuit breaker opened fully ([`BackendHealth::Failed`]):
    /// even degraded autoregressive decoding kept faulting.
    Failed {
        /// Human-readable cause.
        message: String,
    },
    /// Load shedding: the scheduler retired the request from the queue
    /// without running it (expired deadline or queue overflow). No backend
    /// work was spent; the output carries an empty stream.
    Shed {
        /// Why the request was shed.
        reason: String,
    },
}

impl ServeError {
    /// Stable lowercase tag per variant (for logs and reports).
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Transient { .. } => "transient",
            ServeError::Corrupt { .. } => "corrupt",
            ServeError::Deadline { .. } => "deadline",
            ServeError::Exhausted { .. } => "exhausted",
            ServeError::Panic { .. } => "panic",
            ServeError::Failed { .. } => "failed",
            ServeError::Shed { .. } => "shed",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Transient { message } => write!(f, "transient: {message}"),
            ServeError::Corrupt { message } => write!(f, "corrupt: {message}"),
            ServeError::Deadline { elapsed_secs } => {
                write!(f, "deadline exceeded after {elapsed_secs:.3}s")
            }
            ServeError::Exhausted { retries, last } => {
                write!(f, "retries exhausted after {retries} attempts (last: {last})")
            }
            ServeError::Panic { message } => write!(f, "lane panicked: {message}"),
            ServeError::Failed { message } => write!(f, "backend failed: {message}"),
            ServeError::Shed { reason } => write!(f, "shed: {reason}"),
        }
    }
}

/// Backend health as seen by the serving loop's circuit breaker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendHealth {
    /// Speculative decoding, full speed.
    Healthy,
    /// Consecutive faults tripped the breaker: lanes run plain
    /// autoregressive decode (lossless, slower) and the speculative path
    /// is re-probed periodically.
    Degraded,
    /// Even degraded decoding kept faulting: the loop drains every lane
    /// and queued request with [`ServeError::Failed`].
    Failed,
}

/// Recovery policy for [`ServeLoop::with_resilience`].
#[derive(Clone, Debug)]
pub struct ResilienceConfig {
    /// Consecutive per-lane checkpoint retries before the lane retires as
    /// [`ServeError::Exhausted`]. Keep this at least as large as
    /// `degrade_after`, or lanes can exhaust before the loop degrades.
    pub max_retries: usize,
    /// Per-request wall-clock deadline; `None` disables deadline
    /// retirement.
    pub deadline: Option<Duration>,
    /// Consecutive backend faults (across lanes, in lane order) before
    /// `Healthy → Degraded`.
    pub degrade_after: usize,
    /// Consecutive degraded-mode faults before `Degraded → Failed`.
    /// Failed probes do not count — only the autoregressive fallback
    /// itself faulting can open the breaker fully.
    pub fail_after: usize,
    /// Probe the speculative path every this-many degraded ticks (0
    /// disables probing, pinning the loop in degraded mode).
    pub probe_interval: usize,
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig {
            max_retries: 16,
            deadline: None,
            degrade_after: 6,
            fail_after: 12,
            probe_interval: 4,
        }
    }
}

/// Policy knobs for the preemptive priority scheduler
/// ([`ServeLoop::with_scheduler`]).
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// Prefill chunk size in rows. Each prefilling lane commits at most
    /// this many prompt (or rebuild-replay) rows per scheduler tick,
    /// interleaved with the other lanes' decode work. Defaults to the
    /// `SPECDELAY_PREFILL_CHUNK` env knob, else 256.
    pub prefill_chunk: usize,
    /// Queue-overflow shedding threshold: when more than this many
    /// requests are queued, the scheduler sheds from the back of the
    /// lowest-priority non-empty queue. `None` disables overflow shedding
    /// (expired-deadline shedding still applies).
    pub max_queue: Option<usize>,
    /// Stride-scheduling weights per class (`[high, normal, low]`): a
    /// class with weight `w` is admitted `w` times as often as a class
    /// with weight 1 under sustained contention, so lower classes are
    /// starvation-free. Zero weights are clamped to 1.
    pub weights: [u64; 3],
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        let prefill_chunk = std::env::var("SPECDELAY_PREFILL_CHUNK")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&c| c >= 1)
            .unwrap_or(256);
        SchedConfig { prefill_chunk, max_queue: None, weights: [4, 2, 1] }
    }
}

/// Scheduler-side counters for one [`ServeLoop::run`] drain (all zero in
/// FIFO mode except `peak_active`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SchedCounters {
    /// Lanes parked by the preemptor (checkpoint dropped, committed
    /// prefix kept resident).
    pub preempted: usize,
    /// Parked lanes re-admitted.
    pub resumed: usize,
    /// Parked lanes whose KV blocks were released entirely under
    /// continued pool pressure (context rebuilt on resume).
    pub released: usize,
    /// Context rebuilds completed via chunked replay.
    pub rebuilt: usize,
    /// Requests shed from the queue ([`ServeError::Shed`]).
    pub shed: usize,
    /// Prefill chunks dispatched (fresh prompts and rebuild replays).
    pub prefill_chunks: usize,
    /// Peak concurrently active lanes.
    pub peak_active: usize,
}

/// Fault-handling counters for one [`ServeLoop::run`] drain. The chaos
/// suite closes the loop against [`FaultStats`](crate::runtime::FaultStats):
/// `transient_seen + corrupt_seen + panics == retries + surfaced` — every
/// observed fault is either deterministically re-executed or reported on
/// an output, never silently dropped.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryCounters {
    /// Transient dispatch faults observed.
    pub transient_seen: usize,
    /// Corruption guard trips observed.
    pub corrupt_seen: usize,
    /// Lane panics caught (and isolated).
    pub panics: usize,
    /// Faults answered with a checkpoint restore + re-execution.
    pub retries: usize,
    /// Faults surfaced on a retiring output's [`ServeOutput::error`].
    pub surfaced: usize,
    /// Lanes retired by deadline.
    pub deadline_retired: usize,
    /// `Healthy → Degraded` transitions.
    pub degraded_entered: usize,
    /// Ticks served in autoregressive degraded mode.
    pub degraded_ticks: usize,
    /// Speculative re-probes attempted while degraded.
    pub probes: usize,
    /// Probes that returned the loop to `Healthy`.
    pub recoveries: usize,
}

/// One finished request.
pub struct ServeOutput {
    /// Admission-order request id (as returned by [`ServeLoop::submit`]).
    pub id: u64,
    /// Decoded continuation (prompt excluded; possibly partial when
    /// `error` is set).
    pub text: String,
    /// Emitted token ids (prompt excluded) — the raw stream `text` decodes.
    pub tokens: Vec<u32>,
    /// Whole-generation statistics; `wall_secs` spans admission→retirement,
    /// so under batching it includes time sharing the machine with other
    /// lanes.
    pub stats: GenStats,
    /// Set when this lane failed mid-generation. A failing lane retires
    /// with the classified error recorded here; the other lanes are
    /// unaffected — one bad request never discards the batch's completed
    /// results.
    pub error: Option<ServeError>,
    /// True when any token of this stream was emitted by the degraded-mode
    /// autoregressive fallback. The stream is still lossless (every token
    /// sampled from the exact target conditional) but no longer
    /// bit-identical to the fault-free speculative oracle, because
    /// autoregressive sampling consumes the rng stream differently.
    pub degraded: bool,
    /// Checkpoint retries this lane spent over its lifetime.
    pub retries: usize,
    /// The request's service class.
    pub priority: Priority,
    /// Seconds spent queued before admission (arrival → admission).
    pub queue_secs: f64,
    /// Time-to-first-token: seconds from *arrival* to the first tick that
    /// emitted at least one token. `None` when nothing was emitted.
    pub ttft_secs: Option<f64>,
    /// Per-tick emission trace: `(seconds_since_arrival, tokens_emitted)`
    /// for every tick that emitted tokens — the raw series the latency
    /// benches derive per-token inter-arrival gaps from.
    pub tick_emits: Vec<(f64, usize)>,
    /// Prompt KV rows adopted from the cross-request radix prefix cache at
    /// admission instead of being recomputed by prefill — so TTFT
    /// attribution can distinguish cache hits from chunked-prefill speed.
    /// Zero on a cache miss, when prefix caching is disabled, and when the
    /// lane later lost its caches (a released-and-rebuilt or fully
    /// restarted lane recomputes those rows, so the benefit is gone).
    pub cached_prefix_rows: usize,
}

/// A lane's recovery snapshot: the sequence and rng stream state as of the
/// last successful tick. Restoring it makes a retried block re-execute
/// bit-identically to the fault-free schedule; under paged KV the sequence
/// clone is a copy-on-write fork.
struct Checkpoint {
    seq: Sequence,
    rng: Pcg64,
    /// Selector-decision stream state. Without an active selector the
    /// stream never advances, so restoring it is a no-op.
    sel_rng: Pcg64,
}

/// An active lane: one admitted request mid-generation. `seq` stays `None`
/// until the lane's first tick — prefill runs inside the data-parallel
/// fan-out (it is lane-local backend work), never serially in the
/// scheduler thread where it would stall the other lanes.
struct Lane {
    id: u64,
    seed: u64,
    prompt: String,
    max_new: usize,
    seq: Option<Sequence>,
    rng: Pcg64,
    /// Dedicated rng stream for drafter/selector decisions
    /// (`Pcg64::new(selector seed, id)`), so changing the selection policy
    /// or its seed never perturbs the token-sampling stream `rng`.
    sel_rng: Pcg64,
    stats: GenStats,
    started: Instant,
    checkpoint: Option<Checkpoint>,
    /// Consecutive failed ticks since the last success.
    retries: usize,
    /// Lifetime retry count (reported on the output).
    total_retries: usize,
    degraded: bool,
    priority: Priority,
    /// Per-request deadline, measured from `arrival`.
    deadline: Option<Duration>,
    /// When the request was submitted (TTFT / queue-time origin).
    arrival: Instant,
    /// Arrival → admission wait, frozen at admission.
    queue_secs: f64,
    /// Seconds from arrival to the first emitting tick.
    ttft: Option<f64>,
    /// `(seconds_since_arrival, emitted)` per emitting tick.
    tick_emits: Vec<(f64, usize)>,
    /// Tokens already counted into `tick_emits`.
    emitted_seen: usize,
    /// In-flight chunked prefill (fresh prompt or post-release rebuild).
    prefill: Option<PrefillState>,
    /// The lane's KV was released under pool pressure; its context must
    /// be replayed (chunked) before it can decode again.
    needs_rebuild: bool,
    /// Blocks this lane holds reserved against the target/draft pools
    /// (zero when uncapped). Returned at every retirement site.
    reserve_t: usize,
    reserve_d: usize,
    /// Prompt rows adopted from the prefix cache at admission (reported as
    /// [`ServeOutput::cached_prefix_rows`]; reset when the lane's caches
    /// are released or fully restarted).
    cached_rows: usize,
}

/// Worst-case block reservation per admitted lane under a capped pool.
///
/// With paged KV storage a lane allocates blocks lazily as it commits
/// rows, so the loop cannot know a lane's final footprint at admission
/// time. To guarantee an admitted lane never hits pool exhaustion
/// mid-generation, admission reserves the worst case: every target block a
/// full `max_seq` context needs, every draft block, plus the trunk→branch
/// handoff's divergent blocks (the shared prefix is refcounted, only the
/// boundary fork and the trunk's own blocks are unique) — doubled when
/// resilience checkpoints are enabled, since a lane then also pins a
/// copy-on-write snapshot whose footprint is bounded by the same worst
/// case. Requests that don't fit wait in the queue — backpressure instead
/// of failure — and retiring lanes hand their reservation (and, via
/// `Drop`, their actual blocks) back.
struct LaneBudget {
    /// Tokens per block in both pools.
    bt: usize,
    /// 2 with resilience checkpoints (lane + COW snapshot), else 1.
    factor: usize,
    /// Longest trunk the draft handoff cache carries.
    max_trunk: usize,
    /// Worst-case rows one speculation block can commit beyond the
    /// request's stated budget (trunk + branch + bonus overshoot).
    overshoot: usize,
    /// Whole-`max_seq` worst case per pool — the cap clamp, and the
    /// per-tick safety bound for a lane running alone.
    worst_target: usize,
    worst_draft: usize,
    /// Per-pool *effective* cap in actual blocks (both pools): the
    /// f32-equivalent budget scaled by the KV dtype's capacity multiplier
    /// ([`crate::kvcache::BlockPool::effective_max_blocks`]) and clamped
    /// so one lane always fits. All reservations and live-block admission
    /// checks compare against this.
    cap: usize,
}

impl LaneBudget {
    /// Tight per-request reservation: `prompt + max_new + overshoot` rows
    /// (clamped to `max_seq`) instead of the whole-lifetime `max_seq`
    /// worst case, so short requests stop pinning blocks they can never
    /// touch and a small pool admits more concurrent lanes.
    fn reserve(&self, meta: &crate::runtime::FamilyMeta, prompt: &str, max_new: usize) -> (usize, usize) {
        let prompt_len = tokenizer::encode(prompt).len().max(1).min(meta.s_pre);
        let rows = (prompt_len + max_new + self.overshoot).min(meta.target.max_seq);
        let t = (self.factor * rows.div_ceil(self.bt)).min(self.worst_target);
        let d = (self.factor
            * (rows.min(meta.draft.max_seq).div_ceil(self.bt)
                + self.max_trunk.div_ceil(self.bt)
                + 1))
            .min(self.worst_draft);
        (t, d)
    }

    /// Worst-case blocks one tick of this lane can newly allocate
    /// (committed rows plus COW forks of checkpoint-shared tail blocks).
    /// Prefilling lanes commit one chunk per role; decoding lanes commit
    /// at most `overshoot` target rows and the draft handoff.
    fn tick_margin(&self, prefill_chunk: Option<usize>) -> (usize, usize) {
        match prefill_chunk {
            Some(chunk) => {
                let m = chunk.div_ceil(self.bt) + 1;
                (m, m)
            }
            None => {
                let t = self.factor * (self.overshoot.div_ceil(self.bt) + 1);
                let d = self.factor
                    * (self.overshoot.div_ceil(self.bt) + 1 + self.max_trunk.div_ceil(self.bt) + 1);
                (t, d)
            }
        }
    }
}

/// One queued request with its arrival time (open-loop traces submit
/// future arrivals via [`ServeLoop::submit_after`]).
struct QueueEntry {
    id: u64,
    req: ServeRequest,
    arrival: Instant,
}

/// Per-lane tick result, classified in the worker (so only plain data
/// crosses back to the scheduler).
enum StepOutcome {
    Progress(TickReport),
    /// The lane's deadline expired before any work was dispatched this
    /// tick (satellite: deadline granularity — checked per tick, not per
    /// generation).
    DeadlinePre,
    Fault(ServeError),
}

/// What a successful tick actually did (scheduler accounting).
#[derive(Clone, Copy, Default)]
struct TickReport {
    /// This tick dispatched one prefill chunk (fresh or rebuild).
    chunk: bool,
    /// This tick completed a preempted lane's context rebuild.
    rebuilt: bool,
    /// Selector-served block: the chosen arm index and the block's
    /// acceptance tally, folded into the calibration priors in lane order
    /// at tick end (and naturally discarded on a faulted tick — the retry
    /// re-tallies exactly once).
    sel: Option<(usize, ArmStats)>,
}

/// The batched serving loop (see the module docs).
pub struct ServeLoop<'a> {
    spec: SpecEngine<'a>,
    verifier: &'a dyn Verifier,
    policy: &'a dyn ActionPolicy,
    max_batch: usize,
    workers: usize,
    /// Per-class queues, `Priority::index()`-addressed. FIFO mode pops
    /// the globally smallest id; scheduler mode stride-schedules.
    queues: [VecDeque<QueueEntry>; 3],
    next_id: u64,
    budget: Option<LaneBudget>,
    requested_blocks: Option<usize>,
    resilience: Option<ResilienceConfig>,
    recovery: RecoveryCounters,
    sched: Option<SchedConfig>,
    counters: SchedCounters,
    /// Stride-scheduling pass values per class (scheduler mode).
    passes: [u64; 3],
    /// Cross-request radix prefix cache toggle (defaults to the
    /// `SPECDELAY_PREFIX_CACHE` env knob; see [`prefix_cache_enabled`]).
    prefix_enabled: bool,
    /// The cache itself — `Some` only when enabled *and* the engine runs
    /// paged storage (cached runs are refcounted pool blocks).
    prefix: Option<PrefixCache>,
    /// Admissions that wanted the cache but found none because lanes run
    /// contiguous storage (folded into
    /// [`PrefixCacheCounters::skipped_contiguous`]).
    prefix_skipped: u64,
    /// Serving-time online selector ([`ServeLoop::with_selector`], env
    /// knob `SPECDELAY_SELECTOR=1`). `None` — or a selector with no arms —
    /// leaves the static verifier/policy path byte-for-byte unchanged.
    selector: Option<OnlineSelector>,
    /// Seed of the per-lane selector-decision rng streams
    /// (`Pcg64::new(sel_seed, lane id)`); held even with no selector so
    /// lanes can always construct the stream.
    sel_seed: u64,
    /// Online-calibration tallies observed by this loop's runs, one entry
    /// per selector arm. Folded in lane order at tick end, so the result
    /// is identical for every worker count.
    sel_priors: SelectorPriors,
}

impl<'a> ServeLoop<'a> {
    /// Build a loop serving up to `max_batch` concurrent sequences with one
    /// verifier/policy pair.
    pub fn new(
        engine: &'a dyn Backend,
        sampling: SamplingConfig,
        verifier: &'a dyn Verifier,
        policy: &'a dyn ActionPolicy,
        max_batch: usize,
    ) -> ServeLoop<'a> {
        // opt the whole process into scheduler mode without touching call
        // sites (the CI equality rerun flips this)
        let sched = match std::env::var("SPECDELAY_SCHED") {
            Ok(v) if v == "1" => Some(SchedConfig::default()),
            _ => None,
        };
        // engage the selector machinery process-wide without touching call
        // sites (the CI equality rerun flips this); the default config has
        // no arms, so the engaged selector is transparent — streams match
        // the static path byte for byte until arms are configured
        let selector = match std::env::var("SPECDELAY_SELECTOR") {
            Ok(v) if v == "1" => Some(
                OnlineSelector::new(SelectorConfig::default())
                    .expect("default selector config is valid"),
            ),
            _ => None,
        };
        let sel_seed = SelectorConfig::default().seed;
        let mut sl = ServeLoop {
            spec: SpecEngine::new(engine, sampling),
            verifier,
            policy,
            max_batch: max_batch.max(1),
            workers: threadpool::default_workers(),
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            next_id: 0,
            budget: None,
            requested_blocks: None,
            resilience: None,
            recovery: RecoveryCounters::default(),
            sched,
            counters: SchedCounters::default(),
            passes: [0; 3],
            prefix_enabled: prefix_cache_enabled(),
            prefix: None,
            prefix_skipped: 0,
            selector,
            sel_seed,
            sel_priors: SelectorPriors::default(),
        };
        sl.rebuild_prefix();
        sl
    }

    /// Serve with the online dynamic selector: each lane picks a
    /// (verifier × drafter × action) arm per block from its live
    /// [`StepFeatures`](super::StepFeatures), on a dedicated decision rng
    /// stream seeded from [`SelectorConfig::seed`] and the lane id (token
    /// sampling rng is never touched). A config with no arms is engaged
    /// but transparent — streams stay byte-for-byte the static path.
    /// Acceptance tallies are calibrated online into
    /// [`ServeLoop::selector_priors`], deterministically for every worker
    /// count. Panics on a config naming an unknown verifier.
    pub fn with_selector(mut self, cfg: SelectorConfig) -> ServeLoop<'a> {
        self.sel_seed = cfg.seed;
        self.sel_priors = SelectorPriors::zeros(cfg.arms.len());
        self.selector = Some(OnlineSelector::new(cfg).expect("selector config"));
        self
    }

    /// Select the drafting policy lanes speculate with on the static path
    /// (selector arms carry their own drafter). Survives the engine
    /// rebuilds of [`ServeLoop::with_kv_storage`] and
    /// [`ServeLoop::with_block_budget`].
    pub fn with_drafter(mut self, kind: DrafterKind) -> ServeLoop<'a> {
        self.spec.set_drafter(kind);
        self
    }

    /// The online selector, when one is configured.
    pub fn selector(&self) -> Option<&OnlineSelector> {
        self.selector.as_ref()
    }

    /// Whether an *active* selector (configured with at least one arm) is
    /// driving the lanes.
    pub fn selector_active(&self) -> bool {
        self.selector.as_ref().is_some_and(|s| s.is_active())
    }

    /// Online-calibration tallies accumulated by this loop's runs, one
    /// [`ArmStats`] per selector arm (empty with no selector). Feed them
    /// back as [`SelectorConfig::priors`] to warm-start the next run.
    pub fn selector_priors(&self) -> &SelectorPriors {
        &self.sel_priors
    }

    /// Enable the preemptive priority scheduler (chunked prefill,
    /// weighted per-class admission, preempt-and-requeue under a block
    /// budget, deadline-aware shedding — see the module docs). Completed
    /// streams stay bit-identical to FIFO and to the serial oracle; only
    /// *scheduling* (ordering, latency, shedding) changes.
    pub fn with_scheduler(mut self, cfg: SchedConfig) -> ServeLoop<'a> {
        self.sched = Some(cfg);
        self
    }

    /// Disable the scheduler (back to strict-FIFO admission), overriding
    /// the `SPECDELAY_SCHED` env default. Benches use this to hold the
    /// comparison baseline fixed.
    pub fn without_scheduler(mut self) -> ServeLoop<'a> {
        self.sched = None;
        self
    }

    /// Override the per-tick worker count (defaults to
    /// [`threadpool::default_workers`]; token streams do not depend on it).
    pub fn with_workers(mut self, workers: usize) -> ServeLoop<'a> {
        self.workers = workers.max(1);
        self
    }

    /// Select the lanes' KV representation explicitly (the default follows
    /// the `SPECDELAY_PAGED_KV` env knob). Clears any block budget; token
    /// streams do not depend on the storage — paged is bit-identical to
    /// the contiguous oracle.
    pub fn with_kv_storage(mut self, storage: KvStorage) -> ServeLoop<'a> {
        self.spec = SpecEngine::new(self.spec.engine, self.spec.sampling)
            .with_kv_storage(storage)
            .with_drafter(self.spec.drafter());
        self.budget = None;
        self.requested_blocks = None;
        self.rebuild_prefix();
        self
    }

    /// Serve from a capped paged block pool: both the target and the draft
    /// pool are capped at `blocks` *f32-equivalent* blocks (of
    /// [`default_block_tokens`] tokens each), clamped up so a single lane
    /// always fits. With a reduced-precision KV dtype
    /// (`SPECDELAY_KV_DTYPE`) the same byte budget holds 2× (f16) or 4×
    /// (int8) the actual blocks, and admission schedules against that
    /// effective capacity — more concurrent lanes, same stated budget.
    /// Admission switches from "a free batch slot" to "a free
    /// batch slot *and* a worst-case block reservation in both pools" —
    /// requests that don't fit queue until running lanes retire
    /// (out-of-blocks backpressure), and token streams are identical to an
    /// uncapped run because lane content never depends on admission timing.
    pub fn with_block_budget(mut self, blocks: usize) -> ServeLoop<'a> {
        self.requested_blocks = Some(blocks);
        self.rebuild_budget();
        self
    }

    /// Enable checkpoint/retry recovery, deadlines and the backend health
    /// state machine (see the module docs). Completed non-degraded streams
    /// stay bit-identical to the fault-free oracle; degraded streams stay
    /// lossless. When a block budget is also set, per-lane reservations
    /// double to cover the checkpoint snapshot.
    pub fn with_resilience(mut self, cfg: ResilienceConfig) -> ServeLoop<'a> {
        self.resilience = Some(cfg);
        self.rebuild_budget();
        self
    }

    /// Recompute the paged pools and per-lane reservations from the
    /// requested budget and the resilience mode (builder-order
    /// independent: `with_block_budget` and `with_resilience` may be
    /// called either way around).
    fn rebuild_budget(&mut self) {
        let Some(blocks) = self.requested_blocks else { return };
        let bt = default_block_tokens();
        let meta = self.spec.engine.meta();
        let max_trunk = meta.trunk_lens.iter().copied().max().unwrap_or(8);
        let max_branch = meta.branch_lens.iter().copied().max().unwrap_or(8);
        // lane + (with resilience) its copy-on-write checkpoint, each
        // bounded by the single-lane worst case
        let factor = if self.resilience.is_some() { 2 } else { 1 };
        // one block commits at most trunk + branch rows plus the bonus
        // token — the per-tick (and per-request) growth bound
        let overshoot = max_trunk + max_branch + 2;
        let worst_target = factor * meta.target.max_seq.div_ceil(bt);
        // draft lane + the handoff cache's divergent blocks (boundary fork
        // + the trunk's own rows; the shared prefix costs nothing)
        let worst_draft =
            factor * (meta.draft.max_seq.div_ceil(bt) + max_trunk.div_ceil(bt) + 1);
        // the stated budget is in f32-equivalent block units (bytes); a
        // reduced-precision pool fits `mult×` more actual blocks in the
        // same bytes, so admission schedules against the *effective*
        // capacity. Clamp the effective capacity up so one lane always
        // fits, then hand the pool the raw (f32-unit) budget it scales by
        // the same multiplier.
        let mult = KvDtype::global().capacity_multiplier();
        let raw = blocks.max(worst_target.div_ceil(mult)).max(worst_draft.div_ceil(mult));
        let cap = raw * mult;
        self.spec = SpecEngine::new(self.spec.engine, self.spec.sampling)
            .with_paged_kv(bt, Some(raw))
            .with_drafter(self.spec.drafter());
        self.budget =
            Some(LaneBudget { bt, factor, max_trunk, overshoot, worst_target, worst_draft, cap });
        self.rebuild_prefix();
    }

    /// The engine driving the lanes (pool introspection for tests/benches).
    pub fn spec(&self) -> &SpecEngine<'a> {
        &self.spec
    }

    /// Fault-handling counters of the most recent [`ServeLoop::run`].
    pub fn recovery(&self) -> &RecoveryCounters {
        &self.recovery
    }

    /// Scheduler counters of the most recent [`ServeLoop::run`].
    pub fn sched_counters(&self) -> &SchedCounters {
        &self.counters
    }

    /// Whether the preemptive scheduler is enabled.
    pub fn scheduler_enabled(&self) -> bool {
        self.sched.is_some()
    }

    /// Enable or disable the cross-request radix prefix cache explicitly,
    /// overriding the `SPECDELAY_PREFIX_CACHE` env default. The cache only
    /// materialises over paged storage; contiguous lanes fall back to cold
    /// prefill and count `skipped_contiguous`. Warm streams are
    /// bit-identical to cold ones: a cached row is exactly the row a cold
    /// prefill of the same tokens would have committed (the backend
    /// consistency contract), and admission adopts runs via refcounted
    /// block handles, never by copying or mutating shared rows.
    pub fn with_prefix_cache(mut self, enabled: bool) -> ServeLoop<'a> {
        self.prefix_enabled = enabled;
        self.rebuild_prefix();
        self
    }

    /// Prefix-cache counters accumulated so far (lookups, hits, matched
    /// rows, inserted runs, evictions). `skipped_contiguous` folds in
    /// admissions that found no cache at all because the engine runs
    /// contiguous storage.
    pub fn prefix_counters(&self) -> PrefixCacheCounters {
        let mut c = self.prefix.as_ref().map(|p| p.counters()).unwrap_or_default();
        c.skipped_contiguous += self.prefix_skipped;
        c
    }

    /// Whether prefix caching is enabled (it still needs paged storage to
    /// materialise; see [`ServeLoop::with_prefix_cache`]).
    pub fn prefix_cache_on(&self) -> bool {
        self.prefix_enabled
    }

    /// Flush every cached prefix run back to the block pools (cache
    /// invalidation — e.g. after a model swap, or to assert a drained
    /// loop holds zero live blocks). Blocks still adopted by live lanes
    /// only lose the cache's reference. The cache stays enabled and
    /// repopulates from subsequent retirements; counters are kept.
    pub fn clear_prefix_cache(&mut self) {
        if let Some(cache) = self.prefix.as_mut() {
            cache.clear();
        }
    }

    /// (Re)build the cache over the engine's current pools. Called after
    /// every builder that swaps the engine, because cached runs are only
    /// valid against the pools that allocated them; dropping the old cache
    /// releases every cached block back to its pool.
    fn rebuild_prefix(&mut self) {
        self.prefix = if self.prefix_enabled {
            self.spec.kv_pools().map(|p| PrefixCache::new(&p.target, &p.draft))
        } else {
            None
        };
    }

    /// Enqueue a request; returns its admission-order id.
    pub fn submit(&mut self, req: ServeRequest) -> u64 {
        self.submit_after(req, Duration::ZERO)
    }

    /// Enqueue a request that *arrives* `delay` from now: it is invisible
    /// to admission until its arrival time, which lets a bench drive the
    /// loop with a precomputed open-loop arrival trace. Ids are still
    /// assigned in submission order.
    pub fn submit_after(&mut self, req: ServeRequest, delay: Duration) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let class = req.priority.index();
        self.queues[class].push_back(QueueEntry { id, req, arrival: Instant::now() + delay });
        id
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Requests waiting for admission, per class (`[high, normal, low]`).
    pub fn queued_by_class(&self) -> [usize; 3] {
        [self.queues[0].len(), self.queues[1].len(), self.queues[2].len()]
    }

    fn lane_done(lane: &Lane) -> bool {
        match &lane.seq {
            Some(seq) => {
                !lane.needs_rebuild
                    && lane.prefill.is_none()
                    && (seq.finished || seq.tokens.len() - seq.prompt_len >= lane.max_new)
            }
            None => false, // not even prefilled yet
        }
    }

    fn retire(lane: Lane, error: Option<ServeError>) -> ServeOutput {
        let mut stats = lane.stats;
        stats.wall_secs = lane.started.elapsed().as_secs_f64();
        let (text, tokens) = lane
            .seq
            .as_ref()
            .map(|seq| {
                let emitted = seq.tokens[seq.prompt_len..].to_vec();
                (tokenizer::decode(&emitted), emitted)
            })
            .unwrap_or_default();
        ServeOutput {
            id: lane.id,
            text,
            tokens,
            stats,
            error,
            degraded: lane.degraded,
            retries: lane.total_retries,
            priority: lane.priority,
            queue_secs: lane.queue_secs,
            ttft_secs: lane.ttft,
            tick_emits: lane.tick_emits,
            cached_prefix_rows: lane.cached_rows,
        }
    }

    /// A shed queue entry's output: empty stream, structured error, the
    /// queue wait it paid before being turned away.
    fn shed_output(entry: QueueEntry, reason: &str) -> ServeOutput {
        ServeOutput {
            id: entry.id,
            text: String::new(),
            tokens: Vec::new(),
            stats: GenStats::default(),
            error: Some(ServeError::Shed { reason: reason.to_string() }),
            degraded: false,
            retries: 0,
            priority: entry.req.priority,
            queue_secs: entry.arrival.elapsed().as_secs_f64(),
            ttft_secs: None,
            tick_emits: Vec::new(),
            cached_prefix_rows: 0,
        }
    }

    /// FIFO head: the class holding the globally smallest id among
    /// arrived queue fronts (each per-class queue is id-ordered, so the
    /// global head is one of the three fronts) — byte-for-byte the legacy
    /// admission order.
    fn fifo_front(&self, now: Instant) -> Option<usize> {
        let mut best: Option<usize> = None;
        for c in 0..3 {
            if let Some(e) = self.queues[c].front() {
                if e.arrival <= now
                    && best.map_or(true, |b| {
                        e.id < self.queues[b].front().map_or(u64::MAX, |f| f.id)
                    })
                {
                    best = Some(c);
                }
            }
        }
        best
    }

    /// Stride-scheduled head across the arrived class fronts: each class
    /// advances a pass value by `STRIDE / weight` per admission and the
    /// smallest pass wins (ties to the higher class), so admissions
    /// converge to the weight ratios without starving any class.
    fn weighted_front(&self, now: Instant) -> Option<usize> {
        let mut best: Option<usize> = None;
        for c in 0..3 {
            let arrived = self.queues[c].front().is_some_and(|e| e.arrival <= now);
            if arrived && best.map_or(true, |b| self.passes[c] < self.passes[b]) {
                best = Some(c);
            }
        }
        best
    }

    /// Pop the head of `class`, advancing its stride pass in scheduler
    /// mode (pass values reset at the start of every drain).
    fn take_front(&mut self, class: usize) -> Option<QueueEntry> {
        const STRIDE: u64 = 1 << 20;
        if let Some(cfg) = &self.sched {
            self.passes[class] += STRIDE / cfg.weights[class].max(1);
        }
        self.queues[class].pop_front()
    }

    /// Committed-usage fit check (scheduler mode under a budget): do the
    /// blocks actually resident in both pools, plus every given lane's
    /// worst-case per-tick growth margin — and, when `extra` is set, one
    /// more lane whose next tick is a prefill chunk — fit under the cap?
    /// Uncapped or contiguous storage always fits.
    fn usage_fits(&self, lanes: &[Lane], extra: Option<usize>) -> bool {
        let Some(b) = &self.budget else { return true };
        let Some(pools) = self.spec.kv_pools() else { return true };
        let chunk = self.sched.as_ref().map_or(256, |s| s.prefill_chunk);
        let (mut need_t, mut need_d) = (0usize, 0usize);
        for lane in lanes {
            let pre = (lane.prefill.is_some() || lane.seq.is_none() || lane.needs_rebuild)
                .then_some(chunk);
            let (t, d) = b.tick_margin(pre);
            need_t += t;
            need_d += d;
        }
        if let Some(chunk) = extra {
            let (t, d) = b.tick_margin(Some(chunk));
            need_t += t;
            need_d += d;
        }
        // cached-but-unreferenced prefix runs are reclaimable on demand
        // (the pre-tick headroom pass physically evicts them), so they
        // never count against admission or preemption headroom
        let reclaim = self.prefix.as_ref().map_or(0, |c| c.reclaimable_pairs());
        pools.target.live_blocks() + need_t <= b.cap + reclaim
            && pools.draft.live_blocks() + need_d <= b.cap + reclaim
    }

    /// Would resuming `lane` on top of `active` stay under the cap for
    /// its next tick?
    fn resume_fits(&self, active: &[Lane], lane: &Lane) -> bool {
        let chunk = self.sched.as_ref().map_or(256, |s| s.prefill_chunk);
        let pre = (lane.prefill.is_some() || lane.seq.is_none() || lane.needs_rebuild)
            .then_some(chunk);
        let Some(b) = &self.budget else { return true };
        let Some(pools) = self.spec.kv_pools() else { return true };
        let (mut need_t, mut need_d) = b.tick_margin(pre);
        for l in active {
            let p = (l.prefill.is_some() || l.seq.is_none() || l.needs_rebuild)
                .then_some(chunk);
            let (t, d) = b.tick_margin(p);
            need_t += t;
            need_d += d;
        }
        // see usage_fits: reclaimable cache blocks count as headroom
        let reclaim = self.prefix.as_ref().map_or(0, |c| c.reclaimable_pairs());
        pools.target.live_blocks() + need_t <= b.cap + reclaim
            && pools.draft.live_blocks() + need_d <= b.cap + reclaim
    }

    /// Physically evict cached-but-unreferenced prefix runs when the
    /// upcoming tick's worst-case block growth does not fit the pools'
    /// actual residency. The fit checks above treat reclaimable cache
    /// blocks as free headroom; this pass makes that headroom real before
    /// any lane dispatches, so the dispatch-side `alloc_zeroed` panic
    /// ("lane admission must reserve pool headroom") stays unreachable.
    /// FIFO mode needs it too: worst-case reservations bound lane growth,
    /// but never accounted for cache-only resident blocks.
    fn reclaim_headroom(&mut self, active: &[Lane]) {
        if self.prefix.is_none() {
            return;
        }
        let Some(b) = &self.budget else { return };
        let chunk = self.sched.as_ref().map_or(256, |s| s.prefill_chunk);
        let (mut need_t, mut need_d) = (0usize, 0usize);
        for lane in active {
            let pre = (lane.prefill.is_some() || lane.seq.is_none() || lane.needs_rebuild)
                .then_some(chunk);
            let (t, d) = b.tick_margin(pre);
            need_t += t;
            need_d += d;
        }
        let cap = b.cap;
        let Some(pools) = self.spec.kv_pools() else { return };
        let short_t = (pools.target.live_blocks() + need_t).saturating_sub(cap);
        let short_d = (pools.draft.live_blocks() + need_d).saturating_sub(cap);
        let need_pairs = short_t.max(short_d);
        if need_pairs > 0 {
            if let Some(cache) = self.prefix.as_mut() {
                cache.reclaim(need_pairs);
            }
        }
    }

    /// Warm admission: consult the prefix cache for the longest cached
    /// block run matching the lane's prompt and adopt it into the lane's
    /// caches (refcounted handles only — no backend work, no row copies),
    /// leaving a pre-seeded chunked prefill that resumes at the first
    /// uncached row. A miss leaves the lane cold, byte-for-byte the legacy
    /// admission path.
    fn warm_admit(&mut self, lane: &mut Lane) {
        if !self.prefix_enabled {
            return;
        }
        let Some(cache) = self.prefix.as_mut() else {
            // enabled but the engine runs contiguous storage: graceful
            // cold-prefill fallback, counted rather than erroring
            self.prefix_skipped += 1;
            return;
        };
        let st = self.spec.start_chunked_cached(&lane.prompt, cache);
        if st.rows_done() > 0 {
            lane.cached_rows = st.rows_done();
            lane.prefill = Some(st);
        }
    }

    /// On clean retirement, publish the lane's committed prefix
    /// (`tokens[..root_pos]` — rows the backend consistency contract makes
    /// bit-identical to any future prefill of the same tokens) into the
    /// radix cache so later requests sharing the prefix skip that much
    /// prefill. Faulted and deadline retirements never insert: their
    /// caches may be half-built.
    fn cache_retired_prefix(&mut self, lane: &Lane) {
        let Some(cache) = self.prefix.as_mut() else { return };
        let Some(seq) = &lane.seq else { return };
        let (Some(t), Some(d)) = (seq.target_kv.as_paged(), seq.draft_kv.as_paged()) else {
            return;
        };
        cache.insert(&seq.tokens[..seq.root_pos], t, d);
    }

    /// Drop every block a parked lane holds: discard an in-flight fresh
    /// prefill outright, or release a decoded sequence's caches and mark
    /// it for a chunked rebuild on resume. The lane's stream is unchanged
    /// — the rebuild replays its exact committed context.
    fn release_lane(lane: &mut Lane) {
        lane.checkpoint = None;
        // either arm recomputes the adopted rows (chunked replay or cold
        // restart), so the cache benefit is gone — report honestly
        lane.cached_rows = 0;
        if let Some(seq) = &mut lane.seq {
            seq.release_kv();
            lane.needs_rebuild = true;
            lane.prefill = None;
        } else {
            lane.prefill = None;
        }
    }

    /// A parked lane still holds pool blocks (so releasing it would help).
    fn holds_blocks(lane: &Lane) -> bool {
        (lane.seq.is_some() && !lane.needs_rebuild) || lane.prefill.is_some()
    }

    /// Make room for parked lane `keep` by releasing the *other* parked
    /// lanes' blocks, youngest first. Returns true when something was
    /// released (the caller re-checks the fit).
    fn force_resume_room(&mut self, parked: &mut [Lane], keep: usize) -> bool {
        let mut victim: Option<usize> = None;
        for (i, lane) in parked.iter().enumerate() {
            if i == keep || !Self::holds_blocks(lane) {
                continue;
            }
            let better = victim.map_or(true, |v| {
                (lane.priority.index(), lane.id) > (parked[v].priority.index(), parked[v].id)
            });
            if better {
                victim = Some(i);
            }
        }
        let Some(v) = victim else { return false };
        Self::release_lane(&mut parked[v]);
        self.counters.released += 1;
        true
    }

    /// Drain the queue: admit, tick, retire until every submitted request
    /// has finished. Returns one output per request, sorted by request id;
    /// a lane that fails mid-generation retires with
    /// [`ServeOutput::error`] set and does not disturb the other lanes,
    /// and a lane that panics is caught and retired the same way.
    /// Under a block budget ([`ServeLoop::with_block_budget`]) admission
    /// additionally requires a worst-case block reservation in both pools,
    /// so requests queue — never fail — when blocks run out. With
    /// [`ServeLoop::with_resilience`] faults are retried from per-lane
    /// checkpoints and the backend health machine arbitrates speculative
    /// vs degraded autoregressive mode (see the module docs).
    pub fn run(&mut self) -> Result<Vec<ServeOutput>> {
        self.recovery = RecoveryCounters::default();
        self.counters = SchedCounters::default();
        self.passes = [0; 3];
        let mut active: Vec<Lane> = Vec::new();
        // lanes preempted under pool pressure, waiting to be re-admitted
        let mut parked: Vec<Lane> = Vec::new();
        let mut done: Vec<ServeOutput> = Vec::new();
        // worst-case blocks reserved by active lanes (FIFO mode under a
        // budget; scheduler mode admits on committed usage instead)
        let (mut reserved_t, mut reserved_d) = (0usize, 0usize);
        let mut health = BackendHealth::Healthy;
        // consecutive-fault streaks, in lane order across ticks
        let (mut healthy_faults, mut degraded_faults) = (0usize, 0usize);
        let mut degraded_ticks = 0usize;
        loop {
            if health == BackendHealth::Failed {
                // breaker fully open: drain everything with a structured
                // error instead of spinning (each lane's blocks return to
                // the pools as its Sequence drops)
                const MSG: &str = "backend circuit breaker open (degraded decode kept faulting)";
                for lane in active.drain(..).chain(parked.drain(..)) {
                    reserved_t -= lane.reserve_t;
                    reserved_d -= lane.reserve_d;
                    done.push(Self::retire(
                        lane,
                        Some(ServeError::Failed { message: MSG.to_string() }),
                    ));
                }
                for q in &mut self.queues {
                    while let Some(entry) = q.pop_front() {
                        done.push(ServeOutput {
                            id: entry.id,
                            text: String::new(),
                            tokens: Vec::new(),
                            stats: GenStats::default(),
                            error: Some(ServeError::Failed { message: MSG.to_string() }),
                            degraded: false,
                            retries: 0,
                            priority: entry.req.priority,
                            queue_secs: entry.arrival.elapsed().as_secs_f64(),
                            ttft_secs: None,
                            tick_emits: Vec::new(),
                            cached_prefix_rows: 0,
                        });
                    }
                }
                break;
            }
            // load shedding (scheduler mode): expired-deadline entries and
            // queue overflow retire from the queue as structured Shed
            // outputs — no backend work is ever spent on them
            if self.sched.is_some() {
                for c in 0..3 {
                    let mut i = 0;
                    while i < self.queues[c].len() {
                        let e = &self.queues[c][i];
                        let expired = e.req.deadline.is_some_and(|d| e.arrival.elapsed() >= d);
                        if expired {
                            let entry = self.queues[c].remove(i).expect("indexed entry");
                            self.counters.shed += 1;
                            done.push(Self::shed_output(entry, "deadline expired in queue"));
                        } else {
                            i += 1;
                        }
                    }
                }
                if let Some(max_queue) = self.sched.as_ref().and_then(|s| s.max_queue) {
                    while self.queued() > max_queue {
                        // shed from the back of the lowest-priority class
                        let c = (0..3).rev().find(|&c| !self.queues[c].is_empty());
                        let Some(c) = c else { break };
                        let entry = self.queues[c].pop_back().expect("non-empty queue");
                        self.counters.shed += 1;
                        done.push(Self::shed_output(entry, "queue overflow"));
                    }
                }
            }
            // admit into free batch slots (no backend work here: lanes
            // prefill inside the fan-out). Parked lanes resume first —
            // they hold committed work; fresh admissions come from the
            // queues by FIFO id (legacy) or stride-weighted class order.
            let now = Instant::now();
            while active.len() < self.max_batch {
                // resume the best parked lane (highest class, oldest id)
                // whose tick margin fits on top of the committed blocks
                if !parked.is_empty() {
                    let mut best = 0usize;
                    for i in 1..parked.len() {
                        let (bp, bi) = (parked[best].priority.index(), parked[best].id);
                        let (cp, ci) = (parked[i].priority.index(), parked[i].id);
                        if (cp, ci) < (bp, bi) {
                            best = i;
                        }
                    }
                    let fits = self.resume_fits(&active, &parked[best]);
                    if fits {
                        let lane = parked.remove(best);
                        self.counters.resumed += 1;
                        active.push(lane);
                        continue;
                    }
                    if active.is_empty() {
                        // nothing running and the best parked lane still
                        // does not fit: release other parked lanes'
                        // blocks (youngest first), then its own — alone
                        // it always fits, so the drain cannot strand it
                        if !self.force_resume_room(&mut parked, best) {
                            let mut lane = parked.remove(best);
                            Self::release_lane(&mut lane);
                            self.counters.released += 1;
                            self.counters.resumed += 1;
                            active.push(lane);
                            continue;
                        }
                        // room was made: re-check fit on the next pass
                        continue;
                    }
                    // parked lanes wait for running lanes to retire;
                    // fresh admissions would only add pressure
                    break;
                }
                let class = match if self.sched.is_some() {
                    self.weighted_front(now)
                } else {
                    self.fifo_front(now)
                } {
                    Some(c) => c,
                    None => break,
                };
                let entry = &self.queues[class][0];
                let (mut r_t, mut r_d) = (0usize, 0usize);
                if let Some(b) = &self.budget {
                    if self.sched.is_some() {
                        // committed-usage admission: the new lane only
                        // needs its first tick's margin on top of what is
                        // actually resident — overload is handled by
                        // preemption, not by worst-case reservations
                        let chunk =
                            self.sched.as_ref().map_or(256, |s| s.prefill_chunk);
                        if !self.usage_fits(&active, Some(chunk)) {
                            break;
                        }
                    } else {
                        // FIFO out-of-blocks backpressure: leave the
                        // request queued unless its (tight) worst case
                        // fits both pools
                        let meta = self.spec.engine.meta();
                        let (t, d) = b.reserve(meta, &entry.req.prompt, entry.req.max_new);
                        let fits = reserved_t + t <= b.cap && reserved_d + d <= b.cap;
                        if !fits {
                            break;
                        }
                        (r_t, r_d) = (t, d);
                    }
                }
                let entry = self.take_front(class).expect("peeked entry");
                reserved_t += r_t;
                reserved_d += r_d;
                let QueueEntry { id, req, arrival } = entry;
                let mut lane = Lane {
                    id,
                    seed: req.seed,
                    prompt: req.prompt,
                    max_new: req.max_new,
                    seq: None,
                    rng: Pcg64::new(req.seed, id),
                    sel_rng: Pcg64::new(self.sel_seed, id),
                    stats: GenStats::default(),
                    started: Instant::now(),
                    checkpoint: None,
                    retries: 0,
                    total_retries: 0,
                    degraded: false,
                    priority: req.priority,
                    deadline: req.deadline,
                    arrival,
                    queue_secs: arrival.elapsed().as_secs_f64(),
                    ttft: None,
                    tick_emits: Vec::new(),
                    emitted_seen: 0,
                    prefill: None,
                    needs_rebuild: false,
                    reserve_t: r_t,
                    reserve_d: r_d,
                    cached_rows: 0,
                };
                // warm admission: adopt any cached prefix rows before the
                // first tick (handle clones only — no backend work)
                self.warm_admit(&mut lane);
                active.push(lane);
            }
            self.counters.peak_active = self.counters.peak_active.max(active.len());
            if active.is_empty() {
                if self.queued() == 0 && parked.is_empty() {
                    break;
                }
                // only future arrivals remain: sleep until the earliest
                // one instead of spinning
                let next = self
                    .queues
                    .iter()
                    .filter_map(|q| q.iter().map(|e| e.arrival).min())
                    .min();
                if let Some(at) = next {
                    let now = Instant::now();
                    if at > now {
                        std::thread::sleep(at - now);
                    }
                }
                continue;
            }
            // preemption (scheduler mode under a budget): when the blocks
            // actually resident plus every active lane's worst-case
            // per-tick growth no longer fit the pools, park the
            // lowest-priority / youngest lane (its checkpoint fork is
            // dropped, its committed prefix stays resident); if a single
            // lane still does not fit, release parked lanes' blocks
            // entirely — they rebuild their context by chunked replay on
            // resume. An admitted tick therefore can never hit pool
            // exhaustion mid-dispatch.
            if self.sched.is_some() && self.budget.is_some() {
                while !self.usage_fits(&active, None) {
                    if active.len() > 1 {
                        let mut v = 0usize;
                        for i in 1..active.len() {
                            if (active[i].priority.index(), active[i].id)
                                > (active[v].priority.index(), active[v].id)
                            {
                                v = i;
                            }
                        }
                        let mut lane = active.remove(v);
                        lane.checkpoint = None; // frees the COW snapshot
                        self.counters.preempted += 1;
                        parked.push(lane);
                        continue;
                    }
                    let mut victim: Option<usize> = None;
                    for (i, lane) in parked.iter().enumerate() {
                        if !Self::holds_blocks(lane) {
                            continue;
                        }
                        let better = victim.map_or(true, |w| {
                            (lane.priority.index(), lane.id)
                                > (parked[w].priority.index(), parked[w].id)
                        });
                        if better {
                            victim = Some(i);
                        }
                    }
                    match victim {
                        Some(i) => {
                            Self::release_lane(&mut parked[i]);
                            self.counters.released += 1;
                        }
                        // a lone lane always fits under the cap clamp
                        None => break,
                    }
                }
            }
            // turn the reclaimable headroom the fit checks promised into
            // real free blocks before any lane dispatches
            self.reclaim_headroom(&active);
            // tick mode: degraded lanes decode autoregressively, except on
            // probe ticks, which re-attempt the speculative path
            let probing = health == BackendHealth::Degraded
                && self
                    .resilience
                    .as_ref()
                    .is_some_and(|r| r.probe_interval > 0
                        && (degraded_ticks + 1) % r.probe_interval == 0);
            let ar = health == BackendHealth::Degraded && !probing;
            if probing {
                self.recovery.probes += 1;
            }

            // one block (or one AR token) per lane, fanned out over the
            // pool; panics are caught per lane so one poisoned request
            // cannot take down the batch
            let spec = &self.spec;
            let verifier = self.verifier;
            let policy = self.policy;
            let selector = self.selector.as_ref();
            let chunk = self.sched.as_ref().map(|s| s.prefill_chunk);
            let global_deadline = self.resilience.as_ref().and_then(|r| r.deadline);
            let stepped = threadpool::par_map_init(
                std::mem::take(&mut active),
                self.workers,
                || (),
                |_state, _i, mut lane: Lane| -> (Lane, StepOutcome) {
                    // deadline granularity: check before dispatching any
                    // work for this tick, so an expired lane retires
                    // within one chunk/block of its deadline instead of
                    // running its whole generation first
                    let expired = lane.deadline.is_some_and(|d| lane.arrival.elapsed() >= d)
                        || global_deadline.is_some_and(|d| lane.started.elapsed() >= d);
                    if expired {
                        return (lane, StepOutcome::DeadlinePre);
                    }
                    let res = catch_unwind(AssertUnwindSafe(|| {
                        lane_tick(spec, verifier, policy, selector, &mut lane, ar, chunk)
                    }));
                    let outcome = match res {
                        Ok(Ok(rep)) => StepOutcome::Progress(rep),
                        Ok(Err(e)) => StepOutcome::Fault(classify(e)),
                        Err(p) => {
                            StepOutcome::Fault(ServeError::Panic { message: panic_message(p) })
                        }
                    };
                    (lane, outcome)
                },
            );

            // phase 1: update the health machine from this tick's outcomes
            // (lane order — deterministic given a deterministic fault
            // schedule, never dependent on worker timing)
            let prev_health = health;
            let mut tick_faults = 0usize;
            if let Some(cfg) = &self.resilience {
                for (_, outcome) in &stepped {
                    match outcome {
                        StepOutcome::Progress(_) => match health {
                            BackendHealth::Healthy => healthy_faults = 0,
                            BackendHealth::Degraded if ar => degraded_faults = 0,
                            _ => {}
                        },
                        // no dispatch happened: says nothing about health
                        StepOutcome::DeadlinePre => {}
                        StepOutcome::Fault(_) => {
                            tick_faults += 1;
                            match health {
                                BackendHealth::Healthy => {
                                    healthy_faults += 1;
                                    if healthy_faults >= cfg.degrade_after {
                                        health = BackendHealth::Degraded;
                                        degraded_faults = 0;
                                        degraded_ticks = 0;
                                        self.recovery.degraded_entered += 1;
                                    }
                                }
                                BackendHealth::Degraded if ar => {
                                    degraded_faults += 1;
                                    if degraded_faults >= cfg.fail_after {
                                        health = BackendHealth::Failed;
                                    }
                                }
                                // probe failures keep the loop degraded but
                                // never open the breaker fully
                                _ => {}
                            }
                        }
                    }
                }
                if probing && tick_faults == 0 {
                    health = BackendHealth::Healthy;
                    healthy_faults = 0;
                    self.recovery.recoveries += 1;
                }
            }
            let just_degraded =
                prev_health == BackendHealth::Healthy && health != BackendHealth::Healthy;

            // phase 2: lane fates, with the post-tick health known
            for (mut lane, outcome) in stepped {
                match outcome {
                    StepOutcome::Progress(rep) => {
                        lane.retries = 0;
                        if rep.chunk {
                            self.counters.prefill_chunks += 1;
                        }
                        if rep.rebuilt {
                            self.counters.rebuilt += 1;
                        }
                        // online calibration: fold the block's tally into
                        // the arm's prior. This loop runs in lane order on
                        // the scheduler thread, so the accumulated priors
                        // are identical for every worker count (the
                        // par_map_init contract: results index-addressed,
                        // never schedule-ordered).
                        if let Some((arm, delta)) = rep.sel {
                            if let Some(s) = self.sel_priors.arms.get_mut(arm) {
                                s.merge(&delta);
                            }
                        }
                        // never checkpoint a half-built cache: a lane
                        // mid-prefill or mid-rebuild restores from scratch
                        // instead (its stream is deterministic either way)
                        if self.resilience.is_some()
                            && lane.prefill.is_none()
                            && !lane.needs_rebuild
                        {
                            if let Some(seq) = &lane.seq {
                                lane.checkpoint = Some(Checkpoint {
                                    seq: seq.clone(),
                                    rng: lane.rng.clone(),
                                    sel_rng: lane.sel_rng.clone(),
                                });
                            }
                        }
                        // emission trace: TTFT and the per-tick series the
                        // latency benches aggregate
                        if let Some(seq) = &lane.seq {
                            let emitted = seq.tokens.len() - seq.prompt_len;
                            if emitted > lane.emitted_seen {
                                let at = lane.arrival.elapsed().as_secs_f64();
                                if lane.ttft.is_none() {
                                    lane.ttft = Some(at);
                                }
                                lane.tick_emits.push((at, emitted - lane.emitted_seen));
                                lane.emitted_seen = emitted;
                            }
                        }
                        let deadline_hit = self
                            .resilience
                            .as_ref()
                            .and_then(|r| r.deadline)
                            .is_some_and(|d| lane.started.elapsed() >= d)
                            || lane.deadline.is_some_and(|d| lane.arrival.elapsed() >= d);
                        if Self::lane_done(&lane) {
                            self.cache_retired_prefix(&lane);
                            reserved_t -= lane.reserve_t;
                            reserved_d -= lane.reserve_d;
                            done.push(Self::retire(lane, None));
                        } else if deadline_hit {
                            self.recovery.deadline_retired += 1;
                            reserved_t -= lane.reserve_t;
                            reserved_d -= lane.reserve_d;
                            let elapsed_secs = lane.started.elapsed().as_secs_f64();
                            done.push(Self::retire(
                                lane,
                                Some(ServeError::Deadline { elapsed_secs }),
                            ));
                        } else {
                            active.push(lane);
                        }
                    }
                    StepOutcome::DeadlinePre => {
                        // expired before any work was dispatched: retire
                        // with the partial stream it already has
                        self.recovery.deadline_retired += 1;
                        reserved_t -= lane.reserve_t;
                        reserved_d -= lane.reserve_d;
                        let elapsed_secs = lane.started.elapsed().as_secs_f64();
                        done.push(Self::retire(
                            lane,
                            Some(ServeError::Deadline { elapsed_secs }),
                        ));
                    }
                    StepOutcome::Fault(err) => {
                        match &err {
                            ServeError::Transient { .. } => self.recovery.transient_seen += 1,
                            ServeError::Corrupt { .. } => self.recovery.corrupt_seen += 1,
                            ServeError::Panic { .. } => self.recovery.panics += 1,
                            _ => {}
                        }
                        let Some(cfg) = &self.resilience else {
                            // no recovery configured: the fault retires the
                            // lane immediately (its blocks return via Drop);
                            // the other lanes are unaffected
                            self.recovery.surfaced += 1;
                            reserved_t -= lane.reserve_t;
                            reserved_d -= lane.reserve_d;
                            done.push(Self::retire(lane, Some(err)));
                            continue;
                        };
                        // restore the checkpoint: sequence (partially
                        // committed blocks return to the pools as the
                        // failed state drops) and rng stream state, so the
                        // re-execution is bit-identical to a fault-free run
                        match &lane.checkpoint {
                            Some(cp) => {
                                lane.seq = Some(cp.seq.clone());
                                lane.rng = cp.rng.clone();
                                lane.sel_rng = cp.sel_rng.clone();
                            }
                            None => {
                                // full restart (also the only fault path
                                // for a lane caught mid-prefill or
                                // mid-rebuild, whose caches are half
                                // built): drop the partial stream and its
                                // emission trace — deterministic replay
                                // re-emits the identical tokens
                                lane.seq = None;
                                lane.rng = Pcg64::new(lane.seed, lane.id);
                                lane.sel_rng = Pcg64::new(self.sel_seed, lane.id);
                                lane.prefill = None;
                                lane.needs_rebuild = false;
                                lane.emitted_seen = 0;
                                lane.tick_emits.clear();
                                lane.ttft = None;
                                // the replay prefills cold
                                lane.cached_rows = 0;
                            }
                        }
                        let deadline_hit = cfg
                            .deadline
                            .is_some_and(|d| lane.started.elapsed() >= d)
                            || lane.deadline.is_some_and(|d| lane.arrival.elapsed() >= d);
                        if health == BackendHealth::Failed {
                            // drained (with a surfaced error) next tick
                            self.recovery.surfaced += 1;
                            active.push(lane);
                        } else if deadline_hit {
                            self.recovery.surfaced += 1;
                            self.recovery.deadline_retired += 1;
                            reserved_t -= lane.reserve_t;
                            reserved_d -= lane.reserve_d;
                            let elapsed_secs = lane.started.elapsed().as_secs_f64();
                            done.push(Self::retire(
                                lane,
                                Some(ServeError::Deadline { elapsed_secs }),
                            ));
                        } else if just_degraded || probing {
                            // mode switch / failed probe: re-execute from
                            // the checkpoint without charging the lane —
                            // the fault was the backend's, not the lane's
                            self.recovery.retries += 1;
                            lane.retries = 0;
                            lane.total_retries += 1;
                            active.push(lane);
                        } else if lane.retries < cfg.max_retries {
                            self.recovery.retries += 1;
                            lane.retries += 1;
                            lane.total_retries += 1;
                            active.push(lane);
                        } else {
                            self.recovery.surfaced += 1;
                            reserved_t -= lane.reserve_t;
                            reserved_d -= lane.reserve_d;
                            let retries = lane.retries;
                            done.push(Self::retire(
                                lane,
                                Some(ServeError::Exhausted { retries, last: err.to_string() }),
                            ));
                        }
                    }
                }
            }
            if health == BackendHealth::Degraded {
                degraded_ticks += 1;
                if ar {
                    self.recovery.degraded_ticks += 1;
                }
            }
        }
        done.sort_by_key(|o| o.id);
        Ok(done)
    }
}

/// One tick of lane-local work. In FIFO mode (`chunk == None`): one-shot
/// prefill on the first tick, then one speculation block per tick (the
/// exact per-block body of [`SpecEngine::generate`], so a lane's stream
/// matches a serial run) or — in degraded mode — one lossless
/// autoregressive token. In scheduler mode (`chunk == Some(_)`): a lane
/// mid-prefill (fresh prompt) or mid-rebuild (preempted-and-released
/// context replay) commits at most one chunk of rows and yields the tick;
/// decode work resumes only once the caches are whole. The chunk schedule
/// changes *when* rows are committed, never their values, so streams are
/// bit-identical across modes.
fn lane_tick(
    spec: &SpecEngine<'_>,
    verifier: &dyn Verifier,
    policy: &dyn ActionPolicy,
    selector: Option<&OnlineSelector>,
    lane: &mut Lane,
    ar: bool,
    chunk: Option<usize>,
) -> Result<TickReport> {
    let mut rep = TickReport::default();
    match chunk {
        None => {
            if let Some(mut st) = lane.prefill.take() {
                // warm admission pre-seeded this lane with cached prefix
                // rows; drive the chunked prefill to completion within the
                // tick — chunking commits the same rows as the one-shot
                // `start`, so FIFO streams are unchanged
                while !spec.prefill_step(&mut st, usize::MAX)? {}
                lane.seq = Some(spec.finish_prefill(st)?);
            } else if lane.seq.is_none() {
                lane.seq = Some(spec.start(&lane.prompt)?);
            }
        }
        Some(chunk) => {
            if lane.needs_rebuild && lane.prefill.is_none() {
                let seq = lane.seq.as_ref().expect("rebuild implies a sequence");
                lane.prefill = Some(spec.rebuild_prefill(seq));
            }
            if lane.seq.is_none() && lane.prefill.is_none() {
                lane.prefill = Some(spec.start_chunked(&lane.prompt));
            }
            if let Some(st) = &mut lane.prefill {
                rep.chunk = true;
                let finished = spec.prefill_step(st, chunk)?;
                if finished {
                    let st = lane.prefill.take().expect("prefill state present");
                    if st.is_rebuild() {
                        let seq = lane.seq.as_mut().expect("rebuild implies a sequence");
                        spec.finish_rebuild(st, seq)?;
                        lane.needs_rebuild = false;
                        rep.rebuilt = true;
                    } else {
                        lane.seq = Some(spec.finish_prefill(st)?);
                    }
                }
                return Ok(rep);
            }
        }
    }
    if !ServeLoop::lane_done(lane) {
        if ar {
            let seq = lane.seq.as_mut().expect("lane prefilled before stepping");
            let b = spec.step_autoregressive(seq, &mut lane.rng)?;
            if b.emitted > 0 {
                lane.degraded = true;
            }
            lane.stats.add_block(&b);
        } else if let Some(sel) = selector.filter(|s| s.is_active()) {
            // dynamic selection: score the arms from this root's live
            // features on the lane's dedicated decision stream, then run
            // the chosen (verifier × drafter × action) block with the
            // untouched token-sampling stream. Degraded AR ticks (above)
            // make no decision and consume no selector rng.
            let seq = lane.seq.as_mut().expect("lane prefilled before stepping");
            let i = {
                let f = spec.root_features(seq)?;
                let feats = f.as_features(seq, spec.sampling);
                sel.choose(&feats, &mut lane.sel_rng).expect("active selector has arms")
            };
            let arm = &sel.arms()[i];
            let b = spec.step_drafted(seq, sel.verifier(i), arm.action, arm.drafter, &mut lane.rng)?;
            let mut delta = ArmStats::default();
            delta.record(b.tree_nodes.saturating_sub(1), b.accepted, b.emitted);
            rep.sel = Some((i, delta));
            lane.stats.add_block(&b);
        } else {
            let seq = lane.seq.as_mut().expect("lane prefilled before stepping");
            let action = spec.choose_action(seq, policy)?;
            let b = spec.step(seq, verifier, action, &mut lane.rng)?;
            lane.stats.add_block(&b);
        }
    }
    Ok(rep)
}

/// Classify a lane failure into the [`ServeError`] taxonomy: typed
/// [`DispatchFault`]s (raised by the fault injector and the corruption
/// guards) map to their class; anything else is treated as transient —
/// retry-worthy by default, and a deterministic error simply exhausts its
/// bounded retries.
fn classify(e: anyhow::Error) -> ServeError {
    match e.downcast_ref::<DispatchFault>() {
        Some(f) if f.kind == FaultKind::Corrupt => ServeError::Corrupt { message: e.to_string() },
        _ => ServeError::Transient { message: e.to_string() },
    }
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "lane panicked (non-string payload)".to_string()
    }
}
