//! Multi-request batched serving loop over any [`Backend`].
//!
//! [`ServeLoop`] is a continuous-batching scheduler: a FIFO request queue,
//! up to `max_batch` concurrently active sequences, and per-request
//! KV-cache lanes (each [`Sequence`](super::Sequence) owns its own target
//! and draft caches, so lanes never alias). Every scheduler tick runs one
//! speculation block — draft → tree pass → verify → commit — for every
//! active lane, fanned out over
//! [`par_map_init`](crate::util::threadpool::par_map_init); finished lanes
//! retire and queued requests are admitted in their place, so the batch
//! stays full until the queue drains.
//!
//! ## Memory: lanes vs blocks
//!
//! With contiguous KV storage every admitted lane pins `max_seq` rows per
//! model whether it uses them or not, so `max_batch` is the memory
//! ceiling. With paged storage ([`ServeLoop::with_kv_storage`], env knob
//! `SPECDELAY_PAGED_KV`) lanes allocate fixed-size blocks lazily from
//! shared per-role [`BlockPool`](crate::kvcache::BlockPool)s and share
//! trunk prefixes copy-on-write, so resident memory tracks committed
//! tokens. [`ServeLoop::with_block_budget`] caps those pools and turns the
//! ceiling into admission-level *backpressure*: a request is admitted only
//! when its worst-case block reservation fits both pools, and otherwise
//! waits in the queue until running lanes retire and return their blocks —
//! no in-flight lane can fail for lack of blocks, and streams stay
//! bit-identical to an uncapped (or contiguous) run.
//!
//! ## Determinism contract
//!
//! A lane's speculation stream is driven entirely by lane-local state: its
//! own rng (seeded from the request seed and the admission-order id), its
//! own [`Sequence`](super::Sequence), and the shared immutable backend.
//! Nothing a lane computes depends on which other lanes are in flight or
//! on the worker schedule, so **per-request token streams are
//! bit-identical for every batch size and worker count**, and identical to
//! a serial [`SpecEngine::generate`] call driven by the same
//! `Pcg64::new(seed, id)` stream. `tests/e2e_serve.rs` asserts both; the
//! `serve_loop` bench re-asserts them before timing anything.
//!
//! Each tick currently pays one scoped-thread spawn/join round
//! ([`par_map_init`](crate::util::threadpool::par_map_init)); for model
//! sizes where a block is sub-millisecond that overhead is visible in
//! `BENCH_serve_loop.json`. Because results are index-addressed (never
//! schedule-dependent), swapping in a persistent
//! [`ThreadPool`](crate::util::threadpool::ThreadPool) would preserve the
//! determinism contract — left as a follow-up.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::{ActionPolicy, GenStats, Sequence, SpecEngine};
use crate::dist::SamplingConfig;
use crate::kvcache::{default_block_tokens, KvStorage};
use crate::runtime::Backend;
use crate::tokenizer;
use crate::util::threadpool;
use crate::util::Pcg64;
use crate::verify::Verifier;

/// One queued generation request.
pub struct ServeRequest {
    /// Prompt text (byte-tokenized; truncated to the family's `s_pre`).
    pub prompt: String,
    /// Generation budget: the lane stops once it has emitted at least this
    /// many tokens (the final block may overshoot, exactly like
    /// [`SpecEngine::generate`]).
    pub max_new: usize,
    /// Seed of this request's private rng stream (the admission id is the
    /// stream selector, so equal seeds still draw independent streams).
    pub seed: u64,
}

/// One finished request.
pub struct ServeOutput {
    /// Admission-order request id (as returned by [`ServeLoop::submit`]).
    pub id: u64,
    /// Decoded continuation (prompt excluded; possibly partial when
    /// `error` is set).
    pub text: String,
    /// Whole-generation statistics; `wall_secs` spans admission→retirement,
    /// so under batching it includes time sharing the machine with other
    /// lanes.
    pub stats: GenStats,
    /// Set when this lane failed mid-generation. A failing lane retires
    /// with the error recorded here; the other lanes are unaffected — one
    /// bad request never discards the batch's completed results.
    pub error: Option<String>,
}

/// An active lane: one admitted request mid-generation. `seq` stays `None`
/// until the lane's first tick — prefill runs inside the data-parallel
/// fan-out (it is lane-local backend work), never serially in the
/// scheduler thread where it would stall the other lanes.
struct Lane {
    id: u64,
    prompt: String,
    max_new: usize,
    seq: Option<Sequence>,
    rng: Pcg64,
    stats: GenStats,
    started: Instant,
}

/// Worst-case block reservation per admitted lane under a capped pool.
///
/// With paged KV storage a lane allocates blocks lazily as it commits
/// rows, so the loop cannot know a lane's final footprint at admission
/// time. To guarantee an admitted lane never hits pool exhaustion
/// mid-generation, admission reserves the worst case: every target block a
/// full `max_seq` context needs, every draft block, plus the trunk→branch
/// handoff's divergent blocks (the shared prefix is refcounted, only the
/// boundary fork and the trunk's own blocks are unique). Requests that
/// don't fit wait in the queue — backpressure instead of failure — and
/// retiring lanes hand their reservation (and, via `Drop`, their actual
/// blocks) back.
struct LaneBudget {
    /// Blocks reserved against the target pool per lane.
    reserve_target: usize,
    /// Blocks reserved against the draft pool per lane.
    reserve_draft: usize,
    /// Per-pool cap (both pools), clamped so one lane always fits.
    cap: usize,
}

/// The batched serving loop (see the module docs).
pub struct ServeLoop<'a> {
    spec: SpecEngine<'a>,
    verifier: &'a dyn Verifier,
    policy: &'a dyn ActionPolicy,
    max_batch: usize,
    workers: usize,
    queue: VecDeque<(u64, ServeRequest)>,
    next_id: u64,
    budget: Option<LaneBudget>,
}

impl<'a> ServeLoop<'a> {
    /// Build a loop serving up to `max_batch` concurrent sequences with one
    /// verifier/policy pair.
    pub fn new(
        engine: &'a dyn Backend,
        sampling: SamplingConfig,
        verifier: &'a dyn Verifier,
        policy: &'a dyn ActionPolicy,
        max_batch: usize,
    ) -> ServeLoop<'a> {
        ServeLoop {
            spec: SpecEngine::new(engine, sampling),
            verifier,
            policy,
            max_batch: max_batch.max(1),
            workers: threadpool::default_workers(),
            queue: VecDeque::new(),
            next_id: 0,
            budget: None,
        }
    }

    /// Override the per-tick worker count (defaults to
    /// [`threadpool::default_workers`]; token streams do not depend on it).
    pub fn with_workers(mut self, workers: usize) -> ServeLoop<'a> {
        self.workers = workers.max(1);
        self
    }

    /// Select the lanes' KV representation explicitly (the default follows
    /// the `SPECDELAY_PAGED_KV` env knob). Clears any block budget; token
    /// streams do not depend on the storage — paged is bit-identical to
    /// the contiguous oracle.
    pub fn with_kv_storage(mut self, storage: KvStorage) -> ServeLoop<'a> {
        self.spec =
            SpecEngine::new(self.spec.engine, self.spec.sampling).with_kv_storage(storage);
        self.budget = None;
        self
    }

    /// Serve from a capped paged block pool: both the target and the draft
    /// pool are capped at `blocks` blocks (of
    /// [`default_block_tokens`] tokens each), clamped up so a single lane
    /// always fits. Admission switches from "a free batch slot" to "a free
    /// batch slot *and* a worst-case block reservation in both pools" —
    /// requests that don't fit queue until running lanes retire
    /// (out-of-blocks backpressure), and token streams are identical to an
    /// uncapped run because lane content never depends on admission timing.
    pub fn with_block_budget(mut self, blocks: usize) -> ServeLoop<'a> {
        let bt = default_block_tokens();
        let meta = self.spec.engine.meta();
        let max_trunk = meta.trunk_lens.iter().copied().max().unwrap_or(8);
        let reserve_target = meta.target.max_seq.div_ceil(bt);
        // draft lane + the handoff cache's divergent blocks (boundary fork
        // + the trunk's own rows; the shared prefix costs nothing)
        let reserve_draft = meta.draft.max_seq.div_ceil(bt) + max_trunk.div_ceil(bt) + 1;
        let cap = blocks.max(reserve_target).max(reserve_draft);
        self.spec = SpecEngine::new(self.spec.engine, self.spec.sampling)
            .with_paged_kv(bt, Some(cap));
        self.budget = Some(LaneBudget { reserve_target, reserve_draft, cap });
        self
    }

    /// The engine driving the lanes (pool introspection for tests/benches).
    pub fn spec(&self) -> &SpecEngine<'a> {
        &self.spec
    }

    /// Enqueue a request; returns its admission-order id.
    pub fn submit(&mut self, req: ServeRequest) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, req));
        id
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    fn lane_done(lane: &Lane) -> bool {
        match &lane.seq {
            Some(seq) => seq.finished || seq.tokens.len() - seq.prompt_len >= lane.max_new,
            None => false, // not even prefilled yet
        }
    }

    fn retire(lane: Lane, error: Option<String>) -> ServeOutput {
        let mut stats = lane.stats;
        stats.wall_secs = lane.started.elapsed().as_secs_f64();
        let text = lane
            .seq
            .as_ref()
            .map(|seq| tokenizer::decode(&seq.tokens[seq.prompt_len..]))
            .unwrap_or_default();
        ServeOutput { id: lane.id, text, stats, error }
    }

    /// Drain the queue: admit, tick, retire until every submitted request
    /// has finished. Returns one output per request, sorted by request id;
    /// a lane that fails mid-generation retires with
    /// [`ServeOutput::error`] set and does not disturb the other lanes.
    /// Under a block budget ([`ServeLoop::with_block_budget`]) admission
    /// additionally requires a worst-case block reservation in both pools,
    /// so requests queue — never fail — when blocks run out.
    pub fn run(&mut self) -> Result<Vec<ServeOutput>> {
        let mut active: Vec<Lane> = Vec::new();
        let mut done: Vec<ServeOutput> = Vec::new();
        // worst-case blocks reserved by active lanes (0 when uncapped)
        let (mut reserved_t, mut reserved_d) = (0usize, 0usize);
        loop {
            // admit queued requests into free batch slots (no backend work
            // here: the lane prefills on its first fan-out tick)
            while active.len() < self.max_batch {
                if let Some(b) = &self.budget {
                    // out-of-blocks backpressure: leave the request queued
                    // unless its worst case fits both pools (a single lane
                    // always fits — the caps are clamped to the reserve)
                    let fits = reserved_t + b.reserve_target <= b.cap
                        && reserved_d + b.reserve_draft <= b.cap;
                    if !fits {
                        break;
                    }
                }
                let Some((id, req)) = self.queue.pop_front() else { break };
                if let Some(b) = &self.budget {
                    reserved_t += b.reserve_target;
                    reserved_d += b.reserve_draft;
                }
                active.push(Lane {
                    id,
                    prompt: req.prompt,
                    max_new: req.max_new,
                    seq: None,
                    rng: Pcg64::new(req.seed, id),
                    stats: GenStats::default(),
                    started: Instant::now(),
                });
            }
            if active.is_empty() {
                break;
            }
            // one speculation block per lane, fanned out over the pool
            let spec = &self.spec;
            let verifier = self.verifier;
            let policy = self.policy;
            let stepped = threadpool::par_map_init(
                std::mem::take(&mut active),
                self.workers,
                || (),
                |_state, _i, mut lane: Lane| -> (Lane, Option<String>) {
                    let res = (|| -> Result<()> {
                        if lane.seq.is_none() {
                            lane.seq = Some(spec.start(&lane.prompt)?);
                        }
                        if !Self::lane_done(&lane) {
                            step_lane(spec, verifier, policy, &mut lane)?;
                        }
                        Ok(())
                    })();
                    let err = res.err().map(|e| e.to_string());
                    (lane, err)
                },
            );
            for (lane, err) in stepped {
                let retiring = err.is_some() || Self::lane_done(&lane);
                if retiring {
                    if let Some(b) = &self.budget {
                        // the lane's Sequence drops with it, returning its
                        // actual blocks to the pools' free lists
                        reserved_t -= b.reserve_target;
                        reserved_d -= b.reserve_draft;
                    }
                    // a failing lane retires with its error recorded; the
                    // other lanes are unaffected
                    done.push(Self::retire(lane, err));
                } else {
                    active.push(lane);
                }
            }
        }
        done.sort_by_key(|o| o.id);
        Ok(done)
    }
}

/// One speculation block for one lane — the exact per-block body of
/// [`SpecEngine::generate`], so a lane's stream matches a serial run.
fn step_lane(
    spec: &SpecEngine<'_>,
    verifier: &dyn Verifier,
    policy: &dyn ActionPolicy,
    lane: &mut Lane,
) -> Result<()> {
    let seq = lane.seq.as_mut().expect("lane prefilled before stepping");
    let action = spec.choose_action(seq, policy)?;
    let b = spec.step(seq, verifier, action, &mut lane.rng)?;
    lane.stats.add_block(&b);
    Ok(())
}
