//! Multi-request batched serving loop over any [`Backend`].
//!
//! [`ServeLoop`] is a continuous-batching scheduler: a FIFO request queue,
//! up to `max_batch` concurrently active sequences, and per-request
//! KV-cache lanes (each [`Sequence`](super::Sequence) owns its own target
//! and draft caches, so lanes never alias). Every scheduler tick runs one
//! speculation block — draft → tree pass → verify → commit — for every
//! active lane, fanned out over
//! [`par_map_init`](crate::util::threadpool::par_map_init); finished lanes
//! retire and queued requests are admitted in their place, so the batch
//! stays full until the queue drains.
//!
//! ## Memory: lanes vs blocks
//!
//! With contiguous KV storage every admitted lane pins `max_seq` rows per
//! model whether it uses them or not, so `max_batch` is the memory
//! ceiling. With paged storage ([`ServeLoop::with_kv_storage`], env knob
//! `SPECDELAY_PAGED_KV`) lanes allocate fixed-size blocks lazily from
//! shared per-role [`BlockPool`](crate::kvcache::BlockPool)s and share
//! trunk prefixes copy-on-write, so resident memory tracks committed
//! tokens. [`ServeLoop::with_block_budget`] caps those pools and turns the
//! ceiling into admission-level *backpressure*: a request is admitted only
//! when its worst-case block reservation fits both pools, and otherwise
//! waits in the queue until running lanes retire and return their blocks —
//! no in-flight lane can fail for lack of blocks, and streams stay
//! bit-identical to an uncapped (or contiguous) run. With
//! [`ServeLoop::with_resilience`] enabled the per-lane reservation doubles:
//! a lane's checkpoint is a copy-on-write fork of its sequence, so lane +
//! checkpoint together are bounded by twice the single-lane worst case.
//!
//! ## Determinism contract
//!
//! A lane's speculation stream is driven entirely by lane-local state: its
//! own rng (seeded from the request seed and the admission-order id), its
//! own [`Sequence`](super::Sequence), and the shared immutable backend.
//! Nothing a lane computes depends on which other lanes are in flight or
//! on the worker schedule, so **per-request token streams are
//! bit-identical for every batch size and worker count**, and identical to
//! a serial [`SpecEngine::generate`] call driven by the same
//! `Pcg64::new(seed, id)` stream. `tests/e2e_serve.rs` asserts both; the
//! `serve_loop` bench re-asserts them before timing anything.
//!
//! ## Failure model & recovery
//!
//! Backend dispatches can fail (transient errors), return corrupted
//! surfaces (caught by the [`guard_finite`](crate::runtime::guard_finite)
//! boundary guards and raised as typed faults), straggle, or panic. The
//! loop always isolates panics — per-lane tick work runs under
//! `catch_unwind`, so one poisoned lane never takes down the batch — and
//! classifies every lane failure into the structured [`ServeError`]
//! taxonomy instead of a bare string.
//!
//! With [`ServeLoop::with_resilience`] the loop additionally *recovers*:
//!
//! * **checkpoint + deterministic retry** — after every successful tick a
//!   lane snapshots `(Sequence, rng)`; under paged KV the sequence
//!   snapshot is a copy-on-write fork (O(blocks) refcount bumps, see
//!   `kvcache::paged`). A faulting tick restores the snapshot — returning
//!   any partially-committed blocks to the pools — and re-executes with
//!   the *same rng stream state*, so a recovered stream is bit-identical
//!   to the fault-free oracle. Bounded by
//!   [`ResilienceConfig::max_retries`] consecutive attempts, then the
//!   lane retires as [`ServeError::Exhausted`].
//! * **deadlines** — a lane whose wall clock exceeds
//!   [`ResilienceConfig::deadline`] retires as [`ServeError::Deadline`]
//!   with whatever partial stream it has.
//! * **health state machine** — `Healthy → Degraded → Failed` with a
//!   consecutive-fault circuit breaker ([`BackendHealth`]). While
//!   `Degraded`, lanes switch from speculation to plain autoregressive
//!   decoding ([`SpecEngine::step_autoregressive`]): slower, but each
//!   token is still sampled from the exact target conditional, so the
//!   served stream stays lossless (degraded outputs are flagged via
//!   [`ServeOutput::degraded`]). Every
//!   [`ResilienceConfig::probe_interval`]-th degraded tick re-probes the
//!   speculative path; a clean probe returns the loop to `Healthy`.
//!   Consecutive faults *in degraded mode* trip the breaker fully open
//!   (`Failed`): all in-flight and queued requests retire with
//!   [`ServeError::Failed`] rather than spinning forever.
//!
//! Each tick currently pays one scoped-thread spawn/join round
//! ([`par_map_init`](crate::util::threadpool::par_map_init)); for model
//! sizes where a block is sub-millisecond that overhead is visible in
//! `BENCH_serve_loop.json`. Because results are index-addressed (never
//! schedule-dependent), swapping in a persistent
//! [`ThreadPool`](crate::util::threadpool::ThreadPool) would preserve the
//! determinism contract — left as a follow-up.

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::{ActionPolicy, GenStats, Sequence, SpecEngine};
use crate::dist::SamplingConfig;
use crate::kvcache::{default_block_tokens, KvStorage};
use crate::runtime::{Backend, DispatchFault, FaultKind};
use crate::tokenizer;
use crate::util::threadpool;
use crate::util::Pcg64;
use crate::verify::Verifier;

/// One queued generation request.
pub struct ServeRequest {
    /// Prompt text (byte-tokenized; truncated to the family's `s_pre`).
    pub prompt: String,
    /// Generation budget: the lane stops once it has emitted at least this
    /// many tokens (the final block may overshoot, exactly like
    /// [`SpecEngine::generate`]).
    pub max_new: usize,
    /// Seed of this request's private rng stream (the admission id is the
    /// stream selector, so equal seeds still draw independent streams).
    pub seed: u64,
}

/// Structured lane-failure taxonomy: why a request retired without (or
/// with only part of) its stream. Carried on [`ServeOutput::error`].
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// A dispatch failed outright (injected or real); retryable.
    Transient {
        /// Human-readable cause.
        message: String,
    },
    /// A dispatch returned a non-finite sampled surface, caught by the
    /// boundary guards before anything was sampled from it.
    Corrupt {
        /// Human-readable cause.
        message: String,
    },
    /// The request exceeded its per-request deadline and retired with a
    /// partial stream.
    Deadline {
        /// Wall-clock seconds from admission to retirement.
        elapsed_secs: f64,
    },
    /// Consecutive retries exceeded [`ResilienceConfig::max_retries`].
    Exhausted {
        /// Consecutive retries spent before giving up.
        retries: usize,
        /// The final failure's description.
        last: String,
    },
    /// The lane's tick panicked (isolated; the batch was unaffected).
    Panic {
        /// The panic payload, when it was a string.
        message: String,
    },
    /// The backend circuit breaker opened fully ([`BackendHealth::Failed`]):
    /// even degraded autoregressive decoding kept faulting.
    Failed {
        /// Human-readable cause.
        message: String,
    },
}

impl ServeError {
    /// Stable lowercase tag per variant (for logs and reports).
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Transient { .. } => "transient",
            ServeError::Corrupt { .. } => "corrupt",
            ServeError::Deadline { .. } => "deadline",
            ServeError::Exhausted { .. } => "exhausted",
            ServeError::Panic { .. } => "panic",
            ServeError::Failed { .. } => "failed",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Transient { message } => write!(f, "transient: {message}"),
            ServeError::Corrupt { message } => write!(f, "corrupt: {message}"),
            ServeError::Deadline { elapsed_secs } => {
                write!(f, "deadline exceeded after {elapsed_secs:.3}s")
            }
            ServeError::Exhausted { retries, last } => {
                write!(f, "retries exhausted after {retries} attempts (last: {last})")
            }
            ServeError::Panic { message } => write!(f, "lane panicked: {message}"),
            ServeError::Failed { message } => write!(f, "backend failed: {message}"),
        }
    }
}

/// Backend health as seen by the serving loop's circuit breaker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendHealth {
    /// Speculative decoding, full speed.
    Healthy,
    /// Consecutive faults tripped the breaker: lanes run plain
    /// autoregressive decode (lossless, slower) and the speculative path
    /// is re-probed periodically.
    Degraded,
    /// Even degraded decoding kept faulting: the loop drains every lane
    /// and queued request with [`ServeError::Failed`].
    Failed,
}

/// Recovery policy for [`ServeLoop::with_resilience`].
#[derive(Clone, Debug)]
pub struct ResilienceConfig {
    /// Consecutive per-lane checkpoint retries before the lane retires as
    /// [`ServeError::Exhausted`]. Keep this at least as large as
    /// `degrade_after`, or lanes can exhaust before the loop degrades.
    pub max_retries: usize,
    /// Per-request wall-clock deadline; `None` disables deadline
    /// retirement.
    pub deadline: Option<Duration>,
    /// Consecutive backend faults (across lanes, in lane order) before
    /// `Healthy → Degraded`.
    pub degrade_after: usize,
    /// Consecutive degraded-mode faults before `Degraded → Failed`.
    /// Failed probes do not count — only the autoregressive fallback
    /// itself faulting can open the breaker fully.
    pub fail_after: usize,
    /// Probe the speculative path every this-many degraded ticks (0
    /// disables probing, pinning the loop in degraded mode).
    pub probe_interval: usize,
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig {
            max_retries: 16,
            deadline: None,
            degrade_after: 6,
            fail_after: 12,
            probe_interval: 4,
        }
    }
}

/// Fault-handling counters for one [`ServeLoop::run`] drain. The chaos
/// suite closes the loop against [`FaultStats`](crate::runtime::FaultStats):
/// `transient_seen + corrupt_seen + panics == retries + surfaced` — every
/// observed fault is either deterministically re-executed or reported on
/// an output, never silently dropped.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryCounters {
    /// Transient dispatch faults observed.
    pub transient_seen: usize,
    /// Corruption guard trips observed.
    pub corrupt_seen: usize,
    /// Lane panics caught (and isolated).
    pub panics: usize,
    /// Faults answered with a checkpoint restore + re-execution.
    pub retries: usize,
    /// Faults surfaced on a retiring output's [`ServeOutput::error`].
    pub surfaced: usize,
    /// Lanes retired by deadline.
    pub deadline_retired: usize,
    /// `Healthy → Degraded` transitions.
    pub degraded_entered: usize,
    /// Ticks served in autoregressive degraded mode.
    pub degraded_ticks: usize,
    /// Speculative re-probes attempted while degraded.
    pub probes: usize,
    /// Probes that returned the loop to `Healthy`.
    pub recoveries: usize,
}

/// One finished request.
pub struct ServeOutput {
    /// Admission-order request id (as returned by [`ServeLoop::submit`]).
    pub id: u64,
    /// Decoded continuation (prompt excluded; possibly partial when
    /// `error` is set).
    pub text: String,
    /// Emitted token ids (prompt excluded) — the raw stream `text` decodes.
    pub tokens: Vec<u32>,
    /// Whole-generation statistics; `wall_secs` spans admission→retirement,
    /// so under batching it includes time sharing the machine with other
    /// lanes.
    pub stats: GenStats,
    /// Set when this lane failed mid-generation. A failing lane retires
    /// with the classified error recorded here; the other lanes are
    /// unaffected — one bad request never discards the batch's completed
    /// results.
    pub error: Option<ServeError>,
    /// True when any token of this stream was emitted by the degraded-mode
    /// autoregressive fallback. The stream is still lossless (every token
    /// sampled from the exact target conditional) but no longer
    /// bit-identical to the fault-free speculative oracle, because
    /// autoregressive sampling consumes the rng stream differently.
    pub degraded: bool,
    /// Checkpoint retries this lane spent over its lifetime.
    pub retries: usize,
}

/// A lane's recovery snapshot: the sequence and rng stream state as of the
/// last successful tick. Restoring it makes a retried block re-execute
/// bit-identically to the fault-free schedule; under paged KV the sequence
/// clone is a copy-on-write fork.
struct Checkpoint {
    seq: Sequence,
    rng: Pcg64,
}

/// An active lane: one admitted request mid-generation. `seq` stays `None`
/// until the lane's first tick — prefill runs inside the data-parallel
/// fan-out (it is lane-local backend work), never serially in the
/// scheduler thread where it would stall the other lanes.
struct Lane {
    id: u64,
    seed: u64,
    prompt: String,
    max_new: usize,
    seq: Option<Sequence>,
    rng: Pcg64,
    stats: GenStats,
    started: Instant,
    checkpoint: Option<Checkpoint>,
    /// Consecutive failed ticks since the last success.
    retries: usize,
    /// Lifetime retry count (reported on the output).
    total_retries: usize,
    degraded: bool,
}

/// Worst-case block reservation per admitted lane under a capped pool.
///
/// With paged KV storage a lane allocates blocks lazily as it commits
/// rows, so the loop cannot know a lane's final footprint at admission
/// time. To guarantee an admitted lane never hits pool exhaustion
/// mid-generation, admission reserves the worst case: every target block a
/// full `max_seq` context needs, every draft block, plus the trunk→branch
/// handoff's divergent blocks (the shared prefix is refcounted, only the
/// boundary fork and the trunk's own blocks are unique) — doubled when
/// resilience checkpoints are enabled, since a lane then also pins a
/// copy-on-write snapshot whose footprint is bounded by the same worst
/// case. Requests that don't fit wait in the queue — backpressure instead
/// of failure — and retiring lanes hand their reservation (and, via
/// `Drop`, their actual blocks) back.
struct LaneBudget {
    /// Blocks reserved against the target pool per lane.
    reserve_target: usize,
    /// Blocks reserved against the draft pool per lane.
    reserve_draft: usize,
    /// Per-pool cap (both pools), clamped so one lane always fits.
    cap: usize,
}

/// Per-lane tick result, classified in the worker (so only plain data
/// crosses back to the scheduler).
enum StepOutcome {
    Progress,
    Fault(ServeError),
}

/// The batched serving loop (see the module docs).
pub struct ServeLoop<'a> {
    spec: SpecEngine<'a>,
    verifier: &'a dyn Verifier,
    policy: &'a dyn ActionPolicy,
    max_batch: usize,
    workers: usize,
    queue: VecDeque<(u64, ServeRequest)>,
    next_id: u64,
    budget: Option<LaneBudget>,
    requested_blocks: Option<usize>,
    resilience: Option<ResilienceConfig>,
    recovery: RecoveryCounters,
}

impl<'a> ServeLoop<'a> {
    /// Build a loop serving up to `max_batch` concurrent sequences with one
    /// verifier/policy pair.
    pub fn new(
        engine: &'a dyn Backend,
        sampling: SamplingConfig,
        verifier: &'a dyn Verifier,
        policy: &'a dyn ActionPolicy,
        max_batch: usize,
    ) -> ServeLoop<'a> {
        ServeLoop {
            spec: SpecEngine::new(engine, sampling),
            verifier,
            policy,
            max_batch: max_batch.max(1),
            workers: threadpool::default_workers(),
            queue: VecDeque::new(),
            next_id: 0,
            budget: None,
            requested_blocks: None,
            resilience: None,
            recovery: RecoveryCounters::default(),
        }
    }

    /// Override the per-tick worker count (defaults to
    /// [`threadpool::default_workers`]; token streams do not depend on it).
    pub fn with_workers(mut self, workers: usize) -> ServeLoop<'a> {
        self.workers = workers.max(1);
        self
    }

    /// Select the lanes' KV representation explicitly (the default follows
    /// the `SPECDELAY_PAGED_KV` env knob). Clears any block budget; token
    /// streams do not depend on the storage — paged is bit-identical to
    /// the contiguous oracle.
    pub fn with_kv_storage(mut self, storage: KvStorage) -> ServeLoop<'a> {
        self.spec =
            SpecEngine::new(self.spec.engine, self.spec.sampling).with_kv_storage(storage);
        self.budget = None;
        self.requested_blocks = None;
        self
    }

    /// Serve from a capped paged block pool: both the target and the draft
    /// pool are capped at `blocks` blocks (of
    /// [`default_block_tokens`] tokens each), clamped up so a single lane
    /// always fits. Admission switches from "a free batch slot" to "a free
    /// batch slot *and* a worst-case block reservation in both pools" —
    /// requests that don't fit queue until running lanes retire
    /// (out-of-blocks backpressure), and token streams are identical to an
    /// uncapped run because lane content never depends on admission timing.
    pub fn with_block_budget(mut self, blocks: usize) -> ServeLoop<'a> {
        self.requested_blocks = Some(blocks);
        self.rebuild_budget();
        self
    }

    /// Enable checkpoint/retry recovery, deadlines and the backend health
    /// state machine (see the module docs). Completed non-degraded streams
    /// stay bit-identical to the fault-free oracle; degraded streams stay
    /// lossless. When a block budget is also set, per-lane reservations
    /// double to cover the checkpoint snapshot.
    pub fn with_resilience(mut self, cfg: ResilienceConfig) -> ServeLoop<'a> {
        self.resilience = Some(cfg);
        self.rebuild_budget();
        self
    }

    /// Recompute the paged pools and per-lane reservations from the
    /// requested budget and the resilience mode (builder-order
    /// independent: `with_block_budget` and `with_resilience` may be
    /// called either way around).
    fn rebuild_budget(&mut self) {
        let Some(blocks) = self.requested_blocks else { return };
        let bt = default_block_tokens();
        let meta = self.spec.engine.meta();
        let max_trunk = meta.trunk_lens.iter().copied().max().unwrap_or(8);
        // lane + (with resilience) its copy-on-write checkpoint, each
        // bounded by the single-lane worst case
        let factor = if self.resilience.is_some() { 2 } else { 1 };
        let reserve_target = factor * meta.target.max_seq.div_ceil(bt);
        // draft lane + the handoff cache's divergent blocks (boundary fork
        // + the trunk's own rows; the shared prefix costs nothing)
        let reserve_draft =
            factor * (meta.draft.max_seq.div_ceil(bt) + max_trunk.div_ceil(bt) + 1);
        let cap = blocks.max(reserve_target).max(reserve_draft);
        self.spec = SpecEngine::new(self.spec.engine, self.spec.sampling)
            .with_paged_kv(bt, Some(cap));
        self.budget = Some(LaneBudget { reserve_target, reserve_draft, cap });
    }

    /// The engine driving the lanes (pool introspection for tests/benches).
    pub fn spec(&self) -> &SpecEngine<'a> {
        &self.spec
    }

    /// Fault-handling counters of the most recent [`ServeLoop::run`].
    pub fn recovery(&self) -> &RecoveryCounters {
        &self.recovery
    }

    /// Enqueue a request; returns its admission-order id.
    pub fn submit(&mut self, req: ServeRequest) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, req));
        id
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    fn lane_done(lane: &Lane) -> bool {
        match &lane.seq {
            Some(seq) => seq.finished || seq.tokens.len() - seq.prompt_len >= lane.max_new,
            None => false, // not even prefilled yet
        }
    }

    fn retire(lane: Lane, error: Option<ServeError>) -> ServeOutput {
        let mut stats = lane.stats;
        stats.wall_secs = lane.started.elapsed().as_secs_f64();
        let (text, tokens) = lane
            .seq
            .as_ref()
            .map(|seq| {
                let emitted = seq.tokens[seq.prompt_len..].to_vec();
                (tokenizer::decode(&emitted), emitted)
            })
            .unwrap_or_default();
        ServeOutput {
            id: lane.id,
            text,
            tokens,
            stats,
            error,
            degraded: lane.degraded,
            retries: lane.total_retries,
        }
    }

    /// Drain the queue: admit, tick, retire until every submitted request
    /// has finished. Returns one output per request, sorted by request id;
    /// a lane that fails mid-generation retires with
    /// [`ServeOutput::error`] set and does not disturb the other lanes,
    /// and a lane that panics is caught and retired the same way.
    /// Under a block budget ([`ServeLoop::with_block_budget`]) admission
    /// additionally requires a worst-case block reservation in both pools,
    /// so requests queue — never fail — when blocks run out. With
    /// [`ServeLoop::with_resilience`] faults are retried from per-lane
    /// checkpoints and the backend health machine arbitrates speculative
    /// vs degraded autoregressive mode (see the module docs).
    pub fn run(&mut self) -> Result<Vec<ServeOutput>> {
        self.recovery = RecoveryCounters::default();
        let mut active: Vec<Lane> = Vec::new();
        let mut done: Vec<ServeOutput> = Vec::new();
        // worst-case blocks reserved by active lanes (0 when uncapped)
        let (mut reserved_t, mut reserved_d) = (0usize, 0usize);
        let mut health = BackendHealth::Healthy;
        // consecutive-fault streaks, in lane order across ticks
        let (mut healthy_faults, mut degraded_faults) = (0usize, 0usize);
        let mut degraded_ticks = 0usize;
        loop {
            if health == BackendHealth::Failed {
                // breaker fully open: drain everything with a structured
                // error instead of spinning (each lane's blocks return to
                // the pools as its Sequence drops)
                const MSG: &str = "backend circuit breaker open (degraded decode kept faulting)";
                for lane in active.drain(..) {
                    if let Some(b) = &self.budget {
                        reserved_t -= b.reserve_target;
                        reserved_d -= b.reserve_draft;
                    }
                    done.push(Self::retire(
                        lane,
                        Some(ServeError::Failed { message: MSG.to_string() }),
                    ));
                }
                while let Some((id, _req)) = self.queue.pop_front() {
                    done.push(ServeOutput {
                        id,
                        text: String::new(),
                        tokens: Vec::new(),
                        stats: GenStats::default(),
                        error: Some(ServeError::Failed { message: MSG.to_string() }),
                        degraded: false,
                        retries: 0,
                    });
                }
                break;
            }
            // admit queued requests into free batch slots (no backend work
            // here: the lane prefills on its first fan-out tick)
            while active.len() < self.max_batch {
                if let Some(b) = &self.budget {
                    // out-of-blocks backpressure: leave the request queued
                    // unless its worst case fits both pools (a single lane
                    // always fits — the caps are clamped to the reserve)
                    let fits = reserved_t + b.reserve_target <= b.cap
                        && reserved_d + b.reserve_draft <= b.cap;
                    if !fits {
                        break;
                    }
                }
                let Some((id, req)) = self.queue.pop_front() else { break };
                if let Some(b) = &self.budget {
                    reserved_t += b.reserve_target;
                    reserved_d += b.reserve_draft;
                }
                active.push(Lane {
                    id,
                    seed: req.seed,
                    prompt: req.prompt,
                    max_new: req.max_new,
                    seq: None,
                    rng: Pcg64::new(req.seed, id),
                    stats: GenStats::default(),
                    started: Instant::now(),
                    checkpoint: None,
                    retries: 0,
                    total_retries: 0,
                    degraded: false,
                });
            }
            if active.is_empty() {
                break;
            }
            // tick mode: degraded lanes decode autoregressively, except on
            // probe ticks, which re-attempt the speculative path
            let probing = health == BackendHealth::Degraded
                && self
                    .resilience
                    .as_ref()
                    .is_some_and(|r| r.probe_interval > 0
                        && (degraded_ticks + 1) % r.probe_interval == 0);
            let ar = health == BackendHealth::Degraded && !probing;
            if probing {
                self.recovery.probes += 1;
            }

            // one block (or one AR token) per lane, fanned out over the
            // pool; panics are caught per lane so one poisoned request
            // cannot take down the batch
            let spec = &self.spec;
            let verifier = self.verifier;
            let policy = self.policy;
            let stepped = threadpool::par_map_init(
                std::mem::take(&mut active),
                self.workers,
                || (),
                |_state, _i, mut lane: Lane| -> (Lane, StepOutcome) {
                    let res = catch_unwind(AssertUnwindSafe(|| {
                        lane_tick(spec, verifier, policy, &mut lane, ar)
                    }));
                    let outcome = match res {
                        Ok(Ok(())) => StepOutcome::Progress,
                        Ok(Err(e)) => StepOutcome::Fault(classify(e)),
                        Err(p) => {
                            StepOutcome::Fault(ServeError::Panic { message: panic_message(p) })
                        }
                    };
                    (lane, outcome)
                },
            );

            // phase 1: update the health machine from this tick's outcomes
            // (lane order — deterministic given a deterministic fault
            // schedule, never dependent on worker timing)
            let prev_health = health;
            let mut tick_faults = 0usize;
            if let Some(cfg) = &self.resilience {
                for (_, outcome) in &stepped {
                    match outcome {
                        StepOutcome::Progress => match health {
                            BackendHealth::Healthy => healthy_faults = 0,
                            BackendHealth::Degraded if ar => degraded_faults = 0,
                            _ => {}
                        },
                        StepOutcome::Fault(_) => {
                            tick_faults += 1;
                            match health {
                                BackendHealth::Healthy => {
                                    healthy_faults += 1;
                                    if healthy_faults >= cfg.degrade_after {
                                        health = BackendHealth::Degraded;
                                        degraded_faults = 0;
                                        degraded_ticks = 0;
                                        self.recovery.degraded_entered += 1;
                                    }
                                }
                                BackendHealth::Degraded if ar => {
                                    degraded_faults += 1;
                                    if degraded_faults >= cfg.fail_after {
                                        health = BackendHealth::Failed;
                                    }
                                }
                                // probe failures keep the loop degraded but
                                // never open the breaker fully
                                _ => {}
                            }
                        }
                    }
                }
                if probing && tick_faults == 0 {
                    health = BackendHealth::Healthy;
                    healthy_faults = 0;
                    self.recovery.recoveries += 1;
                }
            }
            let just_degraded =
                prev_health == BackendHealth::Healthy && health != BackendHealth::Healthy;

            // phase 2: lane fates, with the post-tick health known
            for (mut lane, outcome) in stepped {
                match outcome {
                    StepOutcome::Progress => {
                        lane.retries = 0;
                        if self.resilience.is_some() {
                            if let Some(seq) = &lane.seq {
                                lane.checkpoint =
                                    Some(Checkpoint { seq: seq.clone(), rng: lane.rng.clone() });
                            }
                        }
                        let deadline_hit = self
                            .resilience
                            .as_ref()
                            .and_then(|r| r.deadline)
                            .is_some_and(|d| lane.started.elapsed() >= d);
                        if Self::lane_done(&lane) {
                            if let Some(b) = &self.budget {
                                reserved_t -= b.reserve_target;
                                reserved_d -= b.reserve_draft;
                            }
                            done.push(Self::retire(lane, None));
                        } else if deadline_hit {
                            self.recovery.deadline_retired += 1;
                            if let Some(b) = &self.budget {
                                reserved_t -= b.reserve_target;
                                reserved_d -= b.reserve_draft;
                            }
                            let elapsed_secs = lane.started.elapsed().as_secs_f64();
                            done.push(Self::retire(
                                lane,
                                Some(ServeError::Deadline { elapsed_secs }),
                            ));
                        } else {
                            active.push(lane);
                        }
                    }
                    StepOutcome::Fault(err) => {
                        match &err {
                            ServeError::Transient { .. } => self.recovery.transient_seen += 1,
                            ServeError::Corrupt { .. } => self.recovery.corrupt_seen += 1,
                            ServeError::Panic { .. } => self.recovery.panics += 1,
                            _ => {}
                        }
                        let Some(cfg) = &self.resilience else {
                            // no recovery configured: the fault retires the
                            // lane immediately (its blocks return via Drop);
                            // the other lanes are unaffected
                            self.recovery.surfaced += 1;
                            if let Some(b) = &self.budget {
                                reserved_t -= b.reserve_target;
                                reserved_d -= b.reserve_draft;
                            }
                            done.push(Self::retire(lane, Some(err)));
                            continue;
                        };
                        // restore the checkpoint: sequence (partially
                        // committed blocks return to the pools as the
                        // failed state drops) and rng stream state, so the
                        // re-execution is bit-identical to a fault-free run
                        match &lane.checkpoint {
                            Some(cp) => {
                                lane.seq = Some(cp.seq.clone());
                                lane.rng = cp.rng.clone();
                            }
                            None => {
                                lane.seq = None;
                                lane.rng = Pcg64::new(lane.seed, lane.id);
                            }
                        }
                        let deadline_hit =
                            cfg.deadline.is_some_and(|d| lane.started.elapsed() >= d);
                        if health == BackendHealth::Failed {
                            // drained (with a surfaced error) next tick
                            self.recovery.surfaced += 1;
                            active.push(lane);
                        } else if deadline_hit {
                            self.recovery.surfaced += 1;
                            self.recovery.deadline_retired += 1;
                            if let Some(b) = &self.budget {
                                reserved_t -= b.reserve_target;
                                reserved_d -= b.reserve_draft;
                            }
                            let elapsed_secs = lane.started.elapsed().as_secs_f64();
                            done.push(Self::retire(
                                lane,
                                Some(ServeError::Deadline { elapsed_secs }),
                            ));
                        } else if just_degraded || probing {
                            // mode switch / failed probe: re-execute from
                            // the checkpoint without charging the lane —
                            // the fault was the backend's, not the lane's
                            self.recovery.retries += 1;
                            lane.retries = 0;
                            lane.total_retries += 1;
                            active.push(lane);
                        } else if lane.retries < cfg.max_retries {
                            self.recovery.retries += 1;
                            lane.retries += 1;
                            lane.total_retries += 1;
                            active.push(lane);
                        } else {
                            self.recovery.surfaced += 1;
                            if let Some(b) = &self.budget {
                                reserved_t -= b.reserve_target;
                                reserved_d -= b.reserve_draft;
                            }
                            let retries = lane.retries;
                            done.push(Self::retire(
                                lane,
                                Some(ServeError::Exhausted { retries, last: err.to_string() }),
                            ));
                        }
                    }
                }
            }
            if health == BackendHealth::Degraded {
                degraded_ticks += 1;
                if ar {
                    self.recovery.degraded_ticks += 1;
                }
            }
        }
        done.sort_by_key(|o| o.id);
        Ok(done)
    }
}

/// One tick of lane-local work: prefill on the first tick, then either one
/// speculation block (the exact per-block body of [`SpecEngine::generate`],
/// so a lane's stream matches a serial run) or — in degraded mode — one
/// lossless autoregressive token.
fn lane_tick(
    spec: &SpecEngine<'_>,
    verifier: &dyn Verifier,
    policy: &dyn ActionPolicy,
    lane: &mut Lane,
    ar: bool,
) -> Result<()> {
    if lane.seq.is_none() {
        lane.seq = Some(spec.start(&lane.prompt)?);
    }
    if !ServeLoop::lane_done(lane) {
        if ar {
            let seq = lane.seq.as_mut().expect("lane prefilled before stepping");
            let b = spec.step_autoregressive(seq, &mut lane.rng)?;
            if b.emitted > 0 {
                lane.degraded = true;
            }
            lane.stats.add_block(&b);
        } else {
            let seq = lane.seq.as_mut().expect("lane prefilled before stepping");
            let action = spec.choose_action(seq, policy)?;
            let b = spec.step(seq, verifier, action, &mut lane.rng)?;
            lane.stats.add_block(&b);
        }
    }
    Ok(())
}

/// Classify a lane failure into the [`ServeError`] taxonomy: typed
/// [`DispatchFault`]s (raised by the fault injector and the corruption
/// guards) map to their class; anything else is treated as transient —
/// retry-worthy by default, and a deterministic error simply exhausts its
/// bounded retries.
fn classify(e: anyhow::Error) -> ServeError {
    match e.downcast_ref::<DispatchFault>() {
        Some(f) if f.kind == FaultKind::Corrupt => ServeError::Corrupt { message: e.to_string() },
        _ => ServeError::Transient { message: e.to_string() },
    }
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "lane panicked (non-string payload)".to_string()
    }
}
